//! Dynamic-model training on the real executor — the paper's headline
//! capability (Sec. 1, Sec. 4.1): workloads whose computation graph is
//! *data-dependent*, which no static checkpointing planner (Checkmate's
//! ILP, optimal chain schedules) can schedule ahead of time, and which DTR
//! handles online through plain operator interposition.
//!
//! Two trainers, both driven purely through the `dtr::api` [`Session`]:
//!
//! * [`LstmTrainer`] — an LSTM unrolled over a *per-batch random sequence
//!   length*; BPTT re-walks exactly the timesteps the data demanded.
//! * [`TreeLstmTrainer`] — a TreeLSTM over a *per-sample random tree
//!   shape*; forward and backward recurse over whatever topology this
//!   batch drew.
//!
//! Both train a synthetic but genuinely learnable classification task
//! (inputs carry a one-hot class signal; the readout and recurrent weights
//! must align to separate the classes), so the loss provably descends —
//! under any feasible budget, bitwise-identically to the unbudgeted run,
//! because rematerialization is exact replay of pure ops.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::api::{ExecBackend, OpContract, Session, SharedExecutor, Tensor};
use crate::dtr;
use crate::runtime::executor::{randn_host, Executor, HostTensor};
use crate::runtime::{InterpExecutor, NullExecutor, RnnConfig};
use crate::util::rng::Rng;

/// Default weight-init seeds (the data stream derives from them).
pub const LSTM_SEED: u64 = 0x15D1;
pub const TREE_SEED: u64 = 0x7133;

const INIT_SCALE: f32 = 0.2;

/// Result of one dynamic training step.
#[derive(Debug, Clone)]
pub struct DynStepResult {
    pub loss: f32,
    pub stats: dtr::Stats,
    /// Bytes pinned by this step's constants (weights + data batch): the
    /// per-step feasibility floor. Dynamic shapes make this vary by step.
    pub pinned_bytes: u64,
    /// Size of the dynamic structure this step drew: timesteps for the
    /// LSTM, leaves for the TreeLSTM.
    pub units: u64,
    pub wall_ns: u64,
    pub exec_ns: u64,
}

/// Budget at `pct`% of the headroom between a measured pinned floor and a
/// measured unbudgeted peak (the same formula `Engine::budgets_from_peak`
/// uses, with the floor taken over the dynamic envelope).
pub fn headroom_budget(peak: u64, floor: u64, pct: u64) -> u64 {
    floor + peak.saturating_sub(floor) * pct / 100
}

fn accumulate(
    s: &Session<ExecBackend>,
    op: &str,
    acc: Option<Tensor>,
    g: Tensor,
) -> Result<Tensor> {
    match acc {
        None => Ok(g),
        Some(a) => Ok(s.call(op, &[&a, &g])?.remove(0)), // a and g release here
    }
}

// ------------------------------------------------------------------- LSTM

/// LSTM over data-dependent sequence lengths, trained with SGD through a
/// fresh DTR session per step.
pub struct LstmTrainer {
    exec: SharedExecutor,
    contract: OpContract,
    pub rnn: RnnConfig,
    pub dtr_cfg: dtr::Config,
    /// Per-batch sequence length is uniform in `min_len..=max_len`.
    pub min_len: usize,
    pub max_len: usize,
    wx: HostTensor,
    wh: HostTensor,
    b: HostTensor,
    w_out: HostTensor,
    step: u64,
    data_rng: Rng,
}

impl LstmTrainer {
    pub fn new(
        exec: Box<dyn Executor>,
        rnn: RnnConfig,
        dtr_cfg: dtr::Config,
        seed: u64,
    ) -> Result<LstmTrainer> {
        rnn.validate()?;
        let (i, h) = (rnn.input, rnn.hidden);
        let mut wrng = Rng::new(seed);
        let wx = randn_host(&mut wrng, &[i, 4 * h], INIT_SCALE);
        let wh = randn_host(&mut wrng, &[h, 4 * h], INIT_SCALE);
        let w_out = randn_host(&mut wrng, &[h, rnn.classes], INIT_SCALE);
        // Zero biases except the forget gate at 1.0 (standard LSTM init).
        let mut b = HostTensor::zeros(&[1, 4 * h]);
        for k in h..2 * h {
            b.data[k] = 1.0;
        }
        let exec: SharedExecutor = Arc::new(Mutex::new(exec));
        let contract = OpContract::of(&exec);
        Ok(LstmTrainer {
            exec,
            contract,
            rnn,
            dtr_cfg,
            min_len: 3,
            max_len: 10,
            wx,
            wh,
            b,
            w_out,
            step: 0,
            data_rng: Rng::new(seed.wrapping_add(0xDA7A)),
        })
    }

    /// Hermetic trainer over the pure-Rust interpreter.
    pub fn interp(rnn: RnnConfig, dtr_cfg: dtr::Config) -> Result<LstmTrainer> {
        LstmTrainer::new(Box::new(InterpExecutor::rnn(rnn)?), rnn, dtr_cfg, LSTM_SEED)
    }

    /// Like [`LstmTrainer::interp`] with `threads` intra-op kernel workers
    /// (bit-identical at any thread count).
    pub fn interp_threaded(
        rnn: RnnConfig,
        threads: usize,
        dtr_cfg: dtr::Config,
    ) -> Result<LstmTrainer> {
        let exec = InterpExecutor::rnn(rnn)?.with_threads(threads);
        LstmTrainer::new(Box::new(exec), rnn, dtr_cfg, LSTM_SEED)
    }

    /// Accounting-only trainer (zero buffers): DTR stats must match the
    /// interpreter's exactly.
    pub fn null(rnn: RnnConfig, dtr_cfg: dtr::Config) -> Result<LstmTrainer> {
        LstmTrainer::new(Box::new(NullExecutor::rnn(rnn)?), rnn, dtr_cfg, LSTM_SEED)
    }

    /// Draw a batch: a random sequence length (the dynamism) and a one-hot
    /// class signal per batch row, constant across timesteps.
    fn sample_batch(
        rnn: RnnConfig,
        min_len: usize,
        max_len: usize,
        rng: &mut Rng,
    ) -> (usize, HostTensor, HostTensor) {
        let len = (min_len + rng.below((max_len - min_len + 1) as u64) as usize).max(1);
        let mut ys = Vec::with_capacity(rnn.batch);
        for _ in 0..rnn.batch {
            ys.push(rng.below(rnn.classes as u64) as usize);
        }
        let mut x = HostTensor::zeros(&[rnn.batch, rnn.input]);
        for (bi, &y) in ys.iter().enumerate() {
            x.data[bi * rnn.input + y % rnn.input] = 1.0;
        }
        let tgt = HostTensor::new(vec![rnn.batch], ys.iter().map(|&y| y as f32).collect());
        (len, x, tgt)
    }

    /// One BPTT training step under DTR. The unroll length is decided by
    /// the batch, *after* the budget was fixed — the scenario static
    /// planners cannot handle.
    pub fn train_step(&mut self) -> Result<DynStepResult> {
        let wall0 = Instant::now();
        self.step += 1;
        let rnn = self.rnn;
        let (seq_len, x, tgt) =
            Self::sample_batch(rnn, self.min_len, self.max_len, &mut self.data_rng);

        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);

        // --- constants: weights + per-timestep data + BPTT seeds ---
        let wx = s.constant(self.wx.clone());
        let wh = s.constant(self.wh.clone());
        let bias = s.constant(self.b.clone());
        let w_out = s.constant(self.w_out.clone());
        let tgt_t = s.constant(tgt);
        let xs: Vec<Tensor> = (0..seq_len).map(|_| s.constant(x.clone())).collect();
        let h0 = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        let c0 = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        let dc0 = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        let pinned = s.memory();

        // --- forward over however many steps the data demanded ---
        let mut hs: Vec<Tensor> = Vec::with_capacity(seq_len + 1);
        let mut cs: Vec<Tensor> = Vec::with_capacity(seq_len + 1);
        hs.push(h0);
        cs.push(c0);
        for t in 0..seq_len {
            let mut outs = s
                .call("lstm_cell_fwd", &[&xs[t], &hs[t], &cs[t], &wx, &wh, &bias])?
                .into_iter();
            hs.push(outs.next().unwrap());
            cs.push(outs.next().unwrap());
        }
        let loss_t = s.call("rnn_loss_fwd", &[hs.last().unwrap(), &w_out, &tgt_t])?.remove(0);
        let loss = s.scalar(&loss_t)?;
        drop(loss_t);

        // --- backward through time ---
        let mut louts = s.call("rnn_loss_bwd", &[hs.last().unwrap(), &w_out, &tgt_t])?.into_iter();
        let mut dh = louts.next().unwrap();
        let dw_out = louts.next().unwrap();
        let mut dc = dc0;
        let mut gwx: Option<Tensor> = None;
        let mut gwh: Option<Tensor> = None;
        let mut gb: Option<Tensor> = None;
        for t in (0..seq_len).rev() {
            // h_{t+1}/c_{t+1} had their last consumer in the previous
            // backward iteration (or the loss); dropping them releases.
            drop(hs.pop());
            drop(cs.pop());
            let mut outs = s
                .call(
                    "lstm_cell_bwd",
                    &[&xs[t], hs.last().unwrap(), cs.last().unwrap(), &wx, &wh, &bias, &dh, &dc],
                )?
                .into_iter();
            let _dx = outs.next().unwrap(); // inputs are pinned data: gradient unused
            dh = outs.next().unwrap(); // reassignment releases the consumed grads
            dc = outs.next().unwrap();
            gwx = Some(accumulate(&s, "acc_wx", gwx, outs.next().unwrap())?);
            gwh = Some(accumulate(&s, "acc_wh", gwh, outs.next().unwrap())?);
            gb = Some(accumulate(&s, "acc_b", gb, outs.next().unwrap())?);
        }
        drop(dh);
        drop(dc);

        // --- SGD updates, read back immediately (decheckpoint while hot) ---
        let gwx = gwx.expect("at least one timestep");
        let gwh = gwh.expect("at least one timestep");
        let gb = gb.expect("at least one timestep");
        let up = s.call("sgd_wx", &[&wx, &gwx])?.remove(0);
        self.wx = s.get(&up)?;
        drop(up);
        drop(gwx);
        let up = s.call("sgd_wh", &[&wh, &gwh])?.remove(0);
        self.wh = s.get(&up)?;
        drop(up);
        drop(gwh);
        let up = s.call("sgd_b", &[&bias, &gb])?.remove(0);
        self.b = s.get(&up)?;
        drop(up);
        drop(gb);
        let up = s.call("sgd_wout", &[&w_out, &dw_out])?.remove(0);
        self.w_out = s.get(&up)?;
        drop(up);
        drop(dw_out);

        s.check_invariants()?;
        Ok(DynStepResult {
            loss,
            stats: s.stats(),
            pinned_bytes: pinned,
            units: seq_len as u64,
            wall_ns: wall0.elapsed().as_nanos() as u64,
            exec_ns: s.exec_ns(),
        })
    }

    /// One *budgeted* forward-only inference pass on the next data batch
    /// (random sequence length, advancing the data stream), under the
    /// trainer's own DTR config/gate — the serving counterpart of
    /// [`LstmTrainer::probe_loss`], which runs unbudgeted.
    pub fn infer_step(&mut self) -> Result<f32> {
        let rnn = self.rnn;
        let (seq_len, x, tgt) =
            Self::sample_batch(rnn, self.min_len, self.max_len, &mut self.data_rng);
        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);
        let wx = s.constant(self.wx.clone());
        let wh = s.constant(self.wh.clone());
        let bias = s.constant(self.b.clone());
        let w_out = s.constant(self.w_out.clone());
        let tgt_t = s.constant(tgt);
        let x_t = s.constant(x);
        let mut h = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        let mut c = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        for _ in 0..seq_len {
            let mut outs = s.call("lstm_cell_fwd", &[&x_t, &h, &c, &wx, &wh, &bias])?.into_iter();
            h = outs.next().unwrap(); // reassignment releases the consumed state
            c = outs.next().unwrap();
        }
        let loss_t = s.call("rnn_loss_fwd", &[&h, &w_out, &tgt_t])?.remove(0);
        let loss = s.scalar(&loss_t)?;
        s.check_invariants()?;
        Ok(loss)
    }

    /// Forward-only loss on a fixed probe batch (deterministic in
    /// `probe_seed`), run unbudgeted: a noise-free progress measure across
    /// varying per-step shapes.
    pub fn probe_loss(&self, probe_seed: u64) -> Result<f32> {
        let rnn = self.rnn;
        let mut rng = Rng::new(probe_seed);
        let (seq_len, x, tgt) = Self::sample_batch(rnn, self.min_len, self.max_len, &mut rng);
        let cfg = self.dtr_cfg.unbudgeted();
        let s = Session::with_contract(Arc::clone(&self.exec), cfg, &self.contract);
        let wx = s.constant(self.wx.clone());
        let wh = s.constant(self.wh.clone());
        let bias = s.constant(self.b.clone());
        let w_out = s.constant(self.w_out.clone());
        let tgt_t = s.constant(tgt);
        let x_t = s.constant(x);
        let mut h = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        let mut c = s.constant(HostTensor::zeros(&[rnn.batch, rnn.hidden]));
        for _ in 0..seq_len {
            let mut outs =
                s.call("lstm_cell_fwd", &[&x_t, &h, &c, &wx, &wh, &bias])?.into_iter();
            h = outs.next().unwrap();
            c = outs.next().unwrap();
        }
        let loss_t = s.call("rnn_loss_fwd", &[&h, &w_out, &tgt_t])?.remove(0);
        s.scalar(&loss_t)
    }

    /// Dry-run `steps` unbudgeted steps on a throwaway copy of the state,
    /// returning the max peak and max pinned floor over the dynamic
    /// envelope — the inputs to [`headroom_budget`].
    pub fn measure_envelope(&mut self, steps: usize) -> Result<(u64, u64)> {
        let saved = (
            self.wx.clone(),
            self.wh.clone(),
            self.b.clone(),
            self.w_out.clone(),
            self.step,
            self.data_rng.clone(),
            self.dtr_cfg.clone(),
        );
        self.dtr_cfg = self.dtr_cfg.unbudgeted();
        let mut peak = 0u64;
        let mut floor = 0u64;
        let mut result = Ok(());
        for _ in 0..steps {
            match self.train_step() {
                Ok(r) => {
                    peak = peak.max(r.stats.peak_memory);
                    floor = floor.max(r.pinned_bytes);
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        (self.wx, self.wh, self.b, self.w_out, self.step, self.data_rng, self.dtr_cfg) = saved;
        result.map(|()| (peak, floor))
    }
}

// --------------------------------------------------------------- TreeLSTM

/// Random binary tree shape — per *sample*, not per architecture.
#[derive(Debug, Clone)]
pub enum TreeShape {
    Leaf,
    Comb(Box<TreeShape>, Box<TreeShape>),
}

impl TreeShape {
    pub fn leaves(&self) -> u64 {
        match self {
            TreeShape::Leaf => 1,
            TreeShape::Comb(l, r) => l.leaves() + r.leaves(),
        }
    }
}

/// Per-node forward state kept for the backward sweep: each node's output
/// handle plus its children (whose hidden states the self-contained
/// backward cell consumes).
enum EvalNode {
    Leaf { h: Tensor },
    Comb { h: Tensor, l: Box<EvalNode>, r: Box<EvalNode> },
}

impl EvalNode {
    fn h(&self) -> &Tensor {
        match self {
            EvalNode::Leaf { h } | EvalNode::Comb { h, .. } => h,
        }
    }
}

struct TreeGrads {
    wc: Option<Tensor>,
    wl: Option<Tensor>,
    wr: Option<Tensor>,
}

/// TreeLSTM over per-sample random tree shapes, trained with SGD through a
/// fresh DTR session per step.
pub struct TreeLstmTrainer {
    exec: SharedExecutor,
    contract: OpContract,
    pub rnn: RnnConfig,
    pub dtr_cfg: dtr::Config,
    /// Trees are random binary trees of at most this depth...
    pub max_depth: usize,
    /// ...splitting at each node with this probability.
    pub split_p: f64,
    wc: HostTensor,
    wl: HostTensor,
    wr: HostTensor,
    w_out: HostTensor,
    step: u64,
    data_rng: Rng,
}

impl TreeLstmTrainer {
    pub fn new(
        exec: Box<dyn Executor>,
        rnn: RnnConfig,
        dtr_cfg: dtr::Config,
        seed: u64,
    ) -> Result<TreeLstmTrainer> {
        rnn.validate()?;
        let (i, h) = (rnn.input, rnn.hidden);
        let mut wrng = Rng::new(seed);
        let wc = randn_host(&mut wrng, &[i, h], INIT_SCALE);
        let wl = randn_host(&mut wrng, &[h, h], INIT_SCALE);
        let wr = randn_host(&mut wrng, &[h, h], INIT_SCALE);
        let w_out = randn_host(&mut wrng, &[h, rnn.classes], INIT_SCALE);
        let exec: SharedExecutor = Arc::new(Mutex::new(exec));
        let contract = OpContract::of(&exec);
        Ok(TreeLstmTrainer {
            exec,
            contract,
            rnn,
            dtr_cfg,
            max_depth: 4,
            split_p: 0.75,
            wc,
            wl,
            wr,
            w_out,
            step: 0,
            data_rng: Rng::new(seed.wrapping_add(0xDA7A)),
        })
    }

    pub fn interp(rnn: RnnConfig, dtr_cfg: dtr::Config) -> Result<TreeLstmTrainer> {
        TreeLstmTrainer::new(Box::new(InterpExecutor::rnn(rnn)?), rnn, dtr_cfg, TREE_SEED)
    }

    /// Like [`TreeLstmTrainer::interp`] with `threads` intra-op kernel
    /// workers (bit-identical at any thread count).
    pub fn interp_threaded(
        rnn: RnnConfig,
        threads: usize,
        dtr_cfg: dtr::Config,
    ) -> Result<TreeLstmTrainer> {
        let exec = InterpExecutor::rnn(rnn)?.with_threads(threads);
        TreeLstmTrainer::new(Box::new(exec), rnn, dtr_cfg, TREE_SEED)
    }

    pub fn null(rnn: RnnConfig, dtr_cfg: dtr::Config) -> Result<TreeLstmTrainer> {
        TreeLstmTrainer::new(Box::new(NullExecutor::rnn(rnn)?), rnn, dtr_cfg, TREE_SEED)
    }

    fn gen_tree(rng: &mut Rng, depth: usize, split_p: f64) -> TreeShape {
        if depth > 0 && rng.chance(split_p) {
            let l = Self::gen_tree(rng, depth - 1, split_p);
            let r = Self::gen_tree(rng, depth - 1, split_p);
            TreeShape::Comb(Box::new(l), Box::new(r))
        } else {
            TreeShape::Leaf
        }
    }

    /// Draw a batch: per-row class signal plus this step's random tree.
    fn sample_batch(
        rnn: RnnConfig,
        max_depth: usize,
        split_p: f64,
        rng: &mut Rng,
    ) -> (TreeShape, HostTensor, HostTensor) {
        let mut ys = Vec::with_capacity(rnn.batch);
        for _ in 0..rnn.batch {
            ys.push(rng.below(rnn.classes as u64) as usize);
        }
        let shape = Self::gen_tree(rng, max_depth, split_p);
        let mut x = HostTensor::zeros(&[rnn.batch, rnn.input]);
        for (bi, &y) in ys.iter().enumerate() {
            x.data[bi * rnn.input + y % rnn.input] = 1.0;
        }
        let tgt = HostTensor::new(vec![rnn.batch], ys.iter().map(|&y| y as f32).collect());
        (shape, x, tgt)
    }

    fn eval_tree(
        s: &Session<ExecBackend>,
        shape: &TreeShape,
        x: &Tensor,
        wc: &Tensor,
        wl: &Tensor,
        wr: &Tensor,
    ) -> Result<EvalNode> {
        match shape {
            TreeShape::Leaf => {
                let h = s.call("tree_leaf_fwd", &[x, wc])?.remove(0);
                Ok(EvalNode::Leaf { h })
            }
            TreeShape::Comb(ls, rs) => {
                let l = Self::eval_tree(s, ls, x, wc, wl, wr)?;
                let r = Self::eval_tree(s, rs, x, wc, wl, wr)?;
                let h = s.call("tree_comb_fwd", &[l.h(), r.h(), wl, wr])?.remove(0);
                Ok(EvalNode::Comb { h, l: Box::new(l), r: Box::new(r) })
            }
        }
    }

    /// Top-down backward sweep: a node's own output handle dies on entry
    /// (its consumers — parent cell and loss — have already run), then the
    /// backward cell consumes the children's hidden states, possibly
    /// rematerializing them.
    fn backward(
        s: &Session<ExecBackend>,
        node: EvalNode,
        x: &Tensor,
        wc: &Tensor,
        wl: &Tensor,
        wr: &Tensor,
        dh: Tensor,
        grads: &mut TreeGrads,
    ) -> Result<()> {
        match node {
            EvalNode::Leaf { h } => {
                drop(h);
                let mut outs = s.call("tree_leaf_bwd", &[x, wc, &dh])?.into_iter();
                drop(dh);
                let _dx = outs.next().unwrap(); // leaf inputs are pinned data
                let dwc = outs.next().unwrap();
                grads.wc = Some(accumulate(s, "acc_wc", grads.wc.take(), dwc)?);
            }
            EvalNode::Comb { h, l, r } => {
                drop(h);
                let mut outs =
                    s.call("tree_comb_bwd", &[l.h(), r.h(), wl, wr, &dh])?.into_iter();
                drop(dh);
                let dhl = outs.next().unwrap();
                let dhr = outs.next().unwrap();
                let dwl = outs.next().unwrap();
                let dwr = outs.next().unwrap();
                grads.wl = Some(accumulate(s, "acc_wl", grads.wl.take(), dwl)?);
                grads.wr = Some(accumulate(s, "acc_wr", grads.wr.take(), dwr)?);
                Self::backward(s, *l, x, wc, wl, wr, dhl, grads)?;
                Self::backward(s, *r, x, wc, wl, wr, dhr, grads)?;
            }
        }
        Ok(())
    }

    /// One training step over this batch's random tree.
    pub fn train_step(&mut self) -> Result<DynStepResult> {
        let wall0 = Instant::now();
        self.step += 1;
        let rnn = self.rnn;
        let (shape, x, tgt) =
            Self::sample_batch(rnn, self.max_depth, self.split_p, &mut self.data_rng);
        let n_leaves = shape.leaves();

        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);
        let wc = s.constant(self.wc.clone());
        let wl = s.constant(self.wl.clone());
        let wr = s.constant(self.wr.clone());
        let w_out = s.constant(self.w_out.clone());
        let x_t = s.constant(x);
        let tgt_t = s.constant(tgt);
        let pinned = s.memory();

        let root = Self::eval_tree(&s, &shape, &x_t, &wc, &wl, &wr)?;
        let loss_t = s.call("rnn_loss_fwd", &[root.h(), &w_out, &tgt_t])?.remove(0);
        let loss = s.scalar(&loss_t)?;
        drop(loss_t);

        let mut louts = s.call("rnn_loss_bwd", &[root.h(), &w_out, &tgt_t])?.into_iter();
        let dh = louts.next().unwrap();
        let dw_out = louts.next().unwrap();
        let mut grads = TreeGrads { wc: None, wl: None, wr: None };
        Self::backward(&s, root, &x_t, &wc, &wl, &wr, dh, &mut grads)?;

        // SGD updates; wl/wr grads are absent when the tree is one leaf
        // (mathematically a zero gradient — the update is the identity).
        if let Some(g) = grads.wc.take() {
            let up = s.call("sgd_wc", &[&wc, &g])?.remove(0);
            self.wc = s.get(&up)?;
        }
        if let Some(g) = grads.wl.take() {
            let up = s.call("sgd_wl", &[&wl, &g])?.remove(0);
            self.wl = s.get(&up)?;
        }
        if let Some(g) = grads.wr.take() {
            let up = s.call("sgd_wr", &[&wr, &g])?.remove(0);
            self.wr = s.get(&up)?;
        }
        let up = s.call("sgd_wout", &[&w_out, &dw_out])?.remove(0);
        self.w_out = s.get(&up)?;
        drop(up);
        drop(dw_out);

        s.check_invariants()?;
        Ok(DynStepResult {
            loss,
            stats: s.stats(),
            pinned_bytes: pinned,
            units: n_leaves,
            wall_ns: wall0.elapsed().as_nanos() as u64,
            exec_ns: s.exec_ns(),
        })
    }

    /// One *budgeted* forward-only inference pass on the next data batch
    /// (random tree shape, advancing the data stream), under the trainer's
    /// own DTR config/gate.
    pub fn infer_step(&mut self) -> Result<f32> {
        let rnn = self.rnn;
        let (shape, x, tgt) =
            Self::sample_batch(rnn, self.max_depth, self.split_p, &mut self.data_rng);
        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);
        let wc = s.constant(self.wc.clone());
        let wl = s.constant(self.wl.clone());
        let wr = s.constant(self.wr.clone());
        let w_out = s.constant(self.w_out.clone());
        let x_t = s.constant(x);
        let tgt_t = s.constant(tgt);
        let root = Self::eval_tree(&s, &shape, &x_t, &wc, &wl, &wr)?;
        let loss_t = s.call("rnn_loss_fwd", &[root.h(), &w_out, &tgt_t])?.remove(0);
        let loss = s.scalar(&loss_t)?;
        s.check_invariants()?;
        Ok(loss)
    }

    /// Forward-only loss on a fixed probe tree/batch, run unbudgeted.
    pub fn probe_loss(&self, probe_seed: u64) -> Result<f32> {
        let rnn = self.rnn;
        let mut rng = Rng::new(probe_seed);
        let (shape, x, tgt) = Self::sample_batch(rnn, self.max_depth, self.split_p, &mut rng);
        let cfg = self.dtr_cfg.unbudgeted();
        let s = Session::with_contract(Arc::clone(&self.exec), cfg, &self.contract);
        let wc = s.constant(self.wc.clone());
        let wl = s.constant(self.wl.clone());
        let wr = s.constant(self.wr.clone());
        let w_out = s.constant(self.w_out.clone());
        let x_t = s.constant(x);
        let tgt_t = s.constant(tgt);
        let root = Self::eval_tree(&s, &shape, &x_t, &wc, &wl, &wr)?;
        let loss_t = s.call("rnn_loss_fwd", &[root.h(), &w_out, &tgt_t])?.remove(0);
        s.scalar(&loss_t)
    }

    /// Dry-run `steps` unbudgeted steps on a throwaway copy of the state,
    /// returning (max peak, max pinned floor) over the dynamic envelope.
    pub fn measure_envelope(&mut self, steps: usize) -> Result<(u64, u64)> {
        let saved = (
            self.wc.clone(),
            self.wl.clone(),
            self.wr.clone(),
            self.w_out.clone(),
            self.step,
            self.data_rng.clone(),
            self.dtr_cfg.clone(),
        );
        self.dtr_cfg = self.dtr_cfg.unbudgeted();
        let mut peak = 0u64;
        let mut floor = 0u64;
        let mut result = Ok(());
        for _ in 0..steps {
            match self.train_step() {
                Ok(r) => {
                    peak = peak.max(r.stats.peak_memory);
                    floor = floor.max(r.pinned_bytes);
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        (self.wc, self.wl, self.wr, self.w_out, self.step, self.data_rng, self.dtr_cfg) = saved;
        result.map(|()| (peak, floor))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Heuristic;

    #[test]
    fn lstm_sequence_lengths_vary_per_batch() {
        let mut t = LstmTrainer::interp(RnnConfig::tiny(), dtr::Config::default()).unwrap();
        let mut lens = Vec::new();
        for _ in 0..10 {
            lens.push(t.train_step().unwrap().units);
        }
        let mut uniq = lens.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 1, "sequence lengths never varied: {lens:?}");
    }

    #[test]
    fn lstm_learns_on_fixed_probe() {
        let mut t = LstmTrainer::interp(RnnConfig::tiny(), dtr::Config::default()).unwrap();
        let before = t.probe_loss(99).unwrap();
        for _ in 0..30 {
            t.train_step().unwrap();
        }
        let after = t.probe_loss(99).unwrap();
        assert!(
            after < before,
            "LSTM probe loss did not descend: {before} -> {after}"
        );
    }

    #[test]
    fn treelstm_shapes_vary_and_probe_descends() {
        let mut t = TreeLstmTrainer::interp(RnnConfig::tiny(), dtr::Config::default()).unwrap();
        let before = t.probe_loss(99).unwrap();
        let mut sizes = Vec::new();
        for _ in 0..30 {
            sizes.push(t.train_step().unwrap().units);
        }
        let after = t.probe_loss(99).unwrap();
        let mut uniq = sizes.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 1, "tree shapes never varied: {sizes:?}");
        assert!(
            after < before,
            "TreeLSTM probe loss did not descend: {before} -> {after}"
        );
    }

    #[test]
    fn budgeted_lstm_training_is_bitwise_identical() {
        let mk = |budget: u64| -> LstmTrainer {
            let cfg = dtr::Config {
                budget,
                heuristic: Heuristic::dtr_eq(),
                ..dtr::Config::default()
            };
            LstmTrainer::interp(RnnConfig::tiny(), cfg).unwrap()
        };
        let (peak, floor) = mk(u64::MAX).measure_envelope(4).unwrap();
        for pct in [70, 55] {
            let mut budgeted = mk(headroom_budget(peak, floor, pct));
            let Ok(first) = budgeted.train_step() else { continue };
            let mut losses = vec![first.loss];
            let mut ok = true;
            for _ in 0..3 {
                match budgeted.train_step() {
                    Ok(r) => losses.push(r.loss),
                    Err(_) => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok {
                let mut reference = mk(u64::MAX);
                let expect: Vec<f32> =
                    (0..4).map(|_| reference.train_step().unwrap().loss).collect();
                assert_eq!(expect, losses, "budgeted LSTM diverged at {pct}%");
                return;
            }
        }
        panic!("no budget rung completed");
    }
}
