//! The real execution engine: one transformer-LM training step driven
//! through the DTR runtime with PJRT buffers as the managed memory.
//!
//! This is the rust analogue of the paper's PyTorch prototype: every
//! operator call is interposed by `dtr::Runtime`, which tracks metadata,
//! evicts under the budget, and transparently re-executes the parent PJRT
//! executable when an evicted activation is needed again (Sec. 5). The
//! weight update runs inside the step as `adam_*`/`sgd_*` ops; updated
//! parameters are read back and re-seeded as constants for the next step
//! (the paper's output condition explicitly permits stepping the optimizer
//! at batch boundaries, Appendix C.6).
//!
//! Memory is accounted logically over real buffer sizes (DESIGN.md §5): the
//! CPU PJRT "device" is host RAM, but DTR only ever sees sizes, costs, and
//! a budget, so the code path is identical to a real accelerator.

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::Literal;

use crate::dtr::{self, Backend, OutSpec, Runtime, TensorId};
use crate::runtime::pjrt::{self, PjrtRuntime};
use crate::runtime::ModelConfig;
use crate::util::rng::Rng;

/// PJRT-backed buffer store implementing the DTR backend trait.
pub struct PjrtBackend {
    rt: Rc<PjrtRuntime>,
    bufs: HashMap<u32, Literal>,
    /// Wall time spent in PJRT execution (Fig. 4's "operator time").
    pub exec_ns: u64,
    pub exec_count: u64,
}

impl PjrtBackend {
    pub fn new(rt: Rc<PjrtRuntime>) -> Self {
        PjrtBackend { rt, bufs: HashMap::new(), exec_ns: 0, exec_count: 0 }
    }

    pub fn put(&mut self, t: TensorId, l: Literal) {
        self.bufs.insert(t.0, l);
    }

    pub fn get(&self, t: TensorId) -> Option<&Literal> {
        self.bufs.get(&t.0)
    }
}

impl Backend for PjrtBackend {
    fn execute(&mut self, name: &str, inputs: &[TensorId], outputs: &[TensorId]) -> Result<()> {
        let t0 = Instant::now();
        let ins: Vec<&Literal> = inputs
            .iter()
            .map(|t| self.bufs.get(&t.0).with_context(|| format!("missing buffer {t}")))
            .collect::<Result<_>>()?;
        let outs = self.rt.execute(name, &ins)?;
        anyhow::ensure!(
            outs.len() == outputs.len(),
            "{name}: {} outputs from PJRT, {} expected",
            outs.len(),
            outputs.len()
        );
        for (t, l) in outputs.iter().zip(outs) {
            self.bufs.insert(t.0, l);
        }
        self.exec_ns += t0.elapsed().as_nanos() as u64;
        self.exec_count += 1;
        Ok(())
    }

    fn free(&mut self, roots: &[TensorId]) {
        for t in roots {
            self.bufs.remove(&t.0);
        }
    }
}

/// Optimizer selection (both are AOT artifacts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    Adam,
    Sgd,
}

/// Result of one training step.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub loss: f32,
    pub stats: dtr::Stats,
    pub wall_ns: u64,
    /// PJRT execution time within the step (operator compute).
    pub exec_ns: u64,
    pub exec_count: u64,
}

/// Persistent training state + per-step DTR-managed execution.
pub struct Engine {
    pub rt: Rc<PjrtRuntime>,
    pub cfg: ModelConfig,
    pub dtr_cfg: dtr::Config,
    pub optimizer: Optimizer,
    /// Measured per-op costs (ns) from the warmup pass — the metadata the
    /// paper's prototype gathers by timing operators dynamically.
    pub op_cost: HashMap<String, u64>,
    /// name -> (literal, param group) for every parameter tensor.
    params: Vec<ParamSlot>,
    step: u64,
    data_rng: Rng,
}

struct ParamSlot {
    name: String,
    /// Parameter group ("emb", "wqkv", ...) selecting the optimizer artifact.
    group: String,
    value: Literal,
    m: Literal,
    v: Literal,
}

impl Engine {
    pub fn new(artifacts_dir: &Path, dtr_cfg: dtr::Config, optimizer: Optimizer) -> Result<Engine> {
        let rt = Rc::new(PjrtRuntime::load(artifacts_dir)?);
        let cfg = rt.manifest.config;
        let mut engine = Engine {
            rt,
            cfg,
            dtr_cfg,
            optimizer,
            op_cost: HashMap::new(),
            params: Vec::new(),
            step: 0,
            data_rng: Rng::new(0xDA7A),
        };
        engine.init_params(0x12AB)?;
        engine.warmup()?;
        Ok(engine)
    }

    /// Initialize parameters + optimizer state host-side (same scheme as
    /// python/compile/model.py init_params).
    fn init_params(&mut self, seed: u64) -> Result<()> {
        let mut rng = Rng::new(seed);
        let shapes = self.rt.manifest.param_shapes.clone();
        let mut slots: Vec<(String, String)> = vec![("emb".into(), "emb".into())];
        for l in 0..self.cfg.n_layers {
            for group in ["ln", "wqkv", "wo", "ln", "w1", "w2"] {
                let idx = slots.len();
                slots.push((format!("blk{l}_{group}_{idx}"), group.to_string()));
            }
        }
        slots.push(("w_out".into(), "w_out".into()));
        for (name, group) in slots {
            let shape = &shapes[&group];
            self.params.push(ParamSlot {
                name,
                group: group.clone(),
                value: pjrt::init_param(&group, shape, &mut rng)?,
                m: pjrt::zeros_literal(shape)?,
                v: pjrt::zeros_literal(shape)?,
            });
        }
        Ok(())
    }

    /// Time each op once (two runs, keep the second) to build the dynamic
    /// cost table DTR's heuristics consume.
    fn warmup(&mut self) -> Result<()> {
        let names: Vec<String> = self.rt.manifest.ops.keys().cloned().collect();
        for name in names {
            let sig = self.rt.manifest.op(&name)?.clone();
            let args: Vec<Literal> =
                sig.inputs.iter().map(pjrt::dtype_zeros).collect::<Result<_>>()?;
            let refs: Vec<&Literal> = args.iter().collect();
            let _ = self.rt.execute(&name, &refs)?; // compile/cache warm
            let t0 = Instant::now();
            let _ = self.rt.execute(&name, &refs)?;
            self.op_cost.insert(name, (t0.elapsed().as_nanos() as u64).max(1));
        }
        Ok(())
    }

    fn cost(&self, op: &str) -> u64 {
        self.op_cost.get(op).copied().unwrap_or(1)
    }

    /// Synthetic LM batch: random tokens; target = fixed affine remap of the
    /// token (a learnable next-token rule, so the loss curve must descend).
    pub fn make_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let n = self.cfg.batch * self.cfg.seq;
        let v = self.cfg.vocab as u64;
        let tokens: Vec<i32> =
            (0..n).map(|_| (self.data_rng.below(v)) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((t as u64 * 31 + 7) % v) as i32).collect();
        (tokens, targets)
    }

    /// Run one full training step under DTR. A fresh DTR runtime is built
    /// per step (parameters re-enter as constants), exactly matching the
    /// paper's per-batch logs; the arena therefore stays bounded.
    pub fn train_step(&mut self) -> Result<StepResult> {
        let wall0 = Instant::now();
        self.step += 1;
        let (tokens, targets) = self.make_batch();
        let cfg = self.cfg;
        let m = self.rt.manifest.clone();

        let backend = PjrtBackend::new(Rc::clone(&self.rt));
        let mut rt: Runtime<PjrtBackend> = Runtime::new(self.dtr_cfg.clone(), backend);

        // --- constants: data + params + optimizer state ---
        let tok_lit = pjrt::i32_literal(&tokens, &[cfg.batch, cfg.seq])?;
        let tgt_lit = pjrt::i32_literal(&targets, &[cfg.batch, cfg.seq])?;
        let tok = constant(&mut rt, tok_lit)?;
        let tgt = constant(&mut rt, tgt_lit)?;

        let mut param_ts = Vec::with_capacity(self.params.len());
        for slot in &self.params {
            let p = constant(&mut rt, slot.value.clone())?;
            let (mm, vv) = if self.optimizer == Optimizer::Adam {
                (Some(constant(&mut rt, slot.m.clone())?), Some(constant(&mut rt, slot.v.clone())?))
            } else {
                (None, None)
            };
            param_ts.push((p, mm, vv));
        }
        let t_lit = pjrt::f32_literal(&[self.step as f32], &[1])?;
        let t_step = constant(&mut rt, t_lit)?;

        // --- forward ---
        let x_sig = m.op("block_fwd")?.outputs[0].bytes();
        let emb_t = param_ts[0].0;
        let mut x = rt.call("embed_fwd", self.cost("embed_fwd"), &[tok, emb_t], &[OutSpec::sized(x_sig)])?[0];
        let mut acts = vec![x]; // x_0 .. x_N
        for l in 0..cfg.n_layers {
            let ps: Vec<TensorId> = (0..6).map(|k| param_ts[1 + l * 6 + k].0).collect();
            let inputs = [&[x][..], &ps[..]].concat();
            x = rt.call("block_fwd", self.cost("block_fwd"), &inputs, &[OutSpec::sized(x_sig)])?[0];
            acts.push(x);
        }
        let w_out_t = param_ts[self.params.len() - 1].0;
        let loss_t = rt.call(
            "loss_fwd",
            self.cost("loss_fwd"),
            &[x, w_out_t, tgt],
            &[OutSpec::sized(4)],
        )?[0];
        // Read the loss while it is hot (re-reading after backward would
        // rematerialize loss_fwd and potentially its inputs).
        let loss = pjrt::first_f32(rt.backend().get(loss_t).context("loss buffer")?)?;
        rt.release(loss_t);

        // --- backward ---
        let lb = m.op("loss_bwd")?;
        let (dx_b, dwout_b) = (lb.outputs[0].bytes(), lb.outputs[1].bytes());
        let outs = rt.call(
            "loss_bwd",
            self.cost("loss_bwd"),
            &[x, w_out_t, tgt],
            &[OutSpec::sized(dx_b), OutSpec::sized(dwout_b)],
        )?;
        let mut dx = outs[0];
        let mut grads: Vec<(usize, TensorId)> = vec![(self.params.len() - 1, outs[1])];
        // x_N (= acts[n_layers]) was consumed by loss fwd+bwd only.
        rt.release(acts[cfg.n_layers]);

        let bb = m.op("block_bwd")?;
        for l in (0..cfg.n_layers).rev() {
            let ps: Vec<TensorId> = (0..6).map(|k| param_ts[1 + l * 6 + k].0).collect();
            let x_in = acts[l];
            let inputs = [&[x_in][..], &ps[..], &[dx][..]].concat();
            let specs: Vec<OutSpec> = bb.outputs.iter().map(|o| OutSpec::sized(o.bytes())).collect();
            let outs = rt.call("block_bwd", self.cost("block_bwd"), &inputs, &specs)?;
            rt.release(dx);
            dx = outs[0];
            for k in 0..6 {
                grads.push((1 + l * 6 + k, outs[1 + k]));
            }
            rt.release(acts[l]); // x_{l} dead once block l's bwd is done
        }
        // Embedding gradient.
        let demb_b = m.op("embed_bwd")?.outputs[0].bytes();
        let demb = rt.call("embed_bwd", self.cost("embed_bwd"), &[tok, dx], &[OutSpec::sized(demb_b)])?[0];
        rt.release(dx);
        grads.push((0, demb));

        // --- optimizer updates (inside DTR, as ops) ---
        // Perf (EXPERIMENTS.md §Perf, L3 iteration 1): read each updated
        // parameter back *immediately* after its optimizer op, while its
        // gradient input is still cheap to hold, then release everything.
        // Deferring the read-back to the end of the step let updated params
        // get evicted after their gradients were freed, so re-reading them
        // replayed entire backward chains (~2x whole-step recompute at 0.9
        // budget). Immediate decheckpointing is also what the paper's
        // prototype does for values the host consumes.
        for (pi, g) in grads {
            let group = self.params[pi].group.clone();
            let (p, mm, vv) = param_ts[pi];
            match self.optimizer {
                Optimizer::Adam => {
                    let op = format!("adam_{group}");
                    let psig = m.op(&op)?.outputs[0].bytes();
                    let outs = rt.call(
                        &op,
                        self.cost(&op),
                        &[p, g, mm.unwrap(), vv.unwrap(), t_step],
                        &[OutSpec::sized(psig), OutSpec::sized(psig), OutSpec::sized(psig)],
                    )?;
                    self.params[pi].value =
                        rt.backend().get(outs[0]).context("param")?.clone();
                    self.params[pi].m = rt.backend().get(outs[1]).context("m")?.clone();
                    self.params[pi].v = rt.backend().get(outs[2]).context("v")?.clone();
                    for &o in &outs {
                        rt.release(o);
                    }
                }
                Optimizer::Sgd => {
                    let op = format!("sgd_{group}");
                    let psig = m.op(&op)?.outputs[0].bytes();
                    let outs = rt.call(&op, self.cost(&op), &[p, g], &[OutSpec::sized(psig)])?;
                    self.params[pi].value =
                        rt.backend().get(outs[0]).context("param")?.clone();
                    rt.release(outs[0]);
                }
            }
            rt.release(g);
        }

        rt.check_invariants()?;

        Ok(StepResult {
            loss,
            stats: rt.stats.clone(),
            wall_ns: wall0.elapsed().as_nanos() as u64,
            exec_ns: rt.backend().exec_ns,
            exec_count: rt.backend().exec_count,
        })
    }

    /// Measure the unbudgeted peak memory of one step (for ratio budgets).
    /// Runs on a throwaway clone of the parameter state.
    pub fn measure_peak(&mut self) -> Result<u64> {
        let saved_cfg = self.dtr_cfg.clone();
        let saved_step = self.step;
        let saved_rng = self.data_rng.clone();
        let saved_params: Vec<(Literal, Literal, Literal)> = self
            .params
            .iter()
            .map(|p| (p.value.clone(), p.m.clone(), p.v.clone()))
            .collect();
        self.dtr_cfg = dtr::Config { budget: u64::MAX, ..self.dtr_cfg.clone() };
        let peak = self.train_step()?.stats.peak_memory;
        // Restore.
        self.dtr_cfg = saved_cfg;
        self.step = saved_step;
        self.data_rng = saved_rng;
        for (slot, (v, m, vv)) in self.params.iter_mut().zip(saved_params) {
            slot.value = v;
            slot.m = m;
            slot.v = vv;
        }
        Ok(peak)
    }

    pub fn total_params(&self) -> u64 {
        self.rt.manifest.total_params
    }
}

fn constant(rt: &mut Runtime<PjrtBackend>, lit: Literal) -> Result<TensorId> {
    let size = lit.size_bytes() as u64;
    let t = rt.constant(size);
    rt.backend_mut().put(t, lit);
    Ok(t)
}

impl Engine {
    /// Parameter inventory (name, group, bytes) for reporting.
    pub fn param_inventory(&self) -> Vec<(String, String, u64)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.group.clone(), p.value.size_bytes() as u64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Heuristic;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn unbudgeted_step_runs_and_loss_near_ln_vocab() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e =
            Engine::new(&artifacts_dir(), dtr::Config::default(), Optimizer::Adam).unwrap();
        let r = e.train_step().unwrap();
        let lnv = (e.cfg.vocab as f32).ln();
        assert!((r.loss - lnv).abs() < 1.0, "init loss {} vs ln(V) {}", r.loss, lnv);
        assert_eq!(r.stats.remat_count, 0);
        assert!(r.stats.peak_memory > 0);
    }

    #[test]
    fn loss_decreases_over_steps() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e =
            Engine::new(&artifacts_dir(), dtr::Config::default(), Optimizer::Adam).unwrap();
        let first = e.train_step().unwrap().loss;
        let mut last = first;
        for _ in 0..5 {
            last = e.train_step().unwrap().loss;
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn budgeted_step_bitwise_matches_unbudgeted() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Rematerialization replays identical executables on identical
        // inputs, so the loss trajectory must be bitwise equal.
        let run = |budget_ratio: Option<f64>| -> Vec<f32> {
            let mut e =
                Engine::new(&artifacts_dir(), dtr::Config::default(), Optimizer::Adam).unwrap();
            if let Some(r) = budget_ratio {
                let peak = e.measure_peak().unwrap();
                let floor = e.total_params() * 4 * 3 + 16 * 1024 * 1024;
                let budget = ((peak as f64 * r) as u64).max(floor);
                e.dtr_cfg = dtr::Config {
                    budget,
                    heuristic: Heuristic::dtr_eq(),
                    ..dtr::Config::default()
                };
            }
            (0..3).map(|_| e.train_step().unwrap().loss).collect()
        };
        let base = run(None);
        let budgeted = run(Some(0.7));
        assert_eq!(base, budgeted, "budgeted training diverged numerically");
    }

    #[test]
    fn budgeted_step_rematerializes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e =
            Engine::new(&artifacts_dir(), dtr::Config::default(), Optimizer::Sgd).unwrap();
        let peak = e.measure_peak().unwrap();
        e.dtr_cfg = dtr::Config {
            budget: peak * 8 / 10,
            heuristic: Heuristic::dtr_eq(),
            ..dtr::Config::default()
        };
        let r = e.train_step().unwrap();
        assert!(r.stats.evict_count > 0, "no evictions at 0.8 budget");
        assert!(r.stats.peak_memory <= peak * 8 / 10);
    }
}
