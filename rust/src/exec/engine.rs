//! The real execution engine: one transformer-LM training step driven
//! through the DTR runtime, with buffers owned by a pluggable [`Executor`].
//!
//! This is the rust analogue of the paper's PyTorch prototype: every
//! operator call is interposed by `dtr::Runtime`, which tracks metadata,
//! evicts under the budget, and transparently re-executes the parent
//! operator when an evicted activation is needed again (Sec. 5). The weight
//! update runs inside the step as `adam_*`/`sgd_*` ops; updated parameters
//! are read back and re-seeded as constants for the next step (the paper's
//! output condition explicitly permits stepping the optimizer at batch
//! boundaries, Appendix C.6).
//!
//! The engine is backend-agnostic: it speaks to compute exclusively through
//! the [`Executor`] trait (hermetic interpreter by default; PJRT behind the
//! `pjrt` feature; accounting-only `NullExecutor` for equivalence tests).
//! Memory is accounted logically over real buffer sizes, and per-op costs
//! come from a deterministic analytic model, so budgeted runs are exactly
//! reproducible and DTR's decisions are identical across backends.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::dtr::{self, Backend, OutSpec, Runtime, TensorId};
use crate::runtime::executor::{analytic_cost, init_param, Executor, HostTensor};
use crate::runtime::{InterpExecutor, Manifest, ModelConfig};
use crate::util::rng::Rng;

/// Shared handle to the executor: the engine keeps it across steps while
/// each per-step DTR backend borrows it for operator execution.
pub type SharedExecutor = Rc<RefCell<Box<dyn Executor>>>;

/// Buffer store implementing the DTR backend trait over any [`Executor`].
pub struct ExecBackend {
    exec: SharedExecutor,
    bufs: HashMap<u32, HostTensor>,
    /// Wall time spent executing operators (Fig. 4's "operator time").
    pub exec_ns: u64,
    pub exec_count: u64,
}

impl ExecBackend {
    pub fn new(exec: SharedExecutor) -> Self {
        ExecBackend { exec, bufs: HashMap::new(), exec_ns: 0, exec_count: 0 }
    }

    pub fn put(&mut self, t: TensorId, v: HostTensor) {
        self.bufs.insert(t.0, v);
    }

    pub fn get(&self, t: TensorId) -> Option<&HostTensor> {
        self.bufs.get(&t.0)
    }
}

impl Backend for ExecBackend {
    fn execute(&mut self, name: &str, inputs: &[TensorId], outputs: &[TensorId]) -> Result<()> {
        let t0 = Instant::now();
        let ins: Vec<&HostTensor> = inputs
            .iter()
            .map(|t| self.bufs.get(&t.0).with_context(|| format!("missing buffer {t}")))
            .collect::<Result<_>>()?;
        let outs = self.exec.borrow_mut().execute(name, &ins)?;
        anyhow::ensure!(
            outs.len() == outputs.len(),
            "{name}: {} outputs from executor, {} expected",
            outs.len(),
            outputs.len()
        );
        for (t, v) in outputs.iter().zip(outs) {
            self.bufs.insert(t.0, v);
        }
        self.exec_ns += t0.elapsed().as_nanos() as u64;
        self.exec_count += 1;
        Ok(())
    }

    fn free(&mut self, roots: &[TensorId]) {
        for t in roots {
            self.bufs.remove(&t.0);
        }
    }
}

/// Optimizer selection (both are manifest ops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    Adam,
    Sgd,
}

/// Result of one training step.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub loss: f32,
    pub stats: dtr::Stats,
    pub wall_ns: u64,
    /// Executor time within the step (operator compute).
    pub exec_ns: u64,
    pub exec_count: u64,
}

/// Persistent training state + per-step DTR-managed execution.
pub struct Engine {
    exec: SharedExecutor,
    pub manifest: Manifest,
    pub cfg: ModelConfig,
    pub dtr_cfg: dtr::Config,
    pub optimizer: Optimizer,
    /// Deterministic per-op costs (analytic flop model) consumed by DTR's
    /// heuristics — the metadata the paper's prototype gathers by timing
    /// operators; modeled analytically here so runs are reproducible.
    pub op_cost: HashMap<String, u64>,
    /// name -> (tensor, param group) for every parameter tensor.
    params: Vec<ParamSlot>,
    step: u64,
    data_rng: Rng,
}

struct ParamSlot {
    name: String,
    /// Parameter group ("emb", "wqkv", ...) selecting the optimizer op.
    group: String,
    value: HostTensor,
    m: HostTensor,
    v: HostTensor,
}

impl Engine {
    /// Build an engine over any executor — the multi-backend seam.
    pub fn new(exec: Box<dyn Executor>, dtr_cfg: dtr::Config, optimizer: Optimizer) -> Result<Engine> {
        let manifest = exec.manifest().clone();
        let cfg = manifest.config;
        let mut op_cost = HashMap::new();
        for (name, op) in &manifest.ops {
            op_cost.insert(name.clone(), analytic_cost(name, op, &cfg));
        }
        let mut engine = Engine {
            exec: Rc::new(RefCell::new(exec)),
            manifest,
            cfg,
            dtr_cfg,
            optimizer,
            op_cost,
            params: Vec::new(),
            step: 0,
            data_rng: Rng::new(0xDA7A),
        };
        engine.init_params(0x12AB);
        Ok(engine)
    }

    /// Hermetic engine over the pure-Rust interpreter (no artifacts, no
    /// external dependencies).
    pub fn interp(model: ModelConfig, dtr_cfg: dtr::Config, optimizer: Optimizer) -> Result<Engine> {
        Engine::new(Box::new(InterpExecutor::new(model)?), dtr_cfg, optimizer)
    }

    /// Engine over AOT-compiled HLO artifacts through PJRT.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(
        artifacts_dir: &std::path::Path,
        dtr_cfg: dtr::Config,
        optimizer: Optimizer,
    ) -> Result<Engine> {
        let exec = crate::runtime::pjrt::PjrtExecutor::load(artifacts_dir)?;
        Engine::new(Box::new(exec), dtr_cfg, optimizer)
    }

    pub fn backend_name(&self) -> &'static str {
        self.exec.borrow().name()
    }

    /// Initialize parameters + optimizer state host-side (same scheme as
    /// python/compile/model.py init_params).
    fn init_params(&mut self, seed: u64) {
        let mut rng = Rng::new(seed);
        let shapes = self.manifest.param_shapes.clone();
        let mut slots: Vec<(String, String)> = vec![("emb".into(), "emb".into())];
        for l in 0..self.cfg.n_layers {
            for group in ["ln", "wqkv", "wo", "ln", "w1", "w2"] {
                let idx = slots.len();
                slots.push((format!("blk{l}_{group}_{idx}"), group.to_string()));
            }
        }
        slots.push(("w_out".into(), "w_out".into()));
        for (name, group) in slots {
            let shape = &shapes[&group];
            self.params.push(ParamSlot {
                name,
                group: group.clone(),
                value: init_param(&group, shape, &mut rng),
                m: HostTensor::zeros(shape),
                v: HostTensor::zeros(shape),
            });
        }
    }

    fn cost(&self, op: &str) -> u64 {
        self.op_cost.get(op).copied().unwrap_or(1)
    }

    /// Synthetic LM batch: random tokens; target = fixed affine remap of the
    /// token (a learnable next-token rule, so the loss curve must descend).
    pub fn make_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let n = self.cfg.batch * self.cfg.seq;
        let v = self.cfg.vocab as u64;
        let tokens: Vec<i32> = (0..n).map(|_| (self.data_rng.below(v)) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((t as u64 * 31 + 7) % v) as i32).collect();
        (tokens, targets)
    }

    /// Bytes held by per-step constants (data batch, parameters, optimizer
    /// state, step counter) — DTR pins these, so any feasible budget must
    /// exceed this floor plus a working set.
    pub fn pinned_bytes(&self) -> u64 {
        let mut total = 2 * (self.cfg.batch * self.cfg.seq) as u64 * 4 + 4;
        for p in &self.params {
            total += p.value.size_bytes();
            if self.optimizer == Optimizer::Adam {
                total += p.m.size_bytes() + p.v.size_bytes();
            }
        }
        total
    }

    /// Run one full training step under DTR. A fresh DTR runtime is built
    /// per step (parameters re-enter as constants), exactly matching the
    /// paper's per-batch logs; the arena therefore stays bounded.
    pub fn train_step(&mut self) -> Result<StepResult> {
        let wall0 = Instant::now();
        self.step += 1;
        let (tokens, targets) = self.make_batch();
        let cfg = self.cfg;
        let m = self.manifest.clone();

        let backend = ExecBackend::new(Rc::clone(&self.exec));
        let mut rt: Runtime<ExecBackend> = Runtime::new(self.dtr_cfg.clone(), backend);

        // --- constants: data + params + optimizer state ---
        let as_f32 = |xs: &[i32]| xs.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let tok = constant(
            &mut rt,
            HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&tokens)),
        );
        let tgt = constant(
            &mut rt,
            HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&targets)),
        );

        let mut param_ts = Vec::with_capacity(self.params.len());
        for slot in &self.params {
            let p = constant(&mut rt, slot.value.clone());
            let (mm, vv) = if self.optimizer == Optimizer::Adam {
                (
                    Some(constant(&mut rt, slot.m.clone())),
                    Some(constant(&mut rt, slot.v.clone())),
                )
            } else {
                (None, None)
            };
            param_ts.push((p, mm, vv));
        }
        let t_step = constant(&mut rt, HostTensor::scalar(self.step as f32));
        // Everything resident at this point is exactly the pinned constant
        // set; keep `pinned_bytes()` honest against the real inventory.
        debug_assert_eq!(
            rt.stats.memory,
            self.pinned_bytes(),
            "pinned_bytes() drifted from the constants train_step registers"
        );

        // --- forward ---
        let x_sig = m.op("block_fwd")?.outputs[0].bytes();
        let emb_t = param_ts[0].0;
        let mut x = rt.call("embed_fwd", self.cost("embed_fwd"), &[tok, emb_t], &[OutSpec::sized(x_sig)])?[0];
        let mut acts = vec![x]; // x_0 .. x_N
        for l in 0..cfg.n_layers {
            let ps: Vec<TensorId> = (0..6).map(|k| param_ts[1 + l * 6 + k].0).collect();
            let inputs = [&[x][..], &ps[..]].concat();
            x = rt.call("block_fwd", self.cost("block_fwd"), &inputs, &[OutSpec::sized(x_sig)])?[0];
            acts.push(x);
        }
        let w_out_t = param_ts[self.params.len() - 1].0;
        let loss_t = rt.call(
            "loss_fwd",
            self.cost("loss_fwd"),
            &[x, w_out_t, tgt],
            &[OutSpec::sized(4)],
        )?[0];
        // Read the loss while it is hot (re-reading after backward would
        // rematerialize loss_fwd and potentially its inputs).
        let loss = rt.backend().get(loss_t).context("loss buffer")?.data[0];
        rt.release(loss_t);

        // --- backward ---
        let lb = m.op("loss_bwd")?;
        let (dx_b, dwout_b) = (lb.outputs[0].bytes(), lb.outputs[1].bytes());
        let outs = rt.call(
            "loss_bwd",
            self.cost("loss_bwd"),
            &[x, w_out_t, tgt],
            &[OutSpec::sized(dx_b), OutSpec::sized(dwout_b)],
        )?;
        let mut dx = outs[0];
        let mut grads: Vec<(usize, TensorId)> = vec![(self.params.len() - 1, outs[1])];
        // x_N (= acts[n_layers]) was consumed by loss fwd+bwd only.
        rt.release(acts[cfg.n_layers]);

        let bb = m.op("block_bwd")?;
        for l in (0..cfg.n_layers).rev() {
            let ps: Vec<TensorId> = (0..6).map(|k| param_ts[1 + l * 6 + k].0).collect();
            let x_in = acts[l];
            let inputs = [&[x_in][..], &ps[..], &[dx][..]].concat();
            let specs: Vec<OutSpec> = bb.outputs.iter().map(|o| OutSpec::sized(o.bytes())).collect();
            let outs = rt.call("block_bwd", self.cost("block_bwd"), &inputs, &specs)?;
            rt.release(dx);
            dx = outs[0];
            for k in 0..6 {
                grads.push((1 + l * 6 + k, outs[1 + k]));
            }
            rt.release(acts[l]); // x_{l} dead once block l's bwd is done
        }
        // Embedding gradient.
        let demb_b = m.op("embed_bwd")?.outputs[0].bytes();
        let demb = rt.call("embed_bwd", self.cost("embed_bwd"), &[tok, dx], &[OutSpec::sized(demb_b)])?[0];
        rt.release(dx);
        grads.push((0, demb));

        // --- optimizer updates (inside DTR, as ops) ---
        // Perf (EXPERIMENTS.md §Perf, L3 iteration 1): read each updated
        // parameter back *immediately* after its optimizer op, while its
        // gradient input is still cheap to hold, then release everything.
        // Deferring the read-back to the end of the step let updated params
        // get evicted after their gradients were freed, so re-reading them
        // replayed entire backward chains (~2x whole-step recompute at 0.9
        // budget). Immediate decheckpointing is also what the paper's
        // prototype does for values the host consumes.
        for (pi, g) in grads {
            let group = self.params[pi].group.clone();
            let (p, mm, vv) = param_ts[pi];
            match self.optimizer {
                Optimizer::Adam => {
                    let op = format!("adam_{group}");
                    let psig = m.op(&op)?.outputs[0].bytes();
                    let outs = rt.call(
                        &op,
                        self.cost(&op),
                        &[p, g, mm.unwrap(), vv.unwrap(), t_step],
                        &[OutSpec::sized(psig), OutSpec::sized(psig), OutSpec::sized(psig)],
                    )?;
                    self.params[pi].value =
                        rt.backend().get(outs[0]).context("param")?.clone();
                    self.params[pi].m = rt.backend().get(outs[1]).context("m")?.clone();
                    self.params[pi].v = rt.backend().get(outs[2]).context("v")?.clone();
                    for &o in &outs {
                        rt.release(o);
                    }
                }
                Optimizer::Sgd => {
                    let op = format!("sgd_{group}");
                    let psig = m.op(&op)?.outputs[0].bytes();
                    let outs = rt.call(&op, self.cost(&op), &[p, g], &[OutSpec::sized(psig)])?;
                    self.params[pi].value =
                        rt.backend().get(outs[0]).context("param")?.clone();
                    rt.release(outs[0]);
                }
            }
            rt.release(g);
        }

        rt.check_invariants()?;

        Ok(StepResult {
            loss,
            stats: rt.stats.clone(),
            wall_ns: wall0.elapsed().as_nanos() as u64,
            exec_ns: rt.backend().exec_ns,
            exec_count: rt.backend().exec_count,
        })
    }

    /// Measure the unbudgeted peak memory of one step (for ratio budgets).
    /// Runs on a throwaway clone of the parameter state.
    pub fn measure_peak(&mut self) -> Result<u64> {
        let saved_cfg = self.dtr_cfg.clone();
        let saved_step = self.step;
        let saved_rng = self.data_rng.clone();
        let saved_params: Vec<(HostTensor, HostTensor, HostTensor)> = self
            .params
            .iter()
            .map(|p| (p.value.clone(), p.m.clone(), p.v.clone()))
            .collect();
        self.dtr_cfg = dtr::Config { budget: u64::MAX, ..self.dtr_cfg.clone() };
        let peak = self.train_step()?.stats.peak_memory;
        // Restore.
        self.dtr_cfg = saved_cfg;
        self.step = saved_step;
        self.data_rng = saved_rng;
        for (slot, (v, m, vv)) in self.params.iter_mut().zip(saved_params) {
            slot.value = v;
            slot.m = m;
            slot.v = vv;
        }
        Ok(peak)
    }

    /// Budgets at `pct`% of the non-pinned headroom above the pinned floor
    /// (`pinned + (peak - pinned) * pct / 100`) from an already-measured
    /// unbudgeted peak — the canonical budget formula for tests and benches
    /// (ratios of raw peak are dominated by the pinned parameter footprint
    /// on small models).
    pub fn budgets_from_peak(&self, peak: u64, pcts: &[u64]) -> Vec<u64> {
        let pinned = self.pinned_bytes();
        pcts.iter().map(|&p| pinned + peak.saturating_sub(pinned) * p / 100).collect()
    }

    /// [`Engine::budgets_from_peak`] including the peak measurement (one
    /// unbudgeted training step).
    pub fn headroom_budgets(&mut self, pcts: &[u64]) -> Result<Vec<u64>> {
        let peak = self.measure_peak()?;
        Ok(self.budgets_from_peak(peak, pcts))
    }

    /// Single-rung convenience over [`Engine::headroom_budgets`].
    pub fn headroom_budget(&mut self, pct: u64) -> Result<u64> {
        Ok(self.headroom_budgets(&[pct])?[0])
    }

    pub fn total_params(&self) -> u64 {
        self.manifest.total_params
    }

    /// Parameter inventory (name, group, bytes) for reporting.
    pub fn param_inventory(&self) -> Vec<(String, String, u64)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.group.clone(), p.value.size_bytes()))
            .collect()
    }
}

fn constant(rt: &mut Runtime<ExecBackend>, v: HostTensor) -> TensorId {
    let size = v.size_bytes();
    let t = rt.constant(size);
    rt.backend_mut().put(t, v);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Heuristic;

    fn engine(opt: Optimizer) -> Engine {
        Engine::interp(ModelConfig::tiny(), dtr::Config::default(), opt).unwrap()
    }

    #[test]
    fn unbudgeted_step_runs_and_loss_near_ln_vocab() {
        let mut e = engine(Optimizer::Adam);
        let r = e.train_step().unwrap();
        let lnv = (e.cfg.vocab as f32).ln();
        assert!((r.loss - lnv).abs() < 1.0, "init loss {} vs ln(V) {}", r.loss, lnv);
        assert_eq!(r.stats.remat_count, 0);
        assert!(r.stats.peak_memory > 0);
        assert!(r.exec_count > 0);
    }

    #[test]
    fn loss_decreases_over_steps() {
        let mut e = engine(Optimizer::Adam);
        let first = e.train_step().unwrap().loss;
        let mut last = first;
        for _ in 0..5 {
            last = e.train_step().unwrap().loss;
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn budgeted_step_bitwise_matches_unbudgeted() {
        // Rematerialization replays identical pure ops on identical inputs,
        // so the loss trajectory must be bitwise equal. Walk the budget
        // ladder from loose to tight; every feasible rung must agree.
        let try_run = |budget: Option<u64>| -> Option<Vec<f32>> {
            let mut e = engine(Optimizer::Sgd);
            if let Some(b) = budget {
                e.dtr_cfg = dtr::Config {
                    budget: b,
                    heuristic: Heuristic::dtr_eq(),
                    ..dtr::Config::default()
                };
            }
            (0..3).map(|_| e.train_step().ok().map(|r| r.loss)).collect()
        };
        let base = try_run(None).expect("unbudgeted run cannot OOM");
        let rungs = engine(Optimizer::Sgd).headroom_budgets(&[85, 75, 65]).unwrap();
        let mut compared = false;
        for budget in rungs {
            if let Some(budgeted) = try_run(Some(budget)) {
                assert_eq!(base, budgeted, "budgeted training diverged at budget {budget}");
                compared = true;
            }
        }
        assert!(compared, "every budget rung OOMed");
    }

    #[test]
    fn budgeted_step_rematerializes() {
        // Descend a ladder of budgets until DTR both evicts and remats
        // (tighter budgets evict more; the looser rungs guard against the
        // ladder starting below the feasibility floor).
        let rungs = engine(Optimizer::Sgd).headroom_budgets(&[80, 70, 60, 50]).unwrap();
        let mut seen_evictions = false;
        for budget in rungs {
            let mut e = engine(Optimizer::Sgd);
            e.dtr_cfg = dtr::Config {
                budget,
                heuristic: Heuristic::dtr_eq(),
                ..dtr::Config::default()
            };
            let Ok(r) = e.train_step() else { continue };
            assert!(r.stats.peak_memory <= budget, "budget {budget} violated");
            seen_evictions |= r.stats.evict_count > 0;
            if r.stats.remat_count > 0 {
                return; // saw a real rematerialization under budget
            }
        }
        panic!("no rung of the budget ladder rematerialized (evictions seen: {seen_evictions})");
    }
}
