//! The real execution engine: one transformer-LM training step driven
//! through the DTR runtime via the public `dtr::api` surface, with buffers
//! owned by a pluggable [`Executor`].
//!
//! This is the rust analogue of the paper's PyTorch prototype: every
//! operator call is interposed by an [`crate::api::Session`], which tracks
//! metadata, evicts under the budget, and transparently re-executes the
//! parent operator when an evicted activation is needed again (Sec. 5).
//! Activations and gradients are RAII [`Tensor`] handles — dropping one is
//! the release event the deallocation policy consumes, so the step body
//! contains no manual id bookkeeping at all. The weight update runs inside
//! the step as `adam_*`/`sgd_*` ops; updated parameters are read back and
//! re-seeded as constants for the next step (the paper's output condition
//! explicitly permits stepping the optimizer at batch boundaries, Appendix
//! C.6).
//!
//! The engine is backend-agnostic: it speaks to compute exclusively through
//! the [`Executor`] trait (hermetic interpreter by default; PJRT behind the
//! `pjrt` feature; accounting-only `NullExecutor` for equivalence tests).
//! Memory is accounted logically over real buffer sizes, and per-op costs
//! come from a deterministic analytic model, so budgeted runs are exactly
//! reproducible and DTR's decisions are identical across backends.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::api::{OpContract, PinnedWeight, Session, SharedExecutor, Tensor, WeightStore};
use crate::dtr;
use crate::runtime::executor::{init_param, Executor, HostTensor};
use crate::runtime::{InterpExecutor, Manifest, ModelConfig};
use crate::util::rng::Rng;

/// Optimizer selection (both are manifest ops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    Adam,
    Sgd,
}

/// Result of one training step.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub loss: f32,
    pub stats: dtr::Stats,
    pub wall_ns: u64,
    /// Executor time within the step (operator compute).
    pub exec_ns: u64,
    pub exec_count: u64,
}

/// Persistent training state + per-step DTR-managed execution.
pub struct Engine {
    exec: SharedExecutor,
    /// Op/cost contract shared by every per-step session.
    contract: OpContract,
    pub manifest: Manifest,
    pub cfg: ModelConfig,
    pub dtr_cfg: dtr::Config,
    pub optimizer: Optimizer,
    /// name -> (tensor, param group) for every parameter tensor.
    params: Vec<ParamSlot>,
    /// Cross-shard weight store, when this engine shares its pinned
    /// parameters ([`Engine::attach_store`]).
    store: Option<Arc<WeightStore>>,
    /// One interned handle per parameter (same order as `params`); empty
    /// when no store is attached.
    pins: Vec<PinnedWeight>,
    step: u64,
    data_rng: Rng,
}

struct ParamSlot {
    name: String,
    /// Parameter group ("emb", "wqkv", ...) selecting the optimizer op.
    group: String,
    value: HostTensor,
    m: HostTensor,
    v: HostTensor,
}

impl Engine {
    /// Build an engine over any executor — the multi-backend seam.
    pub fn new(exec: Box<dyn Executor>, dtr_cfg: dtr::Config, optimizer: Optimizer) -> Result<Engine> {
        let manifest = exec.manifest().clone();
        let cfg = manifest.config;
        let exec: SharedExecutor = Arc::new(Mutex::new(exec));
        let contract = OpContract::of(&exec);
        let mut engine = Engine {
            exec,
            contract,
            manifest,
            cfg,
            dtr_cfg,
            optimizer,
            params: Vec::new(),
            store: None,
            pins: Vec::new(),
            step: 0,
            data_rng: Rng::new(0xDA7A),
        };
        engine.init_params(0x12AB);
        Ok(engine)
    }

    /// Hermetic engine over the pure-Rust interpreter (no artifacts, no
    /// external dependencies).
    pub fn interp(model: ModelConfig, dtr_cfg: dtr::Config, optimizer: Optimizer) -> Result<Engine> {
        Engine::new(Box::new(InterpExecutor::new(model)?), dtr_cfg, optimizer)
    }

    /// Hermetic engine with `threads` intra-op workers in the interpreter's
    /// kernel layer. Bit-identical to [`Engine::interp`] at any thread
    /// count (threads partition disjoint output rows; see
    /// `runtime/kernels`), so losses and DTR decision traces match exactly.
    pub fn interp_threaded(
        model: ModelConfig,
        threads: usize,
        dtr_cfg: dtr::Config,
        optimizer: Optimizer,
    ) -> Result<Engine> {
        let exec = InterpExecutor::new(model)?.with_threads(threads);
        Engine::new(Box::new(exec), dtr_cfg, optimizer)
    }

    /// Engine over AOT-compiled HLO artifacts through PJRT.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(
        artifacts_dir: &std::path::Path,
        dtr_cfg: dtr::Config,
        optimizer: Optimizer,
    ) -> Result<Engine> {
        let exec = crate::runtime::pjrt::PjrtExecutor::load(artifacts_dir)?;
        Engine::new(Box::new(exec), dtr_cfg, optimizer)
    }

    pub fn backend_name(&self) -> &'static str {
        self.exec.lock().expect("executor poisoned").name()
    }

    /// Share this engine's pinned parameters through a content-addressed
    /// [`WeightStore`]: every parameter buffer is interned, so engines with
    /// bit-identical weights (N serving tenants of one base model) hold one
    /// physical copy, charged to the store's ledger once per distinct
    /// buffer. Steps then register parameters via
    /// [`Session::constant_shared`], and each fine-tune update re-interns
    /// the new values (the old interns are released, refunding the ledger
    /// once the last sharer moves on).
    pub fn attach_store(&mut self, store: Arc<WeightStore>) {
        self.store = Some(store);
        self.reintern_pins();
    }

    /// Re-intern every parameter's current value (no-op without a store).
    /// New handles are taken before the old ones drop, so a buffer shared
    /// with other engines is never refunded-and-recharged across an update
    /// that leaves it unchanged.
    fn reintern_pins(&mut self) {
        if let Some(store) = &self.store {
            let fresh: Vec<PinnedWeight> =
                self.params.iter().map(|p| store.intern(p.value.clone())).collect();
            self.pins = fresh;
        }
    }

    /// Initialize parameters + optimizer state host-side (same scheme as
    /// python/compile/model.py init_params).
    fn init_params(&mut self, seed: u64) {
        let mut rng = Rng::new(seed);
        let shapes = self.manifest.param_shapes.clone();
        let mut slots: Vec<(String, String)> = vec![("emb".into(), "emb".into())];
        for l in 0..self.cfg.n_layers {
            for group in ["ln", "wqkv", "wo", "ln", "w1", "w2"] {
                let idx = slots.len();
                slots.push((format!("blk{l}_{group}_{idx}"), group.to_string()));
            }
        }
        slots.push(("w_out".into(), "w_out".into()));
        for (name, group) in slots {
            let shape = &shapes[&group];
            self.params.push(ParamSlot {
                name,
                group: group.clone(),
                value: init_param(&group, shape, &mut rng),
                m: HostTensor::zeros(shape),
                v: HostTensor::zeros(shape),
            });
        }
    }

    /// Synthetic LM batch: random tokens; target = fixed affine remap of the
    /// token (a learnable next-token rule, so the loss curve must descend).
    pub fn make_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let n = self.cfg.batch * self.cfg.seq;
        let v = self.cfg.vocab as u64;
        let tokens: Vec<i32> = (0..n).map(|_| (self.data_rng.below(v)) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((t as u64 * 31 + 7) % v) as i32).collect();
        (tokens, targets)
    }

    /// Bytes held by per-step constants (data batch, parameters, optimizer
    /// state, step counter) — DTR pins these, so any feasible budget must
    /// exceed this floor plus a working set.
    pub fn pinned_bytes(&self) -> u64 {
        let mut total = 2 * (self.cfg.batch * self.cfg.seq) as u64 * 4 + 4;
        for p in &self.params {
            total += p.value.size_bytes();
            if self.optimizer == Optimizer::Adam {
                total += p.m.size_bytes() + p.v.size_bytes();
            }
        }
        total
    }

    /// Run one full training step under DTR. A fresh session is built per
    /// step (parameters re-enter as constants), exactly matching the
    /// paper's per-batch logs; the arena therefore stays bounded. All
    /// tensor lifetimes are RAII handles: dropping a handle is the release
    /// event, so the step body cannot leak pins or double-release.
    pub fn train_step(&mut self) -> Result<StepResult> {
        let wall0 = Instant::now();
        self.step += 1;
        let (tokens, targets) = self.make_batch();
        let cfg = self.cfg;

        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);

        // --- constants: data + params + optimizer state ---
        let as_f32 = |xs: &[i32]| xs.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let tok = s.constant(HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&tokens)));
        let tgt = s.constant(HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&targets)));

        let mut param_ts: Vec<(Tensor, Option<Tensor>, Option<Tensor>)> =
            Vec::with_capacity(self.params.len());
        for (i, slot) in self.params.iter().enumerate() {
            // Shared (deduplicated) parameter buffers when a store is
            // attached; optimizer state stays private either way.
            let p = match self.pins.get(i) {
                Some(pin) => s.constant_shared(pin.arc()),
                None => s.constant(slot.value.clone()),
            };
            let (mm, vv) = if self.optimizer == Optimizer::Adam {
                (Some(s.constant(slot.m.clone())), Some(s.constant(slot.v.clone())))
            } else {
                (None, None)
            };
            param_ts.push((p, mm, vv));
        }
        let t_step = s.constant(HostTensor::scalar(self.step as f32));
        // Everything resident at this point is exactly the pinned constant
        // set; keep `pinned_bytes()` honest against the real inventory.
        debug_assert_eq!(
            s.memory(),
            self.pinned_bytes(),
            "pinned_bytes() drifted from the constants train_step registers"
        );

        // --- forward ---
        let mut acts: Vec<Tensor> = Vec::with_capacity(cfg.n_layers + 1); // x_0 .. x_N
        acts.push(s.call("embed_fwd", &[&tok, &param_ts[0].0])?.remove(0));
        for l in 0..cfg.n_layers {
            let y = {
                let mut ins: Vec<&Tensor> = vec![acts.last().unwrap()];
                for k in 0..6 {
                    ins.push(&param_ts[1 + l * 6 + k].0);
                }
                s.call("block_fwd", &ins)?.remove(0)
            };
            acts.push(y);
        }
        let w_out = &param_ts[self.params.len() - 1].0;
        let loss_t = s.call("loss_fwd", &[acts.last().unwrap(), w_out, &tgt])?.remove(0);
        // Read the loss while it is hot (re-reading after backward would
        // rematerialize loss_fwd and potentially its inputs).
        let loss = s.scalar(&loss_t)?;
        drop(loss_t);

        // --- backward ---
        let mut louts = s.call("loss_bwd", &[acts.last().unwrap(), w_out, &tgt])?.into_iter();
        let mut dx = louts.next().unwrap();
        let mut grads: Vec<(usize, Tensor)> =
            vec![(self.params.len() - 1, louts.next().unwrap())];
        // x_N (= acts[n_layers]) was consumed by loss fwd+bwd only.
        drop(acts.pop());

        for l in (0..cfg.n_layers).rev() {
            let outs = {
                let mut ins: Vec<&Tensor> = vec![acts.last().unwrap()];
                for k in 0..6 {
                    ins.push(&param_ts[1 + l * 6 + k].0);
                }
                ins.push(&dx);
                s.call("block_bwd", &ins)?
            };
            let mut outs = outs.into_iter();
            dx = outs.next().unwrap(); // reassignment releases the consumed upstream gradient
            for (k, g) in outs.enumerate() {
                grads.push((1 + l * 6 + k, g));
            }
            drop(acts.pop()); // x_l dead once block l's bwd is done
        }
        // Embedding gradient.
        let demb = s.call("embed_bwd", &[&tok, &dx])?.remove(0);
        drop(dx);
        grads.push((0, demb));

        // --- optimizer updates (inside DTR, as ops) ---
        // Perf (EXPERIMENTS.md §Perf, L3 iteration 1): read each updated
        // parameter back *immediately* after its optimizer op, while its
        // gradient input is still cheap to hold, then release everything.
        // Deferring the read-back to the end of the step let updated params
        // get evicted after their gradients were freed, so re-reading them
        // replayed entire backward chains (~2x whole-step recompute at 0.9
        // budget). Immediate decheckpointing is also what the paper's
        // prototype does for values the host consumes.
        for (pi, g) in grads {
            let group = self.params[pi].group.clone();
            match self.optimizer {
                Optimizer::Adam => {
                    let op = format!("adam_{group}");
                    let outs = {
                        let (p, mm, vv) = &param_ts[pi];
                        s.call(&op, &[p, &g, mm.as_ref().unwrap(), vv.as_ref().unwrap(), &t_step])?
                    };
                    self.params[pi].value = s.get(&outs[0])?;
                    self.params[pi].m = s.get(&outs[1])?;
                    self.params[pi].v = s.get(&outs[2])?;
                }
                Optimizer::Sgd => {
                    let op = format!("sgd_{group}");
                    let outs = s.call(&op, &[&param_ts[pi].0, &g])?;
                    self.params[pi].value = s.get(&outs[0])?;
                }
            }
            // `outs` then `g` drop here — the releases the manual
            // bookkeeping used to issue, in the same order.
        }

        s.check_invariants()?;
        // The updated parameters are this engine's weights from now on:
        // re-intern them so the shared store serves the *new* bytes to the
        // next step (and releases this engine's claim on the old ones).
        self.reintern_pins();

        Ok(StepResult {
            loss,
            stats: s.stats(),
            wall_ns: wall0.elapsed().as_nanos() as u64,
            exec_ns: s.exec_ns(),
            exec_count: s.exec_count(),
        })
    }

    /// One budgeted forward-only pass (serving): embed -> blocks -> loss
    /// on the next data batch, under the engine's DTR config/gate, with no
    /// backward or optimizer ops. Parameters stay untouched; the returned
    /// loss is the request's response payload. Activations are evictable
    /// like any other tensors, so tight budgets can rematerialize even a
    /// pure inference pass (the forward chain is still a DAG of pure ops).
    pub fn infer_step(&mut self) -> Result<f32> {
        let (tokens, targets) = self.make_batch();
        let cfg = self.cfg;
        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);

        let as_f32 = |xs: &[i32]| xs.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let tok = s.constant(HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&tokens)));
        let tgt = s.constant(HostTensor::new(vec![cfg.batch, cfg.seq], as_f32(&targets)));
        let param_ts: Vec<Tensor> = self
            .params
            .iter()
            .enumerate()
            .map(|(i, slot)| match self.pins.get(i) {
                Some(pin) => s.constant_shared(pin.arc()),
                None => s.constant(slot.value.clone()),
            })
            .collect();

        let mut x = s.call("embed_fwd", &[&tok, &param_ts[0]])?.remove(0);
        for l in 0..cfg.n_layers {
            let y = {
                let mut ins: Vec<&Tensor> = vec![&x];
                for k in 0..6 {
                    ins.push(&param_ts[1 + l * 6 + k]);
                }
                s.call("block_fwd", &ins)?.remove(0)
            };
            x = y; // reassignment releases x_{l}: forward-only keeps O(1) live activations
        }
        let w_out = &param_ts[self.params.len() - 1];
        let loss_t = s.call("loss_fwd", &[&x, w_out, &tgt])?.remove(0);
        let loss = s.scalar(&loss_t)?;
        s.check_invariants()?;
        Ok(loss)
    }

    /// `n` coalesced inference requests as **one** batched kernel
    /// invocation: their token batches are stacked into a `[n*batch, seq]`
    /// input, the forward runs through `batched_embed_fwd` /
    /// `batched_block_fwd` (the interpreter widens its per-sample kernels
    /// to the stacked batch, reading the single shared weight copy), and
    /// each request's loss is computed on its own row-slice.
    ///
    /// Consumes the same `n` data batches, in the same order, as `n`
    /// serial [`Engine::infer_step`] calls — and because every stacked
    /// kernel is per-sample (GEMM rows are independent accumulation
    /// chains, attention loops per (batch, head), layernorm per row), the
    /// returned losses are **bitwise equal** to the serial path
    /// (`tests/stress_dedup.rs`).
    pub fn infer_batch(&mut self, n: usize) -> Result<Vec<f32>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        if n == 1 {
            return Ok(vec![self.infer_step()?]);
        }
        let cfg = self.cfg;
        let (b, sq, d) = (cfg.batch, cfg.seq, cfg.d_model);
        // Same data-RNG stream as n serial infer_steps, consumed in order.
        let batches: Vec<(Vec<i32>, Vec<i32>)> = (0..n).map(|_| self.make_batch()).collect();
        let s =
            Session::with_contract(Arc::clone(&self.exec), self.dtr_cfg.clone(), &self.contract);

        let as_f32 = |xs: &[i32]| xs.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let mut stacked = Vec::with_capacity(n * b * sq);
        for (tokens, _) in &batches {
            stacked.extend(tokens.iter().map(|&x| x as f32));
        }
        let tok = s.constant(HostTensor::new(vec![n * b, sq], stacked));
        let tgts: Vec<Tensor> = batches
            .iter()
            .map(|(_, targets)| s.constant(HostTensor::new(vec![b, sq], as_f32(targets))))
            .collect();
        let param_ts: Vec<Tensor> = self
            .params
            .iter()
            .enumerate()
            .map(|(i, slot)| match self.pins.get(i) {
                Some(pin) => s.constant_shared(pin.arc()),
                None => s.constant(slot.value.clone()),
            })
            .collect();

        // Batched ops are shape-dynamic (the stacked batch is not in the
        // manifest), so they go through call_sized: the interpreter
        // derives the widened batch from the input shapes, and the cost
        // model charges n times the base op.
        let xbytes = (n * b * sq * d * 4) as u64;
        let mut x = s
            .call_sized(
                "batched_embed_fwd",
                n as u64 * s.op_cost("embed_fwd"),
                &[&tok, &param_ts[0]],
                &[xbytes],
            )?
            .remove(0);
        for l in 0..cfg.n_layers {
            let y = {
                let mut ins: Vec<&Tensor> = vec![&x];
                for k in 0..6 {
                    ins.push(&param_ts[1 + l * 6 + k]);
                }
                s.call_sized(
                    "batched_block_fwd",
                    n as u64 * s.op_cost("block_fwd"),
                    &ins,
                    &[xbytes],
                )?
                .remove(0)
            };
            x = y;
        }
        // Per-request losses: loss_fwd averages over its rows, so each
        // request's loss comes from its own sample-slice of the stacked
        // activations (bitwise what its serial forward would produce).
        let w_out = &param_ts[self.params.len() - 1];
        let slice_bytes = (b * sq * d * 4) as u64;
        let mut losses = Vec::with_capacity(n);
        for (i, tgt) in tgts.iter().enumerate() {
            let idx = s.constant(HostTensor::new(vec![2], vec![(i * b) as f32, b as f32]));
            let xi = s
                .call_sized("batched_slice_rows", 1, &[&x, &idx], &[slice_bytes])?
                .remove(0);
            let loss_t = s.call("loss_fwd", &[&xi, w_out, tgt])?.remove(0);
            losses.push(s.scalar(&loss_t)?);
        }
        s.check_invariants()?;
        Ok(losses)
    }

    /// Measure the unbudgeted peak memory of one step (for ratio budgets).
    /// Runs on a throwaway clone of the parameter state.
    pub fn measure_peak(&mut self) -> Result<u64> {
        let saved_cfg = self.dtr_cfg.clone();
        let saved_step = self.step;
        let saved_rng = self.data_rng.clone();
        let saved_params: Vec<(HostTensor, HostTensor, HostTensor)> = self
            .params
            .iter()
            .map(|p| (p.value.clone(), p.m.clone(), p.v.clone()))
            .collect();
        self.dtr_cfg = self.dtr_cfg.unbudgeted();
        let peak = self.train_step()?.stats.peak_memory;
        // Restore.
        self.dtr_cfg = saved_cfg;
        self.step = saved_step;
        self.data_rng = saved_rng;
        for (slot, (v, m, vv)) in self.params.iter_mut().zip(saved_params) {
            slot.value = v;
            slot.m = m;
            slot.v = vv;
        }
        // The throwaway step re-interned the post-step weights; point the
        // shared store back at the restored ones.
        self.reintern_pins();
        Ok(peak)
    }

    /// Budgets at `pct`% of the non-pinned headroom above the pinned floor
    /// (`pinned + (peak - pinned) * pct / 100`) from an already-measured
    /// unbudgeted peak — the canonical budget formula for tests and benches
    /// (ratios of raw peak are dominated by the pinned parameter footprint
    /// on small models).
    pub fn budgets_from_peak(&self, peak: u64, pcts: &[u64]) -> Vec<u64> {
        let pinned = self.pinned_bytes();
        pcts.iter().map(|&p| pinned + peak.saturating_sub(pinned) * p / 100).collect()
    }

    /// [`Engine::budgets_from_peak`] including the peak measurement (one
    /// unbudgeted training step).
    pub fn headroom_budgets(&mut self, pcts: &[u64]) -> Result<Vec<u64>> {
        let peak = self.measure_peak()?;
        Ok(self.budgets_from_peak(peak, pcts))
    }

    /// Single-rung convenience over [`Engine::headroom_budgets`].
    pub fn headroom_budget(&mut self, pct: u64) -> Result<u64> {
        Ok(self.headroom_budgets(&[pct])?[0])
    }

    pub fn total_params(&self) -> u64 {
        self.manifest.total_params
    }

    /// Parameter inventory (name, group, bytes) for reporting.
    pub fn param_inventory(&self) -> Vec<(String, String, u64)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.group.clone(), p.value.size_bytes()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Heuristic;

    fn engine(opt: Optimizer) -> Engine {
        Engine::interp(ModelConfig::tiny(), dtr::Config::default(), opt).unwrap()
    }

    #[test]
    fn unbudgeted_step_runs_and_loss_near_ln_vocab() {
        let mut e = engine(Optimizer::Adam);
        let r = e.train_step().unwrap();
        let lnv = (e.cfg.vocab as f32).ln();
        assert!((r.loss - lnv).abs() < 1.0, "init loss {} vs ln(V) {}", r.loss, lnv);
        assert_eq!(r.stats.remat_count, 0);
        assert!(r.stats.peak_memory > 0);
        assert!(r.exec_count > 0);
    }

    #[test]
    fn loss_decreases_over_steps() {
        let mut e = engine(Optimizer::Adam);
        let first = e.train_step().unwrap().loss;
        let mut last = first;
        for _ in 0..5 {
            last = e.train_step().unwrap().loss;
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn budgeted_step_bitwise_matches_unbudgeted() {
        // Rematerialization replays identical pure ops on identical inputs,
        // so the loss trajectory must be bitwise equal. Walk the budget
        // ladder from loose to tight; every feasible rung must agree.
        let try_run = |budget: Option<u64>| -> Option<Vec<f32>> {
            let mut e = engine(Optimizer::Sgd);
            if let Some(b) = budget {
                e.dtr_cfg = dtr::Config {
                    budget: b,
                    heuristic: Heuristic::dtr_eq(),
                    ..dtr::Config::default()
                };
            }
            (0..3).map(|_| e.train_step().ok().map(|r| r.loss)).collect()
        };
        let base = try_run(None).expect("unbudgeted run cannot OOM");
        let rungs = engine(Optimizer::Sgd).headroom_budgets(&[85, 75, 65]).unwrap();
        let mut compared = false;
        for budget in rungs {
            if let Some(budgeted) = try_run(Some(budget)) {
                assert_eq!(base, budgeted, "budgeted training diverged at budget {budget}");
                compared = true;
            }
        }
        assert!(compared, "every budget rung OOMed");
    }

    #[test]
    fn budgeted_step_rematerializes() {
        // Descend a ladder of budgets until DTR both evicts and remats
        // (tighter budgets evict more; the looser rungs guard against the
        // ladder starting below the feasibility floor).
        let rungs = engine(Optimizer::Sgd).headroom_budgets(&[80, 70, 60, 50]).unwrap();
        let mut seen_evictions = false;
        for budget in rungs {
            let mut e = engine(Optimizer::Sgd);
            e.dtr_cfg = dtr::Config {
                budget,
                heuristic: Heuristic::dtr_eq(),
                ..dtr::Config::default()
            };
            let Ok(r) = e.train_step() else { continue };
            assert!(r.stats.peak_memory <= budget, "budget {budget} violated");
            seen_evictions |= r.stats.evict_count > 0;
            if r.stats.remat_count > 0 {
                return; // saw a real rematerialization under budget
            }
        }
        panic!("no rung of the budget ladder rematerialized (evictions seen: {seen_evictions})");
    }
}
