//! Real execution: the DTR-managed training engine over PJRT artifacts.

pub mod engine;

pub use engine::{Engine, Optimizer, PjrtBackend, StepResult};
