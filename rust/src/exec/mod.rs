//! Real execution: the DTR-managed training engine over a pluggable
//! [`crate::runtime::Executor`] backend.

pub mod engine;

pub use engine::{Engine, ExecBackend, Optimizer, SharedExecutor, StepResult};
