//! Real execution: DTR-managed training over a pluggable
//! [`crate::runtime::Executor`] backend — the static transformer engine and
//! the dynamic (LSTM / TreeLSTM) trainers, all driven through the
//! `dtr::api` session surface.

pub mod dynamic;
pub mod engine;

// `ExecBackend`/`SharedExecutor` live in `dtr::api` (they are the
// interposition machinery); re-exported here for continuity.
pub use crate::api::{ExecBackend, SharedExecutor};
pub use dynamic::{DynStepResult, LstmTrainer, TreeLstmTrainer};
pub use engine::{Engine, Optimizer, StepResult};
