//! Formal-bounds experiments: Fig. 5 (memory-trace visualization of the
//! Appendix-A execution), the Theorem 3.1 O(N) sweep, and the Theorem 3.2 /
//! Fig. 6 adversarial lower bound.

use anyhow::Result;

use crate::dtr::Heuristic;
use crate::graphs::adversarial::run_adversary;
use crate::graphs::linear::{run_linear, theorem_budget, Cell};
use crate::util::csv::{f, CsvOut};

/// Fig. 5: emit the residency matrix for N nodes at B = 2⌈√N⌉ under h_{e*}.
/// One row per operator execution; cells are 0 (absent), 1 (forward), 1.5
/// (gradient) exactly as the paper's color coding.
pub fn fig5(out: &mut CsvOut, n: usize) -> Result<()> {
    let run = run_linear(n, theorem_budget(n), Heuristic::EStarCount, true)?;
    let header: Vec<String> = (1..=n).map(|i| format!("t{i}")).collect();
    out.row(&header)?;
    for row in &run.trace {
        let cells: Vec<String> = row
            .iter()
            .map(|c| match c {
                Cell::Absent => "0".to_string(),
                Cell::Fwd => "1".to_string(),
                Cell::Grad => "1.5".to_string(),
            })
            .collect();
        out.row(&cells)?;
    }
    println!(
        "# fig5: N={n} B={} total_ops={} (2N={})",
        theorem_budget(n),
        run.total_ops,
        2 * n
    );
    Ok(())
}

/// Theorem 3.1: total ops at B = 2⌈√N⌉ must stay within a constant factor
/// of 2N as N grows.
pub fn thm31(out: &mut CsvOut, ns: &[usize]) -> Result<()> {
    out.row(&["n", "budget", "total_ops", "ops_over_2n"])?;
    for &n in ns {
        let b = theorem_budget(n);
        let run = run_linear(n, b, Heuristic::EStarCount, false)?;
        out.row(&[
            n.to_string(),
            b.to_string(),
            run.total_ops.to_string(),
            f(run.total_ops as f64 / (2 * n) as f64),
        ])?;
    }
    Ok(())
}

/// Theorem 3.2 / Fig. 6: the adversary forces Ω(N/B) overhead for every
/// deterministic heuristic, while the optimal static plan stays at N.
pub fn thm32(out: &mut CsvOut, ns: &[usize], b: usize) -> Result<()> {
    out.row(&["heuristic", "n", "b", "dtr_ops", "static_ops", "ratio", "n_over_b"])?;
    for h in [
        Heuristic::dtr(),
        Heuristic::dtr_eq(),
        Heuristic::dtr_local(),
        Heuristic::lru(),
        Heuristic::size(),
        Heuristic::Msps,
    ] {
        for &n in ns {
            let r = run_adversary(n, b, h)?;
            out.row(&[
                h.name(),
                n.to_string(),
                b.to_string(),
                r.dtr_ops.to_string(),
                r.static_ops.to_string(),
                f(r.ratio()),
                f(n as f64 / b as f64),
            ])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::CsvOut;

    #[test]
    fn thm31_factor_bounded() {
        let mut out = CsvOut::create(None, false).unwrap();
        thm31(&mut out, &[64, 256, 1024]).unwrap();
        // Assertions live in graphs::linear tests; here just exercise IO.
    }

    #[test]
    fn fig5_emits_2n_rows() {
        let path = std::env::temp_dir().join("dtr_fig5_test.csv");
        let mut out = CsvOut::create(Some(&path), false).unwrap();
        fig5(&mut out, 50).unwrap();
        drop(out);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2 * 50 + 1);
    }

    #[test]
    fn thm32_ratio_scales_with_n_over_b() {
        let path = std::env::temp_dir().join("dtr_thm32_test.csv");
        let mut out = CsvOut::create(Some(&path), false).unwrap();
        thm32(&mut out, &[64, 256], 8).unwrap();
    }
}
