//! `dtr-repro serve` — the multi-tenant serving scenario: N concurrent
//! tenants (transformer + dynamic LSTM/TreeLSTM mix) train on worker
//! threads under **one** global byte budget, arbitrated per
//! `TrainConfig::arbiter` (static-split vs global-reclaim). Emits one CSV
//! row per tenant plus an aggregate row per run: steps/sec, remat overhead
//! (slowdown), evictions, and probe-loss descent for the dynamic tenants.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::dtr;
use crate::serve::{fleet_budget, run_tenants, ArbiterPolicy, ServePool, TenantSpec};
use crate::util::csv::{f, CsvOut};

/// Run the serving scenario from the coordinator config: `tenants`,
/// `arbiter`, `steps`, `budget_ratio` (fraction of each tenant's non-pinned
/// headroom, summed into the global budget; `None` = 1.0), and the DTR
/// knobs (heuristic, policy, index).
pub fn default_run(out: &mut CsvOut, tc: &TrainConfig, policies: &[ArbiterPolicy]) -> Result<()> {
    let specs = TenantSpec::fleet(tc.tenants.max(1));
    // fleet_budget validates pct ∈ 1..=100, so clamp the ratio into (0, 1].
    let pct = (tc.budget_ratio.unwrap_or(1.0).clamp(0.01, 1.0) * 100.0) as u64;
    let budget = fleet_budget(&specs, pct)?;
    let base = dtr::Config {
        heuristic: tc.heuristic,
        policy: tc.policy,
        index: tc.index,
        auto_crossover: tc.auto_crossover,
        ..dtr::Config::default()
    };
    out.row(&[
        "arbiter",
        "tenant",
        "kind",
        "steps",
        "completed",
        "steps_per_sec",
        "slowdown",
        "evictions",
        "remats",
        "peak_bytes",
        "budget_bytes",
        "probe_before",
        "probe_after",
        "error",
    ])?;
    for &policy in policies {
        let pool = ServePool::new(budget, policy, specs.len())
            .with_dedup(tc.dedup)
            .with_global_index(tc.global_index);
        let reports = run_tenants(&pool, &specs, &base, tc.steps)?;
        pool.check_invariants()?;
        let mut agg_steps = 0usize;
        let mut agg_sps = 0.0f64;
        let mut agg_base = 0u64;
        let mut agg_remat = 0u64;
        let mut agg_evict = 0u64;
        for (i, r) in reports.iter().enumerate() {
            agg_steps += r.completed;
            agg_sps += r.steps_per_sec();
            agg_base += r.stats.base_compute;
            agg_remat += r.stats.remat_compute;
            agg_evict += r.stats.evict_count;
            out.row(&[
                policy.name().to_string(),
                i.to_string(),
                r.kind.to_string(),
                r.steps.to_string(),
                r.completed.to_string(),
                f(r.steps_per_sec()),
                f(r.stats.slowdown()),
                r.stats.evict_count.to_string(),
                r.stats.remat_count.to_string(),
                r.stats.peak_memory.to_string(),
                budget.to_string(),
                r.probe_before.map(|v| f(v as f64)).unwrap_or_default(),
                r.probe_after.map(|v| f(v as f64)).unwrap_or_default(),
                r.error.clone().unwrap_or_default(),
            ])?;
        }
        let agg_slowdown = if agg_base == 0 {
            1.0
        } else {
            (agg_base + agg_remat) as f64 / agg_base as f64
        };
        out.row(&[
            policy.name().to_string(),
            "all".to_string(),
            "aggregate".to_string(),
            (tc.steps * specs.len()).to_string(),
            agg_steps.to_string(),
            f(agg_sps),
            f(agg_slowdown),
            agg_evict.to_string(),
            String::new(),
            String::new(),
            budget.to_string(),
            String::new(),
            String::new(),
            String::new(),
        ])?;
    }
    Ok(())
}
