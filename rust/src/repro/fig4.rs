//! Figure 4: prototype runtime-overhead profile on the *real* engine —
//! per-batch wall time decomposed into operator compute, heuristic score
//! evaluation ("cost compute"), victim search ("eviction loop"), and
//! unprofiled remainder, across memory budgets. Hermetic on the interpreter
//! backend (default); `--backend pjrt` profiles compiled artifacts instead,
//! and `--dynamic` profiles the dynamic-LSTM workload.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::dtr::{self, Heuristic};
use crate::exec::dynamic::{headroom_budget, LstmTrainer};
use crate::exec::{Engine, Optimizer};
use crate::runtime::RnnConfig;
use crate::util::csv::{f, CsvOut};

pub struct Fig4Row {
    pub ratio: f64,
    pub wall_ms: f64,
    pub op_ms: f64,
    pub cost_compute_ms: f64,
    pub eviction_search_ms: f64,
    pub unprofiled_ms: f64,
    pub remats: u64,
    pub failed: bool,
}

/// Accumulate one profiled row from `steps` invocations of a step closure
/// returning `(wall_ns, exec_ns, cost_compute_ns, eviction_loop_ns,
/// remat_count)` — shared by the static-transformer and dynamic-LSTM
/// sweeps so the decomposition cannot drift between them.
fn profile_row(
    ratio: f64,
    steps: usize,
    mut step: impl FnMut() -> Result<(u64, u64, u64, u64, u64)>,
) -> Fig4Row {
    let mut wall = 0u64;
    let mut op = 0u64;
    let mut cost = 0u64;
    let mut search = 0u64;
    let mut remats = 0u64;
    let mut failed = false;
    for _ in 0..steps {
        match step() {
            Ok((w, o, c, eviction_loop, r)) => {
                wall += w;
                op += o;
                cost += c;
                search += eviction_loop - c;
                remats += r;
            }
            Err(_) => {
                failed = true;
                break;
            }
        }
    }
    let n = steps as f64;
    Fig4Row {
        ratio,
        wall_ms: wall as f64 / 1e6 / n,
        op_ms: op as f64 / 1e6 / n,
        cost_compute_ms: cost as f64 / 1e6 / n,
        eviction_search_ms: search as f64 / 1e6 / n,
        unprofiled_ms: (wall.saturating_sub(op + cost + search)) as f64 / 1e6 / n,
        remats: remats / steps as u64,
        failed,
    }
}

/// `ratios` are fractions of the non-pinned headroom above the pinned
/// parameter floor (1.0 = the unbudgeted peak). Raw-peak ratios would sit
/// mostly below the feasibility floor on small models, where pinned
/// parameters dominate, and the sweep would degenerate to OOM rows.
pub fn run(tc: &TrainConfig, ratios: &[f64], steps: usize, h: Heuristic) -> Result<Vec<Fig4Row>> {
    let base_cfg = dtr::Config { heuristic: h, profile: true, ..dtr::Config::default() };
    let mut engine = Engine::new(tc.build_executor()?, base_cfg.clone(), Optimizer::Sgd)?;
    let peak = engine.measure_peak()?;
    let mut rows = Vec::new();
    for &ratio in ratios {
        let budget = engine.budgets_from_peak(peak, &[(ratio * 100.0).round() as u64])[0];
        engine.dtr_cfg = dtr::Config { budget, ..base_cfg.clone() };
        rows.push(profile_row(ratio, steps, || {
            engine.train_step().map(|r| {
                (
                    r.wall_ns,
                    r.exec_ns,
                    r.stats.cost_compute_ns,
                    r.stats.eviction_loop_ns,
                    r.stats.remat_count,
                )
            })
        }));
    }
    Ok(rows)
}

/// Fig. 4 over the *dynamic* LSTM workload (`dtr-repro fig4 --dynamic`):
/// the same overhead decomposition, but with the per-batch sequence length
/// drawn at run time — the paper's point that DTR's overhead story covers
/// workloads no static planner can even schedule. Ratios are fractions of
/// the headroom between the dynamic envelope's pinned floor and its
/// unbudgeted peak (both measured over a dry run of the step stream).
pub fn run_dynamic(ratios: &[f64], steps: usize, h: Heuristic) -> Result<Vec<Fig4Row>> {
    let base_cfg = dtr::Config { heuristic: h, profile: true, ..dtr::Config::default() };
    let rnn = RnnConfig::small();
    let mut probe = LstmTrainer::interp(rnn, base_cfg.clone())?;
    probe.min_len = 8;
    probe.max_len = 24;
    let (peak, floor) = probe.measure_envelope(steps.max(3))?;

    let mut rows = Vec::new();
    for &ratio in ratios {
        let budget = headroom_budget(peak, floor, (ratio * 100.0).round() as u64);
        let mut tr = LstmTrainer::interp(rnn, dtr::Config { budget, ..base_cfg.clone() })?;
        tr.min_len = 8;
        tr.max_len = 24;
        rows.push(profile_row(ratio, steps, || {
            tr.train_step().map(|r| {
                (
                    r.wall_ns,
                    r.exec_ns,
                    r.stats.cost_compute_ns,
                    r.stats.eviction_loop_ns,
                    r.stats.remat_count,
                )
            })
        }));
    }
    Ok(rows)
}

pub fn emit(out: &mut CsvOut, rows: &[Fig4Row]) -> Result<()> {
    out.row(&[
        "headroom_ratio",
        "wall_ms",
        "operator_ms",
        "cost_compute_ms",
        "eviction_loop_ms",
        "unprofiled_ms",
        "remats_per_step",
        "status",
    ])?;
    for r in rows {
        out.row(&[
            f(r.ratio),
            f(r.wall_ms),
            f(r.op_ms),
            f(r.cost_compute_ms),
            f(r.eviction_search_ms),
            f(r.unprofiled_ms),
            r.remats.to_string(),
            if r.failed { "oom".into() } else { "ok".to_string() },
        ])?;
    }
    Ok(())
}

pub fn default_run(out: &mut CsvOut, tc: &TrainConfig, steps: usize) -> Result<()> {
    let ratios = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4];
    let rows = run(tc, &ratios, steps, Heuristic::dtr_eq())?;
    emit(out, &rows)
}

pub fn default_run_dynamic(out: &mut CsvOut, steps: usize) -> Result<()> {
    let ratios = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4];
    let rows = run_dynamic(&ratios, steps, Heuristic::dtr_eq())?;
    emit(out, &rows)
}
