//! Figure 4: prototype runtime-overhead profile on the *real* engine —
//! per-batch wall time decomposed into operator compute, heuristic score
//! evaluation ("cost compute"), victim search ("eviction loop"), and
//! unprofiled remainder, across memory budgets. Hermetic on the interpreter
//! backend (default); `--backend pjrt` profiles compiled artifacts instead.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::dtr::{self, Heuristic};
use crate::exec::{Engine, Optimizer};
use crate::util::csv::{f, CsvOut};

pub struct Fig4Row {
    pub ratio: f64,
    pub wall_ms: f64,
    pub op_ms: f64,
    pub cost_compute_ms: f64,
    pub eviction_search_ms: f64,
    pub unprofiled_ms: f64,
    pub remats: u64,
    pub failed: bool,
}

/// `ratios` are fractions of the non-pinned headroom above the pinned
/// parameter floor (1.0 = the unbudgeted peak). Raw-peak ratios would sit
/// mostly below the feasibility floor on small models, where pinned
/// parameters dominate, and the sweep would degenerate to OOM rows.
pub fn run(tc: &TrainConfig, ratios: &[f64], steps: usize, h: Heuristic) -> Result<Vec<Fig4Row>> {
    let base_cfg = dtr::Config { heuristic: h, profile: true, ..dtr::Config::default() };
    let mut engine = Engine::new(tc.build_executor()?, base_cfg.clone(), Optimizer::Sgd)?;
    let peak = engine.measure_peak()?;
    let mut rows = Vec::new();
    for &ratio in ratios {
        let budget = engine.budgets_from_peak(peak, &[(ratio * 100.0).round() as u64])[0];
        engine.dtr_cfg = dtr::Config { budget, ..base_cfg.clone() };
        let mut wall = 0u64;
        let mut op = 0u64;
        let mut cost = 0u64;
        let mut search = 0u64;
        let mut remats = 0u64;
        let mut failed = false;
        for _ in 0..steps {
            match engine.train_step() {
                Ok(r) => {
                    wall += r.wall_ns;
                    op += r.exec_ns;
                    cost += r.stats.cost_compute_ns;
                    search += r.stats.eviction_loop_ns - r.stats.cost_compute_ns;
                    remats += r.stats.remat_count;
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        let n = steps as f64;
        rows.push(Fig4Row {
            ratio,
            wall_ms: wall as f64 / 1e6 / n,
            op_ms: op as f64 / 1e6 / n,
            cost_compute_ms: cost as f64 / 1e6 / n,
            eviction_search_ms: search as f64 / 1e6 / n,
            unprofiled_ms: (wall.saturating_sub(op + cost + search)) as f64 / 1e6 / n,
            remats: remats / steps as u64,
            failed,
        });
    }
    Ok(rows)
}

pub fn emit(out: &mut CsvOut, rows: &[Fig4Row]) -> Result<()> {
    out.row(&[
        "headroom_ratio",
        "wall_ms",
        "operator_ms",
        "cost_compute_ms",
        "eviction_loop_ms",
        "unprofiled_ms",
        "remats_per_step",
        "status",
    ])?;
    for r in rows {
        out.row(&[
            f(r.ratio),
            f(r.wall_ms),
            f(r.op_ms),
            f(r.cost_compute_ms),
            f(r.eviction_search_ms),
            f(r.unprofiled_ms),
            r.remats.to_string(),
            if r.failed { "oom".into() } else { "ok".to_string() },
        ])?;
    }
    Ok(())
}

pub fn default_run(out: &mut CsvOut, tc: &TrainConfig, steps: usize) -> Result<()> {
    let ratios = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4];
    let rows = run(tc, &ratios, steps, Heuristic::dtr_eq())?;
    emit(out, &rows)
}
