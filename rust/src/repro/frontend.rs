//! `dtr-repro frontend` — the request front-end scenario: bursty
//! open-loop clients (one stream per tenant class) submit inference /
//! fine-tune / probe requests through the bounded-queue scheduler onto
//! shard workers under **one** arbitrated global budget. Emits one CSV
//! row per tenant class plus an aggregate row per arbiter policy:
//! submitted/completed/rejected/failed counts, requests/sec, p50/p95/p99
//! latency, and mean batch size.

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::dtr;
use crate::frontend::{frontend_budget, serve_bursty, ClassMetrics, FrontendConfig};
use crate::serve::{ArbiterPolicy, ServePool};
use crate::util::csv::{f, CsvOut};

/// Requests submitted per class (per policy run).
const PER_CLASS: usize = 24;
const SEED: u64 = 0xF0_11;

fn ns_to_ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

fn metrics_row(
    out: &mut CsvOut,
    policy: ArbiterPolicy,
    label: &str,
    m: &ClassMetrics,
) -> Result<()> {
    out.row(&[
        policy.name().to_string(),
        label.to_string(),
        m.kind.to_string(),
        m.submitted.to_string(),
        m.completed.to_string(),
        m.rejected.to_string(),
        m.failed.to_string(),
        f(m.requests_per_sec),
        f(ns_to_ms(m.p50_ns)),
        f(ns_to_ms(m.p95_ns)),
        f(ns_to_ms(m.p99_ns)),
        f(ns_to_ms(m.max_ns)),
        f(m.mean_batch),
    ])?;
    Ok(())
}

/// Run the front-end scenario from the coordinator config: `tenants`
/// (class count), `queue_cap`, `budget_ratio` (fraction of summed shard
/// headroom), and the DTR knobs. One run per arbiter policy.
pub fn default_run(out: &mut CsvOut, tc: &TrainConfig, policies: &[ArbiterPolicy]) -> Result<()> {
    let mut cfg = FrontendConfig::mixed(tc.tenants.max(1));
    cfg.queue_cap = tc.queue_cap;
    cfg.coalesce = tc.coalesce;
    let pct = (tc.budget_ratio.unwrap_or(1.0).clamp(0.01, 1.0) * 100.0) as u64;
    let budget = frontend_budget(&cfg.classes, pct)?;
    let base = dtr::Config {
        heuristic: tc.heuristic,
        policy: tc.policy,
        index: tc.index,
        auto_crossover: tc.auto_crossover,
        ..dtr::Config::default()
    };
    out.row(&[
        "arbiter",
        "class",
        "kind",
        "submitted",
        "completed",
        "rejected",
        "failed",
        "requests_per_sec",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "max_ms",
        "mean_batch",
    ])?;
    for &policy in policies {
        let shards: usize = cfg.classes.iter().map(|c| c.shards.max(1)).sum();
        let pool = ServePool::new(budget, policy, shards)
            .with_dedup(tc.dedup)
            .with_global_index(tc.global_index);
        let report = serve_bursty(&pool, &cfg, &base, PER_CLASS, SEED)?;
        for (ci, m) in report.classes.iter().enumerate() {
            metrics_row(out, policy, &ci.to_string(), m)?;
        }
        metrics_row(out, policy, "all", &report.total)?;
        for e in &report.errors {
            eprintln!("frontend worker error ({}): {e}", policy.name());
        }
    }
    Ok(())
}
