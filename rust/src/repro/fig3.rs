//! Figure 3: DTR vs static checkpointing (Checkmate-optimal = Revolve DP on
//! chains, Chen √N, Chen greedy) — total operator executions vs memory
//! budget on linear networks, the setting where every comparator is exactly
//! defined. DTR runs the real runtime (`run_linear`); baselines are
//! analytic/DP (DESIGN.md §5).

use anyhow::Result;

use crate::baselines::{chen_greedy, chen_sqrt, Revolve};
use crate::dtr::Heuristic;
use crate::graphs::linear::run_linear;
use crate::util::csv::{f, CsvOut};

pub struct Fig3Row {
    pub n: usize,
    pub budget: u64,
    pub scheme: String,
    pub ops: Option<u64>,
}

pub fn run(n: usize, budgets: &[u64]) -> Result<Vec<Fig3Row>> {
    let mut rows = Vec::new();
    let mut revolve = Revolve::new(n, n);
    for &b in budgets {
        // Optimal (Checkmate-equivalent on chains).
        rows.push(Fig3Row {
            n,
            budget: b,
            scheme: "checkmate_optimal".into(),
            ops: revolve.total_ops(n, b),
        });
        rows.push(Fig3Row {
            n,
            budget: b,
            scheme: "chen_sqrt".into(),
            ops: chen_sqrt(n, b).map(|(ops, _)| ops),
        });
        rows.push(Fig3Row {
            n,
            budget: b,
            scheme: "chen_greedy".into(),
            ops: chen_greedy(n, b).map(|(ops, _)| ops),
        });
        for h in [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::lru()] {
            let ops = run_linear(n, b, h, false).ok().map(|r| r.total_ops);
            rows.push(Fig3Row {
                n,
                budget: b,
                scheme: format!("dtr_{}", h.name()),
                ops,
            });
        }
    }
    Ok(rows)
}

pub fn emit(out: &mut CsvOut, rows: &[Fig3Row]) -> Result<()> {
    out.row(&["n", "budget", "scheme", "total_ops", "overhead_vs_2n"])?;
    for r in rows {
        let (ops, overhead) = match r.ops {
            Some(o) => (o.to_string(), f(o as f64 / (2.0 * r.n as f64))),
            None => ("oom".to_string(), "oom".to_string()),
        };
        out.row(&[r.n.to_string(), r.budget.to_string(), r.scheme.clone(), ops, overhead])?;
    }
    Ok(())
}

pub fn default_run(out: &mut CsvOut, n: usize) -> Result<()> {
    let sqrt_n = (n as f64).sqrt().ceil() as u64;
    let budgets: Vec<u64> = [
        sqrt_n,
        sqrt_n * 3 / 2,
        2 * sqrt_n,
        3 * sqrt_n,
        4 * sqrt_n,
        6 * sqrt_n,
        8 * sqrt_n,
        (n as u64) / 2,
        n as u64 + 3,
    ]
    .into_iter()
    .filter(|&b| b >= 4)
    .collect();
    let rows = run(n, &budgets)?;
    emit(out, &rows)?;
    // Headline check: DTR h_dtr within a small factor of optimal.
    println!("\n# DTR/optimal overhead ratio by budget:");
    for &b in &budgets {
        let get = |s: &str| {
            rows.iter()
                .find(|r| r.budget == b && r.scheme == s)
                .and_then(|r| r.ops)
        };
        if let (Some(d), Some(o)) = (get("dtr_h_dtr"), get("checkmate_optimal")) {
            println!("  b={b:<5} dtr={d:<8} optimal={o:<8} ratio={:.3}", d as f64 / o as f64);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtr_close_to_optimal_on_chains() {
        // The paper's Fig. 3 claim: DTR's overhead is competitive with the
        // ILP optimum. Check h_dtr stays within 1.6x of optimal ops at
        // moderate budgets.
        let n = 256;
        let budgets = [48u64, 64, 96, 128];
        let rows = run(n, &budgets).unwrap();
        for &b in &budgets {
            let get = |s: &str| {
                rows.iter().find(|r| r.budget == b && r.scheme == s).and_then(|r| r.ops)
            };
            let dtr = get("dtr_h_dtr").expect("dtr feasible") as f64;
            let opt = get("checkmate_optimal").expect("optimal feasible") as f64;
            assert!(
                dtr <= opt * 1.6 + 8.0,
                "b={b}: dtr {dtr} not close to optimal {opt}"
            );
        }
    }

    #[test]
    fn dtr_beats_or_matches_chen_at_low_budgets() {
        let n = 256;
        let rows = run(n, &[40, 64]).unwrap();
        for &b in &[40u64, 64] {
            let get = |s: &str| {
                rows.iter().find(|r| r.budget == b && r.scheme == s).and_then(|r| r.ops)
            };
            if let (Some(d), Some(c)) = (get("dtr_h_dtr"), get("chen_sqrt")) {
                assert!(d <= c * 13 / 10, "b={b}: dtr {d} much worse than chen {c}");
            }
        }
    }
}
