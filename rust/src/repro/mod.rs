//! Experiment harnesses: one subcommand per paper table/figure
//! (DESIGN.md §4), plus `train` (the coordinator) and `gen-log` utilities.

pub mod ablation;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod formal;
pub mod frontend;
pub mod serve;
pub mod tables;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::{self, TrainConfig};
use crate::graphs::models::{by_name, ALL_MODELS};
use crate::util::cli::Args;
use crate::util::csv::CsvOut;

const USAGE: &str = "\
dtr-repro — Dynamic Tensor Rematerialization (ICLR 2021) reproduction

USAGE: dtr-repro <command> [--out results/x.csv] [options]

experiment commands (regenerate paper tables/figures):
  fig2       heuristic comparison: slowdown vs budget ratio, 8 models
             [--models a,b --ratios 0.1,..,1.0 --scale 1]
  fig3       DTR vs static checkpointing on linear networks [--n 512]
  fig4       real-engine runtime overhead profile [--steps 3]
             [--backend interp|pjrt --artifacts artifacts]
             [--dynamic: profile the dynamic-LSTM workload instead]
  table1     largest supported input size, baseline vs DTR
  fig5       memory-trace visualization (N=200, B=2*sqrt(N), h_e*) [--n 200]
  thm31      Theorem 3.1 O(N) sweep [--ns 64,256,1024,4096]
  thm32      Theorem 3.2 adversarial lower bound [--ns 64,128,256,512 --b 8]
  ablation   Appendix D.1 s*m*c heuristic grid (Figs. 7-10)
  fig11      deallocation-policy comparison (ignore/eager/banish)
  fig12      metadata-access overhead per heuristic

system commands:
  serve      multi-tenant serving: N tenants (transformer + LSTM/TreeLSTM
             mix) on worker threads under ONE global budget
             [--tenants 4 --arbiter static|global (default: both policies)
              --steps 10 --budget-ratio 0.6 --heuristic h_dtr_eq
              --no-dedup (private per-tenant weight copies)]
  frontend   request front-end: bursty per-class client streams (infer/
             fine-tune/probe) through bounded queues onto shard workers
             under ONE global budget; reports requests/sec + p50/p95/p99
             [--tenants 4 --arbiter static|global (default: both policies)
              --queue-cap 64 --budget-ratio 0.6 --heuristic h_dtr_eq
              --no-dedup --no-coalesce (disable weight sharing / batched
              infer; both default on and are result-identical)]
  train      train the transformer LM under a DTR budget (budget-ratio is
             a fraction of the non-pinned headroom; floor is ~0.6)
             [--config cfg.json --steps 50 --budget-ratio 0.8
              --heuristic h_dtr_eq --optimizer adam --curve-out loss.csv
              --index auto|scan|indexed|cached|differential (victim-selection index family)
              --threads N (intra-op kernel workers; bit-identical to 1)]
             [--backend interp|pjrt] (interp is hermetic; pjrt needs
             `--features pjrt` + artifacts) [--vocab N --d-model N
              --n-heads N --d-ff N --seq N --batch N --layers N]
  gen-log    dump a model's operation log [--model resnet --scale 1 --out m.jsonl]
  models     list available workload models
";

pub fn dispatch() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let out_path = args.get("out").map(PathBuf::from);
    let mut out = CsvOut::create(out_path.as_deref(), true)?;
    let scale = args.u64_or("scale", 1);

    match cmd {
        "fig2" => {
            let models: Vec<String> = args
                .list("models")
                .unwrap_or_else(|| ALL_MODELS.iter().map(|s| s.to_string()).collect());
            let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
            let ratios =
                args.f64_list_or("ratios", &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]);
            let hs = crate::dtr::Heuristic::fig2_set();
            let rows = fig2::run(&model_refs, &hs, &ratios, scale)?;
            fig2::emit(&mut out, &rows, &model_refs, scale)?;
        }
        "fig3" => fig3::default_run(&mut out, args.usize_or("n", 512))?,
        "fig4" => {
            if args.bool("dynamic") {
                anyhow::ensure!(
                    args.get("backend").is_none(),
                    "fig4 --dynamic profiles the hermetic interpreter; --backend is not supported"
                );
                fig4::default_run_dynamic(&mut out, args.usize_or("steps", 3))?;
            } else {
                let tc = TrainConfig::load(&args)?;
                fig4::default_run(&mut out, &tc, args.usize_or("steps", 3))?;
            }
        }
        "table1" => tables::default_run(&mut out)?,
        "fig5" => formal::fig5(&mut out, args.usize_or("n", 200))?,
        "thm31" => {
            let ns: Vec<usize> = args
                .list("ns")
                .map(|v| v.iter().filter_map(|s| s.parse().ok()).collect())
                .unwrap_or_else(|| vec![64, 128, 256, 512, 1024, 2048, 4096]);
            formal::thm31(&mut out, &ns)?;
        }
        "thm32" => {
            let ns: Vec<usize> = args
                .list("ns")
                .map(|v| v.iter().filter_map(|s| s.parse().ok()).collect())
                .unwrap_or_else(|| vec![64, 128, 256, 512]);
            formal::thm32(&mut out, &ns, args.usize_or("b", 8))?;
        }
        "ablation" => {
            let models: Vec<String> = args
                .list("models")
                .unwrap_or_else(|| vec!["mlp".into(), "resnet".into(), "lstm".into(), "unet".into()]);
            let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
            let ratios = args.f64_list_or("ratios", &[0.3, 0.4, 0.5, 0.6, 0.8]);
            ablation::ablation(&mut out, &model_refs, &ratios, scale)?;
        }
        "fig11" => {
            let models: Vec<String> = args
                .list("models")
                .unwrap_or_else(|| vec!["mlp".into(), "resnet".into(), "unet".into(), "lstm".into()]);
            let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
            let ratios = args.f64_list_or("ratios", &[0.3, 0.4, 0.5, 0.6, 0.8, 0.9]);
            ablation::fig11(&mut out, &model_refs, &ratios, scale)?;
        }
        "fig12" => {
            let models: Vec<String> = args
                .list("models")
                .unwrap_or_else(|| vec!["mlp".into(), "resnet".into(), "densenet".into(), "lstm".into()]);
            let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
            let ratios = args.f64_list_or("ratios", &[0.4, 0.5, 0.6, 0.8]);
            ablation::fig12(&mut out, &model_refs, &ratios, scale)?;
        }
        "serve" => {
            let mut tc = TrainConfig::load(&args)?;
            // A config file (or CLI flags) fully specifies the run — its
            // `arbiter` is honored as-is. With neither, apply serve demo
            // defaults and sweep BOTH arbitration policies for comparison.
            let pinned_policy = args.get("arbiter").is_some() || args.get("config").is_some();
            if args.get("config").is_none() {
                if args.get("steps").is_none() {
                    tc.steps = 10;
                }
                if args.get("budget-ratio").is_none() {
                    tc.budget_ratio = Some(0.6);
                }
                if args.get("tenants").is_none() {
                    tc.tenants = 4;
                }
            }
            let policies: Vec<crate::serve::ArbiterPolicy> = if pinned_policy {
                vec![tc.arbiter]
            } else {
                crate::serve::ArbiterPolicy::all().to_vec()
            };
            serve::default_run(&mut out, &tc, &policies)?;
        }
        "frontend" => {
            let mut tc = TrainConfig::load(&args)?;
            // Same defaulting contract as `serve`: a config file or an
            // explicit --arbiter pins the policy; otherwise sweep both.
            let pinned_policy = args.get("arbiter").is_some() || args.get("config").is_some();
            if args.get("config").is_none() {
                if args.get("budget-ratio").is_none() {
                    tc.budget_ratio = Some(0.6);
                }
                if args.get("tenants").is_none() {
                    tc.tenants = 4;
                }
            }
            let policies: Vec<crate::serve::ArbiterPolicy> = if pinned_policy {
                vec![tc.arbiter]
            } else {
                crate::serve::ArbiterPolicy::all().to_vec()
            };
            frontend::default_run(&mut out, &tc, &policies)?;
        }
        "train" => {
            let cfg = TrainConfig::load(&args)?;
            coordinator::train(&cfg)?;
        }
        "gen-log" => {
            let model = args.str_or("model", "resnet");
            let log = by_name(&model, scale)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
            match args.get("out") {
                Some(p) => {
                    log.save(Path::new(p))?;
                    println!("wrote {} instructions to {p}", log.instrs.len());
                }
                None => print!("{}", log.to_jsonl()),
            }
        }
        "models" => {
            for m in ALL_MODELS {
                let log = by_name(m, scale).unwrap();
                let b = crate::sim::replay::baseline(&log);
                println!(
                    "{m:<14} {:>5} calls  peak {:>12} B  constants {:>12} B",
                    b.calls, b.peak_memory, b.constant_bytes
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
