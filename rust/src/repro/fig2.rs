//! Figure 2: simulated slowdown vs memory-budget ratio for every heuristic
//! on every model. Also emits the black/gray floor columns (constant bytes,
//! largest-op bytes) the paper shades, and marks OOM points.

use anyhow::Result;

use crate::dtr::{Config, Heuristic};
use crate::graphs::models::{by_name, ALL_MODELS};
use crate::sim::replay::{baseline, simulate};
use crate::util::csv::{f, CsvOut};

pub struct Fig2Row {
    pub model: String,
    pub heuristic: String,
    pub ratio: f64,
    /// `None` = OOM at this budget.
    pub slowdown: Option<f64>,
    pub remats: u64,
}

pub fn run(
    models: &[&str],
    heuristics: &[Heuristic],
    ratios: &[f64],
    scale: u64,
) -> Result<Vec<Fig2Row>> {
    let mut rows = Vec::new();
    for &model in models {
        let log = by_name(model, scale)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        let b = baseline(&log);
        for &h in heuristics {
            for &ratio in ratios {
                let budget = (b.peak_memory as f64 * ratio) as u64;
                let out = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
                rows.push(Fig2Row {
                    model: model.to_string(),
                    heuristic: h.name(),
                    ratio,
                    slowdown: if out.ok() { Some(out.stats.slowdown()) } else { None },
                    remats: out.stats.remat_count,
                });
            }
        }
    }
    Ok(rows)
}

pub fn emit(out: &mut CsvOut, rows: &[Fig2Row], models: &[&str], scale: u64) -> Result<()> {
    out.row(&["model", "heuristic", "budget_ratio", "slowdown", "remats"])?;
    for r in rows {
        out.row(&[
            r.model.clone(),
            r.heuristic.clone(),
            f(r.ratio),
            r.slowdown.map(f).unwrap_or_else(|| "oom".to_string()),
            r.remats.to_string(),
        ])?;
    }
    // Floor metadata (the paper's shaded regions), one row per model.
    out.row(&["#model", "constant_bytes", "max_op_bytes", "peak_bytes", "calls"])?;
    for &m in models {
        let b = baseline(&by_name(m, scale).unwrap());
        out.row(&[
            format!("#{m}"),
            b.constant_bytes.to_string(),
            b.max_op_bytes.to_string(),
            b.peak_memory.to_string(),
            b.calls.to_string(),
        ])?;
    }
    Ok(())
}

/// Default Fig. 2 sweep.
pub fn default_run(out: &mut CsvOut, scale: u64) -> Result<()> {
    let ratios: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    let hs = Heuristic::fig2_set();
    let rows = run(&ALL_MODELS, &hs, &ratios, scale)?;
    emit(out, &rows, &ALL_MODELS, scale)?;
    summarize(&rows);
    Ok(())
}

/// Print the paper's qualitative claims as a quick check.
fn summarize(rows: &[Fig2Row]) {
    // Lowest feasible ratio per heuristic, averaged over models.
    println!("\n# lowest feasible budget ratio (mean over models):");
    for h in Heuristic::fig2_set() {
        let name = h.name();
        let mut lows = Vec::new();
        for model in rows.iter().map(|r| r.model.clone()).collect::<std::collections::BTreeSet<_>>() {
            let low = rows
                .iter()
                .filter(|r| r.model == model && r.heuristic == name && r.slowdown.is_some())
                .map(|r| r.ratio)
                .fold(f64::INFINITY, f64::min);
            if low.is_finite() {
                lows.push(low);
            }
        }
        let mean = lows.iter().sum::<f64>() / lows.len().max(1) as f64;
        println!("  {name:<14} {mean:.2}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_small_sweep_has_expected_shape() {
        let rows = run(&["mlp"], &[Heuristic::dtr_eq(), Heuristic::lru()], &[0.5, 0.9], 1).unwrap();
        assert_eq!(rows.len(), 4);
        // At 0.9 everything must succeed with low slowdown.
        for r in rows.iter().filter(|r| r.ratio == 0.9) {
            let s = r.slowdown.expect("0.9 budget must be feasible");
            assert!(s < 1.5, "{}: slowdown {s}", r.heuristic);
        }
    }

    #[test]
    fn informed_heuristics_reach_lower_budgets() {
        // The paper's headline: neighborhood-aware heuristics (h_dtr_eq)
        // support budgets at least as low as metadata-free ones (h_rand).
        let ratios: Vec<f64> = (2..=10).map(|i| i as f64 / 10.0).collect();
        let rows = run(
            &["mlp", "lstm"],
            &[Heuristic::dtr_eq(), Heuristic::Random],
            &ratios,
            1,
        )
        .unwrap();
        for model in ["mlp", "lstm"] {
            let low = |h: &str| {
                rows.iter()
                    .filter(|r| r.model == model && r.heuristic == h && r.slowdown.is_some())
                    .map(|r| r.ratio)
                    .fold(f64::INFINITY, f64::min)
            };
            assert!(
                low("h_dtr_eq") <= low("h_rand") + 1e-9,
                "{model}: eq {} vs rand {}",
                low("h_dtr_eq"),
                low("h_rand")
            );
        }
    }
}
