//! Table 1: the largest input size each model supports on a fixed-capacity
//! "device", with and without DTR, and the per-batch compute at each size.
//!
//! The paper's Titan V is replaced by a simulated device whose capacity is
//! pegged to 2x the scale-1 model's unbudgeted peak (DESIGN.md §5): the
//! baseline ("PT") fits only while its peak stays under capacity, while DTR
//! keeps training by rematerializing — the table's qualitative shape
//! (baseline OOMs at small inputs, DTR continues with modest slowdown).

use anyhow::Result;

use crate::dtr::{Config, Heuristic};
use crate::graphs::models::by_name;
use crate::sim::replay::{baseline, simulate};
use crate::util::csv::{f, CsvOut};

pub struct Table1Row {
    pub model: String,
    pub scale: u64,
    pub peak: u64,
    pub capacity: u64,
    /// Baseline (no checkpointing): compute if it fits, None if OOM.
    pub baseline_cost: Option<u64>,
    /// DTR at device capacity: compute, None if infeasible even with remat.
    pub dtr_cost: Option<u64>,
}

pub fn run(models: &[&str], scales: &[u64], h: Heuristic) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for &model in models {
        // Device capacity pegged to the scale-1 workload.
        let small = baseline(&by_name(model, 1).unwrap());
        let capacity = small.peak_memory * 2;
        for &scale in scales {
            let log = by_name(model, scale).unwrap();
            let b = baseline(&log);
            let baseline_cost = if b.peak_memory <= capacity { Some(b.total_compute) } else { None };
            let out = simulate(
                &log,
                Config { budget: capacity, heuristic: h, ..Config::default() },
            );
            rows.push(Table1Row {
                model: model.to_string(),
                scale,
                peak: b.peak_memory,
                capacity,
                baseline_cost,
                dtr_cost: if out.ok() { Some(out.stats.total_compute()) } else { None },
            });
        }
    }
    Ok(rows)
}

pub fn emit(out: &mut CsvOut, rows: &[Table1Row]) -> Result<()> {
    out.row(&[
        "model",
        "input_scale",
        "peak_bytes",
        "device_capacity",
        "baseline_compute",
        "dtr_compute",
        "dtr_slowdown_vs_baseline_need",
    ])?;
    for r in rows {
        out.row(&[
            r.model.clone(),
            r.scale.to_string(),
            r.peak.to_string(),
            r.capacity.to_string(),
            r.baseline_cost.map(|c| c.to_string()).unwrap_or_else(|| "X".into()),
            r.dtr_cost.map(|c| c.to_string()).unwrap_or_else(|| "X".into()),
            match r.dtr_cost {
                Some(d) => {
                    // Slowdown vs the compute the baseline *would* need.
                    let base = r.baseline_cost.unwrap_or_else(|| {
                        // Unbudgeted compute equals the log's base compute.
                        d.min(d) // placeholder replaced below
                    });
                    if r.baseline_cost.is_some() {
                        f(d as f64 / base as f64)
                    } else {
                        "n/a(baseline OOM)".to_string()
                    }
                }
                None => "X".into(),
            },
        ])?;
    }
    Ok(())
}

pub fn default_run(out: &mut CsvOut) -> Result<()> {
    let models = ["resnet", "transformer", "unet", "treelstm"];
    let scales = [1u64, 2, 3, 4, 6];
    let rows = run(&models, &scales, Heuristic::dtr_eq())?;
    emit(out, &rows)?;
    // Headline: largest supported scale per scheme.
    println!("\n# largest supported input scale (baseline vs DTR):");
    for m in models {
        let max_base = rows
            .iter()
            .filter(|r| r.model == m && r.baseline_cost.is_some())
            .map(|r| r.scale)
            .max()
            .unwrap_or(0);
        let max_dtr = rows
            .iter()
            .filter(|r| r.model == m && r.dtr_cost.is_some())
            .map(|r| r.scale)
            .max()
            .unwrap_or(0);
        println!("  {m:<12} baseline={max_base}  dtr={max_dtr}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtr_supports_larger_inputs_than_baseline() {
        let rows = run(&["transformer"], &[1, 2, 3, 4], Heuristic::dtr_eq()).unwrap();
        let max_base = rows
            .iter()
            .filter(|r| r.baseline_cost.is_some())
            .map(|r| r.scale)
            .max()
            .unwrap();
        let max_dtr =
            rows.iter().filter(|r| r.dtr_cost.is_some()).map(|r| r.scale).max().unwrap();
        assert!(
            max_dtr > max_base,
            "DTR ({max_dtr}) must outscale the baseline ({max_base})"
        );
    }

    #[test]
    fn dtr_matches_baseline_when_memory_ample() {
        let rows = run(&["treelstm"], &[1], Heuristic::dtr_eq()).unwrap();
        let r = &rows[0];
        // At scale 1 capacity is 2x peak: no rematerialization needed.
        assert_eq!(r.baseline_cost, r.dtr_cost);
    }
}
