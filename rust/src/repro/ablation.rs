//! Appendix-D experiments: the s × m × c ablation grid (Figs. 7–10), the
//! deallocation-policy comparison (Fig. 11), and the metadata/storage-access
//! overhead comparison (Fig. 12).

use anyhow::Result;

use crate::dtr::{Config, DeallocPolicy, Heuristic};
use crate::graphs::models::by_name;
use crate::sim::replay::{baseline, simulate};
use crate::util::csv::{f, CsvOut};

/// Figs. 7–10: every (cost, size, staleness) combination on each model.
pub fn ablation(out: &mut CsvOut, models: &[&str], ratios: &[f64], scale: u64) -> Result<()> {
    out.row(&["model", "heuristic", "budget_ratio", "slowdown", "remats"])?;
    for &model in models {
        let log = by_name(model, scale).unwrap();
        let b = baseline(&log);
        for h in Heuristic::ablation_grid() {
            for &ratio in ratios {
                let budget = (b.peak_memory as f64 * ratio) as u64;
                let o = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
                out.row(&[
                    model.to_string(),
                    h.name(),
                    f(ratio),
                    o.failed
                        .is_none()
                        .then(|| f(o.stats.slowdown()))
                        .unwrap_or_else(|| "oom".to_string()),
                    o.stats.remat_count.to_string(),
                ])?;
            }
        }
    }
    Ok(())
}

/// Fig. 11: h_dtr under ignore / eager-evict / banish deallocation.
pub fn fig11(out: &mut CsvOut, models: &[&str], ratios: &[f64], scale: u64) -> Result<()> {
    out.row(&["model", "policy", "budget_ratio", "slowdown", "banishes"])?;
    for &model in models {
        let log = by_name(model, scale).unwrap();
        let b = baseline(&log);
        for policy in DeallocPolicy::all() {
            for &ratio in ratios {
                let budget = (b.peak_memory as f64 * ratio) as u64;
                let o = simulate(
                    &log,
                    Config { budget, heuristic: Heuristic::dtr(), policy, ..Config::default() },
                );
                out.row(&[
                    model.to_string(),
                    policy.name().to_string(),
                    f(ratio),
                    o.failed
                        .is_none()
                        .then(|| f(o.stats.slowdown()))
                        .unwrap_or_else(|| "oom".to_string()),
                    o.stats.banish_count.to_string(),
                ])?;
            }
        }
    }
    Ok(())
}

/// Fig. 12: metadata/storage accesses per heuristic and budget. Pinned to
/// the reference scan so the counts keep Appendix D.3's meaning — the cost
/// of evaluating each heuristic fresh per search. (The incremental policy
/// indexes exist precisely to cut these; compare by flipping
/// `Config::index` to `PolicyKind::Auto`.)
pub fn fig12(out: &mut CsvOut, models: &[&str], ratios: &[f64], scale: u64) -> Result<()> {
    out.row(&["model", "heuristic", "budget_ratio", "metadata_accesses", "evictions"])?;
    for &model in models {
        let log = by_name(model, scale).unwrap();
        let b = baseline(&log);
        for h in [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::dtr_local()] {
            for &ratio in ratios {
                let budget = (b.peak_memory as f64 * ratio) as u64;
                let o = simulate(
                    &log,
                    Config {
                        budget,
                        heuristic: h,
                        index: crate::dtr::PolicyKind::Scan,
                        ..Config::default()
                    },
                );
                if o.ok() {
                    out.row(&[
                        model.to_string(),
                        h.name(),
                        f(ratio),
                        o.stats.metadata_accesses.to_string(),
                        o.stats.evict_count.to_string(),
                    ])?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Config;
    use crate::sim::replay::simulate;

    #[test]
    fn fig12_access_ordering_holds_on_models() {
        // Appendix D.3: h_dtr >> h_dtr_eq >> h_dtr_local in metadata
        // accesses (1-2 orders of magnitude each in the paper).
        // Needs real memory pressure: large evicted neighborhoods are what
        // make the exact-e* DFS expensive (Appendix D.3's regime).
        let log = by_name("mlp", 1).unwrap();
        let b = baseline(&log);
        let budget = b.budget_at(0.08);
        // Normalize per victim-search pass: raw totals also reflect how
        // *many* searches each heuristic's decisions caused, which is the
        // overhead-vs-quality tradeoff the paper plots separately.
        let acc = |h: Heuristic| {
            // Scan-pinned like the fig12 harness: the ordering is about the
            // per-search cost of *fresh* heuristic evaluation.
            let o = simulate(
                &log,
                Config {
                    budget,
                    heuristic: h,
                    index: crate::dtr::PolicyKind::Scan,
                    ..Config::default()
                },
            );
            assert!(o.ok(), "{}: {:?}", h.name(), o.failed);
            o.stats.metadata_accesses as f64 / o.stats.eviction_searches.max(1) as f64
        };
        let full = acc(Heuristic::dtr());
        let eq = acc(Heuristic::dtr_eq());
        let local = acc(Heuristic::dtr_local());
        assert!(full > 2.0 * eq, "e* {full} vs eq {eq} per search");
        assert!(eq > local, "eq {eq} vs local {local} per search");
    }

    #[test]
    fn fig11_dealloc_aware_policies_beat_ignore() {
        // Appendix D.2's robust claim: both deallocation-aware policies
        // (eager, banish) achieve lower overhead than ignoring deallocation
        // events, which wastes the liveness information. (The eager-vs-
        // banish ordering is log-specific in the paper — banish loses badly
        // on *their* UNet logs — so we assert the weaker, robust property
        // and report the full comparison in the fig11 CSV.)
        let log = by_name("unet", 1).unwrap();
        let b = baseline(&log);
        let lowest_ok = |policy: DeallocPolicy| {
            let mut lowest = f64::INFINITY;
            for i in (2..=10).rev() {
                let ratio = i as f64 / 10.0;
                let budget = (b.peak_memory as f64 * ratio) as u64;
                let o = simulate(
                    &log,
                    Config { budget, heuristic: Heuristic::dtr(), policy, ..Config::default() },
                );
                if o.ok() {
                    lowest = ratio;
                } else {
                    break;
                }
            }
            lowest
        };
        let eager = lowest_ok(DeallocPolicy::EagerEvict);
        let banish = lowest_ok(DeallocPolicy::Banish);
        let ignore = lowest_ok(DeallocPolicy::Ignore);
        assert!(
            eager <= ignore && banish <= ignore,
            "dealloc-aware policies (eager {eager}, banish {banish}) must \
             reach budgets at least as low as ignore ({ignore})"
        );
    }
}
