//! The training loop: budget resolution, step iteration, metrics, and the
//! loss-curve record — the E2E driver behind `examples/train_transformer.rs`
//! and `dtr-repro train`. Sits entirely on the `Engine`, which drives every
//! step through the `dtr::api` session surface (no raw tensor ids or
//! manual releases anywhere in the coordinator stack).

use anyhow::Result;

use super::config::TrainConfig;
use crate::dtr;
use crate::exec::{Engine, StepResult};
use crate::util::csv::{f, CsvOut};

/// Aggregated results of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub total_params: u64,
    pub peak_unbudgeted: u64,
    pub budget: u64,
    pub peak_budgeted: u64,
    pub total_remats: u64,
    pub total_evictions: u64,
    pub total_wall_ns: u64,
    pub total_exec_ns: u64,
    pub tokens_per_step: u64,
}

impl TrainReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let total_tokens = self.tokens_per_step as f64 * self.losses.len() as f64;
        total_tokens / (self.total_wall_ns as f64 / 1e9)
    }

    /// DTR runtime overhead: wall time not spent executing operators.
    pub fn overhead_fraction(&self) -> f64 {
        1.0 - self.total_exec_ns as f64 / self.total_wall_ns.max(1) as f64
    }
}

/// Run a training session per `cfg`, printing progress and returning the
/// report. The loss curve is optionally written as CSV.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let dtr_cfg = dtr::Config {
        budget: u64::MAX,
        heuristic: cfg.heuristic,
        policy: cfg.policy,
        index: cfg.index,
        sqrt_sample: cfg.sqrt_sample,
        small_filter: cfg.small_filter,
        profile: true,
        ..dtr::Config::default()
    };
    let mut engine = Engine::new(cfg.build_executor()?, dtr_cfg.clone(), cfg.optimizer)?;
    let mcfg = engine.cfg;
    println!(
        "backend: {} | model: {} params, {} layers, d_model={}, seq={}, batch={}",
        engine.backend_name(),
        engine.total_params(),
        mcfg.n_layers,
        mcfg.d_model,
        mcfg.seq,
        mcfg.batch
    );

    // Resolve the budget from the measured unbudgeted peak. The ratio is a
    // fraction of the non-pinned headroom above the pinned-constant floor
    // (params + optimizer state + batch): raw-peak ratios would sit below
    // the feasibility floor on small models where pinned constants
    // dominate.
    let peak = engine.measure_peak()?;
    let budget = match cfg.budget_ratio {
        Some(r) => engine.budgets_from_peak(peak, &[(r * 100.0).round() as u64])[0],
        None => u64::MAX,
    };
    engine.dtr_cfg = dtr::Config { budget, ..dtr_cfg };
    println!(
        "unbudgeted peak = {:.1} MiB ({:.1} MiB pinned); budget = {}",
        peak as f64 / (1 << 20) as f64,
        engine.pinned_bytes() as f64 / (1 << 20) as f64,
        if budget == u64::MAX {
            "unlimited".to_string()
        } else {
            format!(
                "{:.1} MiB ({}% of headroom)",
                budget as f64 / (1 << 20) as f64,
                (cfg.budget_ratio.unwrap() * 100.0).round() as u32
            )
        }
    );

    let mut report = TrainReport {
        losses: Vec::with_capacity(cfg.steps),
        total_params: engine.total_params(),
        peak_unbudgeted: peak,
        budget,
        peak_budgeted: 0,
        total_remats: 0,
        total_evictions: 0,
        total_wall_ns: 0,
        total_exec_ns: 0,
        tokens_per_step: (mcfg.batch * mcfg.seq) as u64,
    };

    let mut curve = match &cfg.curve_out {
        Some(p) => Some(CsvOut::create(Some(p), false)?),
        None => None,
    };
    if let Some(c) = &mut curve {
        c.row(&["step", "loss", "remats", "evictions", "peak_bytes", "wall_ms"])?;
    }

    for step in 1..=cfg.steps {
        let StepResult { loss, stats, wall_ns, exec_ns, .. } = engine.train_step()?;
        report.losses.push(loss);
        report.peak_budgeted = report.peak_budgeted.max(stats.peak_memory);
        report.total_remats += stats.remat_count;
        report.total_evictions += stats.evict_count;
        report.total_wall_ns += wall_ns;
        report.total_exec_ns += exec_ns;
        if let Some(c) = &mut curve {
            c.row(&[
                step.to_string(),
                f(loss as f64),
                stats.remat_count.to_string(),
                stats.evict_count.to_string(),
                stats.peak_memory.to_string(),
                f(wall_ns as f64 / 1e6),
            ])?;
        }
        if step % cfg.log_every == 0 || step == 1 || step == cfg.steps {
            println!(
                "step {step:>4}  loss {loss:.4}  remats {:>4}  evictions {:>4}  peak {:.1} MiB  {:.0} ms",
                stats.remat_count,
                stats.evict_count,
                stats.peak_memory as f64 / (1 << 20) as f64,
                wall_ns as f64 / 1e6,
            );
        }
    }

    println!(
        "done: loss {:.4} -> {:.4} | {:.0} tok/s | remats/step {:.1} | DTR overhead {:.1}%",
        report.losses.first().copied().unwrap_or(f32::NAN),
        report.losses.last().copied().unwrap_or(f32::NAN),
        report.tokens_per_sec(),
        report.total_remats as f64 / cfg.steps as f64,
        report.overhead_fraction() * 100.0,
    );
    Ok(report)
}
