//! Training configuration: JSON file + CLI-flag overrides.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::dtr::{DeallocPolicy, Heuristic};
use crate::exec::Optimizer;
use crate::util::cli::Args;
use crate::util::json::parse;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    /// Memory budget as a fraction of the measured unbudgeted peak;
    /// `None` = unlimited.
    pub budget_ratio: Option<f64>,
    pub heuristic: Heuristic,
    pub policy: DeallocPolicy,
    pub optimizer: Optimizer,
    pub sqrt_sample: bool,
    pub small_filter: bool,
    pub log_every: usize,
    /// Where to write the loss-curve CSV (optional).
    pub curve_out: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 50,
            budget_ratio: Some(0.65),
            heuristic: Heuristic::dtr_eq(),
            policy: DeallocPolicy::EagerEvict,
            // SGD by default: Adam's m/v state triples the pinned constant
            // footprint, which dominates small models and raises the
            // feasible-budget floor to ~0.8 of peak (see EXPERIMENTS.md).
            optimizer: Optimizer::Sgd,
            sqrt_sample: false,
            small_filter: false,
            log_every: 10,
            curve_out: None,
        }
    }
}

impl TrainConfig {
    /// Load from a JSON file; unknown keys are rejected to catch typos.
    pub fn from_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let v = parse(&text).context("parsing train config")?;
        let mut cfg = TrainConfig::default();
        let obj = v.as_obj().context("config must be a JSON object")?;
        for (key, val) in obj {
            match key.as_str() {
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(val.as_str().context("artifacts_dir")?)
                }
                "steps" => cfg.steps = val.as_usize().context("steps")?,
                "budget_ratio" => {
                    cfg.budget_ratio = match val.as_f64() {
                        Some(r) if r > 0.0 => Some(r),
                        _ => None,
                    }
                }
                "heuristic" => {
                    let name = val.as_str().context("heuristic")?;
                    cfg.heuristic =
                        Heuristic::parse(name).with_context(|| format!("unknown heuristic {name}"))?;
                }
                "policy" => {
                    let name = val.as_str().context("policy")?;
                    cfg.policy = DeallocPolicy::parse(name)
                        .with_context(|| format!("unknown policy {name}"))?;
                }
                "optimizer" => {
                    cfg.optimizer = match val.as_str().context("optimizer")? {
                        "adam" => Optimizer::Adam,
                        "sgd" => Optimizer::Sgd,
                        other => anyhow::bail!("unknown optimizer {other}"),
                    }
                }
                "sqrt_sample" => cfg.sqrt_sample = val.as_bool().context("sqrt_sample")?,
                "small_filter" => cfg.small_filter = val.as_bool().context("small_filter")?,
                "log_every" => cfg.log_every = val.as_usize().context("log_every")?,
                "curve_out" => cfg.curve_out = val.as_str().map(PathBuf::from),
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides on top (flags win over file).
    pub fn apply_args(mut self, args: &Args) -> Result<TrainConfig> {
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.steps = args.usize_or("steps", self.steps);
        if let Some(r) = args.get("budget-ratio") {
            let r: f64 = r.parse().context("budget-ratio")?;
            self.budget_ratio = if r > 0.0 { Some(r) } else { None };
        }
        if args.bool("no-budget") {
            self.budget_ratio = None;
        }
        if let Some(h) = args.get("heuristic") {
            self.heuristic = Heuristic::parse(h).with_context(|| format!("heuristic {h}"))?;
        }
        if let Some(p) = args.get("policy") {
            self.policy = DeallocPolicy::parse(p).with_context(|| format!("policy {p}"))?;
        }
        if let Some(o) = args.get("optimizer") {
            self.optimizer = match o {
                "adam" => Optimizer::Adam,
                "sgd" => Optimizer::Sgd,
                other => anyhow::bail!("unknown optimizer {other}"),
            };
        }
        if args.bool("sqrt-sample") {
            self.sqrt_sample = true;
        }
        if args.bool("small-filter") {
            self.small_filter = true;
        }
        self.log_every = args.usize_or("log-every", self.log_every);
        if let Some(c) = args.get("curve-out") {
            self.curve_out = Some(PathBuf::from(c));
        }
        Ok(self)
    }

    pub fn load(args: &Args) -> Result<TrainConfig> {
        let base = match args.get("config") {
            Some(path) => TrainConfig::from_file(Path::new(path))?,
            None => TrainConfig::default(),
        };
        base.apply_args(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(content: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dtr_cfg_{}.json", content.len()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn defaults_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.budget_ratio, Some(0.65));
        assert_eq!(c.heuristic, Heuristic::dtr_eq());
    }

    #[test]
    fn parses_file() {
        let p = write_tmp(
            r#"{"steps": 7, "budget_ratio": 0.4, "heuristic": "h_lru",
                "policy": "banish", "optimizer": "sgd", "log_every": 2}"#,
        );
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.budget_ratio, Some(0.4));
        assert_eq!(c.heuristic, Heuristic::lru());
        assert_eq!(c.policy, DeallocPolicy::Banish);
        assert_eq!(c.optimizer, Optimizer::Sgd);
    }

    #[test]
    fn rejects_unknown_keys() {
        let p = write_tmp(r#"{"stepz": 7}"#);
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn cli_overrides_file() {
        let p = write_tmp(r#"{"steps": 7}"#);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--steps".to_string(),
                "99".to_string(),
                "--heuristic".to_string(),
                "h_dtr".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.steps, 99);
        assert_eq!(c.heuristic, Heuristic::dtr());
    }

    #[test]
    fn no_budget_flag() {
        let args = crate::util::cli::Args::parse(vec!["--no-budget".to_string()].into_iter());
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.budget_ratio, None);
    }
}
