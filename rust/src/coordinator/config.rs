//! Training configuration: JSON file + CLI-flag overrides, plus executor
//! construction (the coordinator-level end of the backend seam).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::dtr::policy::AUTO_CROSSOVER_POOL;
use crate::dtr::{DeallocPolicy, Heuristic, PolicyKind};
use crate::exec::Optimizer;
use crate::runtime::{BackendKind, Executor, InterpExecutor, ModelConfig};
use crate::serve::{ArbiterPolicy, GlobalIndexKind};
use crate::util::cli::Args;
use crate::util::json::parse;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Which executor to construct (interp is hermetic; pjrt needs the
    /// `pjrt` cargo feature and compiled artifacts).
    pub backend: BackendKind,
    /// Model dimensions for the interpreter backend (the pjrt backend reads
    /// dimensions from the artifact manifest instead).
    pub model: ModelConfig,
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    /// Memory budget as a fraction of the non-pinned headroom between the
    /// pinned-constant floor and the measured unbudgeted peak (1.0 = peak);
    /// `None` = unlimited.
    pub budget_ratio: Option<f64>,
    pub heuristic: Heuristic,
    pub policy: DeallocPolicy,
    /// Victim-selection index family (auto / scan / indexed / cached /
    /// differential — `cached` pins the O(pool) cached-numerator scan,
    /// `differential` forces the kinetic epoch-tier index for every
    /// staleness-bearing heuristic; `auto` already picks differential for
    /// the `h_DTR` family).
    pub index: PolicyKind,
    /// Pool size at which the `auto` index upgrades from the scan to the
    /// differential index (`--auto-crossover`): bench sweeps price the
    /// boundary without recompiling. 0 upgrades at the first pop.
    pub auto_crossover: usize,
    pub optimizer: Optimizer,
    pub sqrt_sample: bool,
    pub small_filter: bool,
    pub log_every: usize,
    /// Where to write the loss-curve CSV (optional).
    pub curve_out: Option<PathBuf>,
    /// Serving knobs (`dtr-repro serve`): concurrent tenant count sharing
    /// one global budget...
    pub tenants: usize,
    /// ...and how the arbiter divides it (static-split vs global-reclaim).
    pub arbiter: ArbiterPolicy,
    /// How `GlobalReclaim` finds the fleet-wide victim (`--global-index`):
    /// `shared` = one cross-shard tournament over published per-shard
    /// minima (default), `scan` = the peek-every-peer loop.
    pub global_index: GlobalIndexKind,
    /// Intra-op worker threads for the interpreter's kernel layer. Any
    /// value is bit-identical to 1 (threads partition disjoint output
    /// rows; see `runtime/kernels`), so DTR decision traces are
    /// unaffected; 1 (the default) never spawns.
    pub threads: usize,
    /// Route `block_fwd`/`block_bwd` through the fused layernorm /
    /// flash-attention kernels (`runtime/kernels/fused`). Off by default:
    /// the fused attention reassociates its reductions, so results are
    /// tolerance-equivalent rather than bitwise — opting in trades the
    /// pre-PR bit-exact traces for the fused hot path.
    pub fused: bool,
    /// Per-class queue cap for the request front-end (`dtr-repro
    /// frontend`): submits beyond it are shed with an explicit Rejected
    /// outcome (backpressure instead of unbounded queues).
    pub queue_cap: usize,
    /// Content-addressed pinned-weight sharing for the serve fleet: tenants
    /// of the same base model intern their pinned parameters in the pool's
    /// `WeightStore` and share one physical copy, charged to the arbiter
    /// once per distinct buffer. On by default; `--no-dedup` reverts to
    /// private per-tenant copies (decision-exact either way at N=1).
    pub dedup: bool,
    /// Cross-shard request coalescing in the front-end scheduler: runs of
    /// compatible Infer requests in one worker batch execute as a single
    /// stacked kernel invocation instead of back-to-back singles. On by
    /// default; `--no-coalesce` forces serial execution (the coalesced
    /// path is bitwise-equal, so this is a perf knob, not a results knob).
    pub coalesce: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            backend: BackendKind::Interp,
            model: ModelConfig::small(),
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 50,
            // Headroom fraction (see Engine::budgets_from_peak): the
            // largest single-op working set (block_bwd's 7 outputs) puts
            // the feasibility floor near 0.6 of the headroom; 0.9 evicts
            // and rematerializes while staying comfortably feasible.
            budget_ratio: Some(0.9),
            heuristic: Heuristic::dtr_eq(),
            policy: DeallocPolicy::EagerEvict,
            index: PolicyKind::Auto,
            auto_crossover: AUTO_CROSSOVER_POOL,
            // SGD by default: Adam's m/v state triples the pinned constant
            // footprint, which dominates small models and shrinks the
            // evictable headroom the budget ladder sweeps.
            optimizer: Optimizer::Sgd,
            sqrt_sample: false,
            small_filter: false,
            log_every: 10,
            curve_out: None,
            tenants: 1,
            arbiter: ArbiterPolicy::GlobalReclaim,
            global_index: GlobalIndexKind::Shared,
            threads: 1,
            fused: false,
            queue_cap: 64,
            dedup: true,
            coalesce: true,
        }
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt(dir: &Path) -> Result<Box<dyn Executor>> {
    Ok(Box::new(crate::runtime::pjrt::PjrtExecutor::load(dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt(_dir: &Path) -> Result<Box<dyn Executor>> {
    anyhow::bail!(
        "backend 'pjrt' requires building with `--features pjrt` \
         (and the real xla crate; see rust/Cargo.toml)"
    )
}

impl TrainConfig {
    /// Construct the executor this config selects.
    pub fn build_executor(&self) -> Result<Box<dyn Executor>> {
        match self.backend {
            BackendKind::Interp => Ok(Box::new(
                InterpExecutor::new(self.model)?.with_threads(self.threads).with_fused(self.fused),
            )),
            BackendKind::Pjrt => build_pjrt(&self.artifacts_dir),
        }
    }

    /// Load from a JSON file; unknown keys are rejected to catch typos.
    pub fn from_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let v = parse(&text).context("parsing train config")?;
        let mut cfg = TrainConfig::default();
        let obj = v.as_obj().context("config must be a JSON object")?;
        for (key, val) in obj {
            match key.as_str() {
                "backend" => {
                    let name = val.as_str().context("backend")?;
                    cfg.backend = BackendKind::parse(name)
                        .with_context(|| format!("unknown backend {name}"))?;
                }
                "model" => {
                    let m = val.as_obj().context("model must be a JSON object")?;
                    for (mk, mv) in m {
                        let dim = mv.as_usize().with_context(|| format!("model.{mk}"))?;
                        match mk.as_str() {
                            "vocab" => cfg.model.vocab = dim,
                            "d_model" => cfg.model.d_model = dim,
                            "n_heads" => cfg.model.n_heads = dim,
                            "d_ff" => cfg.model.d_ff = dim,
                            "seq" => cfg.model.seq = dim,
                            "batch" => cfg.model.batch = dim,
                            "n_layers" => cfg.model.n_layers = dim,
                            other => anyhow::bail!("unknown model key '{other}'"),
                        }
                    }
                }
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(val.as_str().context("artifacts_dir")?)
                }
                "steps" => cfg.steps = val.as_usize().context("steps")?,
                "budget_ratio" => {
                    cfg.budget_ratio = match val.as_f64() {
                        Some(r) if r > 0.0 => Some(r),
                        _ => None,
                    }
                }
                "heuristic" => {
                    let name = val.as_str().context("heuristic")?;
                    cfg.heuristic =
                        Heuristic::parse(name).with_context(|| format!("unknown heuristic {name}"))?;
                }
                "policy" => {
                    let name = val.as_str().context("policy")?;
                    cfg.policy = DeallocPolicy::parse(name)
                        .with_context(|| format!("unknown policy {name}"))?;
                }
                "index" => {
                    let name = val.as_str().context("index")?;
                    cfg.index = PolicyKind::parse(name)
                        .with_context(|| format!("unknown index kind {name}"))?;
                }
                "auto_crossover" => {
                    cfg.auto_crossover = val.as_usize().context("auto_crossover")?
                }
                "global_index" => {
                    let name = val.as_str().context("global_index")?;
                    cfg.global_index = GlobalIndexKind::parse(name)
                        .with_context(|| format!("unknown global index kind {name}"))?;
                }
                "optimizer" => {
                    cfg.optimizer = match val.as_str().context("optimizer")? {
                        "adam" => Optimizer::Adam,
                        "sgd" => Optimizer::Sgd,
                        other => anyhow::bail!("unknown optimizer {other}"),
                    }
                }
                "tenants" => cfg.tenants = val.as_usize().context("tenants")?,
                "threads" => cfg.threads = val.as_usize().context("threads")?,
                "fused" => cfg.fused = val.as_bool().context("fused")?,
                "queue_cap" => cfg.queue_cap = val.as_usize().context("queue_cap")?,
                "dedup" => cfg.dedup = val.as_bool().context("dedup")?,
                "coalesce" => cfg.coalesce = val.as_bool().context("coalesce")?,
                "arbiter" => {
                    let name = val.as_str().context("arbiter")?;
                    cfg.arbiter = ArbiterPolicy::parse(name)
                        .with_context(|| format!("unknown arbiter policy {name}"))?;
                }
                "sqrt_sample" => cfg.sqrt_sample = val.as_bool().context("sqrt_sample")?,
                "small_filter" => cfg.small_filter = val.as_bool().context("small_filter")?,
                "log_every" => cfg.log_every = val.as_usize().context("log_every")?,
                "curve_out" => cfg.curve_out = val.as_str().map(PathBuf::from),
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        cfg.model.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top (flags win over file).
    pub fn apply_args(mut self, args: &Args) -> Result<TrainConfig> {
        if let Some(b) = args.get("backend") {
            self.backend =
                BackendKind::parse(b).with_context(|| format!("unknown backend {b}"))?;
        }
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.model.vocab = args.usize_or("vocab", self.model.vocab);
        self.model.d_model = args.usize_or("d-model", self.model.d_model);
        self.model.n_heads = args.usize_or("n-heads", self.model.n_heads);
        self.model.d_ff = args.usize_or("d-ff", self.model.d_ff);
        self.model.seq = args.usize_or("seq", self.model.seq);
        self.model.batch = args.usize_or("batch", self.model.batch);
        self.model.n_layers = args.usize_or("layers", self.model.n_layers);
        self.model.validate()?;
        self.steps = args.usize_or("steps", self.steps);
        if let Some(r) = args.get("budget-ratio") {
            let r: f64 = r.parse().context("budget-ratio")?;
            self.budget_ratio = if r > 0.0 { Some(r) } else { None };
        }
        if args.bool("no-budget") {
            self.budget_ratio = None;
        }
        if let Some(h) = args.get("heuristic") {
            self.heuristic = Heuristic::parse(h).with_context(|| format!("heuristic {h}"))?;
        }
        if let Some(p) = args.get("policy") {
            self.policy = DeallocPolicy::parse(p).with_context(|| format!("policy {p}"))?;
        }
        if let Some(i) = args.get("index") {
            self.index = PolicyKind::parse(i).with_context(|| format!("index kind {i}"))?;
        }
        self.auto_crossover = args.usize_or("auto-crossover", self.auto_crossover);
        if let Some(g) = args.get("global-index") {
            self.global_index =
                GlobalIndexKind::parse(g).with_context(|| format!("global index kind {g}"))?;
        }
        if let Some(o) = args.get("optimizer") {
            self.optimizer = match o {
                "adam" => Optimizer::Adam,
                "sgd" => Optimizer::Sgd,
                other => anyhow::bail!("unknown optimizer {other}"),
            };
        }
        self.tenants = args.usize_or("tenants", self.tenants);
        self.threads = args.usize_or("threads", self.threads);
        if args.bool("fused") {
            self.fused = true;
        }
        self.queue_cap = args.usize_or("queue-cap", self.queue_cap);
        if args.bool("no-dedup") {
            self.dedup = false;
        }
        if args.bool("no-coalesce") {
            self.coalesce = false;
        }
        if let Some(a) = args.get("arbiter") {
            self.arbiter =
                ArbiterPolicy::parse(a).with_context(|| format!("arbiter policy {a}"))?;
        }
        if args.bool("sqrt-sample") {
            self.sqrt_sample = true;
        }
        if args.bool("small-filter") {
            self.small_filter = true;
        }
        self.log_every = args.usize_or("log-every", self.log_every);
        if let Some(c) = args.get("curve-out") {
            self.curve_out = Some(PathBuf::from(c));
        }
        Ok(self)
    }

    pub fn load(args: &Args) -> Result<TrainConfig> {
        let base = match args.get("config") {
            Some(path) => TrainConfig::from_file(Path::new(path))?,
            None => TrainConfig::default(),
        };
        base.apply_args(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(content: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dtr_cfg_{}.json", content.len()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn defaults_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.budget_ratio, Some(0.9));
        assert_eq!(c.heuristic, Heuristic::dtr_eq());
        assert_eq!(c.backend, BackendKind::Interp);
        assert!(c.model.validate().is_ok());
    }

    #[test]
    fn parses_file() {
        let p = write_tmp(
            r#"{"steps": 7, "budget_ratio": 0.4, "heuristic": "h_lru",
                "policy": "banish", "optimizer": "sgd", "log_every": 2,
                "backend": "interp",
                "model": {"vocab": 32, "d_model": 16, "n_heads": 2,
                          "d_ff": 32, "seq": 8, "batch": 2, "n_layers": 1}}"#,
        );
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.budget_ratio, Some(0.4));
        assert_eq!(c.heuristic, Heuristic::lru());
        assert_eq!(c.policy, DeallocPolicy::Banish);
        assert_eq!(c.optimizer, Optimizer::Sgd);
        assert_eq!(c.model.vocab, 32);
        assert_eq!(c.model.n_layers, 1);
    }

    #[test]
    fn rejects_unknown_keys() {
        let p = write_tmp(r#"{"stepz": 7}"#);
        assert!(TrainConfig::from_file(&p).is_err());
        let p2 = write_tmp(r#"{"model": {"wocab": 9}}"#);
        assert!(TrainConfig::from_file(&p2).is_err());
    }

    #[test]
    fn rejects_invalid_model_dims() {
        let p = write_tmp(r#"{"model": {"d_model": 30, "n_heads": 4}}"#);
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn cli_overrides_file() {
        let p = write_tmp(r#"{"steps": 7}"#);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--steps".to_string(),
                "99".to_string(),
                "--heuristic".to_string(),
                "h_dtr".to_string(),
                "--layers".to_string(),
                "3".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.steps, 99);
        assert_eq!(c.heuristic, Heuristic::dtr());
        assert_eq!(c.model.n_layers, 3);
    }

    #[test]
    fn index_knob_parses_and_overrides() {
        let p = write_tmp(r#"{"index": "scan"}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.index, PolicyKind::Scan);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--index".to_string(),
                "indexed".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.index, PolicyKind::Indexed);
        let p = write_tmp(r#"{"index": "differential"}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.index, PolicyKind::Differential);
        let p = write_tmp(r#"{"index": "cached"}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.index, PolicyKind::Cached);
        let bad = write_tmp(r#"{"index": "fancy"}"#);
        assert!(TrainConfig::from_file(&bad).is_err());
    }

    #[test]
    fn serve_knobs_parse_and_override() {
        let c = TrainConfig::default();
        assert_eq!(c.tenants, 1);
        assert_eq!(c.arbiter, ArbiterPolicy::GlobalReclaim);
        let p = write_tmp(r#"{"tenants": 4, "arbiter": "static-split"}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.tenants, 4);
        assert_eq!(c.arbiter, ArbiterPolicy::StaticSplit);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--tenants".to_string(),
                "8".to_string(),
                "--arbiter".to_string(),
                "global".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.tenants, 8);
        assert_eq!(c.arbiter, ArbiterPolicy::GlobalReclaim);
        let bad = write_tmp(r#"{"arbiter": "roundrobin"}"#);
        assert!(TrainConfig::from_file(&bad).is_err());
    }

    #[test]
    fn global_index_and_auto_crossover_knobs_parse_and_override() {
        let c = TrainConfig::default();
        assert_eq!(c.global_index, GlobalIndexKind::Shared, "shared must be the default");
        assert_eq!(c.auto_crossover, AUTO_CROSSOVER_POOL);
        let p = write_tmp(r#"{"global_index": "scan", "auto_crossover": 0}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.global_index, GlobalIndexKind::Scan);
        assert_eq!(c.auto_crossover, 0);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--global-index".to_string(),
                "shared".to_string(),
                "--auto-crossover".to_string(),
                "1".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.global_index, GlobalIndexKind::Shared, "flag must win over the file");
        assert_eq!(c.auto_crossover, 1);
        let bad = write_tmp(r#"{"global_index": "gossip"}"#);
        assert!(TrainConfig::from_file(&bad).is_err());
    }

    #[test]
    fn threads_knob_parses_and_overrides() {
        assert_eq!(TrainConfig::default().threads, 1);
        let p = write_tmp(r#"{"threads": 4}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.threads, 4);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--threads".to_string(),
                "2".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.threads, 2);
    }

    #[test]
    fn fused_knob_parses_and_overrides() {
        assert!(!TrainConfig::default().fused, "fused must default off (bit-exact traces)");
        let p = write_tmp(r#"{"fused": true}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert!(c.fused);
        let p2 = write_tmp(r#"{"fused": false}"#);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p2.to_str().unwrap().to_string(),
                "--fused".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert!(c.fused, "--fused flag must win over the file");
        let bad = write_tmp(r#"{"fused": "yes"}"#);
        assert!(TrainConfig::from_file(&bad).is_err());
    }

    #[test]
    fn queue_cap_knob_parses_and_overrides() {
        assert_eq!(TrainConfig::default().queue_cap, 64);
        let p = write_tmp(r#"{"queue_cap": 8}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.queue_cap, 8);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p.to_str().unwrap().to_string(),
                "--queue-cap".to_string(),
                "3".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.queue_cap, 3);
    }

    #[test]
    fn dedup_and_coalesce_knobs_parse_and_override() {
        let c = TrainConfig::default();
        assert!(c.dedup, "dedup must default on (pinned floor is the capacity win)");
        assert!(c.coalesce, "coalesce must default on (bitwise-equal perf knob)");
        let p = write_tmp(r#"{"dedup": false, "coalesce": false}"#);
        let c = TrainConfig::from_file(&p).unwrap();
        assert!(!c.dedup);
        assert!(!c.coalesce);
        let p2 = write_tmp(r#"{"dedup": true, "coalesce": true}"#);
        let args = crate::util::cli::Args::parse(
            vec![
                "--config".to_string(),
                p2.to_str().unwrap().to_string(),
                "--no-dedup".to_string(),
                "--no-coalesce".to_string(),
            ]
            .into_iter(),
        );
        let c = TrainConfig::load(&args).unwrap();
        assert!(!c.dedup, "--no-dedup must win over the file");
        assert!(!c.coalesce, "--no-coalesce must win over the file");
        let bad = write_tmp(r#"{"dedup": "yes"}"#);
        assert!(TrainConfig::from_file(&bad).is_err());
    }

    #[test]
    fn fused_executor_builds_and_reports_flag() {
        let c = TrainConfig { fused: true, ..TrainConfig::default() };
        let exec = c.build_executor().unwrap();
        assert_eq!(exec.name(), "interp");
    }

    #[test]
    fn no_budget_flag() {
        let args = crate::util::cli::Args::parse(vec!["--no-budget".to_string()].into_iter());
        let c = TrainConfig::load(&args).unwrap();
        assert_eq!(c.budget_ratio, None);
    }

    #[test]
    fn interp_executor_builds_without_artifacts() {
        let c = TrainConfig::default();
        let exec = c.build_executor().unwrap();
        assert_eq!(exec.name(), "interp");
        assert_eq!(exec.manifest().config, c.model);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_feature() {
        let c = TrainConfig { backend: BackendKind::Pjrt, ..TrainConfig::default() };
        let err = c.build_executor().unwrap_err();
        assert!(format!("{err:#}").contains("--features pjrt"));
    }
}
