//! Training coordinator: config system, launcher, metrics, loss-curve
//! logging. This is the user-facing layer a downstream team drives
//! (`dtr-repro train --config configs/train_small.json` or flag overrides).

pub mod config;
pub mod trainer;

pub use config::TrainConfig;
pub use trainer::{train, TrainReport};
