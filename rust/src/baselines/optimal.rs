//! Exhaustively optimal rematerialization scheduling for *small general
//! DAGs* — our stand-in for Checkmate's ILP solver (DESIGN.md §5): Dijkstra
//! over residency states where executing an operator costs its compute and
//! evictions are free edges.
//!
//! State: bitmask of resident values (unit sizes). An operator is executable
//! when all its dependencies are resident; the goal is any state where every
//! target is resident *simultaneously* (the output condition). This explores
//! every schedule, including the reorderings static planners exploit — on
//! the Theorem-3.2 adversarial graph it finds the Θ(N) path-at-a-time plan.

use std::collections::BinaryHeap;

/// A small DAG: `deps[i]` lists the values node `i` reads (indices < i).
/// `cost[i]` is node i's compute cost. Node count must be ≤ 20.
#[derive(Debug, Clone)]
pub struct SmallDag {
    pub deps: Vec<Vec<usize>>,
    pub cost: Vec<u64>,
}

impl SmallDag {
    pub fn n(&self) -> usize {
        self.deps.len()
    }

    /// Linear chain of `n` unit ops.
    pub fn chain(n: usize) -> SmallDag {
        SmallDag {
            deps: (0..n).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect(),
            cost: vec![1; n],
        }
    }
}

/// Minimal total compute to reach a state where all `targets` are resident
/// at once, with at most `budget` values resident at any time. Returns
/// `None` if infeasible.
pub fn optimal_cost(dag: &SmallDag, budget: u32, targets: &[usize]) -> Option<u64> {
    let n = dag.n();
    assert!(n <= 20, "state space is 2^n");
    let full = 1u32 << n;
    let target_mask: u32 = targets.iter().fold(0, |m, &t| m | (1 << t));
    let dep_masks: Vec<u32> = dag
        .deps
        .iter()
        .map(|ds| ds.iter().fold(0u32, |m, &d| m | (1 << d)))
        .collect();

    let mut dist: Vec<u64> = vec![u64::MAX; full as usize];
    let mut heap: BinaryHeap<std::cmp::Reverse<(u64, u32)>> = BinaryHeap::new();
    dist[0] = 0;
    heap.push(std::cmp::Reverse((0, 0)));

    while let Some(std::cmp::Reverse((d, mask))) = heap.pop() {
        if d > dist[mask as usize] {
            continue;
        }
        if mask & target_mask == target_mask {
            return Some(d);
        }
        // Free evictions of non-target values (evicting targets is never
        // useful on the way to the goal only if they must be recomputed —
        // allow evicting anything for full generality).
        for i in 0..n {
            let bit = 1u32 << i;
            if mask & bit != 0 {
                let next = mask & !bit;
                if d < dist[next as usize] {
                    dist[next as usize] = d;
                    heap.push(std::cmp::Reverse((d, next)));
                }
            }
        }
        // Execute any enabled op (within budget).
        for i in 0..n {
            let bit = 1u32 << i;
            if mask & bit != 0 {
                continue; // already resident
            }
            if mask & dep_masks[i] != dep_masks[i] {
                continue; // deps missing
            }
            let next = mask | bit;
            if next.count_ones() > budget {
                continue;
            }
            let nd = d + dag.cost[i];
            if nd < dist[next as usize] {
                dist[next as usize] = nd;
                heap.push(std::cmp::Reverse((nd, next)));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_with_full_memory_is_n() {
        let dag = SmallDag::chain(8);
        assert_eq!(optimal_cost(&dag, 8, &[7]), Some(8));
    }

    #[test]
    fn chain_with_two_slots_quadratic() {
        // Budget 2: keep only the frontier; computing node k costs k+1 from
        // scratch — but the final target only needs one pass: cost = n.
        let dag = SmallDag::chain(6);
        assert_eq!(optimal_cost(&dag, 2, &[5]), Some(6));
    }

    #[test]
    fn two_targets_force_recompute_under_tight_memory() {
        // Targets 0 and 5 must coexist; budget 2 means the frontier can't
        // carry node 0 along: recompute needed.
        let dag = SmallDag::chain(6);
        let tight = optimal_cost(&dag, 2, &[0, 5]).unwrap();
        let loose = optimal_cost(&dag, 6, &[0, 5]).unwrap();
        assert_eq!(loose, 6);
        assert!(tight > loose, "tight {tight} loose {loose}");
    }

    #[test]
    fn infeasible_when_budget_below_deps() {
        // A node with 3 deps + itself needs 4 resident values.
        let dag = SmallDag {
            deps: vec![vec![], vec![], vec![], vec![0, 1, 2]],
            cost: vec![1; 4],
        };
        assert_eq!(optimal_cost(&dag, 3, &[3]), None);
        assert!(optimal_cost(&dag, 4, &[3]).is_some());
    }

    #[test]
    fn adversarial_star_paths_solved_linearly() {
        // B paths of length L off a root: a static scheduler does them one
        // at a time in ~n ops even with budget 3.
        let b = 3usize;
        let l = 4usize;
        let mut deps: Vec<Vec<usize>> = vec![vec![]]; // root = node 0
        for p in 0..b {
            for i in 0..l {
                if i == 0 {
                    deps.push(vec![0]);
                } else {
                    deps.push(vec![p * l + i]);
                }
            }
        }
        let n = deps.len();
        let dag = SmallDag { deps, cost: vec![1; n] };
        let ends: Vec<usize> = (0..b).map(|p| p * l + l).collect();
        // With budget = b ends + root + frontier: all ends fit.
        let c = optimal_cost(&dag, b as u32 + 2, &ends).unwrap();
        assert_eq!(c, n as u64, "static optimum computes each node once");
    }

    #[test]
    fn costs_respected() {
        let dag = SmallDag { deps: vec![vec![], vec![0]], cost: vec![5, 7] };
        assert_eq!(optimal_cost(&dag, 2, &[1]), Some(12));
    }
}
