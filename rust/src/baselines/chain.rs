//! Static checkpointing baselines on linear chains (the Fig. 3 comparators):
//!
//! * **Chen √N** (Chen et al. 2016): evenly spaced segment checkpoints, one
//!   extra forward pass;
//! * **Chen greedy** (the GreedyRemat-style variant): grow segments until
//!   the budget is hit;
//! * **unbounded**: no eviction (2N ops, N memory).
//!
//! All are expressed analytically for a unit-cost unit-size chain of length
//! `n` under a peak-memory budget `b` (in tensors), returning total operator
//! executions (forward + recompute + backward) — the same metric the DTR
//! simulator reports — or `None` when the scheme cannot fit in `b`.

/// Cost of running forward+backward with no eviction.
pub fn unbounded(n: usize) -> (u64, u64) {
    (2 * n as u64, n as u64 + 2)
}

/// Chen et al. segmented checkpointing with segment length `k`:
/// memory ≈ n/k checkpoints + k live recomputed tensors + O(1) for the
/// gradient; compute = n forward + (n - n/k) recompute + n backward.
fn chen_with_segment(n: usize, k: usize) -> (u64, u64) {
    let checkpoints = n.div_ceil(k);
    let mem = checkpoints as u64 + k as u64 + 2;
    let recompute = (n - checkpoints) as u64;
    (2 * n as u64 + recompute, mem)
}

/// Chen √N: pick the segment length minimizing ops subject to the budget.
/// Returns `None` if no segmentation fits.
pub fn chen_sqrt(n: usize, b: u64) -> Option<(u64, u64)> {
    // The classic choice is k = √n; under a budget we search all k and keep
    // the cheapest feasible (the paper's scheme family).
    let mut best: Option<(u64, u64)> = None;
    for k in 1..=n {
        let (ops, mem) = chen_with_segment(n, k);
        if mem <= b && best.map_or(true, |(bo, _)| ops < bo) {
            best = Some((ops, mem));
        }
    }
    best
}

/// Chen greedy: fix checkpoint *count* to the budget's leftover after the
/// working set, i.e. segments of length ⌈n / (b - 2)⌉ — a memory-first
/// greedy placement (sizes only, like GreedyRemat).
pub fn chen_greedy(n: usize, b: u64) -> Option<(u64, u64)> {
    if b < 4 {
        return None;
    }
    // Reserve half the budget for checkpoints, half for the live segment.
    let checkpoints = ((b - 2) / 2).max(1) as usize;
    let k = n.div_ceil(checkpoints);
    let (ops, mem) = chen_with_segment(n, k);
    if mem <= b {
        Some((ops, mem))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_is_2n() {
        assert_eq!(unbounded(100).0, 200);
    }

    #[test]
    fn chen_sqrt_one_extra_forward() {
        // With ample budget the optimum inside the family approaches zero
        // recompute; at b ≈ 2√n it is ~one extra forward pass.
        let n = 1024;
        let b = 2 * (n as f64).sqrt() as u64 + 2;
        let (ops, mem) = chen_sqrt(n, b).unwrap();
        assert!(mem <= b);
        let extra = ops - 2 * n as u64;
        assert!(extra <= n as u64, "extra {extra} > one forward pass");
        assert!(extra >= n as u64 / 2, "extra {extra} suspiciously low");
    }

    #[test]
    fn chen_infeasible_below_2sqrt() {
        // Minimum memory of the scheme is ~2√n.
        assert!(chen_sqrt(1024, 16).is_none());
        assert!(chen_sqrt(1024, 80).is_some());
    }

    #[test]
    fn more_memory_never_hurts() {
        let n = 512;
        let mut last = u64::MAX;
        for b in [50u64, 80, 120, 240, 520] {
            if let Some((ops, _)) = chen_sqrt(n, b) {
                assert!(ops <= last, "ops increased with memory");
                last = ops;
            }
        }
        assert!(last < u64::MAX);
    }

    #[test]
    fn greedy_feasible_and_worse_or_equal() {
        let n = 512;
        for b in [60u64, 100, 200] {
            let g = chen_greedy(n, b).unwrap();
            let s = chen_sqrt(n, b).unwrap();
            assert!(g.0 >= s.0, "greedy beat exhaustive-k search");
        }
    }
}
