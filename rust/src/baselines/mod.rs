//! Static checkpointing baselines for the Fig. 3 comparison: Chen et al.
//! √N segmentation (+greedy), Griewank–Walther Revolve (optimal on chains),
//! and an exhaustively optimal small-DAG scheduler (the Checkmate stand-in).

pub mod chain;
pub mod optimal;
pub mod revolve;

pub use chain::{chen_greedy, chen_sqrt, unbounded};
pub use optimal::{optimal_cost, SmallDag};
pub use revolve::{optimal_chain_ops, Revolve};
