//! Griewank–Walther Treeverse/Revolve: *optimal* checkpointing for reversing
//! a homogeneous chain with a fixed number of checkpoint slots. On unit
//! chains this is exactly the schedule Checkmate's ILP would find, so it
//! doubles as our optimal comparator in Fig. 3 (DESIGN.md §5).
//!
//! `forward_ops(l, c)` is the binomial-checkpointing dynamic program (the
//! form used by Gruslys et al. 2016): minimal *total forward executions*
//! (including the initial advance) to reverse a chain of `l` steps whose
//! start state is resident, with `c` spare checkpoint slots:
//!
//! ```text
//! D(0, c) = 0
//! D(l, 0) = l (l + 1) / 2              (re-advance from the start each time)
//! D(l, c) = min_{1<=y<=l} y + D(l-y, c-1) + D(y-1, c)
//! ```
//!
//! advance `y` steps and checkpoint there (one slot), reverse the suffix
//! with `c-1` slots, then reverse the remaining prefix with all `c` slots
//! (the suffix checkpoint has been freed). With `c >= l` this is `l`
//! (store everything); the optimum interpolates binomially in between.

/// Memoized DP table (flat, indexed l * (c_max+1) + c).
pub struct Revolve {
    l_max: usize,
    c_max: usize,
    table: Vec<u64>,
}

const UNSET: u64 = u64::MAX;

impl Revolve {
    pub fn new(l_max: usize, c_max: usize) -> Self {
        Revolve { l_max, c_max, table: vec![UNSET; (l_max + 1) * (c_max + 1)] }
    }

    /// Minimal total forward executions to reverse a chain of `l` steps with
    /// `c` spare checkpoint slots (iterative bottom-up fill).
    pub fn forward_ops(&mut self, l: usize, c: usize) -> u64 {
        assert!(l <= self.l_max && c <= self.c_max, "Revolve table too small");
        let cw = self.c_max + 1;
        // Bottom-up: for each cc in 0..=c, fill lengths 0..=l.
        for cc in 0..=c {
            for ll in 0..=l {
                let idx = ll * cw + cc;
                if self.table[idx] != UNSET {
                    continue;
                }
                let v = if ll == 0 {
                    0
                } else if cc == 0 {
                    (ll as u64 * (ll as u64 + 1)) / 2
                } else {
                    let mut best = u64::MAX;
                    for y in 1..=ll {
                        let cost = y as u64
                            + self.table[(ll - y) * cw + (cc - 1)]
                            + self.table[(y - 1) * cw + cc];
                        if cost < best {
                            best = cost;
                        }
                    }
                    best
                };
                self.table[idx] = v;
            }
        }
        self.table[l * cw + c]
    }

    /// Total operator executions (forwards incl. recomputation + n backward
    /// steps) for a chain of `n` under peak-memory budget `b` (unit
    /// tensors). Slots: the input, the working value, and the gradient are
    /// live, leaving `b - 3` checkpoint slots.
    pub fn total_ops(&mut self, n: usize, b: u64) -> Option<u64> {
        if b < 4 {
            return None;
        }
        let c = (b - 3).min(self.c_max as u64) as usize;
        Some(self.forward_ops(n, c) + n as u64)
    }
}

/// Convenience: one-shot optimal ops for a unit chain.
pub fn optimal_chain_ops(n: usize, b: u64) -> Option<u64> {
    let c = b.saturating_sub(3).min(n as u64) as usize;
    Revolve::new(n, c).total_ops(n, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_cases() {
        let mut r = Revolve::new(16, 4);
        assert_eq!(r.forward_ops(0, 2), 0);
        assert_eq!(r.forward_ops(4, 0), 10); // 4+3+2+1
        assert_eq!(r.forward_ops(1, 3), 1);
    }

    #[test]
    fn store_everything_is_linear() {
        let mut r = Revolve::new(32, 32);
        assert_eq!(r.forward_ops(32, 32), 32);
        // total = fwd + bwd = 2n with ample budget.
        assert_eq!(optimal_chain_ops(32, 64), Some(64));
    }

    #[test]
    fn monotone_in_checkpoints() {
        let mut r = Revolve::new(64, 16);
        let mut last = u64::MAX;
        for c in 1..=16 {
            let v = r.forward_ops(64, c);
            assert!(v <= last);
            last = v;
        }
    }

    #[test]
    fn sqrt_budget_gives_linear_overhead() {
        // At b ≈ 2√n the optimal extra cost is about one forward pass
        // (Chen's √N bound; the true optimum is below it).
        let n = 256;
        let b = 2 * 16 + 3;
        let ops = optimal_chain_ops(n, b).unwrap();
        let extra = ops - 2 * n as u64;
        assert!(extra <= n as u64, "extra {extra}");
    }

    #[test]
    fn optimal_beats_chen() {
        use crate::baselines::chain::chen_sqrt;
        let n = 256;
        for b in [20u64, 40, 80, 160] {
            if let Some((chen_ops, _)) = chen_sqrt(n, b) {
                let opt = optimal_chain_ops(n, b).unwrap();
                assert!(opt <= chen_ops, "optimal {opt} > chen {chen_ops} at b={b}");
            }
        }
    }

    #[test]
    fn matches_exhaustive_small_graph_optimum() {
        // Cross-validate the chain DP against the Dijkstra scheduler on a
        // small chain: same model (forward chain; targets = each gradient
        // requires its forward input). We compare forward-op counts for the
        // pure "reverse sweep" abstraction: total_ops(n, b) vs Dijkstra on
        // the forward chain asked to materialize each node in reverse order.
        // The Dijkstra model has no gradient ops, so compare forward counts:
        // D(n, c) from the DP vs optimal sequential touches.
        let mut r = Revolve::new(8, 8);
        // With one slot: D(3,1) = min_y y + D(3-y,0) + D(y-1,1)
        //  y=1: 1 + D(2,0)=3 + 0 = 4 ; y=2: 2 + 1 + D(1,1)=1 → 4; y=3: 3+0+D(2,1)
        //  D(2,1)= y=1: 1+1+0=2; y=2: 2+0+D(1,1)=3 → 2. So y=3: 3+0+2=5.
        assert_eq!(r.forward_ops(3, 1), 4);
        assert_eq!(r.forward_ops(2, 1), 2);
    }

    #[test]
    fn infeasible_budget() {
        assert!(optimal_chain_ops(64, 3).is_none());
    }
}
