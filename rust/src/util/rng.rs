//! Small deterministic PRNG (SplitMix64) used everywhere randomness is
//! needed: the `h_rand` heuristic, workload generators, sampling
//! optimizations, and the property-test harness. We avoid `rand` (not in the
//! offline crate cache) and want cross-run reproducibility from a seed.

/// SplitMix64: tiny, fast, and statistically solid for simulation purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point.
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (fine for simulation use).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Partial Fisher–Yates over an index vec; fine for our sizes.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(5, 8);
            assert!((5..=8).contains(&x));
        }
    }
}
