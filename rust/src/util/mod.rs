//! Dependency-free support utilities (the offline crate cache has no serde /
//! rand / proptest / criterion — see DESIGN.md §6).

pub mod cli;
pub mod csv;
pub mod json;
pub mod miniprop;
pub mod rng;
