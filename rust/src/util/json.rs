//! Minimal JSON parser/writer.
//!
//! `serde`/`serde_json` are not in the offline crate cache, and the paper's
//! Appendix C.6 log format, our artifact manifest, and the config system are
//! all JSON — so we carry a small, dependency-free implementation. It
//! supports the full JSON value model with the usual escapes; numbers are
//! kept as f64 (all our integers fit in 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Field access with a descriptive error, for manifest/config parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key: {key}"))
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v);
        }
    }

    pub fn push(&mut self, v: Json) {
        if let Json::Arr(a) = self {
            a.push(v);
        }
    }

    // ---- writer ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ----

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Parse a newline-delimited JSON stream (one value per non-empty line) —
/// the format the simulator logs use, mirroring the paper's prototype.
pub fn parse_lines(input: &str) -> Result<Vec<Json>, JsonError> {
    input
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse)
        .collect()
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // No surrogate-pair handling: our producers never
                            // emit astral-plane characters.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":"x\ny"}],"c":null,"d":{"e":[true,false]}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\tbA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\tbA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n":3,"s":"x","b":true,"a":[1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn parse_lines_stream() {
        let src = "{\"a\":1}\n\n{\"a\":2}\n";
        let vs = parse_lines(src).unwrap();
        assert_eq!(vs.len(), 2);
    }

    #[test]
    fn big_integers_exact() {
        // Tensor sizes in bytes can be large; ensure integers round-trip.
        let v = parse("123456789012").unwrap();
        assert_eq!(v.as_u64(), Some(123456789012));
        assert_eq!(v.to_string(), "123456789012");
    }
}
