//! Miniature property-testing harness (proptest is not in the offline crate
//! cache). Runs a predicate over many seeded random cases and, on failure,
//! shrinks the *size parameter* by halving to report a smaller counter-
//! example seed/size pair.

use super::rng::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop(rng, size)` for `cases` random cases with sizes in
/// `[min_size, max_size]`. On failure, attempt to shrink `size` by halving
/// (re-running with the same seed) and panic with the smallest failing case.
pub fn check<F>(name: &str, cases: usize, min_size: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut meta = Rng::new(0xD7A_5EED);
    for case in 0..cases {
        let seed = meta.next_u64();
        let size = min_size + meta.index(max_size - min_size + 1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: halve the size while it still fails with this seed.
            let mut best = PropFailure { seed, size, message: msg };
            let mut s = size / 2;
            while s >= min_size.max(1) {
                let mut rng = Rng::new(seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        best = PropFailure { seed, size: s, message: m };
                        if s == min_size {
                            break;
                        }
                        s = (s / 2).max(min_size);
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}/{cases}) at seed={} size={}: {}",
                best.seed, best.size, best.message
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, 1, 10, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, 4, 100, |_, size| {
            if size >= 4 {
                Err("too big".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        // The same meta-seed must generate identical case streams.
        let mut first = Vec::new();
        check("record", 5, 1, 5, |rng, size| {
            first.push((rng.next_u64(), size));
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 5, 1, 5, |rng, size| {
            second.push((rng.next_u64(), size));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
