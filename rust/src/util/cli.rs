//! Minimal flag parser (clap is not in the offline crate cache).
//!
//! Supports `--key value`, `--key=value`, and bare `--flag` booleans, plus
//! positional arguments, with typed accessors and a usage-error path.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list (e.g. `--budgets 0.1,0.2,0.5`).
    pub fn list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Vec<f64> {
        self.list(key)
            .map(|v| v.iter().filter_map(|s| s.parse().ok()).collect())
            .unwrap_or_else(|| default.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("fig2 --out results/x.csv --budget=1000 --verbose");
        assert_eq!(a.positional, vec!["fig2"]);
        assert_eq!(a.get("out"), Some("results/x.csv"));
        assert_eq!(a.u64_or("budget", 0), 1000);
        assert!(a.bool("verbose"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.str_or("x", "d"), "d");
        assert_eq!(a.f64_or("y", 2.5), 2.5);
    }

    #[test]
    fn lists() {
        let a = parse("cmd --budgets 0.1,0.2,0.5");
        assert_eq!(a.f64_list_or("budgets", &[]), vec![0.1, 0.2, 0.5]);
    }
}
