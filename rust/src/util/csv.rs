//! Tiny CSV writer used by every figure harness: rows print both to stdout
//! (so `dtr-repro figN` shows the paper's series directly) and to an output
//! file for plotting.

use std::io::Write;
use std::path::Path;

pub struct CsvOut {
    file: Option<std::fs::File>,
    echo: bool,
}

impl CsvOut {
    /// `path = None` prints to stdout only.
    pub fn create(path: Option<&Path>, echo: bool) -> anyhow::Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(CsvOut { file, echo })
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> anyhow::Result<()> {
        let line = cells.iter().map(|c| c.as_ref()).collect::<Vec<_>>().join(",");
        if self.echo {
            println!("{line}");
        }
        if let Some(f) = &mut self.file {
            writeln!(f, "{line}")?;
        }
        Ok(())
    }
}

/// Format a float with enough digits for plotting but stable output.
pub fn f(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{:.4}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let dir = std::env::temp_dir().join("dtr_csv_test");
        let path = dir.join("x.csv");
        let mut out = CsvOut::create(Some(&path), false).unwrap();
        out.row(&["a", "b"]).unwrap();
        out.row(&[f(1.0), f(2.5)]).unwrap();
        drop(out);
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,b\n1,2.5000\n");
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(3.0), "3");
        assert_eq!(f(0.12345), "0.1235");
    }
}
