//! `dtr-repro` — experiment launcher. Subcommands regenerate each paper
//! table/figure; see DESIGN.md §4 for the experiment index.
fn main() {
    if let Err(e) = dtr::repro::dispatch() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
