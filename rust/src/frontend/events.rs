//! Event bus: the front-end's observability spine. Every request deposits
//! one terminal event (completed/rejected/failed) with timestamps off a
//! single shared epoch, and the summarizer folds the event log into
//! requests/sec and p50/p95/p99 latency per tenant class — the measured
//! proxy for the ROADMAP's "millions of users" claim.

use std::sync::Mutex;
use std::time::Instant;

use super::request::{Outcome, RequestOp};

/// Terminal record of one request's life.
#[derive(Debug, Clone)]
pub struct RequestEvent {
    pub id: u64,
    pub class: usize,
    pub op: RequestOp,
    pub outcome: Outcome,
    /// Timestamps in nanoseconds since the bus epoch. Rejected requests
    /// have `start_ns == done_ns == submit_ns` (they never ran).
    pub submit_ns: u64,
    pub start_ns: u64,
    pub done_ns: u64,
    /// Queue depth observed at the admission decision: post-enqueue depth
    /// for admitted requests, the (== cap) depth for shed ones.
    pub queue_depth: usize,
    /// Size of the worker batch this request ran in (0 if it never ran).
    pub batch: usize,
}

impl RequestEvent {
    /// Client-visible latency: queue wait + service time.
    pub fn latency_ns(&self) -> u64 {
        self.done_ns.saturating_sub(self.submit_ns)
    }
}

/// Append-only event log with one shared clock. Recording is a short
/// mutex push (workers record once per request, after the op ran, so the
/// lock is far off the compute path).
pub struct EventBus {
    epoch: Instant,
    events: Mutex<Vec<RequestEvent>>,
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus { epoch: Instant::now(), events: Mutex::new(Vec::new()) }
    }

    /// Nanoseconds since the bus epoch (every timestamp's common clock).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    pub fn record(&self, ev: RequestEvent) {
        self.events.lock().expect("event bus poisoned").push(ev);
    }

    /// Drain the log (ordered by record time, not request id).
    pub fn take(&self) -> Vec<RequestEvent> {
        std::mem::take(&mut *self.events.lock().expect("event bus poisoned"))
    }
}

/// Per-class (or aggregate) service metrics over one run.
#[derive(Debug, Clone)]
pub struct ClassMetrics {
    /// Class index, or `usize::MAX` for the all-classes aggregate.
    pub class: usize,
    pub kind: &'static str,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub failed: usize,
    /// Completed requests per wall-clock second.
    pub requests_per_sec: f64,
    /// Latency percentiles over *completed* requests (nearest-rank).
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    /// Mean worker-batch size over completed requests (batching evidence).
    pub mean_batch: f64,
}

impl ClassMetrics {
    fn empty(class: usize, kind: &'static str) -> ClassMetrics {
        ClassMetrics {
            class,
            kind,
            submitted: 0,
            completed: 0,
            rejected: 0,
            failed: 0,
            requests_per_sec: 0.0,
            p50_ns: 0,
            p95_ns: 0,
            p99_ns: 0,
            max_ns: 0,
            mean_batch: 0.0,
        }
    }

    fn fold(
        events: &[&RequestEvent],
        class: usize,
        kind: &'static str,
        wall_ns: u64,
    ) -> ClassMetrics {
        let mut m = ClassMetrics::empty(class, kind);
        let mut lats: Vec<u64> = Vec::new();
        let mut batch_sum = 0usize;
        for ev in events {
            m.submitted += 1;
            match ev.outcome {
                Outcome::Completed => {
                    m.completed += 1;
                    lats.push(ev.latency_ns());
                    batch_sum += ev.batch;
                }
                Outcome::Rejected => m.rejected += 1,
                Outcome::Failed => m.failed += 1,
            }
        }
        lats.sort_unstable();
        m.p50_ns = percentile(&lats, 50.0);
        m.p95_ns = percentile(&lats, 95.0);
        m.p99_ns = percentile(&lats, 99.0);
        m.max_ns = lats.last().copied().unwrap_or(0);
        if m.completed > 0 {
            m.mean_batch = batch_sum as f64 / m.completed as f64;
        }
        if wall_ns > 0 {
            m.requests_per_sec = m.completed as f64 / (wall_ns as f64 / 1e9);
        }
        m
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (0 if empty).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Fold the event log into one `ClassMetrics` per class (indexed by the
/// given kind names) plus the all-classes aggregate row.
pub fn summarize(
    events: &[RequestEvent],
    class_kinds: &[&'static str],
    wall_ns: u64,
) -> (Vec<ClassMetrics>, ClassMetrics) {
    let per_class: Vec<ClassMetrics> = class_kinds
        .iter()
        .enumerate()
        .map(|(ci, kind)| {
            let evs: Vec<&RequestEvent> = events.iter().filter(|e| e.class == ci).collect();
            ClassMetrics::fold(&evs, ci, kind, wall_ns)
        })
        .collect();
    let all: Vec<&RequestEvent> = events.iter().collect();
    let total = ClassMetrics::fold(&all, usize::MAX, "all", wall_ns);
    (per_class, total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 50.0), 50);
        assert_eq!(percentile(&v, 95.0), 95);
        assert_eq!(percentile(&v, 99.0), 99);
        assert_eq!(percentile(&v, 100.0), 100);
        assert_eq!(percentile(&[7], 50.0), 7);
        assert_eq!(percentile(&[], 99.0), 0);
        // Small samples round up to the next rank.
        assert_eq!(percentile(&[10, 20], 50.0), 10);
        assert_eq!(percentile(&[10, 20], 99.0), 20);
    }

    #[test]
    fn summarize_splits_outcomes_by_class() {
        let mk = |id, class, outcome, lat| RequestEvent {
            id,
            class,
            op: RequestOp::Infer,
            outcome,
            submit_ns: 0,
            start_ns: 0,
            done_ns: lat,
            queue_depth: 1,
            batch: 2,
        };
        let events = vec![
            mk(1, 0, Outcome::Completed, 100),
            mk(2, 0, Outcome::Completed, 300),
            mk(3, 0, Outcome::Rejected, 0),
            mk(4, 1, Outcome::Failed, 0),
        ];
        let (per, total) = summarize(&events, &["a", "b"], 1_000_000_000);
        assert_eq!(per[0].completed, 2);
        assert_eq!(per[0].rejected, 1);
        assert_eq!(per[0].p50_ns, 100);
        assert_eq!(per[0].p99_ns, 300);
        assert_eq!(per[0].mean_batch, 2.0);
        assert_eq!(per[1].failed, 1);
        assert_eq!(per[1].p99_ns, 0);
        assert_eq!(total.submitted, 4);
        assert_eq!(total.completed, 2);
        assert!((total.requests_per_sec - 2.0).abs() < 1e-9);
    }
}
