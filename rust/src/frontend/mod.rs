//! # `dtr::frontend` — event-loop request front-end for the shard fleet
//!
//! `dtr::serve` (PR 5) runs one long-lived training tenant per worker
//! thread; real traffic is the opposite shape: many short requests —
//! inference steps, fine-tune steps, probes — arriving in bursts across
//! tenant classes. This module multiplexes those request streams onto the
//! existing shard `Session`s, following the runtime-core shape of
//! SNIPPETS.md Snippet 1 (locus.codes) one-for-one:
//!
//! * **Orchestrator** ([`run`]) — owns the run: spawns one worker per
//!   shard (each with its own [`TenantDriver`] and arbiter lease from
//!   [`ServePool::lease`]), hands the client a submit handle, then drains
//!   gracefully and folds the event log into a report. Snippet 1's
//!   `Orchestrator` that "spawns subagents and coordinates them".
//! * **Scheduler** ([`scheduler::Scheduler`]) — bounded per-class FIFO
//!   queues behind one mutex + condvar. Submits are admit-or-shed (never
//!   block, never grow unbounded); workers pull FIFO batches of up to
//!   `batch_max` same-class requests, and with
//!   [`FrontendConfig::coalesce`] on (the default) a run of consecutive
//!   `Infer` requests inside a batch runs as **one** batched kernel
//!   invocation (`TenantDriver::infer_batch`: the requests' token batches
//!   stack into one GEMM-widened forward over the shard's single shared
//!   weight copy, and each member's loss comes off its own row-slice,
//!   bitwise what serial service would return). Remaining requests run
//!   back-to-back — that batching win is amortizing queue wakeups and
//!   keeping a shard's working set hot. Snippet 1's `Scheduler`
//!   "assigning tasks to idle agents".
//! * **Event bus** ([`events::EventBus`]) — every request deposits exactly
//!   one terminal event (completed / rejected / failed) with timestamps
//!   off a shared epoch; [`events::summarize`] turns the log into
//!   requests/sec and p50/p95/p99 latency per tenant class. Snippet 1's
//!   `EventBus` the UI subscribes to.
//!
//! The memory story is PAPER §5 unchanged: DTR interposes on "tensor
//! allocations and operator calls" at a central allocator, and here that
//! chokepoint is the `BudgetArbiter` — every request, on any shard, does
//! its allocations through its shard's revocable lease, so bursty request
//! streams are exactly the concurrent demand the arbiter's policies
//! (static-split vs global-reclaim) are meant to absorb. Because DTR is
//! online (PAPER §1), requests with data-dependent shapes (LSTM/TreeLSTM
//! classes) need no ahead-of-time plan — admission control is the *only*
//! planning the front-end does. When the pool was built
//! [`ServePool::with_dedup`], shard workers also intern their pinned
//! weights in the pool's content-addressed [`WeightStore`], so every
//! transformer shard reads one physical copy of the base model and the
//! fleet's pinned floor scales with distinct models, not shards.
//!
//! **Backpressure contract**: queues are bounded by
//! `TrainConfig::queue_cap`; a submit against a full queue is shed with an
//! explicit [`Outcome::Rejected`] event recording the depth it observed
//! (always `== cap` — pinned by `tests/stress_frontend.rs`). Admitted
//! requests never starve: draining wakes every worker and workers exit
//! only once their queue is empty, so each admitted request ends
//! `Completed` or `Failed`, and after the drain the arbiter ledger is
//! balanced (`ServePool::check_invariants`).

mod events;
mod queue;
mod request;
mod scheduler;

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

pub use events::{percentile, summarize, ClassMetrics, EventBus, RequestEvent};
pub use queue::{Admission, ClassQueue};
pub use request::{ClassSpec, Outcome, Request, RequestOp};
pub use scheduler::Scheduler;

use crate::api::WeightStore;
use crate::dtr;
use crate::serve::{fleet_budget, ServePool, TenantDriver};
use crate::util::rng::Rng;

/// Front-end knobs (the serving-side analogue of `TrainConfig`'s training
/// knobs; `queue_cap` flows in from `TrainConfig::queue_cap`).
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    pub classes: Vec<ClassSpec>,
    /// Per-class queue cap: submits beyond it are shed (backpressure).
    pub queue_cap: usize,
    /// Max same-class requests a worker runs back-to-back per wakeup.
    pub batch_max: usize,
    /// Coalesce runs of consecutive `Infer` requests within a worker batch
    /// into **one** batched kernel invocation (`TenantDriver::infer_batch`
    /// — stacked GEMMs over the shard's single weight copy). Per-request
    /// results are bitwise what serial execution produces, so this is a
    /// pure throughput knob; off restores request-at-a-time service.
    pub coalesce: bool,
}

impl FrontendConfig {
    pub fn new(classes: Vec<ClassSpec>) -> FrontendConfig {
        FrontendConfig { classes, queue_cap: 64, batch_max: 4, coalesce: true }
    }

    /// The canonical mixed fleet: `n` classes, one shard each.
    pub fn mixed(n: usize) -> FrontendConfig {
        FrontendConfig::new(ClassSpec::mixed(n))
    }
}

/// Global budget for a front-end fleet: `pct`% of each shard's non-pinned
/// headroom, summed over every shard of every class (the per-shard
/// [`fleet_budget`] formula; `pct` must be in `1..=100`).
pub fn frontend_budget(classes: &[ClassSpec], pct: u64) -> Result<u64> {
    fleet_budget(&ClassSpec::tenant_specs(classes), pct)
}

/// Client-side handle: submit requests while the run is live. `Sync`, so
/// a client closure may fan submissions out over its own scoped threads
/// (N concurrent streams).
pub struct FrontendHandle<'a> {
    sched: &'a Scheduler,
    bus: &'a EventBus,
}

impl FrontendHandle<'_> {
    /// Submit one request. Returns `false` if it was shed at admission
    /// (queue at cap); the `Rejected` event is recorded on the bus either
    /// way, so accounting stays exact: submitted = completed + rejected +
    /// failed.
    pub fn submit(&self, class: usize, op: RequestOp) -> bool {
        let now = self.bus.now_ns();
        let (req, admission) = self.sched.submit(class, op, now);
        match admission {
            Admission::Enqueued { .. } => true,
            Admission::Shed { depth } => {
                self.bus.record(RequestEvent {
                    id: req.id,
                    class,
                    op,
                    outcome: Outcome::Rejected,
                    submit_ns: now,
                    start_ns: now,
                    done_ns: now,
                    queue_depth: depth,
                    batch: 0,
                });
                false
            }
        }
    }

    /// Current depth of a class queue (load probing).
    pub fn depth(&self, class: usize) -> usize {
        self.sched.depth(class)
    }
}

/// Outcome of one front-end run.
#[derive(Debug, Clone)]
pub struct FrontendReport {
    pub wall_ns: u64,
    /// Per-class service metrics, indexed like `FrontendConfig::classes`.
    pub classes: Vec<ClassMetrics>,
    /// All-classes aggregate.
    pub total: ClassMetrics,
    /// The raw event log (one terminal event per submitted request).
    pub events: Vec<RequestEvent>,
    /// Worker-level errors (driver build failures, worker panics). Request
    /// outcomes already account for these as `Failed`.
    pub errors: Vec<String>,
}

/// Run the front-end: spawn the shard workers, hand the client a submit
/// handle, drain when the client returns, and report. `base` supplies the
/// DTR knobs (heuristic/policy/index); each shard worker gets `base` plus
/// its own freshly leased gate from `pool`.
pub fn run<F>(
    pool: &ServePool,
    cfg: &FrontendConfig,
    base: &dtr::Config,
    client: F,
) -> Result<FrontendReport>
where
    F: FnOnce(&FrontendHandle<'_>),
{
    ensure!(!cfg.classes.is_empty(), "frontend: at least one tenant class required");
    let sched = Scheduler::new(cfg.classes.len(), cfg.queue_cap);
    let bus = EventBus::new();
    let t0 = Instant::now();

    let errors: Vec<String> = std::thread::scope(|scope| {
        let mut workers = Vec::new();
        for (ci, class) in cfg.classes.iter().enumerate() {
            for shard in 0..class.shards.max(1) {
                let mut dcfg = base.clone();
                dcfg.gate = Some(pool.lease());
                let (sched, bus, class) = (&sched, &bus, *class);
                let (batch_max, coalesce) = (cfg.batch_max, cfg.coalesce);
                let store = pool.store().cloned();
                workers.push(scope.spawn(move || {
                    worker_loop(sched, bus, ci, class, shard, dcfg, batch_max, coalesce, store)
                }));
            }
        }

        let handle = FrontendHandle { sched: &sched, bus: &bus };
        client(&handle);

        sched.drain();
        let mut errs = Vec::new();
        for w in workers {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => errs.push(format!("{e:#}")),
                Err(_) => errs.push("frontend worker panicked".to_string()),
            }
        }
        errs
    });

    // A class whose every worker died may leave orphans behind; give them
    // a terminal outcome so the ledger of requests stays balanced.
    for req in sched.drain_leftovers() {
        let now = bus.now_ns();
        bus.record(RequestEvent {
            id: req.id,
            class: req.class,
            op: req.op,
            outcome: Outcome::Failed,
            submit_ns: req.submit_ns,
            start_ns: now,
            done_ns: now,
            queue_depth: req.depth,
            batch: 0,
        });
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;

    // Workers (and their gates) are gone: the drained front-end must leave
    // the arbiter ledger balanced.
    pool.check_invariants().context("frontend drain left the arbiter ledger unbalanced")?;

    let events = bus.take();
    let kinds: Vec<&'static str> = cfg.classes.iter().map(|c| c.kind.name()).collect();
    let (classes, total) = summarize(&events, &kinds, wall_ns);
    Ok(FrontendReport { wall_ns, classes, total, events, errors })
}

/// One shard worker: build the class driver under this shard's leased
/// gate (interning its weights in the pool's shared store when dedup is
/// on), then serve batches until drained. A failed build does not stall
/// the drain — the worker keeps consuming its queue, failing requests,
/// and surfaces the build error to the report.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    sched: &Scheduler,
    bus: &EventBus,
    ci: usize,
    class: ClassSpec,
    shard: usize,
    dcfg: dtr::Config,
    batch_max: usize,
    coalesce: bool,
    store: Option<Arc<WeightStore>>,
) -> Result<()> {
    let mut driver = None;
    let mut build_err = None;
    match TenantDriver::build_with_store(class.kind, dcfg, class.seed + shard as u64, store) {
        Ok(d) => driver = Some(d),
        Err(e) => build_err = Some(e),
    }
    while let Some(batch) = sched.next_batch(ci, batch_max) {
        let bsize = batch.len();
        let mut i = 0;
        while i < batch.len() {
            // Cross-request coalescing: a run of >= 2 consecutive Infer
            // requests becomes ONE batched kernel invocation instead of
            // back-to-back singles. Members share start/done timestamps
            // and record the coalesced group size as their batch.
            let run = if coalesce && driver.is_some() {
                batch[i..].iter().take_while(|r| r.op == RequestOp::Infer).count()
            } else {
                0
            };
            if run >= 2 {
                let start_ns = bus.now_ns();
                let outcome = match driver.as_mut().unwrap().infer_batch(run) {
                    Ok(_) => Outcome::Completed,
                    Err(_) => Outcome::Failed,
                };
                let done_ns = bus.now_ns();
                for req in &batch[i..i + run] {
                    bus.record(RequestEvent {
                        id: req.id,
                        class: ci,
                        op: req.op,
                        outcome,
                        submit_ns: req.submit_ns,
                        start_ns,
                        done_ns,
                        queue_depth: req.depth,
                        batch: run,
                    });
                }
                i += run;
                continue;
            }
            let req = &batch[i];
            let start_ns = bus.now_ns();
            let outcome = match driver.as_mut() {
                Some(d) => match run_request(d, req.op) {
                    Ok(()) => Outcome::Completed,
                    Err(_) => Outcome::Failed,
                },
                None => Outcome::Failed,
            };
            bus.record(RequestEvent {
                id: req.id,
                class: ci,
                op: req.op,
                outcome,
                submit_ns: req.submit_ns,
                start_ns,
                done_ns: bus.now_ns(),
                queue_depth: req.depth,
                batch: bsize,
            });
            i += 1;
        }
    }
    match build_err {
        Some(e) => {
            Err(e.context(format!("building {} driver for class {ci}", class.kind.name())))
        }
        None => Ok(()),
    }
}

fn run_request(driver: &mut TenantDriver, op: RequestOp) -> Result<()> {
    match op {
        RequestOp::Infer => driver.infer().map(|_| ()),
        RequestOp::FineTune => driver.step().map(|_| ()),
        RequestOp::Probe => {
            let _ = driver.probe();
            Ok(())
        }
    }
}

/// Bursty open-loop load: one client thread per class submits
/// `per_class` requests in random bursts (1–4 requests, then a short
/// random pause) with a serving-shaped op mix (~50% infer, ~40%
/// fine-tune, ~10% probe). Deterministic in `seed` up to scheduling.
pub fn drive_bursty(handle: &FrontendHandle<'_>, classes: usize, per_class: usize, seed: u64) {
    std::thread::scope(|scope| {
        for ci in 0..classes {
            let mut rng = Rng::new(seed ^ (0x9E37_79B9 + 131 * ci as u64));
            scope.spawn(move || {
                let mut sent = 0usize;
                while sent < per_class {
                    let burst = (1 + rng.index(4)).min(per_class - sent);
                    for _ in 0..burst {
                        let op = match rng.below(10) {
                            0..=4 => RequestOp::Infer,
                            5..=8 => RequestOp::FineTune,
                            _ => RequestOp::Probe,
                        };
                        handle.submit(ci, op);
                        sent += 1;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(50 + rng.below(400)));
                }
            });
        }
    });
}

/// [`run`] under the canonical bursty open-loop client — the scenario and
/// bench entry point.
pub fn serve_bursty(
    pool: &ServePool,
    cfg: &FrontendConfig,
    base: &dtr::Config,
    per_class: usize,
    seed: u64,
) -> Result<FrontendReport> {
    run(pool, cfg, base, |h| drive_bursty(h, cfg.classes.len(), per_class, seed))
}
