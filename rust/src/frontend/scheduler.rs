//! The scheduler: one mutex-guarded set of per-class queues plus a
//! condvar that shard workers park on. Submits are non-blocking (admit or
//! shed under the same lock), workers pull FIFO batches of same-class
//! requests, and draining is a flag + broadcast — workers exit only once
//! their queue is empty, so every admitted request reaches a terminal
//! outcome.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use super::queue::{Admission, ClassQueue};
use super::request::{Request, RequestOp};

struct SchedState {
    queues: Vec<ClassQueue>,
    draining: bool,
}

pub struct Scheduler {
    state: Mutex<SchedState>,
    work: Condvar,
    next_id: AtomicU64,
}

impl Scheduler {
    pub fn new(classes: usize, queue_cap: usize) -> Scheduler {
        Scheduler {
            state: Mutex::new(SchedState {
                queues: (0..classes).map(|_| ClassQueue::new(queue_cap)).collect(),
                draining: false,
            }),
            work: Condvar::new(),
            next_id: AtomicU64::new(1),
        }
    }

    /// Admit or shed one request. Non-blocking: the queue decides under
    /// the scheduler lock and the caller gets the decision (plus the
    /// request's id and admission timestamp) immediately.
    pub fn submit(&self, class: usize, op: RequestOp, now_ns: u64) -> (Request, Admission) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, class, op, submit_ns: now_ns, depth: 0 };
        let mut st = self.state.lock().expect("scheduler poisoned");
        if st.draining {
            // The run is shutting down; treat like a full queue.
            let depth = st.queues[class].len();
            return (req, Admission::Shed { depth });
        }
        let admission = st.queues[class].push(req.clone());
        drop(st);
        if matches!(admission, Admission::Enqueued { .. }) {
            // notify_all: the condvar is shared across classes, so a
            // targeted notify_one could wake a worker of the wrong class
            // and lose the wakeup.
            self.work.notify_all();
        }
        (req, admission)
    }

    /// Block until a batch of up to `max` same-class requests is
    /// available, or the scheduler is draining *and* the class queue is
    /// empty (then `None`: the worker should exit).
    pub fn next_batch(&self, class: usize, max: usize) -> Option<Vec<Request>> {
        let mut st = self.state.lock().expect("scheduler poisoned");
        loop {
            if !st.queues[class].is_empty() {
                return Some(st.queues[class].pop_up_to(max));
            }
            if st.draining {
                return None;
            }
            st = self.work.wait(st).expect("scheduler poisoned");
        }
    }

    /// Begin shutdown: stop admitting, wake every worker. Queued requests
    /// still run (graceful drain).
    pub fn drain(&self) {
        self.state.lock().expect("scheduler poisoned").draining = true;
        self.work.notify_all();
    }

    /// Pop everything still queued (used after workers have exited, to
    /// give orphaned requests a terminal `Failed` outcome).
    pub fn drain_leftovers(&self) -> Vec<Request> {
        let mut st = self.state.lock().expect("scheduler poisoned");
        let mut left = Vec::new();
        for q in st.queues.iter_mut() {
            left.extend(q.pop_up_to(usize::MAX));
        }
        left
    }

    pub fn depth(&self, class: usize) -> usize {
        self.state.lock().expect("scheduler poisoned").queues[class].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn batches_are_fifo_and_bounded() {
        let sched = Scheduler::new(1, 8);
        for _ in 0..5 {
            let (_, adm) = sched.submit(0, RequestOp::Infer, 0);
            assert!(matches!(adm, Admission::Enqueued { .. }));
        }
        let batch = sched.next_batch(0, 3).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(batch.windows(2).all(|w| w[0].id < w[1].id));
        assert_eq!(sched.depth(0), 2);
    }

    #[test]
    fn drain_wakes_blocked_workers_and_sheds_new_submits() {
        let sched = Scheduler::new(2, 4);
        thread::scope(|s| {
            let h = s.spawn(|| sched.next_batch(1, 4));
            // The worker parks on the empty queue; drain must wake it.
            thread::sleep(std::time::Duration::from_millis(20));
            sched.drain();
            assert!(h.join().unwrap().is_none());
        });
        let (_, adm) = sched.submit(0, RequestOp::Probe, 0);
        assert!(matches!(adm, Admission::Shed { .. }));
    }

    #[test]
    fn drain_lets_queued_work_finish_first() {
        let sched = Scheduler::new(1, 4);
        sched.submit(0, RequestOp::Infer, 0);
        sched.submit(0, RequestOp::FineTune, 0);
        sched.drain();
        // Queued requests still come out before the worker is told to exit.
        assert_eq!(sched.next_batch(0, 1).unwrap().len(), 1);
        assert_eq!(sched.next_batch(0, 4).unwrap().len(), 1);
        assert!(sched.next_batch(0, 4).is_none());
    }
}
