//! Request vocabulary: what clients submit and what the front-end reports
//! back per request.

use crate::serve::{TenantKind, TenantSpec};

/// What a request asks its tenant class to do. All three reuse the
/// [`crate::serve::TenantDriver`] ops, so a request stream exercises the
/// same sessions (and the same arbiter leases) as a training tenant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOp {
    /// Budgeted forward-only pass (serving): tokens in, loss/logit scalar
    /// out, activations evictable under the shard's lease.
    Infer,
    /// One full fine-tuning step (forward + backward + optimizer).
    FineTune,
    /// Unbudgeted fixed-batch probe loss (health check; dynamic tenants).
    Probe,
}

impl RequestOp {
    pub fn name(&self) -> &'static str {
        match self {
            RequestOp::Infer => "infer",
            RequestOp::FineTune => "finetune",
            RequestOp::Probe => "probe",
        }
    }
}

/// One queued request. Built by the scheduler at admission; `submit_ns`
/// and `depth` (queue depth *after* enqueue) are recorded at that moment
/// so latency and backpressure are measured from the client's perspective.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Index into the run's class list.
    pub class: usize,
    pub op: RequestOp,
    /// Event-bus timestamp at admission.
    pub submit_ns: u64,
    /// Queue depth observed when this request was admitted.
    pub depth: usize,
}

/// Terminal state of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Completed,
    /// Shed at admission: its class queue was at cap (backpressure). The
    /// request never touched a shard.
    Rejected,
    /// Admitted but its driver errored (e.g. infeasible budget) or its
    /// class lost every worker before the drain finished.
    Failed,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Rejected => "rejected",
            Outcome::Failed => "failed",
        }
    }
}

/// One tenant class: a model kind served by `shards` dedicated shard
/// workers, each with its own `TenantDriver` and arbiter lease. Requests
/// of a class may run on any of its shards — that is the scheduler's
/// load-balancing degree of freedom.
#[derive(Debug, Clone, Copy)]
pub struct ClassSpec {
    pub kind: TenantKind,
    /// Weight/data seed; shard `j` of a class derives `seed + j` so its
    /// driver streams decorrelated batches.
    pub seed: u64,
    pub shards: usize,
}

impl ClassSpec {
    /// The canonical mixed class list (transformer, LSTM, TreeLSTM, ...),
    /// one shard per class.
    pub fn mixed(n: usize) -> Vec<ClassSpec> {
        (0..n)
            .map(|i| ClassSpec {
                kind: TenantKind::mixed(i),
                seed: 0xF0_5EED + 41 * i as u64,
                shards: 1,
            })
            .collect()
    }

    /// Flatten classes into one `TenantSpec` per shard worker — the unit
    /// [`crate::serve::fleet_budget`] sizes budgets over.
    pub fn tenant_specs(classes: &[ClassSpec]) -> Vec<TenantSpec> {
        let mut specs = Vec::new();
        for c in classes {
            for j in 0..c.shards.max(1) {
                specs.push(TenantSpec { kind: c.kind, seed: c.seed + j as u64 });
            }
        }
        specs
    }
}
