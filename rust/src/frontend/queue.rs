//! Bounded per-class admission queues — the backpressure half of the
//! front-end. A queue either admits a request (recording the depth the
//! client observed) or sheds it because it is exactly at cap; there is no
//! unbounded growth and no blocking submit, so overload turns into
//! explicit `Rejected` outcomes instead of latency collapse.

use std::collections::VecDeque;

use super::request::Request;

/// Admission decision for one submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Enqueued; `depth` is the queue length *including* this request.
    Enqueued { depth: usize },
    /// Shed: the queue held `depth` requests, which is the cap. The shed
    /// invariant (`depth == cap` on every rejection) is pinned by
    /// `tests/stress_frontend.rs`.
    Shed { depth: usize },
}

/// FIFO queue with a hard cap. Plain data — the scheduler's mutex guards
/// it, so admission check + enqueue are one atomic decision.
pub struct ClassQueue {
    cap: usize,
    items: VecDeque<Request>,
}

impl ClassQueue {
    pub fn new(cap: usize) -> ClassQueue {
        ClassQueue { cap: cap.max(1), items: VecDeque::new() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Admit or shed `req`. The request's `depth` field is stamped with
    /// the post-enqueue depth on admission.
    pub fn push(&mut self, mut req: Request) -> Admission {
        if self.items.len() >= self.cap {
            return Admission::Shed { depth: self.items.len() };
        }
        let depth = self.items.len() + 1;
        req.depth = depth;
        self.items.push_back(req);
        Admission::Enqueued { depth }
    }

    /// Dequeue up to `max` requests in FIFO order (one worker batch).
    pub fn pop_up_to(&mut self, max: usize) -> Vec<Request> {
        let n = self.items.len().min(max.max(1));
        self.items.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::request::RequestOp;

    fn req(id: u64) -> Request {
        Request { id, class: 0, op: RequestOp::Infer, submit_ns: 0, depth: 0 }
    }

    #[test]
    fn sheds_exactly_at_cap() {
        let mut q = ClassQueue::new(2);
        assert_eq!(q.push(req(1)), Admission::Enqueued { depth: 1 });
        assert_eq!(q.push(req(2)), Admission::Enqueued { depth: 2 });
        // At cap: every further push sheds, always reporting depth == cap.
        assert_eq!(q.push(req(3)), Admission::Shed { depth: 2 });
        assert_eq!(q.push(req(4)), Admission::Shed { depth: 2 });
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pops_fifo_batches() {
        let mut q = ClassQueue::new(8);
        for i in 0..5 {
            q.push(req(i));
        }
        let batch = q.pop_up_to(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.pop_up_to(10).len(), 2);
        assert!(q.is_empty());
        // Freed capacity readmits.
        assert_eq!(q.push(req(9)), Admission::Enqueued { depth: 1 });
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let mut q = ClassQueue::new(0);
        assert_eq!(q.push(req(1)), Admission::Enqueued { depth: 1 });
        assert_eq!(q.push(req(2)), Admission::Shed { depth: 1 });
    }
}
