//! Tenant drivers: the serving workloads that shard over worker threads.
//!
//! A tenant is one model being trained/served under its own `Session`
//! stream — the static transformer engine or one of the dynamic
//! (data-dependent shape) trainers, all speaking the hermetic interpreter.
//! Each tenant thread owns its driver; the only cross-thread coupling is
//! the shared [`ServePool`] budget, which is exactly the point: the mix of
//! a static model with LSTM/TreeLSTM tenants whose per-step shapes are
//! random reproduces the serving scenario no offline partitioner can plan.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::ServePool;
use crate::api::WeightStore;
use crate::dtr;
use crate::exec::{Engine, LstmTrainer, Optimizer, TreeLstmTrainer};
use crate::runtime::{InterpExecutor, ModelConfig, RnnConfig};

/// Deterministic probe batch for dynamic tenants (loss-descent evidence;
/// same probe seed the dynamic-trainer unit tests pin descent with).
const PROBE_SEED: u64 = 99;

/// Typed serve-layer errors (callers can downcast from `anyhow::Error`).
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum ServeError {
    /// `fleet_budget` percentage outside `1..=100`: 0 would price every
    /// tenant at its bare pinned floor (nothing evictable fits — a
    /// degenerate budget that deadlocks the first activation), and >100
    /// over-commits beyond the measured peaks the formula is defined on.
    #[error("fleet budget pct must be in 1..=100, got {0}")]
    BudgetPct(u64),
}

/// Which model a tenant serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantKind {
    /// Static transformer LM (`exec::Engine`, tiny config, SGD).
    Transformer,
    /// LSTM over per-batch random sequence lengths.
    Lstm,
    /// TreeLSTM over per-sample random tree shapes.
    TreeLstm,
}

impl TenantKind {
    pub fn name(&self) -> &'static str {
        match self {
            TenantKind::Transformer => "transformer",
            TenantKind::Lstm => "lstm",
            TenantKind::TreeLstm => "treelstm",
        }
    }

    /// The canonical mixed-fleet cycle: transformer, LSTM, TreeLSTM, ...
    pub fn mixed(i: usize) -> TenantKind {
        match i % 3 {
            0 => TenantKind::Transformer,
            1 => TenantKind::Lstm,
            _ => TenantKind::TreeLstm,
        }
    }
}

/// One tenant of a serve run.
#[derive(Debug, Clone, Copy)]
pub struct TenantSpec {
    pub kind: TenantKind,
    /// Weight/data seed (dynamic tenants); distinct seeds decorrelate the
    /// tenants' step shapes.
    pub seed: u64,
}

impl TenantSpec {
    /// The default mixed fleet of `n` tenants.
    pub fn fleet(n: usize) -> Vec<TenantSpec> {
        (0..n)
            .map(|i| TenantSpec { kind: TenantKind::mixed(i), seed: 0x5EED + 37 * i as u64 })
            .collect()
    }
}

/// Outcome of one tenant's serving run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub kind: &'static str,
    /// Steps requested / completed (they differ only on error).
    pub steps: usize,
    pub completed: usize,
    pub wall_ns: u64,
    /// DTR counters summed over the tenant's per-step sessions
    /// (`peak_memory` is the max across steps).
    pub stats: dtr::Stats,
    pub first_loss: f32,
    pub last_loss: f32,
    /// Unbudgeted fixed-batch probe loss before/after (dynamic tenants).
    pub probe_before: Option<f32>,
    pub probe_after: Option<f32>,
    pub error: Option<String>,
}

impl TenantReport {
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.completed as f64 / (self.wall_ns as f64 / 1e9)
        }
    }
}

/// Sum decision counters across steps (peak is a max; `memory` is last).
fn accumulate_stats(acc: &mut dtr::Stats, s: &dtr::Stats) {
    acc.clock += s.clock;
    acc.base_compute += s.base_compute;
    acc.remat_compute += s.remat_compute;
    acc.remat_count += s.remat_count;
    acc.evict_count += s.evict_count;
    acc.banish_count += s.banish_count;
    acc.metadata_accesses += s.metadata_accesses;
    acc.memory = s.memory;
    acc.peak_memory = acc.peak_memory.max(s.peak_memory);
    acc.eviction_loop_ns += s.eviction_loop_ns;
    acc.cost_compute_ns += s.cost_compute_ns;
    acc.eviction_searches += s.eviction_searches;
}

/// One tenant's driver: the concrete trainer behind a uniform step/probe
/// interface.
pub enum TenantDriver {
    Transformer(Box<Engine>),
    Lstm(Box<LstmTrainer>),
    TreeLstm(Box<TreeLstmTrainer>),
}

impl TenantDriver {
    /// Build the tenant's trainer over the hermetic interpreter. The
    /// `dtr_cfg` carries the shard's budget gate (or a fixed budget for
    /// standalone runs).
    pub fn build(kind: TenantKind, dtr_cfg: dtr::Config, seed: u64) -> Result<TenantDriver> {
        TenantDriver::build_with_store(kind, dtr_cfg, seed, None)
    }

    /// [`TenantDriver::build`] plus an optional shared [`WeightStore`].
    /// Transformer tenants intern their pinned parameters there (every
    /// transformer tenant serves the same fixed-seed base model, so N
    /// tenants share one physical copy of the weights). Dynamic tenants
    /// stream per-seed weights and keep private copies.
    pub fn build_with_store(
        kind: TenantKind,
        dtr_cfg: dtr::Config,
        seed: u64,
        store: Option<Arc<WeightStore>>,
    ) -> Result<TenantDriver> {
        Ok(match kind {
            TenantKind::Transformer => {
                let mut e = Engine::interp(ModelConfig::tiny(), dtr_cfg, Optimizer::Sgd)?;
                if let Some(store) = store {
                    e.attach_store(store);
                }
                TenantDriver::Transformer(Box::new(e))
            }
            TenantKind::Lstm => {
                let rnn = RnnConfig::tiny();
                TenantDriver::Lstm(Box::new(LstmTrainer::new(
                    Box::new(InterpExecutor::rnn(rnn)?),
                    rnn,
                    dtr_cfg,
                    seed,
                )?))
            }
            TenantKind::TreeLstm => {
                let rnn = RnnConfig::tiny();
                TenantDriver::TreeLstm(Box::new(TreeLstmTrainer::new(
                    Box::new(InterpExecutor::rnn(rnn)?),
                    rnn,
                    dtr_cfg,
                    seed,
                )?))
            }
        })
    }

    /// One training step; returns (loss, this step's DTR stats).
    pub fn step(&mut self) -> Result<(f32, dtr::Stats)> {
        match self {
            TenantDriver::Transformer(e) => {
                let r = e.train_step()?;
                Ok((r.loss, r.stats))
            }
            TenantDriver::Lstm(t) => {
                let r = t.train_step()?;
                Ok((r.loss, r.stats))
            }
            TenantDriver::TreeLstm(t) => {
                let r = t.train_step()?;
                Ok((r.loss, r.stats))
            }
        }
    }

    /// One budgeted forward-only inference pass on the driver's next data
    /// batch, under the same gate/budget as training steps (activations
    /// are evictable; the arbiter sees the allocation stream). Returns
    /// the batch loss as the response payload.
    pub fn infer(&mut self) -> Result<f32> {
        match self {
            TenantDriver::Transformer(e) => e.infer_step(),
            TenantDriver::Lstm(t) => t.infer_step(),
            TenantDriver::TreeLstm(t) => t.infer_step(),
        }
    }

    /// `n` coalesced inference requests as one batched kernel invocation
    /// where the driver supports it (transformer: stacked GEMMs over one
    /// shared weight copy), falling back to serial [`TenantDriver::infer`]
    /// calls otherwise. Either path consumes the same data batches in the
    /// same order, so the returned per-request losses are bitwise-equal to
    /// `n` serial calls.
    pub fn infer_batch(&mut self, n: usize) -> Result<Vec<f32>> {
        if let TenantDriver::Transformer(e) = self {
            return e.infer_batch(n);
        }
        let mut losses = Vec::with_capacity(n);
        for _ in 0..n {
            losses.push(self.infer()?);
        }
        Ok(losses)
    }

    /// Unbudgeted fixed-batch probe loss (dynamic tenants only).
    pub fn probe(&self) -> Option<f32> {
        match self {
            TenantDriver::Transformer(_) => None,
            TenantDriver::Lstm(t) => t.probe_loss(PROBE_SEED).ok(),
            TenantDriver::TreeLstm(t) => t.probe_loss(PROBE_SEED).ok(),
        }
    }

    /// Unbudgeted (peak, pinned-floor) envelope of this tenant.
    pub fn envelope(&mut self) -> Result<(u64, u64)> {
        match self {
            TenantDriver::Transformer(e) => {
                let peak = e.measure_peak()?;
                Ok((peak, e.pinned_bytes()))
            }
            TenantDriver::Lstm(t) => t.measure_envelope(3),
            TenantDriver::TreeLstm(t) => t.measure_envelope(3),
        }
    }
}

/// Measure a tenant's standalone unbudgeted envelope: (peak, pinned floor).
pub fn tenant_envelope(kind: TenantKind, seed: u64) -> Result<(u64, u64)> {
    let mut d = TenantDriver::build(kind, dtr::Config::default(), seed)?;
    d.envelope()
}

/// One global budget sized at `pct`% of each tenant's non-pinned headroom,
/// summed: `sum_i(floor_i + (peak_i - floor_i) * pct / 100)`. At 100 every
/// tenant fits its own peak; below that, tenants genuinely compete.
/// `pct` outside `1..=100` is rejected with [`ServeError::BudgetPct`]
/// before any envelope is measured.
pub fn fleet_budget(specs: &[TenantSpec], pct: u64) -> Result<u64> {
    if pct == 0 || pct > 100 {
        return Err(ServeError::BudgetPct(pct).into());
    }
    let mut total = 0u64;
    for spec in specs {
        let (peak, floor) = tenant_envelope(spec.kind, spec.seed)?;
        total += floor + peak.saturating_sub(floor) * pct / 100;
    }
    Ok(total)
}

fn run_one(
    spec: TenantSpec,
    cfg: dtr::Config,
    steps: usize,
    store: Option<Arc<WeightStore>>,
) -> TenantReport {
    let mut report = TenantReport {
        kind: spec.kind.name(),
        steps,
        completed: 0,
        wall_ns: 0,
        stats: dtr::Stats::default(),
        first_loss: f32::NAN,
        last_loss: f32::NAN,
        probe_before: None,
        probe_after: None,
        error: None,
    };
    let mut driver = match TenantDriver::build_with_store(spec.kind, cfg, spec.seed, store) {
        Ok(d) => d,
        Err(e) => {
            report.error = Some(format!("build: {e:#}"));
            return report;
        }
    };
    report.probe_before = driver.probe();
    let t0 = Instant::now();
    for i in 0..steps {
        match driver.step() {
            Ok((loss, stats)) => {
                if i == 0 {
                    report.first_loss = loss;
                }
                report.last_loss = loss;
                report.completed += 1;
                accumulate_stats(&mut report.stats, &stats);
            }
            Err(e) => {
                report.error = Some(format!("step {i}: {e:#}"));
                break;
            }
        }
    }
    report.wall_ns = t0.elapsed().as_nanos() as u64;
    report.probe_after = driver.probe();
    report
}

/// Run every tenant for `steps` training steps on its own worker thread,
/// all sharded over `pool`'s single global budget. `base` supplies the
/// heuristic/policy/index knobs; each tenant gets `base` plus its own
/// freshly leased gate.
///
/// Churn safety under the shared fleet tournament: each `pool.lease()`
/// binds the shard's [`crate::dtr::policy::MinSlot`] with a fresh
/// generation, so a tenant that tears down mid-run (gate dropped at
/// thread exit) retires its tournament leaf and any publishes still in
/// the dirty queue are dropped as dead-generation entries rather than
/// replayed into a recycled slot — a later joiner reusing the shard id
/// can never inherit a dead tenant's minimum.
pub fn run_tenants(
    pool: &ServePool,
    specs: &[TenantSpec],
    base: &dtr::Config,
    steps: usize,
) -> Result<Vec<TenantReport>> {
    let gates: Vec<_> = specs.iter().map(|_| pool.lease()).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(specs.len());
        for (spec, gate) in specs.iter().zip(gates) {
            let mut cfg = base.clone();
            cfg.gate = Some(gate);
            let spec = *spec;
            let store = pool.store().cloned();
            handles.push(scope.spawn(move || run_one(spec, cfg, steps, store)));
        }
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("tenant thread panicked")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Boundary behaviour of the budget formula: 0 and >100 are typed
    /// errors (caught before any envelope is measured), 1 and 100 are the
    /// extreme legal rungs and still satisfy floor <= budget <= peak sum.
    #[test]
    fn fleet_budget_rejects_out_of_range_pct() {
        let specs = [TenantSpec { kind: TenantKind::Transformer, seed: 7 }];
        for bad in [0u64, 101, 400] {
            let err = fleet_budget(&specs, bad).unwrap_err();
            assert_eq!(
                err.downcast_ref::<ServeError>(),
                Some(&ServeError::BudgetPct(bad)),
                "pct {bad} must fail with the typed error"
            );
        }
    }

    #[test]
    fn fleet_budget_boundary_pcts_bracket_the_envelope() {
        let specs = [TenantSpec { kind: TenantKind::Transformer, seed: 7 }];
        let (peak, floor) = tenant_envelope(specs[0].kind, specs[0].seed).unwrap();
        assert!(floor < peak);
        let at1 = fleet_budget(&specs, 1).unwrap();
        let at100 = fleet_budget(&specs, 100).unwrap();
        assert_eq!(at100, peak, "pct 100 prices the tenant at its full peak");
        assert_eq!(at1, floor + (peak - floor) / 100);
        assert!(at1 >= floor && at1 <= at100);
    }
}
