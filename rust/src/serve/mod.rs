//! # `dtr::serve` — multi-tenant serving over one arbitrated budget
//!
//! PAPER §5 implements DTR by interposing on "tensor allocations and
//! operator calls" at a *central allocator*: every allocation funnels
//! through one chokepoint that may evict before it returns. This module
//! generalizes that chokepoint from one training process to **N concurrent
//! tenants**: each tenant is a shard — its own `Session` stream, its own
//! `Runtime` and `PolicyIndex` (the per-shard index seam left by PR 3) —
//! running on a worker thread, while a single [`BudgetArbiter`] owns the
//! global byte budget. Shards hold **revocable leases**: allocations inside
//! a lease are a lock-free fast path; exhausting the lease escalates to
//! the arbiter, which grants unleased budget, revokes idle leases, or —
//! under [`ArbiterPolicy::GlobalReclaim`] — evicts the *globally*
//! least-valuable evictable tensor, comparing heuristic scores across
//! shards so an idle tenant's stale activations go before a hot tenant's
//! fresh ones. [`ArbiterPolicy::StaticSplit`] is the offline baseline:
//! budget divided `total/N` up front, every shard on its own.
//!
//! *How* the global minimum is found is the [`GlobalIndexKind`] knob
//! ([`ServePool::with_global_index`], `--global-index`). The default,
//! `Shared`, is the fleet-wide differential index: every shard's kinetic
//! tournament publishes its local minimum into a lock-free slot, and the
//! arbiter folds the slots in one cross-shard tournament
//! (`dtr::policy::fleet`) — a victim decision reads O(log shards) of
//! arbiter-local state instead of `try_lock`ing every peer runtime. This
//! is Coop's pooled reclaim carried to its conclusion: not only is the
//! *budget* one pool, the *eviction index* is one pool. `Scan` retains
//! the peek-every-peer loop as the fallback and benchmark bar
//! (`bench_serve`'s `global_evict` section), and shared-vs-scan
//! decision-exactness is pinned by `tests/serve_exact.rs`.
//!
//! Treating memory as one shared pool rather than per-tenant silos is the
//! central lesson of Coop (see PAPERS.md): eviction and allocation must
//! cooperate over the *whole* pool or they strand memory in fragments —
//! here the "fragments" are whole tenant partitions, and pooled reclaim is
//! what lets a burst tenant borrow a quiet tenant's bytes. DTR's own
//! online premise (no ahead-of-time plan, PAPER §1) is what makes this
//! possible at all: tenants come and go and draw data-dependent shapes
//! (LSTM/TreeLSTM tenants, [`TenantKind`]), so no offline partitioning of
//! the budget can be computed.
//!
//! Correctness is pinned the same way PR 3 pinned its policy indexes:
//! serving with **N=1 tenant is decision-exact** against a plain
//! single-`Session` run under the same bytes — identical victim sequences
//! and `Stats::same_decisions` (`tests/serve_exact.rs`) — because the
//! arbiter's reclaim loop degenerates to exactly the fixed-budget
//! `free_for` loop when there is nobody to reclaim from.
//!
//! ```no_run
//! use dtr::serve::{ArbiterPolicy, ServePool, TenantSpec, run_tenants, fleet_budget};
//!
//! # fn main() -> anyhow::Result<()> {
//! let specs = TenantSpec::fleet(4); // transformer + LSTM + TreeLSTM mix
//! let budget = fleet_budget(&specs, 60)?; // 60% of summed headroom
//! let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, specs.len());
//! let reports = run_tenants(&pool, &specs, &dtr::dtr::Config::default(), 10)?;
//! for r in &reports {
//!     println!("{}: {:.1} steps/s, slowdown {:.2}", r.kind, r.steps_per_sec(),
//!              r.stats.slowdown());
//! }
//! pool.check_invariants()?;
//! # Ok(())
//! # }
//! ```

mod arbiter;
mod tenants;

use std::sync::Arc;

use anyhow::Result;

pub use arbiter::{
    ArbiterPolicy, BudgetArbiter, GlobalIndexKind, LeaseGate, ShardMeter, ShardSnapshot,
};
pub use tenants::{
    fleet_budget, run_tenants, tenant_envelope, ServeError, TenantDriver, TenantKind,
    TenantReport, TenantSpec,
};

use crate::api::WeightStore;
use crate::dtr::{GateRef, PinnedLedger};

/// A multi-tenant serving pool: one global byte budget, N shard leases.
///
/// Construction fixes the budget and arbitration policy; [`ServePool::lease`]
/// registers a shard and returns the [`GateRef`] to install into that
/// tenant's `Config::gate`. All shards' resident bytes sum to at most the
/// budget (up to pinned-constant overdraft, which mirrors the fixed-budget
/// runtime's unconditional constant registration).
///
/// With [`ServePool::with_dedup`] the pool also owns a content-addressed
/// [`WeightStore`]: tenants that serve the *same* base model intern their
/// pinned parameter buffers there and share one physical copy, charged to
/// the arbiter's shared ledger exactly once per distinct buffer. That is
/// Coop's pooled-memory lesson (PAPERS.md) applied to the pinned floor
/// itself — the N-fold copy of identical weights was the one fragment the
/// leased pool could never reclaim — while PAPER §5's allocator
/// interposition is what makes it safe: every pinned byte already funnels
/// through the arbiter chokepoint, so moving a buffer from a shard lease
/// to the shared ledger is invisible to the eviction policy.
pub struct ServePool {
    arb: Arc<BudgetArbiter>,
    store: Option<Arc<WeightStore>>,
}

impl ServePool {
    /// `planned_tenants` is a sizing hint retained for API stability; the
    /// static-split policy re-splits caps over *live* membership on every
    /// join/leave, so the hint no longer fixes the share.
    pub fn new(total: u64, policy: ArbiterPolicy, planned_tenants: usize) -> ServePool {
        ServePool { arb: BudgetArbiter::new(total, policy, planned_tenants), store: None }
    }

    /// Enable (or disable) content-addressed pinned-weight sharing. With
    /// dedup on, [`run_tenants`] and the front-end attach the pool's
    /// [`WeightStore`] to every tenant that can share weights.
    pub fn with_dedup(mut self, on: bool) -> ServePool {
        self.store = on
            .then(|| WeightStore::new(Arc::clone(&self.arb) as Arc<dyn PinnedLedger>));
        self
    }

    /// Select how `GlobalReclaim` finds the fleet-wide victim (see
    /// [`GlobalIndexKind`]). Call before building sessions: the gate hands
    /// the publish slot to each session's runtime at construction.
    pub fn with_global_index(self, kind: GlobalIndexKind) -> ServePool {
        self.arb.set_global_index(kind);
        self
    }

    /// The active global victim-index kind.
    pub fn global_index(&self) -> GlobalIndexKind {
        self.arb.global_index()
    }

    /// The pool's shared weight store, when dedup is enabled.
    pub fn store(&self) -> Option<&Arc<WeightStore>> {
        self.store.as_ref()
    }

    /// Register a new shard and lease it a gate. Install the result as
    /// `Config::gate` on the tenant's DTR config; every session built from
    /// that config reserves through this shard's lease.
    pub fn lease(&self) -> GateRef {
        GateRef::new(Arc::new(self.arb.register()))
    }

    pub fn total(&self) -> u64 {
        self.arb.total()
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.arb.policy()
    }

    /// The underlying arbiter (snapshots, ledger checks).
    pub fn arbiter(&self) -> &Arc<BudgetArbiter> {
        &self.arb
    }

    /// Bytes currently resident across all live shards (shared pinned
    /// bytes included, counted once).
    pub fn used_bytes(&self) -> u64 {
        self.arb.used_bytes()
    }

    /// Bytes currently charged to the shared pinned ledger (deduplicated
    /// weights; 0 with dedup off).
    pub fn shared_bytes(&self) -> u64 {
        self.arb.shared_bytes()
    }

    /// Per-shard ledger rows.
    pub fn snapshot(&self) -> Vec<ShardSnapshot> {
        self.arb.snapshot()
    }

    /// Cross-shard accounting invariant (quiescent): every live shard's
    /// `lease == used + headroom` and live leases sum within the budget —
    /// the serve-level extension of `Runtime::check_invariants`, whose
    /// per-shard half ties `used` to the runtime's own `Stats::memory` and
    /// pool-byte counters.
    pub fn check_invariants(&self) -> Result<()> {
        self.arb.check_ledger()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Session;
    use crate::dtr::{Config, Heuristic};

    /// Two accounting shards on one pool: the second tenant's pressure
    /// reclaims the idle first tenant's bytes (global reclaim), and the
    /// ledger stays exact throughout.
    #[test]
    fn cross_shard_reclaim_takes_idle_tenants_bytes() {
        let pool = ServePool::new(64, ArbiterPolicy::GlobalReclaim, 2);
        let mk = |pool: &ServePool| {
            Session::accounting(Config {
                heuristic: Heuristic::lru(),
                gate: Some(pool.lease()),
                ..Config::default()
            })
        };
        let a = mk(&pool);
        let b = mk(&pool);

        // Tenant A fills most of the pool with evictable activations. The
        // large op costs advance A's clock far ahead, so A's tensors are
        // decisively staler than anything B produces (h_lru compares raw
        // per-shard scores).
        let a0 = a.constant_sized(4);
        let mut prev = a0.clone();
        let mut held = Vec::new();
        for _ in 0..10 {
            let t = a.call_sized("f", 50, &[&prev], &[4]).unwrap().remove(0);
            held.push(prev);
            prev = t;
        }
        held.push(prev);
        assert_eq!(a.memory(), 44);
        pool.check_invariants().unwrap();

        // Tenant B's demand must evict A's stale tensors cross-shard.
        let b0 = b.constant_sized(4);
        let mut bprev = b0.clone();
        let mut bheld = Vec::new();
        for _ in 0..8 {
            let t = b.call_sized("g", 1, &[&bprev], &[4]).unwrap().remove(0);
            bheld.push(bprev);
            bprev = t;
        }
        bheld.push(bprev);
        assert!(b.memory() >= 36, "tenant B got {} bytes", b.memory());
        assert!(
            a.stats().evict_count > 0,
            "tenant A was never evicted cross-shard"
        );
        assert!(a.memory() + b.memory() <= 64, "global budget violated");
        pool.check_invariants().unwrap();
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    /// Static split never reclaims across shards: each tenant is boxed
    /// into its share.
    #[test]
    fn static_split_isolates_shards() {
        let pool = ServePool::new(64, ArbiterPolicy::StaticSplit, 2);
        let cfg = |pool: &ServePool| Config {
            heuristic: Heuristic::lru(),
            gate: Some(pool.lease()),
            ..Config::default()
        };
        let a = Session::accounting(cfg(&pool));
        let b = Session::accounting(cfg(&pool));
        let a0 = a.constant_sized(4);
        let mut prev = a0.clone();
        let mut held = Vec::new();
        for _ in 0..12 {
            let t = a.call_sized("f", 1, &[&prev], &[4]).unwrap().remove(0);
            held.push(prev);
            prev = t;
        }
        held.push(prev);
        // A's share is 32: it must have evicted itself under its own cap
        // even though B holds nothing.
        assert!(a.memory() <= 32, "A exceeded its static share: {}", a.memory());
        assert!(a.stats().evict_count > 0);
        let _b0 = b.constant_sized(4);
        pool.check_invariants().unwrap();
    }

    /// Dropping a tenant's sessions and gate returns every byte.
    #[test]
    fn teardown_refunds_the_ledger() {
        let pool = ServePool::new(128, ArbiterPolicy::GlobalReclaim, 1);
        {
            let s = Session::accounting(Config {
                gate: Some(pool.lease()),
                ..Config::default()
            });
            let c = s.constant_sized(16);
            let _o = s.call_sized("f", 1, &[&c], &[16]).unwrap();
            assert_eq!(pool.used_bytes(), 32);
        }
        // Sessions and handles dropped: runtime Drop refunded, gate Drop
        // unregistered.
        assert_eq!(pool.used_bytes(), 0);
        pool.check_invariants().unwrap();
    }
}
