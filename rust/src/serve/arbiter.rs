//! The central budget arbiter: one global byte budget, revocable per-shard
//! leases, cross-shard eviction by globally-minimal heuristic score.
//!
//! Every shard owns a [`ShardMeter`] — two atomics mirroring its runtime's
//! resident bytes (`used`) and its unspent lease (`headroom`). The fast
//! path of a reservation is a lock-free CAS against `headroom`; only when a
//! shard's lease is exhausted does it enter [`BudgetArbiter::request`],
//! which serializes on the arbiter mutex and, in order of preference:
//!
//! 1. grants unleased budget from the global pool;
//! 2. **revokes** lease headroom idling on other shards (global-reclaim
//!    policy) — an idle tenant's unspent allowance moves to the hot one
//!    without evicting anything;
//! 3. **reclaims**: compares the requester's own victim candidate against
//!    the other shards' and evicts the globally least-valuable storage —
//!    an idle tenant's stale activations go before a hot tenant's fresh
//!    ones.
//!
//! ## The global victim choice ([`GlobalIndexKind`])
//!
//! Coop's pooled-reclaim lesson (PAPERS.md) and PAPER §5's central
//! allocator interposition both say the eviction decision must see the
//! whole pool, not one silo. How the arbiter *finds* the fleet minimum is
//! the [`GlobalIndexKind`] knob:
//!
//! * [`GlobalIndexKind::Shared`] (default) — every shard's differential
//!   index publishes its current tier-minimum into a lock-free
//!   [`MinSlot`], and the arbiter folds those leaves in one
//!   [`FleetTournament`]: a victim decision is a drain of the dirty-slot
//!   queue plus an O(log shards) tournament read, touching **no** shard
//!   runtime. Shards whose leaf cannot answer (no publishing index bound
//!   yet, or a stale mark) are peeked directly — and the peek itself heals
//!   the leaf, because the peer's `pop_min` republishes.
//! * [`GlobalIndexKind::Scan`] — the retained peek loop: query every live
//!   peer per decision ([`RemoteEvictor::peek`] under `try_lock`). The
//!   fallback and the benchmark bar the shared path is measured against
//!   (`bench_serve`'s `global_evict` section); decision-exactness of
//!   shared-vs-scan is pinned by `tests/serve_exact.rs`.
//!
//! Lock discipline (deadlock freedom): a requester holds (a) its own
//! runtime lock — it arrived here from inside `Runtime::free_for` — and
//! (b) the arbiter state mutex. Other shards' runtimes are only ever
//! `try_lock`ed; a busy peer is skipped and retried after a bounded
//! `Condvar` wait that releases the arbiter mutex. No thread blocks on a
//! runtime mutex while holding another, so no cycle of blocking waits can
//! form; exhausted retries surface as a genuine OOM. A skipped-while-busy
//! peer may hold the true global minimum for the duration of the skip;
//! each shard's [`ShardSnapshot::busy_skips`] counts how often it was
//! passed over, and `tests/stress_serve.rs` asserts the count stays
//! bounded (no livelock, no silent staleness).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::dtr::lease::{
    BudgetGate, LocalEvictor, PinnedLedger, RemoteEvictor, RemotePeek, RemoteReclaim,
};
use crate::dtr::policy::{FleetTournament, Leaf, MinSlot};
use crate::dtr::DtrError;

/// How the arbiter divides the global budget among shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterPolicy {
    /// Each shard's lease is capped at its even share of the budget, split
    /// over the *live* shards (the division remainder spread one byte per
    /// low slot so the shares sum exactly to the splittable total) and
    /// recomputed on every join, leave, and shared-ledger change; shards
    /// reclaim only from themselves. The offline-partitioning baseline.
    StaticSplit,
    /// Any shard may lease up to the whole budget; the arbiter revokes idle
    /// leases and evicts the globally least-valuable tensor across shards.
    GlobalReclaim,
}

impl ArbiterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ArbiterPolicy::StaticSplit => "static-split",
            ArbiterPolicy::GlobalReclaim => "global-reclaim",
        }
    }

    pub fn parse(s: &str) -> Option<ArbiterPolicy> {
        Some(match s {
            "static" | "static-split" | "static_split" => ArbiterPolicy::StaticSplit,
            "global" | "global-reclaim" | "global_reclaim" => ArbiterPolicy::GlobalReclaim,
            _ => return None,
        })
    }

    pub fn all() -> [ArbiterPolicy; 2] {
        [ArbiterPolicy::StaticSplit, ArbiterPolicy::GlobalReclaim]
    }
}

/// How `GlobalReclaim` finds the fleet-wide minimum-score victim (see the
/// module docs): the shared kinetic tournament over published per-shard
/// minima, or the retained peek-every-peer loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlobalIndexKind {
    /// One fleet-wide tournament over lock-free published shard minima;
    /// victim choice is O(log shards) and touches no shard runtime in
    /// steady state. The default.
    Shared,
    /// Peek every live peer per decision — the fallback and benchmark bar.
    Scan,
}

impl GlobalIndexKind {
    pub fn name(&self) -> &'static str {
        match self {
            GlobalIndexKind::Shared => "shared",
            GlobalIndexKind::Scan => "scan",
        }
    }

    pub fn parse(s: &str) -> Option<GlobalIndexKind> {
        Some(match s {
            "shared" | "tournament" => GlobalIndexKind::Shared,
            "scan" | "peek" => GlobalIndexKind::Scan,
            _ => return None,
        })
    }

    pub fn all() -> [GlobalIndexKind; 2] {
        [GlobalIndexKind::Shared, GlobalIndexKind::Scan]
    }
}

/// Per-shard byte gauges. `lease == used + headroom` is the ledger identity
/// the arbiter maintains (checked at quiescence by
/// [`BudgetArbiter::check_ledger`]); `headroom` goes negative only for
/// pinned-constant overdraft, mirroring the fixed-budget runtime where
/// constants register unconditionally.
#[derive(Debug, Default)]
pub struct ShardMeter {
    used: AtomicU64,
    headroom: AtomicI64,
    /// Set (lock-free) by `LeaseGate::drop`; the arbiter lazily reaps
    /// flagged shards next time it holds the state mutex. Unregistration
    /// must not take that mutex itself: the last gate reference can die
    /// inside a remote peek, on a thread already holding it.
    dead: AtomicBool,
}

/// The single checked `u64 -> i64` conversion for ledger deltas. Every
/// mutation of a [`ShardMeter`]'s signed headroom routes through this, so an
/// oversize reserve/refund pair can never clamp asymmetrically and drift the
/// ledger: a request that does not fit is rejected (or rejected up front by
/// the caller), never silently truncated.
fn ledger_delta(bytes: u64) -> Option<i64> {
    i64::try_from(bytes).ok()
}

impl ShardMeter {
    /// Resident bytes of the shard's runtime (mirror of `Stats::memory`).
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::Acquire)
    }

    /// Unspent lease bytes (negative = pinned-constant overdraft).
    pub fn headroom(&self) -> i64 {
        self.headroom.load(Ordering::Acquire)
    }

    /// Lock-free reservation: take `bytes` from the headroom iff it covers
    /// them entirely. Absurd requests that do not fit the signed ledger can
    /// never be covered by a real lease.
    fn try_take(&self, bytes: u64) -> bool {
        let want = match ledger_delta(bytes) {
            Some(w) => w,
            None => return false,
        };
        let mut cur = self.headroom.load(Ordering::Acquire);
        loop {
            if cur < want {
                return false;
            }
            match self.headroom.compare_exchange_weak(
                cur,
                cur - want,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Unconditional reservation (pinned constants): may overdraw. Callers
    /// validate the size up front; an unrepresentable delta is a logic
    /// error, not something to clamp — a clamped take paired with a
    /// clamped credit of a different oversize value would drift the ledger.
    fn take_unchecked(&self, bytes: u64) {
        let delta = ledger_delta(bytes).expect("pinned take exceeds the signed ledger");
        self.headroom.fetch_sub(delta, Ordering::AcqRel);
    }

    fn credit(&self, bytes: u64) {
        let delta = ledger_delta(bytes).expect("refund exceeds the signed ledger");
        self.headroom.fetch_add(delta, Ordering::AcqRel);
    }

    /// Revoke up to `want` bytes of *positive* headroom; returns the bytes
    /// actually taken.
    fn steal_up_to(&self, want: u64) -> u64 {
        let want = want.min(i64::MAX as u64) as i64;
        let mut cur = self.headroom.load(Ordering::Acquire);
        loop {
            let take = cur.min(want);
            if take <= 0 {
                return 0;
            }
            match self.headroom.compare_exchange_weak(
                cur,
                cur - take,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return take as u64,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Read-only view of one shard's ledger row.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    pub id: usize,
    pub live: bool,
    pub lease: u64,
    pub cap: u64,
    pub used: u64,
    pub headroom: i64,
    /// Times this shard was passed over while busy during a global victim
    /// search (peek or reclaim bounced off its runtime `try_lock`). A
    /// skipped shard may have held the true global minimum; bounded skips
    /// mean bounded staleness of the global decision.
    pub busy_skips: u64,
}

struct Shard {
    live: bool,
    lease: u64,
    cap: u64,
    meter: Arc<ShardMeter>,
    remote: Option<Arc<dyn RemoteEvictor>>,
    /// See [`ShardSnapshot::busy_skips`]; shared so probes can count
    /// skips after the arbiter lock is released.
    busy_skips: Arc<AtomicU64>,
}

struct ArbState {
    shards: Vec<Shard>,
    /// The fleet-wide eviction tournament over published shard minima
    /// ([`GlobalIndexKind::Shared`]); leaves are bound in `register` and
    /// retired in `reap_locked`, so churn can never resurrect a dead
    /// shard's published minimum.
    fleet: FleetTournament,
    /// Bytes charged by the content-addressed [`crate::api::WeightStore`]:
    /// distinct pinned buffers shared across shards, owned by no single
    /// lease. Subtracted from the grantable pool and from the splittable
    /// total of `StaticSplit` caps.
    shared: u64,
}

/// One peer's reclaim handle, captured under the arbiter lock for use
/// after it is released.
struct PeerProbe {
    shard: usize,
    remote: Arc<dyn RemoteEvictor>,
    busy_skips: Arc<AtomicU64>,
}

/// A consistent capture of the global victim-search inputs, taken under
/// the arbiter lock ([`BudgetArbiter::capture_view`]) so the expensive
/// peeks and the eviction itself can run unlocked.
#[derive(Default)]
struct GlobalView {
    /// The best *published* peer minimum — the fleet tournament's winner
    /// excluding the requester, with its reclaim handle. `None` in scan
    /// mode, or when no peer leaf is currently publishable.
    published: Option<(usize, f64, PeerProbe)>,
    /// Peers whose leaf cannot answer (no publishing index bound, or
    /// marked stale) — or every live peer in scan mode. Ascending shard
    /// order, so first-wins tie-breaking matches the tournament's
    /// lowest-shard rule.
    probes: Vec<PeerProbe>,
}

impl GlobalView {
    /// Merge the published winner with direct peeks of the probe peers:
    /// the fleet-wide lowest-score candidate (ties to the lowest shard),
    /// plus whether any peer had to be skipped while busy.
    fn best_candidate(&self) -> (Option<(usize, f64)>, bool) {
        let mut busy = false;
        let mut best: Option<(usize, f64)> =
            self.published.as_ref().map(|&(shard, score, _)| (shard, score));
        for p in &self.probes {
            match p.remote.peek() {
                RemotePeek::Candidate { score, .. } => {
                    let better = match best {
                        None => true,
                        Some((bj, bs)) => score < bs || (score == bs && p.shard < bj),
                    };
                    if better {
                        best = Some((p.shard, score));
                    }
                }
                RemotePeek::Busy => {
                    p.busy_skips.fetch_add(1, Ordering::Relaxed);
                    busy = true;
                }
                _ => {}
            }
        }
        (best, busy)
    }

    fn probe_for(&self, shard: usize) -> Option<&PeerProbe> {
        if let Some((j, _, p)) = &self.published {
            if *j == shard {
                return Some(p);
            }
        }
        self.probes.iter().find(|p| p.shard == shard)
    }

    /// Ask `shard` (a candidate returned by [`GlobalView::best_candidate`])
    /// to evict its top victim.
    fn reclaim(&self, shard: usize) -> RemoteReclaim {
        match self.probe_for(shard) {
            Some(p) => {
                let outcome = p.remote.reclaim_top();
                if matches!(outcome, RemoteReclaim::Busy) {
                    p.busy_skips.fetch_add(1, Ordering::Relaxed);
                }
                outcome
            }
            None => RemoteReclaim::Gone,
        }
    }
}

/// The central allocator-interposition point of PAPER §5, generalized to N
/// tenants: all shard leases plus the shared-weight ledger sum to at most
/// `total`.
pub struct BudgetArbiter {
    total: u64,
    policy: ArbiterPolicy,
    /// `true` = [`GlobalIndexKind::Shared`]. Atomic so `ServePool`'s
    /// builder can flip it after the arbiter is behind an `Arc`; flip it
    /// before sessions are constructed — `LeaseGate::min_slot` is consulted
    /// once per session build.
    shared_index: AtomicBool,
    state: Mutex<ArbState>,
    cv: Condvar,
}

/// Bounded retry against busy peers: 2000 rounds x 2 ms ~ 4 s of
/// consecutive stall before a request gives up and reports OOM.
const STALL_WAIT: Duration = Duration::from_millis(2);
const MAX_STALLED_ROUNDS: usize = 2_000;

impl BudgetArbiter {
    /// `planned_tenants` is a sizing hint only: `StaticSplit` caps follow
    /// the *live* membership (recomputed on every join and leave), so a
    /// fleet that churns below its planned size never strands budget on
    /// absent tenants.
    pub fn new(total: u64, policy: ArbiterPolicy, planned_tenants: usize) -> Arc<BudgetArbiter> {
        let _ = planned_tenants;
        // Ledger arithmetic runs in i64 (signed headroom); clamp the total
        // accordingly — practically unlimited.
        let total = total.min(i64::MAX as u64);
        Arc::new(BudgetArbiter {
            total,
            policy,
            shared_index: AtomicBool::new(true),
            state: Mutex::new(ArbState {
                shards: Vec::new(),
                fleet: FleetTournament::new(),
                shared: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn global_index(&self) -> GlobalIndexKind {
        if self.shared_index.load(Ordering::Acquire) {
            GlobalIndexKind::Shared
        } else {
            GlobalIndexKind::Scan
        }
    }

    pub fn set_global_index(&self, kind: GlobalIndexKind) {
        self.shared_index.store(kind == GlobalIndexKind::Shared, Ordering::Release);
    }

    /// Generation-stamped publishes a departed tenant enqueued that the
    /// tournament dropped instead of applying (churn safety diagnostics).
    pub fn fleet_dead_drops(&self) -> u64 {
        self.state.lock().expect("arbiter poisoned").fleet.dead_drops()
    }

    /// Recompute `StaticSplit` lease caps over the live shards. The
    /// splittable total is the budget minus the shared-weight ledger
    /// (deduplicated pinned buffers belong to everyone, so nobody's cap
    /// covers them). Water-filling: a shard whose granted lease already
    /// exceeds the even share keeps `cap = lease` — caps are never cut
    /// below bytes already granted — and the rest splits evenly over the
    /// others, division remainder spread one byte per low slot so the live
    /// caps sum exactly to the splittable total.
    fn resplit_locked(&self, st: &mut ArbState) {
        if self.policy != ArbiterPolicy::StaticSplit {
            return;
        }
        let splittable = self.total.saturating_sub(st.shared);
        let mut unclamped: Vec<usize> = st
            .shards
            .iter()
            .enumerate()
            .filter(|(_, sh)| sh.live)
            .map(|(i, _)| i)
            .collect();
        if unclamped.is_empty() {
            return;
        }
        let mut remaining = splittable;
        loop {
            let fair = remaining / unclamped.len() as u64;
            let mut clamped_any = false;
            unclamped.retain(|&i| {
                let lease = st.shards[i].lease;
                if lease > fair {
                    st.shards[i].cap = lease;
                    remaining = remaining.saturating_sub(lease);
                    clamped_any = true;
                    false
                } else {
                    true
                }
            });
            if !clamped_any || unclamped.is_empty() {
                break;
            }
        }
        let n = unclamped.len() as u64;
        if n > 0 {
            let base = remaining / n;
            let rem = remaining % n;
            for (k, &i) in unclamped.iter().enumerate() {
                st.shards[i].cap = base + u64::from((k as u64) < rem);
            }
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.policy
    }

    /// Register a new shard; returns its gate (install it as
    /// `Config::gate`). Dropping every clone of the gate unregisters the
    /// shard and returns its lease to the pool.
    pub fn register(self: &Arc<Self>) -> LeaseGate {
        let meter = Arc::new(ShardMeter::default());
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        // Recycle a dead slot (a departed tenant cannot bind or reserve
        // through it anymore — its gate is gone), so tenant churn does not
        // grow the shard table without bound. The slot index is fixed
        // *before* the shard is built: its cap depends on the slot.
        let id = st.shards.iter().position(|sh| !sh.live).unwrap_or(st.shards.len());
        let shard = Shard {
            live: true,
            lease: 0,
            cap: match self.policy {
                ArbiterPolicy::StaticSplit => 0, // set by the resplit below
                ArbiterPolicy::GlobalReclaim => self.total,
            },
            meter: Arc::clone(&meter),
            remote: None,
            busy_skips: Arc::new(AtomicU64::new(0)),
        };
        if id == st.shards.len() {
            st.shards.push(shard);
        } else {
            st.shards[id] = shard;
        }
        // Bind the shard's leaf in the fleet tournament. A recycled slot
        // gets a fresh generation, so any publish still queued by the
        // departed tenant's runtime is dropped, never applied to the new
        // tenant's leaf.
        let slot = st.fleet.bind(id);
        self.resplit_locked(&mut st);
        drop(st);
        LeaseGate { arb: Arc::clone(self), id, meter, slot }
    }

    /// Retire shards whose gate has been dropped (`ShardMeter::dead`),
    /// returning their leases to the pool. Called whenever the state mutex
    /// is (re)acquired; `LeaseGate::drop` itself only flips the atomic —
    /// taking the mutex there could self-deadlock, because the last gate
    /// reference can die on a thread that already holds it (a remote
    /// peek's temporary `Arc` upgrade being the final strong reference).
    fn reap_locked(&self, st: &mut ArbState) {
        let mut reaped = false;
        for j in 0..st.shards.len() {
            let sh = &mut st.shards[j];
            if sh.live && sh.meter.dead.load(Ordering::Acquire) {
                sh.live = false;
                sh.lease = 0;
                sh.remote = None;
                // Vacate the leaf: a dead shard's published minimum must
                // never win another tournament round.
                st.fleet.retire(j);
                reaped = true;
            }
        }
        // A leave frees its lease *and* its static share: re-split so the
        // survivors' caps absorb it instead of idling on a dead slot.
        if reaped {
            self.resplit_locked(st);
        }
    }

    fn bind(&self, id: usize, remote: Arc<dyn RemoteEvictor>) {
        let mut st = self.state.lock().expect("arbiter poisoned");
        st.shards[id].remote = Some(remote);
    }

    fn leased_total(st: &ArbState) -> u64 {
        st.shards.iter().filter(|s| s.live).map(|s| s.lease).sum()
    }

    /// Grant up to `want` new lease bytes to `id` from the unleased pool —
    /// the budget minus live leases minus the shared-weight ledger —
    /// bounded by the shard's cap. Returns the granted amount.
    fn grant_locked(&self, st: &mut ArbState, id: usize, want: u64) -> u64 {
        let pool = self.total.saturating_sub(Self::leased_total(st)).saturating_sub(st.shared);
        let sh = &mut st.shards[id];
        let grant = want.min(pool).min(sh.cap.saturating_sub(sh.lease));
        if grant > 0 {
            sh.lease += grant;
            sh.meter.credit(grant);
        }
        grant
    }

    /// Capture everything a global victim search needs while the state
    /// lock is held, so the peeks and the eviction itself can run
    /// unlocked. The cloned `Arc`s stay valid across a reap/recycle of
    /// their slot: they point at the *original* tenant's runtime (a
    /// recycled slot's new tenant is never reclaimed by a stale round).
    ///
    /// Under [`GlobalIndexKind::Shared`] this is the tournament fast path:
    /// drain the dirty-slot queue (bounded by the shard count), read the
    /// O(log shards) winner, and clone *one* handle — peers with a valid
    /// published leaf are never peeked. Only leaves that cannot answer
    /// (index not publishing yet, or marked stale by a parked winner)
    /// land in `probes`; the probe's peek makes the peer republish, so
    /// the leaf heals for the next round. Under `Scan`, every live peer
    /// is probed — the retained O(shards)-peek loop.
    fn capture_view(&self, st: &mut ArbState, requester: usize) -> GlobalView {
        let shared = self.shared_index.load(Ordering::Acquire);
        if shared {
            st.fleet.drain();
        }
        let mut probes = Vec::new();
        for (j, sh) in st.shards.iter().enumerate() {
            if j == requester || !sh.live {
                continue;
            }
            let Some(remote) = &sh.remote else { continue };
            let need_probe = if shared {
                // `Empty` and `Min` leaves answer without a peek; `Min`
                // winners surface through the tournament read below.
                matches!(st.fleet.leaf(j), Leaf::Vacant | Leaf::NeedsPeek)
            } else {
                true
            };
            if need_probe {
                probes.push(PeerProbe {
                    shard: j,
                    remote: Arc::clone(remote),
                    busy_skips: Arc::clone(&sh.busy_skips),
                });
            }
        }
        let published = if shared {
            st.fleet.best_excluding(requester).and_then(|(j, score)| {
                let sh = &st.shards[j];
                // A `Min` leaf implies a live publishing session, which
                // implies a bound remote; `None` can only mean the session
                // is mid-construction — skip it, exactly as the scan loop
                // skips remote-less shards.
                sh.remote.as_ref().map(|r| {
                    let probe = PeerProbe {
                        shard: j,
                        remote: Arc::clone(r),
                        busy_skips: Arc::clone(&sh.busy_skips),
                    };
                    (j, score, probe)
                })
            })
        } else {
            None
        };
        GlobalView { published, probes }
    }

    /// Choose — without evicting — the peer shard holding the current
    /// fleet-wide minimum-score victim from `requester`'s point of view:
    /// the decision step of the reclaim path, exposed so benches and
    /// equivalence tests can price shared-vs-peek per decision. A probe
    /// of a stale leaf heals it (the peer republishes on peek), so under
    /// [`GlobalIndexKind::Shared`] a quiescent fleet answers from the
    /// tournament alone.
    pub fn pick_victim(&self, requester: usize) -> Option<(usize, f64)> {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        let view = self.capture_view(&mut st, requester);
        drop(st);
        view.best_candidate().0
    }

    /// Revoke idle (positive) headroom from every other live shard,
    /// returning up to `want` bytes to the unleased pool.
    fn revoke_idle(&self, st: &mut ArbState, requester: usize, want: u64) -> u64 {
        let mut got = 0u64;
        for (j, sh) in st.shards.iter_mut().enumerate() {
            if j == requester || !sh.live || got >= want {
                continue;
            }
            let take = sh.meter.steal_up_to(want - got);
            sh.lease = sh.lease.saturating_sub(take);
            got += take;
        }
        got
    }

    /// Reserve `bytes` for a pinned constant: grow the lease from the pool,
    /// from idle peer leases, and — under global reclaim — by evicting
    /// *peer* victims. Constants never evict the requester's own tensors
    /// (the fixed-budget runtime registers them unconditionally, which is
    /// also what keeps N=1 serving decision-exact: with no peers this
    /// degenerates to grant-or-overdraft). The final take happens under
    /// the arbiter lock so a concurrent revocation cannot race the grant
    /// away; any shortfall becomes overdraft (negative headroom).
    fn reserve_pinned_slow(&self, id: usize, bytes: u64) {
        // Pinned constants are real allocations: a size that does not fit
        // the signed ledger is unrepresentable and a logic error upstream.
        let want = ledger_delta(bytes).expect("pinned reservation exceeds the signed ledger");
        let mut st = self.state.lock().expect("arbiter poisoned");
        // Our own slot cannot be reaped or recycled while we hold its gate.
        let meter = Arc::clone(&st.shards[id].meter);
        let mut stalled = 0usize;
        loop {
            self.reap_locked(&mut st);
            let headroom = meter.headroom();
            let deficit = want.saturating_sub(headroom).max(0) as u64;
            if deficit == 0 {
                break;
            }
            let mut granted = self.grant_locked(&mut st, id, deficit);
            if granted < deficit && self.policy == ArbiterPolicy::GlobalReclaim {
                self.revoke_idle(&mut st, id, deficit - granted);
                granted += self.grant_locked(&mut st, id, deficit - granted);
            }
            if granted > 0 {
                stalled = 0;
                continue;
            }
            if self.policy != ArbiterPolicy::GlobalReclaim || stalled >= MAX_STALLED_ROUNDS {
                break; // shortfall overdrafts
            }
            // Choose and reclaim with the arbiter unlocked (the view is
            // captured above under the lock; searches are O(pool)).
            let view = self.capture_view(&mut st, id);
            drop(st);
            let (best, mut busy) = view.best_candidate();
            let reclaimed = match best {
                Some((j, _)) => {
                    let outcome = view.reclaim(j);
                    if matches!(outcome, RemoteReclaim::Busy) {
                        busy = true;
                    }
                    matches!(outcome, RemoteReclaim::Freed(_))
                }
                None => false,
            };
            st = self.state.lock().expect("arbiter poisoned");
            if reclaimed {
                stalled = 0;
                continue;
            }
            if !busy && best.is_none() {
                break; // nothing evictable anywhere: overdraft
            }
            stalled += 1;
            if busy {
                let (guard, _) = self.cv.wait_timeout(st, STALL_WAIT).expect("arbiter poisoned");
                st = guard;
            }
        }
        // Take under the lock so a concurrent revocation cannot race the
        // final grant away.
        meter.take_unchecked(bytes);
        drop(st);
    }

    /// The slow path: make `need` bytes reservable for shard `id`, whose
    /// runtime the calling thread already holds (`local`). With a single
    /// live shard this performs exactly the fixed-budget `free_for` loop —
    /// one victim search, one eviction per round — which is what makes
    /// N=1 serving decision-exact against a plain session.
    fn request(&self, id: usize, need: u64, local: &mut dyn LocalEvictor) -> Result<()> {
        // A need that does not fit the signed ledger can never be granted;
        // reject it up front, before any shard state is touched.
        let want = match ledger_delta(need) {
            Some(w) => w,
            None => {
                return Err(DtrError::Oom {
                    need,
                    free: 0,
                    budget: self.total,
                    resident: local.resident_bytes(),
                }
                .into());
            }
        };
        let mut stalled = 0usize;
        let mut st = self.state.lock().expect("arbiter poisoned");
        // Our own slot cannot be reaped or recycled while we hold its gate.
        let meter = Arc::clone(&st.shards[id].meter);
        loop {
            self.reap_locked(&mut st);
            // Retry the fast path under the arbiter lock: headroom may have
            // been refunded or granted since the caller's attempt.
            if meter.try_take(need) {
                drop(st);
                self.cv.notify_all();
                return Ok(());
            }
            let headroom = meter.headroom();
            let deficit = want.saturating_sub(headroom).max(0) as u64;

            // 1. Unleased pool, then (global reclaim) leases idling on
            // other shards — reclaim-without-eviction.
            let mut granted = self.grant_locked(&mut st, id, deficit);
            if granted < deficit && self.policy == ArbiterPolicy::GlobalReclaim {
                self.revoke_idle(&mut st, id, deficit - granted);
                granted += self.grant_locked(&mut st, id, deficit - granted);
            }
            if granted > 0 {
                stalled = 0;
                continue;
            }

            // 2. Eviction: compare the requester's candidate with the
            // fleet's and take the globally least-valuable one. All victim
            // searches and the eviction itself run with the arbiter
            // *unlocked* — only the view capture (a tournament read under
            // `Shared`, a handle sweep under `Scan`) happens under the
            // mutex, so shards' eviction loops never serialize on it.
            // The local peeked victim cannot race away: this thread holds
            // its own runtime, so remote reclaims bounce off `try_lock`.
            let view = if self.policy == ArbiterPolicy::GlobalReclaim {
                self.capture_view(&mut st, id)
            } else {
                GlobalView::default()
            };
            drop(st);
            let (best_remote, busy) = view.best_candidate();
            let local_best = local.peek_scored();
            let evict_local = match (&local_best, &best_remote) {
                (Some((_, ls, _)), Some((_, rs))) => ls <= rs,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => {
                    if busy && stalled < MAX_STALLED_ROUNDS {
                        stalled += 1;
                        let guard = self.state.lock().expect("arbiter poisoned");
                        let (guard, _) = self
                            .cv
                            .wait_timeout(guard, STALL_WAIT)
                            .expect("arbiter poisoned");
                        st = guard;
                        continue;
                    }
                    return Err(DtrError::Oom {
                        need,
                        free: meter.headroom().max(0) as u64,
                        budget: self.total,
                        resident: local.resident_bytes(),
                    }
                    .into());
                }
            };
            if evict_local {
                let (sid, _, _) = local_best.expect("checked above");
                // Refunds the shard's headroom through the gate's on_free.
                local.evict_storage(sid);
                self.cv.notify_all();
                stalled = 0;
                st = self.state.lock().expect("arbiter poisoned");
                continue;
            }
            let (j, _) = best_remote.expect("checked above");
            let outcome = view.reclaim(j);
            st = self.state.lock().expect("arbiter poisoned");
            match outcome {
                // The victim's bytes landed in j's headroom; the next round
                // revokes them into the pool and grants them to us.
                RemoteReclaim::Freed(_) => stalled = 0,
                RemoteReclaim::Busy => {
                    stalled += 1;
                    if stalled >= MAX_STALLED_ROUNDS {
                        return Err(DtrError::Oom {
                            need,
                            free: meter.headroom().max(0) as u64,
                            budget: self.total,
                            resident: local.resident_bytes(),
                        }
                        .into());
                    }
                    let (guard, _) =
                        self.cv.wait_timeout(st, STALL_WAIT).expect("arbiter poisoned");
                    st = guard;
                }
                // The candidate raced away (peer evicted or committed it);
                // re-run the round.
                RemoteReclaim::Gone | RemoteReclaim::Empty => {}
            }
        }
    }

    /// Ledger identity at quiescence (no reservation in flight on any
    /// shard): every live shard's `lease == used + headroom`, live leases
    /// plus the shared-weight ledger never exceed the global budget, and
    /// under `StaticSplit` the live caps sum exactly to the splittable
    /// total (budget minus shared) whenever the leases fit it.
    pub fn check_ledger(&self) -> Result<()> {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        let mut leased = 0u64;
        let mut cap_sum = 0u64;
        for (i, sh) in st.shards.iter().enumerate() {
            if !sh.live {
                continue;
            }
            leased += sh.lease;
            cap_sum += sh.cap;
            let used = sh.meter.used();
            let headroom = sh.meter.headroom();
            anyhow::ensure!(
                sh.lease as i128 == used as i128 + headroom as i128,
                "shard {i} ledger drift: lease {} != used {} + headroom {}",
                sh.lease,
                used,
                headroom
            );
            anyhow::ensure!(
                sh.lease <= sh.cap,
                "shard {i} lease {} exceeds its cap {}",
                sh.lease,
                sh.cap
            );
        }
        anyhow::ensure!(
            leased.saturating_add(st.shared) <= self.total,
            "live leases {leased} + shared {} exceed the global budget {}",
            st.shared,
            self.total
        );
        if self.policy == ArbiterPolicy::StaticSplit && st.shards.iter().any(|sh| sh.live) {
            let splittable = self.total.saturating_sub(st.shared);
            // Leases exceeding the splittable total (a shared charge landing
            // after grants) clamp every cap to its lease; otherwise the
            // water-filling resplit covers the splittable total exactly.
            if leased <= splittable {
                anyhow::ensure!(
                    cap_sum == splittable,
                    "static-split caps {cap_sum} != splittable budget {splittable}"
                );
            } else {
                anyhow::ensure!(
                    cap_sum >= leased,
                    "static-split caps {cap_sum} dropped below granted leases {leased}"
                );
            }
        }
        Ok(())
    }

    /// Snapshot every shard's ledger row (diagnostics, benches, tests).
    pub fn snapshot(&self) -> Vec<ShardSnapshot> {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        st.shards
            .iter()
            .enumerate()
            .map(|(id, sh)| ShardSnapshot {
                id,
                live: sh.live,
                lease: sh.lease,
                cap: sh.cap,
                used: sh.meter.used(),
                headroom: sh.meter.headroom(),
                busy_skips: sh.busy_skips.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Bytes currently resident across all live shards, including the
    /// shared-weight ledger (live-sampled by the stress tests to assert the
    /// global budget is respected).
    pub fn used_bytes(&self) -> u64 {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        st.shared + st.shards.iter().filter(|s| s.live).map(|s| s.meter.used()).sum::<u64>()
    }

    /// Bytes currently charged to the shared-weight ledger: the physical
    /// footprint of all distinct deduplicated pinned buffers.
    pub fn shared_bytes(&self) -> u64 {
        self.state.lock().expect("arbiter poisoned").shared
    }
}

/// The arbiter *is* the global ledger of content-addressed pinned weights:
/// the [`crate::api::WeightStore`] charges it once per distinct buffer and
/// refunds it when the last shard releases one. Charges shrink the
/// grantable pool (and the `StaticSplit` splittable total); refunds return
/// the bytes to the pool and wake any reservation blocked on it — freed
/// duplicate-weight budget flows straight to activations.
impl PinnedLedger for BudgetArbiter {
    fn charge_shared(&self, bytes: u64) {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        st.shared = st.shared.checked_add(bytes).expect("shared ledger overflow");
        self.resplit_locked(&mut st);
    }

    fn refund_shared(&self, bytes: u64) {
        let mut st = self.state.lock().expect("arbiter poisoned");
        self.reap_locked(&mut st);
        st.shared = st.shared.checked_sub(bytes).expect("shared refund exceeds charges");
        self.resplit_locked(&mut st);
        drop(st);
        self.cv.notify_all();
    }
}

/// A shard's lease on the shared budget: the [`BudgetGate`] installed into
/// `Config::gate`. Cloned freely with the config (one session per step);
/// when the last clone drops, the shard unregisters and its lease returns
/// to the pool.
pub struct LeaseGate {
    arb: Arc<BudgetArbiter>,
    id: usize,
    meter: Arc<ShardMeter>,
    /// The shard's leaf in the fleet tournament, handed to each session's
    /// runtime through [`BudgetGate::min_slot`].
    slot: Arc<MinSlot>,
}

impl LeaseGate {
    pub fn meter(&self) -> Arc<ShardMeter> {
        Arc::clone(&self.meter)
    }

    pub fn shard_id(&self) -> usize {
        self.id
    }
}

impl BudgetGate for LeaseGate {
    fn name(&self) -> &'static str {
        "lease"
    }

    fn try_reserve(&self, bytes: u64) -> bool {
        self.meter.try_take(bytes)
    }

    fn reserve(&self, bytes: u64, local: &mut dyn LocalEvictor) -> Result<()> {
        self.arb.request(self.id, bytes, local)
    }

    fn reserve_pinned(&self, bytes: u64) {
        if !self.meter.try_take(bytes) {
            self.arb.reserve_pinned_slow(self.id, bytes);
        }
    }

    fn on_alloc(&self, bytes: u64) {
        self.meter.used.fetch_add(bytes, Ordering::AcqRel);
    }

    fn on_free(&self, bytes: u64) {
        self.meter.used.fetch_sub(bytes, Ordering::AcqRel);
        self.meter.credit(bytes);
    }

    fn bind(&self, remote: Arc<dyn RemoteEvictor>) {
        self.arb.bind(self.id, remote);
    }

    fn min_slot(&self) -> Option<Arc<MinSlot>> {
        // Under `Scan` the runtime gets no slot at all, so the baseline
        // pays zero publish overhead — the honest benchmark bar.
        match self.arb.global_index() {
            GlobalIndexKind::Shared => Some(Arc::clone(&self.slot)),
            GlobalIndexKind::Scan => None,
        }
    }
}

impl Drop for LeaseGate {
    /// Lock-free unregistration (see `BudgetArbiter::reap_locked`): flag
    /// the shard dead and wake any waiter; the arbiter reclaims the lease
    /// on its next pass.
    fn drop(&mut self) {
        self.meter.dead.store(true, Ordering::Release);
        self.arb.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in ArbiterPolicy::all() {
            assert_eq!(ArbiterPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ArbiterPolicy::parse("bogus"), None);
    }

    #[test]
    fn global_index_parse_roundtrip_and_slot_gating() {
        for k in GlobalIndexKind::all() {
            assert_eq!(GlobalIndexKind::parse(k.name()), Some(k));
        }
        assert_eq!(GlobalIndexKind::parse("bogus"), None);
        let arb = BudgetArbiter::new(100, ArbiterPolicy::GlobalReclaim, 2);
        assert_eq!(arb.global_index(), GlobalIndexKind::Shared, "shared is the default");
        arb.set_global_index(GlobalIndexKind::Scan);
        let g = arb.register();
        assert!(g.min_slot().is_none(), "scan mode hands out no publish slot");
        arb.set_global_index(GlobalIndexKind::Shared);
        assert!(g.min_slot().is_some());
        // No sessions ran: nothing published, nothing to pick.
        assert_eq!(arb.pick_victim(g.shard_id()), None);
        assert_eq!(arb.fleet_dead_drops(), 0);
    }

    #[test]
    fn meter_cas_paths() {
        let m = ShardMeter::default();
        m.credit(100);
        assert!(m.try_take(60));
        assert!(!m.try_take(60));
        assert_eq!(m.headroom(), 40);
        assert_eq!(m.steal_up_to(100), 40);
        assert_eq!(m.headroom(), 0);
        m.take_unchecked(8);
        assert_eq!(m.headroom(), -8, "pinned overdraft goes negative");
        assert_eq!(m.steal_up_to(10), 0, "overdraft is not stealable");
        m.credit(8);
        assert_eq!(m.headroom(), 0);
    }

    #[test]
    fn static_split_caps_leases() {
        // Caps follow the *live* membership: two registered shards split
        // the whole budget evenly, regardless of the planned tenant count.
        let arb = BudgetArbiter::new(100, ArbiterPolicy::StaticSplit, 4);
        let a = arb.register();
        let b = arb.register();
        let snap = arb.snapshot();
        assert_eq!(snap[a.shard_id()].cap, 50);
        assert_eq!(snap[b.shard_id()].cap, 50);
        assert!(!a.try_reserve(10), "no lease granted yet");
        a.reserve_pinned(10);
        a.on_alloc(10);
        // Cap is 50: pinned growth stops at the cap, the rest overdrafts.
        a.reserve_pinned(55);
        a.on_alloc(55);
        let snap = arb.snapshot();
        assert_eq!(snap[a.shard_id()].lease, 50);
        assert_eq!(snap[a.shard_id()].used, 65);
        assert_eq!(snap[a.shard_id()].headroom, -15);
        arb.check_ledger().unwrap();
        // b leaves: the survivor's cap absorbs the freed share.
        drop(b);
        arb.check_ledger().unwrap();
        let snap = arb.snapshot();
        assert_eq!(snap[a.shard_id()].cap, 100);
    }

    #[test]
    fn static_split_resplits_on_join_and_shared_charge() {
        let arb = BudgetArbiter::new(120, ArbiterPolicy::StaticSplit, 2);
        let a = arb.register();
        assert_eq!(arb.snapshot()[a.shard_id()].cap, 120, "sole tenant owns it all");
        // a leases more than a half-share before b joins: water-filling
        // keeps a's cap at its granted lease, b gets the rest.
        a.reserve_pinned(80);
        a.on_alloc(80);
        let b = arb.register();
        let snap = arb.snapshot();
        assert_eq!(snap[a.shard_id()].cap, 80, "caps never cut below granted leases");
        assert_eq!(snap[b.shard_id()].cap, 40);
        arb.check_ledger().unwrap();
        a.on_free(80);
        drop(a);
        // Shared-weight charges shrink the splittable total.
        arb.charge_shared(20);
        assert_eq!(arb.shared_bytes(), 20);
        let snap = arb.snapshot();
        assert_eq!(snap[b.shard_id()].cap, 100);
        arb.check_ledger().unwrap();
        arb.refund_shared(20);
        assert_eq!(arb.shared_bytes(), 0);
        assert_eq!(arb.snapshot()[b.shard_id()].cap, 120);
        arb.check_ledger().unwrap();
    }

    #[test]
    fn shared_ledger_shrinks_the_grantable_pool() {
        let arb = BudgetArbiter::new(100, ArbiterPolicy::GlobalReclaim, 1);
        arb.charge_shared(60);
        let a = arb.register();
        // Pinned growth can lease only the 40 unshared bytes; the rest is
        // overdraft, exactly as if 60 bytes were physically occupied.
        a.reserve_pinned(50);
        a.on_alloc(50);
        let snap = arb.snapshot();
        assert_eq!(snap[a.shard_id()].lease, 40);
        assert_eq!(snap[a.shard_id()].headroom, -10);
        assert_eq!(arb.used_bytes(), 60 + 50);
        arb.check_ledger().unwrap();
        a.on_free(50);
        drop(a);
        arb.refund_shared(60);
        assert_eq!(arb.used_bytes(), 0);
    }

    #[test]
    fn static_split_distributes_remainder() {
        // 103 over 4 planned tenants: caps [26, 26, 26, 25] — the division
        // remainder is spread over the low slots, not stranded.
        let arb = BudgetArbiter::new(103, ArbiterPolicy::StaticSplit, 4);
        let gates: Vec<_> = (0..4).map(|_| arb.register()).collect();
        let snap = arb.snapshot();
        let caps: Vec<u64> = snap.iter().map(|s| s.cap).collect();
        assert_eq!(caps, vec![26, 26, 26, 25]);
        assert_eq!(caps.iter().sum::<u64>(), arb.total(), "caps must cover the whole budget");
        // A low slot can actually lease its full (uneven) cap.
        gates[0].reserve_pinned(26);
        gates[0].on_alloc(26);
        let snap = arb.snapshot();
        assert_eq!(snap[gates[0].shard_id()].lease, 26);
        assert_eq!(snap[gates[0].shard_id()].headroom, 0);
        arb.check_ledger().unwrap();
    }

    #[test]
    fn ledger_exact_at_i64_max_boundary() {
        let m = ShardMeter::default();
        m.credit(i64::MAX as u64);
        assert_eq!(m.headroom(), i64::MAX);
        // One byte past the boundary is rejected without moving the ledger.
        assert!(!m.try_take(i64::MAX as u64 + 1));
        assert_eq!(m.headroom(), i64::MAX);
        // Exactly the boundary drains it to zero.
        assert!(m.try_take(i64::MAX as u64));
        assert_eq!(m.headroom(), 0);
        // An unchecked take/credit pair nets exactly zero — no clamp drift.
        m.take_unchecked(7);
        m.credit(7);
        assert_eq!(m.headroom(), 0);
    }

    #[test]
    #[should_panic(expected = "signed ledger")]
    fn unchecked_take_rejects_oversize() {
        ShardMeter::default().take_unchecked(u64::MAX);
    }

    #[test]
    fn unregister_returns_lease_to_pool() {
        let arb = BudgetArbiter::new(100, ArbiterPolicy::GlobalReclaim, 1);
        let a = arb.register();
        a.reserve_pinned(80);
        a.on_alloc(80);
        assert_eq!(arb.used_bytes(), 80);
        a.on_free(80);
        drop(a);
        let b = arb.register();
        b.reserve_pinned(100);
        b.on_alloc(100);
        arb.check_ledger().unwrap();
        assert_eq!(arb.snapshot()[b.shard_id()].lease, 100);
    }
}
