//! The RAII tensor handle: the only way user code refers to a DTR-managed
//! value. A `Tensor` owns exactly one external reference on its underlying
//! storage — `Clone` retains (the log format's COPY), `Drop` releases
//! (RELEASE, routed through the configured `DeallocPolicy`). Raw
//! [`TensorId`]s never escape `dtr::api`, so callers cannot leak pins,
//! double-release, or touch another session's ids.

use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;

use crate::dtr::{Backend, Runtime, TensorId};

/// Type-erased refcount sink: lets `Tensor` stay non-generic while the
/// session it came from wraps a `Runtime<B>` for any backend `B`.
pub(crate) trait Releaser {
    fn retain_id(&self, t: TensorId);
    fn release_id(&self, t: TensorId);
}

impl<B: Backend> Releaser for RefCell<Runtime<B>> {
    fn retain_id(&self, t: TensorId) {
        self.borrow_mut().retain(t);
    }

    fn release_id(&self, t: TensorId) {
        // `try_borrow_mut` only fails while a session call is unwinding with
        // the runtime borrowed; skipping the release then merely leaks a
        // refcount in a runtime that is already being torn down.
        if let Ok(mut rt) = self.try_borrow_mut() {
            rt.release(t);
        }
    }
}

/// An owned reference to a DTR-managed tensor.
///
/// Dropping the last handle to a storage triggers the session's
/// deallocation policy (eager eviction by default); cloning increments the
/// external reference count. Handles keep the underlying runtime alive, so
/// they may safely outlive the [`super::Session`] that created them.
pub struct Tensor {
    id: TensorId,
    rt: Rc<dyn Releaser>,
}

impl Tensor {
    pub(crate) fn from_parts(rt: Rc<dyn Releaser>, id: TensorId) -> Tensor {
        Tensor { id, rt }
    }

    /// The raw id, visible only inside `dtr::api`.
    pub(crate) fn id(&self) -> TensorId {
        self.id
    }
}

impl Clone for Tensor {
    fn clone(&self) -> Tensor {
        self.rt.retain_id(self.id);
        Tensor { id: self.id, rt: Rc::clone(&self.rt) }
    }
}

impl Drop for Tensor {
    fn drop(&mut self) {
        self.rt.release_id(self.id);
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor({})", self.id)
    }
}
