//! The RAII tensor handle: the only way user code refers to a DTR-managed
//! value. A `Tensor` owns exactly one external reference on its underlying
//! storage — `Clone` retains (the log format's COPY), `Drop` releases
//! (RELEASE, routed through the configured `DeallocPolicy`). Raw
//! [`TensorId`]s never escape `dtr::api`, so callers cannot leak pins,
//! double-release, or touch another session's ids.

use std::fmt;
use std::sync::{Arc, Mutex};

use crate::dtr::{Backend, Runtime, TensorId};

/// Type-erased refcount sink: lets `Tensor` stay non-generic while the
/// session it came from wraps a `Runtime<B>` for any backend `B`. `Send +
/// Sync` supertraits keep handles movable across serving worker threads.
pub(crate) trait Releaser: Send + Sync {
    fn retain_id(&self, t: TensorId);
    fn release_id(&self, t: TensorId);
}

impl<B: Backend> Releaser for Mutex<Runtime<B>> {
    fn retain_id(&self, t: TensorId) {
        if let Ok(mut rt) = self.lock() {
            rt.retain(t);
        }
    }

    fn release_id(&self, t: TensorId) {
        // `lock` only fails when a session call panicked with the runtime
        // poisoned; skipping the release then merely leaks a refcount in a
        // runtime that is already being torn down. Note this is a *blocking*
        // lock: under serving, the arbiter may briefly hold this runtime for
        // a cross-shard reclaim, and a dropped handle must still release.
        if let Ok(mut rt) = self.lock() {
            rt.release(t);
        }
    }
}

/// An owned reference to a DTR-managed tensor.
///
/// Dropping the last handle to a storage triggers the session's
/// deallocation policy (eager eviction by default); cloning increments the
/// external reference count. Handles keep the underlying runtime alive, so
/// they may safely outlive the [`super::Session`] that created them, and
/// they are `Send` — a tenant's handles can live on its worker thread.
pub struct Tensor {
    id: TensorId,
    rt: Arc<dyn Releaser>,
}

impl Tensor {
    pub(crate) fn from_parts(rt: Arc<dyn Releaser>, id: TensorId) -> Tensor {
        Tensor { id, rt }
    }

    /// The raw id, visible only inside `dtr::api`.
    pub(crate) fn id(&self) -> TensorId {
        self.id
    }
}

impl Clone for Tensor {
    fn clone(&self) -> Tensor {
        self.rt.retain_id(self.id);
        Tensor { id: self.id, rt: Arc::clone(&self.rt) }
    }
}

impl Drop for Tensor {
    fn drop(&mut self) {
        self.rt.release_id(self.id);
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor({})", self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_handles_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Tensor>();
    }
}
