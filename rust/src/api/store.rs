//! Content-addressed pinned-weight store: one physical copy of identical
//! parameter buffers, shared across tenant shards.
//!
//! N tenants serving one base model pin N identical copies of the weights —
//! the single largest waste of a shared budget ("millions of users on one
//! base model", ROADMAP item 4). The [`WeightStore`] interns pinned
//! [`HostTensor`] buffers by content address (a 64-bit FNV over the shape
//! and the exact f32 bit patterns, with full bitwise verification on every
//! bucket hit, so hash collisions can never alias two different weights)
//! and refcounts the interned copies:
//!
//! * the **first** intern of a distinct buffer charges the
//!   [`PinnedLedger`] (in production the `serve::BudgetArbiter`'s shared
//!   ledger) exactly once;
//! * later interns of the same bytes bump a refcount and return an `Arc`
//!   to the *same* allocation — the shard's `ExecBackend` maps its tensor
//!   id onto the shared buffer, so sharing is physical, not just
//!   accounting;
//! * dropping a [`PinnedWeight`] decrements; the **last** drop removes the
//!   entry and refunds the ledger once.
//!
//! The DTR side stays honest through `Runtime::constant_shared`: shared
//! storages are pinned (invisible to eviction) and excluded from the lease
//! gate, so with dedup off the decision traces are bit-identical to the
//! private-copy path (`tests/stress_dedup.rs`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::dtr::lease::PinnedLedger;
use crate::runtime::executor::HostTensor;

/// 64-bit FNV-1a over the shape and the exact f32 bit patterns — the
/// content address. Bitwise, not semantic: `-0.0` and `0.0` hash (and
/// compare) differently, which is exactly right for buffers that must be
/// physically interchangeable.
pub fn content_hash(t: &HostTensor) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    eat(&(t.shape.len() as u64).to_le_bytes());
    for &d in &t.shape {
        eat(&(d as u64).to_le_bytes());
    }
    for &v in &t.data {
        eat(&v.to_bits().to_le_bytes());
    }
    h
}

/// Exact interchangeability: same shape and bit-identical data.
fn same_bits(a: &HostTensor, b: &HostTensor) -> bool {
    a.shape == b.shape
        && a.data.len() == b.data.len()
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

struct Entry {
    value: Arc<HostTensor>,
    refs: usize,
}

/// Refcounted, content-addressed store of read-only pinned weights. One
/// per [`crate::serve::ServePool`] when dedup is on; shards intern their
/// parameter buffers at setup and re-intern after each fine-tune update.
pub struct WeightStore {
    ledger: Arc<dyn PinnedLedger>,
    state: Mutex<HashMap<u64, Vec<Entry>>>,
}

impl WeightStore {
    pub fn new(ledger: Arc<dyn PinnedLedger>) -> Arc<WeightStore> {
        Arc::new(WeightStore { ledger, state: Mutex::new(HashMap::new()) })
    }

    /// Intern `value`: return a refcounted handle to the single physical
    /// copy of these bytes, charging the ledger only if no equal buffer is
    /// already interned.
    pub fn intern(self: &Arc<Self>, value: HostTensor) -> PinnedWeight {
        let key = content_hash(&value);
        let mut st = self.state.lock().expect("weight store poisoned");
        let bucket = st.entry(key).or_default();
        for e in bucket.iter_mut() {
            if same_bits(&e.value, &value) {
                e.refs += 1;
                return PinnedWeight {
                    store: Arc::clone(self),
                    key,
                    value: Arc::clone(&e.value),
                };
            }
        }
        let value = Arc::new(value);
        let bytes = value.size_bytes();
        bucket.push(Entry { value: Arc::clone(&value), refs: 1 });
        drop(st);
        self.ledger.charge_shared(bytes);
        PinnedWeight { store: Arc::clone(self), key, value }
    }

    /// Bump the refcount of an already-interned buffer (Clone support).
    fn retain(&self, key: u64, value: &Arc<HostTensor>) {
        let mut st = self.state.lock().expect("weight store poisoned");
        let bucket = st.get_mut(&key).expect("retained weight has no bucket");
        let e = bucket
            .iter_mut()
            .find(|e| Arc::ptr_eq(&e.value, value))
            .expect("retained weight missing from its bucket");
        e.refs += 1;
    }

    /// Drop one reference; the last drop removes the entry and refunds the
    /// ledger exactly once.
    fn release(&self, key: u64, value: &Arc<HostTensor>) {
        let refund = {
            let mut st = self.state.lock().expect("weight store poisoned");
            let bucket = st.get_mut(&key).expect("released weight has no bucket");
            let i = bucket
                .iter()
                .position(|e| Arc::ptr_eq(&e.value, value))
                .expect("released weight missing from its bucket");
            bucket[i].refs -= 1;
            if bucket[i].refs == 0 {
                let e = bucket.swap_remove(i);
                if bucket.is_empty() {
                    st.remove(&key);
                }
                Some(e.value.size_bytes())
            } else {
                None
            }
        };
        if let Some(bytes) = refund {
            self.ledger.refund_shared(bytes);
        }
    }

    /// Number of distinct interned buffers.
    pub fn distinct(&self) -> usize {
        self.state.lock().expect("weight store poisoned").values().map(Vec::len).sum()
    }

    /// Total bytes of distinct interned buffers — what the ledger is
    /// currently charged.
    pub fn shared_bytes(&self) -> u64 {
        self.state
            .lock()
            .expect("weight store poisoned")
            .values()
            .flatten()
            .map(|e| e.value.size_bytes())
            .sum()
    }

    /// Total live references across all entries (observability for the
    /// dedup benches: `total_refs / distinct` ≈ tenants per copy).
    pub fn total_refs(&self) -> usize {
        self.state
            .lock()
            .expect("weight store poisoned")
            .values()
            .flatten()
            .map(|e| e.refs)
            .sum()
    }
}

/// RAII handle to one interned pinned buffer. Holds the shared `Arc` (so
/// the bytes are reachable without locking the store) and one refcount;
/// `Drop` releases it, refunding the ledger when the holder was the last.
pub struct PinnedWeight {
    store: Arc<WeightStore>,
    key: u64,
    value: Arc<HostTensor>,
}

impl PinnedWeight {
    pub fn value(&self) -> &HostTensor {
        &self.value
    }

    /// The shared allocation itself — what `ExecBackend::put_shared` maps a
    /// tensor id onto.
    pub fn arc(&self) -> Arc<HostTensor> {
        Arc::clone(&self.value)
    }

    pub fn bytes(&self) -> u64 {
        self.value.size_bytes()
    }
}

impl Clone for PinnedWeight {
    fn clone(&self) -> PinnedWeight {
        self.store.retain(self.key, &self.value);
        PinnedWeight {
            store: Arc::clone(&self.store),
            key: self.key,
            value: Arc::clone(&self.value),
        }
    }
}

impl Drop for PinnedWeight {
    fn drop(&mut self) {
        self.store.release(self.key, &self.value);
    }
}

impl std::fmt::Debug for PinnedWeight {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PinnedWeight({} B, key {:#x})", self.bytes(), self.key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicI64, Ordering};

    /// Test ledger counting net charged bytes and charge events.
    #[derive(Default)]
    struct CountingLedger {
        net: AtomicI64,
        charges: AtomicI64,
        refunds: AtomicI64,
    }

    impl PinnedLedger for CountingLedger {
        fn charge_shared(&self, bytes: u64) {
            self.net.fetch_add(bytes as i64, Ordering::SeqCst);
            self.charges.fetch_add(1, Ordering::SeqCst);
        }
        fn refund_shared(&self, bytes: u64) {
            self.net.fetch_sub(bytes as i64, Ordering::SeqCst);
            self.refunds.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn w(shape: &[usize], fill: f32) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor::new(shape.to_vec(), vec![fill; n])
    }

    #[test]
    fn identical_buffers_intern_to_one_copy_charged_once() {
        let ledger = Arc::new(CountingLedger::default());
        let store = WeightStore::new(Arc::clone(&ledger) as Arc<dyn PinnedLedger>);
        let a = store.intern(w(&[4, 8], 1.5));
        let b = store.intern(w(&[4, 8], 1.5));
        let c = store.intern(w(&[4, 8], 1.5));
        assert!(Arc::ptr_eq(&a.arc(), &b.arc()), "interns must share one allocation");
        assert!(Arc::ptr_eq(&b.arc(), &c.arc()));
        assert_eq!(store.distinct(), 1);
        assert_eq!(store.total_refs(), 3);
        assert_eq!(ledger.charges.load(Ordering::SeqCst), 1, "charged once for 3 holders");
        assert_eq!(ledger.net.load(Ordering::SeqCst), 4 * 8 * 4);
    }

    #[test]
    fn different_bits_or_shapes_stay_distinct() {
        let store = WeightStore::new(Arc::new(CountingLedger::default()) as _);
        let a = store.intern(w(&[4, 8], 1.0));
        let b = store.intern(w(&[8, 4], 1.0)); // same bytes, different shape
        let c = store.intern(w(&[4, 8], -0.0)); // -0.0 != 0.0 bitwise
        let d = store.intern(w(&[4, 8], 0.0));
        assert!(!Arc::ptr_eq(&a.arc(), &b.arc()));
        assert!(!Arc::ptr_eq(&c.arc(), &d.arc()), "-0.0 must not alias 0.0");
        assert_eq!(store.distinct(), 4);
    }

    #[test]
    fn last_drop_refunds_exactly_once() {
        let ledger = Arc::new(CountingLedger::default());
        let store = WeightStore::new(Arc::clone(&ledger) as Arc<dyn PinnedLedger>);
        let a = store.intern(w(&[16], 2.0));
        let b = a.clone();
        let c = store.intern(w(&[16], 2.0));
        drop(a);
        drop(b);
        assert_eq!(ledger.refunds.load(Ordering::SeqCst), 0, "a holder remains");
        assert_eq!(store.distinct(), 1);
        drop(c);
        assert_eq!(ledger.refunds.load(Ordering::SeqCst), 1, "last drop refunds once");
        assert_eq!(ledger.net.load(Ordering::SeqCst), 0);
        assert_eq!(store.distinct(), 0);
        // Re-interning after full release charges afresh.
        let _d = store.intern(w(&[16], 2.0));
        assert_eq!(ledger.charges.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn hash_collisions_cannot_alias() {
        // Force both tensors into one bucket by checking the full-equality
        // guard directly: same_bits is the arbiter, not the hash.
        let x = w(&[2], 1.0);
        let y = w(&[2], 2.0);
        assert!(!same_bits(&x, &y));
        assert!(same_bits(&x, &w(&[2], 1.0)));
        // And the hash itself is deterministic and shape-sensitive.
        assert_eq!(content_hash(&x), content_hash(&w(&[2], 1.0)));
        assert_ne!(content_hash(&w(&[4, 8], 1.0)), content_hash(&w(&[8, 4], 1.0)));
    }

    #[test]
    fn store_is_send_and_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<WeightStore>();
        assert_ss::<PinnedWeight>();
    }
}
