//! The executor-facing DTR backend: owns the host buffers (keyed by the
//! typed [`TensorId`] end-to-end) and delegates operator execution to a
//! pluggable [`Executor`]. This is interposition machinery — the only
//! place outside the core runtime that touches raw tensor ids — so it
//! lives inside `dtr::api` with the session that drives it.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::dtr::{Backend, TensorId};
use crate::runtime::executor::{Executor, HostTensor};

/// Shared handle to the executor: the engine keeps it across steps while
/// each per-step session's backend locks it for operator execution. The
/// mutex makes an executor shareable across serving tenants too (compiled
/// state is built once; tenants serialize on the op-execute hot path only
/// if they genuinely share one executor — each tenant normally owns its
/// own).
pub type SharedExecutor = Arc<Mutex<Box<dyn Executor>>>;

/// A backend buffer: either owned by this shard or a view of a
/// content-addressed shared weight (`api::WeightStore`). Shared buffers
/// are physically one allocation across every shard that interned the same
/// bytes; they back pinned constants only, so the backend never frees or
/// overwrites them through `execute`.
enum Buf {
    Owned(HostTensor),
    Shared(Arc<HostTensor>),
}

impl Buf {
    #[inline]
    fn tensor(&self) -> &HostTensor {
        match self {
            Buf::Owned(v) => v,
            Buf::Shared(v) => v,
        }
    }
}

/// Buffer store implementing the DTR backend trait over any [`Executor`].
pub struct ExecBackend {
    exec: SharedExecutor,
    bufs: HashMap<TensorId, Buf>,
    /// Wall time spent executing operators (Fig. 4's "operator time").
    pub exec_ns: u64,
    pub exec_count: u64,
}

impl ExecBackend {
    pub fn new(exec: SharedExecutor) -> Self {
        ExecBackend { exec, bufs: HashMap::new(), exec_ns: 0, exec_count: 0 }
    }

    pub fn put(&mut self, t: TensorId, v: HostTensor) {
        self.bufs.insert(t, Buf::Owned(v));
    }

    /// Map a tensor id onto a shared allocation (a deduplicated pinned
    /// weight) instead of a private copy.
    pub fn put_shared(&mut self, t: TensorId, v: Arc<HostTensor>) {
        self.bufs.insert(t, Buf::Shared(v));
    }

    pub fn get(&self, t: TensorId) -> Option<&HostTensor> {
        self.bufs.get(&t).map(Buf::tensor)
    }
}

impl Backend for ExecBackend {
    fn execute(&mut self, name: &str, inputs: &[TensorId], outputs: &[TensorId]) -> Result<()> {
        let t0 = Instant::now();
        let ins: Vec<&HostTensor> = inputs
            .iter()
            .map(|t| {
                self.bufs.get(t).map(Buf::tensor).with_context(|| format!("missing buffer {t}"))
            })
            .collect::<Result<_>>()?;
        let outs = self.exec.lock().expect("executor poisoned").execute(name, &ins)?;
        anyhow::ensure!(
            outs.len() == outputs.len(),
            "{name}: {} outputs from executor, {} expected",
            outs.len(),
            outputs.len()
        );
        for (&t, v) in outputs.iter().zip(outs) {
            self.bufs.insert(t, Buf::Owned(v));
        }
        self.exec_ns += t0.elapsed().as_nanos() as u64;
        self.exec_count += 1;
        Ok(())
    }

    fn free(&mut self, roots: &[TensorId]) {
        for t in roots {
            self.bufs.remove(t);
        }
    }
}
