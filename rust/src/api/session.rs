//! The `Session` facade: the single interposition point between user
//! programs and the DTR runtime.
//!
//! A session wraps a `Runtime<B>` behind shared ownership so that every
//! [`Tensor`] handle it hands out can route its `Drop` back through the
//! runtime's deallocation policy. User code never sees raw `TensorId`s,
//! `OutSpec`s, or the `Runtime` itself: operator interposition happens in
//! [`Session::call`] (sizes and costs derived from the executor's manifest)
//! or [`Session::call_sized`] (explicit sizes, for accounting workloads),
//! and host I/O happens in [`Session::constant`] / [`Session::get`].
//!
//! The runtime sits behind an `Arc<Mutex<…>>`, so sessions (and their
//! handles) are `Send`: a serving tenant runs its session on a worker
//! thread while the budget arbiter (`crate::serve`) may briefly `try_lock`
//! the same runtime to reclaim memory across shards. When the session's
//! `Config` carries a [`crate::dtr::GateRef`], construction registers the
//! runtime with that gate so cross-shard eviction can reach it.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{Context, Result};

use super::backend::{ExecBackend, SharedExecutor};
use super::tensor::{Releaser, Tensor};
use crate::dtr::{Backend, Config, NullBackend, OutSpec, Runtime, RuntimeHandle, Stats, TensorId};
use crate::runtime::executor::{analytic_cost, HostTensor};
use crate::runtime::{Executor, Manifest};

/// The op/shape/cost contract a session serves, precomputed once per
/// executor and shared (cheap `Arc` clones) across the per-step sessions of
/// a long-lived driver — building it is O(op-set), which must not recur in
/// every step's wall-clock window.
#[derive(Clone)]
pub struct OpContract {
    manifest: Arc<Manifest>,
    op_cost: Arc<HashMap<String, u64>>,
}

impl OpContract {
    /// Derive the contract from an executor's manifest, with deterministic
    /// analytic per-op costs.
    pub fn of(exec: &SharedExecutor) -> OpContract {
        let manifest = exec.lock().expect("executor poisoned").manifest().clone();
        let mut op_cost = HashMap::new();
        for (name, op) in &manifest.ops {
            op_cost.insert(name.clone(), analytic_cost(name, op, &manifest.config));
        }
        OpContract { manifest: Arc::new(manifest), op_cost: Arc::new(op_cost) }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

/// A DTR session: one runtime, one budget (fixed or leased from a shared
/// pool), one stream of interposed operator calls. See the module docs of
/// [`crate::api`] for a complete training example.
pub struct Session<B: Backend + 'static> {
    rt: Arc<Mutex<Runtime<B>>>,
    /// The op/shape contract, present on executor-backed sessions; `None`
    /// for accounting sessions driven via [`Session::call_sized`].
    manifest: Option<Arc<Manifest>>,
    /// Deterministic analytic per-op costs derived from the manifest.
    op_cost: Arc<HashMap<String, u64>>,
}

impl<B: Backend + 'static> Session<B> {
    fn from_runtime(
        rt: Runtime<B>,
        manifest: Option<Arc<Manifest>>,
        op_cost: Arc<HashMap<String, u64>>,
    ) -> Session<B> {
        let gate = rt.cfg.gate.clone();
        let rt = Arc::new(Mutex::new(rt));
        // Shared-budget shard: register this runtime with its gate so the
        // arbiter can peek/reclaim across shards (try_lock only).
        if let Some(g) = gate {
            g.0.bind(Arc::new(RuntimeHandle::new(Arc::downgrade(&rt))));
        }
        Session { rt, manifest, op_cost }
    }

    fn rt(&self) -> MutexGuard<'_, Runtime<B>> {
        self.rt.lock().expect("DTR runtime poisoned by a panicked session call")
    }

    fn wrap(&self, id: TensorId) -> Tensor {
        Tensor::from_parts(Arc::clone(&self.rt) as Arc<dyn Releaser>, id)
    }

    /// Register a pinned, never-rematerializable constant of `bytes` bytes
    /// (weights and inputs in accounting workloads).
    pub fn constant_sized(&self, bytes: u64) -> Tensor {
        let id = self.rt().constant(bytes);
        self.wrap(id)
    }

    /// Interpose an operator call with explicit cost and output sizes — the
    /// raw, size-level interface used by accounting sessions (simulation
    /// logs) where no executor manifest describes the op set.
    pub fn call_sized(
        &self,
        op: &str,
        cost: u64,
        inputs: &[&Tensor],
        out_bytes: &[u64],
    ) -> Result<Vec<Tensor>> {
        let ids: Vec<TensorId> = inputs.iter().map(|t| t.id()).collect();
        let specs: Vec<OutSpec> = out_bytes.iter().map(|&b| OutSpec::sized(b)).collect();
        let outs = self.rt().call(op, cost, &ids, &specs)?;
        Ok(outs.into_iter().map(|id| self.wrap(id)).collect())
    }

    /// Rematerialize (if evicted) and touch a tensor — the prototype's
    /// `decheckpoint()`.
    pub fn touch(&self, t: &Tensor) -> Result<()> {
        self.rt().access(t.id())
    }

    /// Is the tensor currently materialized?
    pub fn is_defined(&self, t: &Tensor) -> bool {
        self.rt().is_defined(t.id())
    }

    /// Output condition (Appendix C.6): rematerialize and pin everything
    /// still referenced by live handles.
    pub fn pin_live(&self) -> Result<()> {
        self.rt().pin_live_outputs()
    }

    pub fn stats(&self) -> Stats {
        self.rt().stats.clone()
    }

    /// Name of the victim-selection index the runtime resolved from
    /// `Config::index` (e.g. `"staleness_list"` for `h_lru` and
    /// `"auto_differential"` — a scan that upgrades itself to the
    /// differential index at a measured pool-size crossover — for the
    /// staleness-bearing `h_dtr` family under the default
    /// `PolicyKind::Auto`; `"scan"` for the reference path).
    pub fn policy_index(&self) -> &'static str {
        self.rt().index_name()
    }

    /// Currently resident bytes.
    pub fn memory(&self) -> u64 {
        self.rt().stats.memory
    }

    pub fn peak_memory(&self) -> u64 {
        self.rt().stats.peak_memory
    }

    /// Verify the runtime's internal accounting.
    pub fn check_invariants(&self) -> Result<()> {
        self.rt().check_invariants()
    }
}

impl Session<NullBackend> {
    /// Accounting-only session: DTR decisions (evictions, remats, peak
    /// memory) without any executor. Drive it with [`Session::call_sized`];
    /// its stats must be identical to a real-executor session issuing the
    /// same op stream (the backend-equivalence property).
    pub fn accounting(cfg: Config) -> Session<NullBackend> {
        Session::from_runtime(Runtime::new(cfg, NullBackend::new()), None, Arc::new(HashMap::new()))
    }
}

impl Session<ExecBackend> {
    /// A session owning its executor.
    pub fn new(exec: Box<dyn Executor>, cfg: Config) -> Session<ExecBackend> {
        Session::over(Arc::new(Mutex::new(exec)), cfg)
    }

    /// A session over a shared executor, deriving a fresh [`OpContract`].
    /// Long-lived drivers that build one session per training step should
    /// precompute the contract once and use [`Session::with_contract`].
    pub fn over(exec: SharedExecutor, cfg: Config) -> Session<ExecBackend> {
        let contract = OpContract::of(&exec);
        Session::with_contract(exec, cfg, &contract)
    }

    /// A session over a shared executor and a precomputed contract — the
    /// per-step constructor: the executor (compiled state, scratch buffers)
    /// and the contract persist across steps; only the runtime is fresh.
    pub fn with_contract(
        exec: SharedExecutor,
        cfg: Config,
        contract: &OpContract,
    ) -> Session<ExecBackend> {
        let backend = ExecBackend::new(exec);
        Session::from_runtime(
            Runtime::new(cfg, backend),
            Some(Arc::clone(&contract.manifest)),
            Arc::clone(&contract.op_cost),
        )
    }

    /// The op/shape contract this session serves.
    pub fn manifest(&self) -> &Manifest {
        self.manifest.as_deref().expect("executor-backed sessions always carry a manifest")
    }

    /// Deterministic analytic cost of a manifest op.
    pub fn op_cost(&self, op: &str) -> u64 {
        self.op_cost.get(op).copied().unwrap_or(1)
    }

    /// Register a constant with its host value (weights, data batches,
    /// optimizer state).
    pub fn constant(&self, v: HostTensor) -> Tensor {
        let mut rt = self.rt();
        let id = rt.constant(v.size_bytes());
        rt.backend_mut().put(id, v);
        drop(rt);
        self.wrap(id)
    }

    /// Register a *shared* pinned constant: the bytes are one physical
    /// allocation interned in a cross-shard [`super::WeightStore`], charged
    /// to the arbiter's shared ledger rather than this shard's lease. The
    /// caller keeps the corresponding [`super::PinnedWeight`] alive for as
    /// long as the tensor is in use.
    pub fn constant_shared(&self, v: Arc<HostTensor>) -> Tensor {
        let mut rt = self.rt();
        let id = rt.constant_shared(v.size_bytes());
        rt.backend_mut().put_shared(id, v);
        drop(rt);
        self.wrap(id)
    }

    /// Interpose an operator call: output sizes come from the manifest
    /// signature and the cost from the analytic model, so callers name the
    /// op and pass inputs — nothing else.
    pub fn call(&self, op: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let specs: Vec<OutSpec> = {
            let sig = self.manifest().op(op)?;
            anyhow::ensure!(
                inputs.len() == sig.inputs.len(),
                "{op}: {} inputs given, signature expects {}",
                inputs.len(),
                sig.inputs.len()
            );
            sig.outputs.iter().map(|o| OutSpec::sized(o.bytes())).collect()
        };
        let cost = self.op_cost(op);
        let ids: Vec<TensorId> = inputs.iter().map(|t| t.id()).collect();
        let outs = self.rt().call(op, cost, &ids, &specs)?;
        Ok(outs.into_iter().map(|id| self.wrap(id)).collect())
    }

    /// Read a tensor's host value, transparently rematerializing it first
    /// if DTR evicted it.
    pub fn get(&self, t: &Tensor) -> Result<HostTensor> {
        let mut rt = self.rt();
        rt.access(t.id())?;
        rt.backend()
            .get(t.id())
            .cloned()
            .with_context(|| format!("no buffer for {t:?}"))
    }

    /// Convenience: read a scalar (loss) value.
    pub fn scalar(&self, t: &Tensor) -> Result<f32> {
        Ok(self.get(t)?.data[0])
    }

    /// Wall time spent executing operators so far (Fig. 4 "operator time").
    pub fn exec_ns(&self) -> u64 {
        self.rt().backend().exec_ns
    }

    pub fn exec_count(&self) -> u64 {
        self.rt().backend().exec_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Session<NullBackend>>();
        assert_send::<Session<ExecBackend>>();
        assert_send::<OpContract>();
    }
}
