//! # `dtr::api` — the interposition-first public API
//!
//! The paper's central claim is that DTR needs nothing but interposition
//! "on tensor allocations and operator calls" plus lightweight metadata.
//! This module is that interposition surface: a [`Session`] facade over the
//! DTR runtime plus an RAII [`Tensor`] handle that owns a refcount on its
//! underlying storage. `Clone` retains, `Drop` releases through the
//! configured `DeallocPolicy`, [`Session::call`] interposes every operator
//! (sizes from the executor manifest, costs from the analytic model), and
//! [`Session::constant`] / [`Session::get`] handle host I/O. User code
//! cannot leak pins, double-release, or touch raw ids — and because the
//! program drives the session *online*, arbitrary dynamic models (LSTMs
//! over data-dependent sequence lengths, per-sample tree shapes — see
//! [`crate::exec::dynamic`]) run under a budget with zero ahead-of-time
//! planning, which no static checkpointing planner can do.
//!
//! ## Train your own model under a budget
//!
//! Pick an executor (the hermetic interpreter here), choose a budget, and
//! issue operator calls; DTR evicts and rematerializes behind the API:
//!
//! ```
//! use dtr::api::Session;
//! use dtr::dtr::{Config, Heuristic};
//! use dtr::runtime::{HostTensor, InterpExecutor, RnnConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! // One LSTM cell + readout, trained under a 64 KiB budget.
//! let rnn = RnnConfig { batch: 2, input: 4, hidden: 8, classes: 4 };
//! let cfg = Config { budget: 64 << 10, heuristic: Heuristic::dtr_eq(), ..Config::default() };
//! let s = Session::new(Box::new(InterpExecutor::rnn(rnn)?), cfg);
//!
//! // Constants: weights and the data batch (pinned, never evicted).
//! let wx = s.constant(HostTensor::zeros(&[4, 32])); // [input, 4*hidden]
//! let wh = s.constant(HostTensor::zeros(&[8, 32]));
//! let b = s.constant(HostTensor::zeros(&[1, 32]));
//! let w_out = s.constant(HostTensor::zeros(&[8, 4]));
//! let x = s.constant(HostTensor::zeros(&[2, 4]));
//! let h0 = s.constant(HostTensor::zeros(&[2, 8]));
//! let c0 = s.constant(HostTensor::zeros(&[2, 8]));
//! let tgt = s.constant(HostTensor::zeros(&[2]));
//!
//! // Forward, loss, backward, update — every call interposed by DTR.
//! let hc = s.call("lstm_cell_fwd", &[&x, &h0, &c0, &wx, &wh, &b])?;
//! let loss = s.call("rnn_loss_fwd", &[&hc[0], &w_out, &tgt])?;
//! let grads = s.call("rnn_loss_bwd", &[&hc[0], &w_out, &tgt])?;
//! let updated = s.call("sgd_wout", &[&w_out, &grads[1]])?;
//!
//! println!("loss = {}", s.scalar(&loss[0])?); // remats transparently if evicted
//! let _new_weights = s.get(&updated[0])?;     // read back for the next step
//! s.check_invariants()?;
//! # Ok(())
//! # }
//! ```
//!
//! Dropping a `Tensor` releases its reference — when the last handle goes,
//! the deallocation policy runs (eager eviction frees the buffer
//! immediately). Cloning a handle is the log format's COPY. There is no
//! way to forget a release or issue one twice.
//!
//! For accounting-only studies (no executor, explicit sizes) use
//! [`Session::accounting`] with [`Session::call_sized`]; its DTR decisions
//! are bit-identical to a real executor issuing the same op stream.

mod backend;
mod session;
mod store;
mod tensor;

pub use backend::{ExecBackend, SharedExecutor};
pub use session::{OpContract, Session};
pub use store::{content_hash, PinnedWeight, WeightStore};
pub use tensor::Tensor;
