//! The Appendix-C simulator: operation-log format + replayer over the DTR
//! runtime with pure cost accounting.

pub mod log;
pub mod replay;

pub use log::{Instr, Log, OutDecl};
pub use replay::{baseline, simulate, Baseline, Replayer, SimOutcome};
