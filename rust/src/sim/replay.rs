//! Log replayer: drives a `dtr::Runtime` from an operation log, modeling the
//! paper's simulator (Appendix C): identifier↔tensor environment, in-place
//! mutation via the copy-on-write rewrite, aliasing, multi-output ops,
//! refcount bookkeeping for COPY/COPYFROM/RELEASE, and the output condition
//! (pin all live tensors at the end).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::log::{Instr, Log, OutDecl};
use crate::dtr::{Config, NullBackend, OutSpec, Runtime, Stats, TensorId};

/// Structural facts about a log, independent of any budget: the baseline
/// curve components of Fig. 2.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    /// Peak live memory of the unbudgeted execution (with framework-style
    /// frees on release) — the "1.0 ratio" reference.
    pub peak_memory: u64,
    /// Total compute cost of one batch (no rematerialization).
    pub total_compute: u64,
    /// Bytes held by constants (weights + inputs): Fig. 2's black region.
    pub constant_bytes: u64,
    /// Largest single-operator live set (inputs + outputs): Fig. 2's gray
    /// region — below this no budget can execute the op at all.
    pub max_op_bytes: u64,
    /// Live bytes at the end of the unbudgeted run (weights + weight grads +
    /// loss): together with `max_op_bytes` this lower-bounds any feasible
    /// budget (the output condition requires it all resident at once).
    pub final_memory: u64,
    /// Number of operator calls in the log.
    pub calls: usize,
}

impl Baseline {
    /// A conservative lower bound on feasible budgets.
    pub fn floor(&self) -> u64 {
        self.final_memory + self.max_op_bytes
    }

    /// Budget at `ratio` of the headroom above the feasibility floor:
    /// `floor + ratio * (peak - floor)` — used by tests; figure harnesses
    /// sweep raw ratios of peak like the paper and report OOMs.
    pub fn budget_at(&self, ratio: f64) -> u64 {
        let floor = self.floor().min(self.peak_memory);
        floor + ((self.peak_memory - floor) as f64 * ratio) as u64
    }
}

/// Result of simulating a log under a budget.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub stats: Stats,
    /// `None` on success; `Some(reason)` when the run OOMed/failed.
    pub failed: Option<String>,
}

impl SimOutcome {
    pub fn ok(&self) -> bool {
        self.failed.is_none()
    }
}

/// Replays a log through a fresh runtime under `cfg`.
pub struct Replayer {
    rt: Runtime<NullBackend>,
    env: HashMap<String, TensorId>,
    /// Storage sizes by identifier (for the mutation rewrite).
    mutate_counter: u64,
}

impl Replayer {
    pub fn new(cfg: Config) -> Self {
        Replayer { rt: Runtime::new(cfg, NullBackend::new()), env: HashMap::new(), mutate_counter: 0 }
    }

    pub fn runtime(&self) -> &Runtime<NullBackend> {
        &self.rt
    }

    fn lookup(&self, name: &str) -> Result<TensorId> {
        self.env.get(name).copied().with_context(|| format!("unbound identifier '{name}'"))
    }

    /// Execute one instruction.
    pub fn step(&mut self, ins: &Instr) -> Result<()> {
        match ins {
            Instr::Constant { t, size } => {
                let tid = self.rt.constant(*size);
                self.env.insert(t.clone(), tid);
            }
            Instr::Call { op, cost, inputs, outputs } => {
                let in_ids: Vec<TensorId> =
                    inputs.iter().map(|i| self.lookup(i)).collect::<Result<_>>()?;
                let specs: Vec<OutSpec> = outputs
                    .iter()
                    .map(|o| self.out_spec(o, inputs))
                    .collect::<Result<_>>()?;
                let outs = self.rt.call(op, *cost, &in_ids, &specs)?;
                for (decl, tid) in outputs.iter().zip(outs) {
                    if let Some(old) = self.env.insert(decl.name.clone(), tid) {
                        // Rebinding an identifier drops the old reference.
                        let _ = old;
                        bail!("duplicate tensor identifier '{}' in log", decl.name);
                    }
                }
            }
            Instr::Mutate { op, cost, inputs, mutated } => {
                // Copy-on-write rewrite (Appendix C.6): treat `op` as a pure
                // operator from `inputs` to fresh outputs sized like each
                // mutated input's storage; rebind and release the originals.
                let in_ids: Vec<TensorId> =
                    inputs.iter().map(|i| self.lookup(i)).collect::<Result<_>>()?;
                let specs: Vec<OutSpec> = mutated
                    .iter()
                    .map(|m| {
                        let tid = self.lookup(m)?;
                        let sid = self.rt.graph.storage_of(tid);
                        Ok(OutSpec::sized(self.rt.graph.storage(sid).size))
                    })
                    .collect::<Result<_>>()?;
                self.mutate_counter += 1;
                let name = format!("{op}#mut{}", self.mutate_counter);
                let outs = self.rt.call(&name, *cost, &in_ids, &specs)?;
                for (m, new_tid) in mutated.iter().zip(outs) {
                    let old = self.lookup(m)?;
                    self.rt.release(old);
                    self.env.insert(m.clone(), new_tid);
                }
            }
            Instr::Copy { dst, src } => {
                let tid = self.lookup(src)?;
                self.rt.retain(tid);
                self.env.insert(dst.clone(), tid);
            }
            Instr::CopyFrom { dst, src } => {
                let s = self.lookup(src)?;
                let d = self.lookup(dst)?;
                self.rt.retain(s);
                self.rt.release(d);
                self.env.insert(dst.clone(), s);
            }
            Instr::Release { t } => {
                let tid = self.lookup(t)?;
                self.rt.release(tid);
                self.env.remove(t);
            }
        }
        Ok(())
    }

    fn out_spec(&self, o: &OutDecl, inputs: &[String]) -> Result<OutSpec> {
        match &o.alias_of {
            None => Ok(OutSpec::sized(o.size)),
            Some(target) => {
                let idx = inputs
                    .iter()
                    .position(|i| i == target)
                    .with_context(|| format!("alias target '{target}' is not an input"))?;
                Ok(OutSpec::alias(idx))
            }
        }
    }

    /// Output condition: everything still referenced must end resident.
    pub fn finish(&mut self) -> Result<Stats> {
        self.rt.pin_live_outputs()?;
        self.rt.check_invariants()?;
        Ok(self.rt.stats.clone())
    }
}

/// Simulate `log` under `cfg`; never panics on OOM — reports failure instead.
pub fn simulate(log: &Log, cfg: Config) -> SimOutcome {
    let mut rp = Replayer::new(cfg);
    for (i, ins) in log.instrs.iter().enumerate() {
        if let Err(e) = rp.step(ins) {
            let mut stats = rp.rt.stats.clone();
            stats.eviction_searches = stats.eviction_searches.max(1);
            return SimOutcome { stats, failed: Some(format!("instr {i}: {e:#}")) };
        }
    }
    match rp.finish() {
        Ok(stats) => SimOutcome { stats, failed: None },
        Err(e) => SimOutcome { stats: rp.rt.stats.clone(), failed: Some(format!("finish: {e:#}")) },
    }
}

/// Compute the budget-independent baseline facts for a log.
pub fn baseline(log: &Log) -> Baseline {
    // Unbudgeted replay with framework-style frees gives peak memory and
    // total compute.
    let outcome = simulate(log, Config::default());
    debug_assert!(outcome.ok(), "unbudgeted replay cannot fail: {:?}", outcome.failed);

    // Structural scan for the constant footprint and max single-op live set.
    let mut constant_bytes = 0u64;
    let mut max_op_bytes = 0u64;
    let mut calls = 0usize;
    let mut sizes: HashMap<&str, u64> = HashMap::new();
    for ins in &log.instrs {
        match ins {
            Instr::Constant { t, size } => {
                constant_bytes += size;
                sizes.insert(t, *size);
            }
            Instr::Call { inputs, outputs, .. } => {
                calls += 1;
                let mut live: u64 = outputs.iter().map(|o| o.size).sum();
                for i in inputs {
                    live += sizes.get(i.as_str()).copied().unwrap_or(0);
                }
                for o in &outputs[..] {
                    sizes.insert(&o.name, o.size);
                }
                max_op_bytes = max_op_bytes.max(live);
            }
            Instr::Mutate { inputs, mutated, .. } => {
                calls += 1;
                let mut live: u64 = 0;
                for i in inputs {
                    live += sizes.get(i.as_str()).copied().unwrap_or(0);
                }
                for m in mutated {
                    live += sizes.get(m.as_str()).copied().unwrap_or(0);
                }
                max_op_bytes = max_op_bytes.max(live);
            }
            Instr::Copy { dst, src } | Instr::CopyFrom { dst, src } => {
                if let Some(&s) = sizes.get(src.as_str()) {
                    sizes.insert(dst, s);
                }
            }
            Instr::Release { .. } => {}
        }
    }

    Baseline {
        peak_memory: outcome.stats.peak_memory,
        total_compute: outcome.stats.total_compute(),
        constant_bytes,
        max_op_bytes,
        final_memory: outcome.stats.memory,
        calls,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::Heuristic;

    /// A small training-shaped log: weights (small), a forward activation
    /// chain (large, batch-shaped), loss, and a backward pass producing both
    /// an activation-gradient chain (released as consumed) and weight
    /// gradients (held live, per the output condition).
    fn training_log(n: usize, act: u64) -> Log {
        let w = act / 8;
        let mut log = Log::new("toy");
        log.constant("x", act);
        for i in 0..n {
            log.constant(&format!("w{i}"), w);
        }
        let mut prev = "x".to_string();
        for i in 0..n {
            let out = format!("a{i}");
            log.call1(&format!("fwd{i}"), 10, &[&prev, &format!("w{i}")], &out, act);
            prev = out;
        }
        log.call1("loss", 5, &[&prev], "L", 8);
        let mut grad = "L".to_string();
        for i in (0..n).rev() {
            let da = format!("da{i}");
            let gw = format!("gw{i}");
            let prev_act = if i == 0 { "x".to_string() } else { format!("a{}", i - 1) };
            log.call(
                &format!("bwd{i}"),
                12,
                &[&grad, &prev_act, &format!("w{i}")],
                vec![OutDecl::sized(&da, act), OutDecl::sized(&gw, w)],
            );
            if grad != "L" {
                log.release(&grad);
            }
            log.release(&format!("a{i}"));
            grad = da;
        }
        log.release(&grad);
        log
    }

    #[test]
    fn unbudgeted_replay_matches_structure() {
        let log = training_log(8, 256);
        let b = baseline(&log);
        assert_eq!(b.constant_bytes, 256 + 8 * 32);
        assert_eq!(b.calls, 17);
        assert_eq!(b.total_compute, 8 * 10 + 5 + 8 * 12);
        assert!(b.peak_memory > b.constant_bytes);
        assert!(b.max_op_bytes >= 3 * 256);
    }

    #[test]
    fn budgeted_replay_succeeds_with_remat() {
        let log = training_log(16, 256);
        let b = baseline(&log);
        let cfg = Config {
            budget: b.peak_memory * 7 / 10,
            heuristic: Heuristic::dtr_eq(),
            ..Config::default()
        };
        let out = simulate(&log, cfg);
        assert!(out.ok(), "{:?}", out.failed);
        assert!(out.stats.peak_memory <= b.peak_memory * 7 / 10);
        assert!(out.stats.total_compute() >= b.total_compute);
    }

    #[test]
    fn impossible_budget_reports_failure() {
        let log = training_log(8, 256);
        let cfg = Config { budget: 100, ..Config::default() };
        let out = simulate(&log, cfg);
        assert!(!out.ok());
    }

    #[test]
    fn indexed_replay_matches_scan() {
        use crate::dtr::PolicyKind;
        let log = training_log(16, 256);
        let b = baseline(&log);
        let budget = b.constant_bytes + (b.peak_memory - b.constant_bytes) * 2 / 5;
        for h in Heuristic::fig2_set() {
            let mk = |kind: PolicyKind| {
                simulate(
                    &log,
                    Config {
                        budget,
                        heuristic: h,
                        index: kind,
                        trace_victims: true,
                        ..Config::default()
                    },
                )
            };
            let scan = mk(PolicyKind::Scan);
            let indexed = mk(PolicyKind::Auto);
            assert!(scan.ok(), "{}: {:?}", h.name(), scan.failed);
            assert!(indexed.ok(), "{}: {:?}", h.name(), indexed.failed);
            assert_eq!(scan.stats.victims, indexed.stats.victims, "{} victims", h.name());
            assert!(scan.stats.same_decisions(&indexed.stats), "{} stats", h.name());
        }
    }

    #[test]
    fn all_fig2_heuristics_replay() {
        let log = training_log(12, 256);
        let b = baseline(&log);
        // Constants are pinned, so the feasible floor is constant_bytes plus
        // a working set; budget 40% of the non-constant headroom.
        let budget = b.constant_bytes + (b.peak_memory - b.constant_bytes) * 2 / 5;
        for h in Heuristic::fig2_set() {
            let cfg = Config { budget, heuristic: h, ..Config::default() };
            let out = simulate(&log, cfg);
            assert!(out.ok(), "{} failed: {:?}", h.name(), out.failed);
            assert!(out.stats.remat_count > 0, "{} did not rematerialize", h.name());
        }
    }

    #[test]
    fn mutation_rewrite_preserves_replayability() {
        let mut log = Log::new("mut");
        log.constant("x", 32);
        log.call1("f", 10, &["x"], "y", 32);
        log.mutate("relu_", 2, &["y"], &["y"]);
        log.call1("g", 10, &["y"], "z", 32);
        let out = simulate(&log, Config::default());
        assert!(out.ok(), "{:?}", out.failed);
        // Budgeted too: the mutated value must be rematerializable. The
        // mutation rewrite transiently holds x + y + y' = 96 bytes.
        let out2 = simulate(&log, Config { budget: 96, ..Config::default() });
        assert!(out2.ok(), "{:?}", out2.failed);
    }

    #[test]
    fn copy_and_copyfrom_refcounts() {
        let mut log = Log::new("copies");
        log.constant("x", 16);
        log.call1("f", 5, &["x"], "y", 16);
        log.call1("f2", 5, &["x"], "w", 16);
        log.copy("y2", "y"); // refs(y)++
        log.release("y"); // still held via y2
        log.copy_from("w", "y2"); // w now aliases y's tensor; old w released
        let out = simulate(&log, Config::default());
        assert!(out.ok(), "{:?}", out.failed);
    }

    #[test]
    fn duplicate_identifier_rejected() {
        let mut log = Log::new("dup");
        log.constant("x", 16);
        log.call1("f", 5, &["x"], "y", 16);
        log.call1("g", 5, &["x"], "y", 16);
        let out = simulate(&log, Config::default());
        assert!(!out.ok());
    }

    #[test]
    fn alias_outputs_replay() {
        let mut log = Log::new("alias");
        log.constant("x", 16);
        log.call1("f", 5, &["x"], "y", 64);
        log.call(
            "chunk",
            1,
            &["y"],
            vec![OutDecl::alias("v0", "y"), OutDecl::alias("v1", "y")],
        );
        log.call1("g", 5, &["v0"], "z", 16);
        log.release("v0");
        log.release("y"); // storage still held via v1
        log.call1("h", 5, &["x"], "big", 64); // forces y's eviction at 112
        log.call1("k", 5, &["v1"], "z2", 16); // must remat y's storage + view
        log.release("v1");
        log.release("big");
        let b = baseline(&log);
        let out = simulate(&log, Config { budget: b.peak_memory, ..Config::default() });
        assert!(out.ok(), "{:?}", out.failed);
        assert_eq!(out.stats.remat_count, 0);
        // Tight budget forces evicting y's storage and re-deriving views.
        let out2 = simulate(&log, Config { budget: 112, ..Config::default() });
        assert!(out2.ok(), "{:?}", out2.failed);
        assert!(out2.stats.remat_count >= 1, "expected alias remat");
    }
}
