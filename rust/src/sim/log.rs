//! The operation-log format (Appendix C.6).
//!
//! The paper instruments PyTorch to emit JSON records of every tensor event;
//! our workload generators emit the same instruction stream (and the real
//! PJRT engine can emit measured logs in this format too). Instructions:
//!
//! * `CONSTANT(t, size)` — non-rematerializable input/weight;
//! * `CALL(op, cost, inputs, outputs)` — pure operator call; each output
//!   declares its size and optional alias target (folding the paper's
//!   separate `MEMORY`/`ALIAS` records into the output declaration);
//! * `MUTATE(op, cost, inputs, mutated)` — in-place op, replayed through the
//!   copy-on-write rewrite;
//! * `COPY(dst, src)` — new identifier for the same view (refcount++);
//! * `COPYFROM(dst, src)` — Python rebinding of an existing identifier;
//! * `RELEASE(t)` — destructor (refcount--).

use crate::util::json::{parse_lines, Json};
use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct OutDecl {
    pub name: String,
    pub size: u64,
    /// Aliases the storage of this *input* identifier if set.
    pub alias_of: Option<String>,
}

impl OutDecl {
    pub fn sized(name: &str, size: u64) -> Self {
        OutDecl { name: name.to_string(), size, alias_of: None }
    }
    pub fn alias(name: &str, of: &str) -> Self {
        OutDecl { name: name.to_string(), size: 0, alias_of: Some(of.to_string()) }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    Constant { t: String, size: u64 },
    Call { op: String, cost: u64, inputs: Vec<String>, outputs: Vec<OutDecl> },
    Mutate { op: String, cost: u64, inputs: Vec<String>, mutated: Vec<String> },
    Copy { dst: String, src: String },
    CopyFrom { dst: String, src: String },
    Release { t: String },
}

/// A complete single-batch operation log (forward + loss + backward, in the
/// paper's experiments), plus a model name for reporting.
#[derive(Debug, Clone, Default)]
pub struct Log {
    pub name: String,
    pub instrs: Vec<Instr>,
}

impl Log {
    pub fn new(name: &str) -> Self {
        Log { name: name.to_string(), instrs: Vec::new() }
    }

    // ---- builder helpers used by the workload generators ----

    pub fn constant(&mut self, t: &str, size: u64) {
        self.instrs.push(Instr::Constant { t: t.to_string(), size });
    }

    /// Single-output pure call.
    pub fn call1(&mut self, op: &str, cost: u64, inputs: &[&str], out: &str, size: u64) {
        self.instrs.push(Instr::Call {
            op: op.to_string(),
            cost,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: vec![OutDecl::sized(out, size)],
        });
    }

    pub fn call(&mut self, op: &str, cost: u64, inputs: &[&str], outputs: Vec<OutDecl>) {
        self.instrs.push(Instr::Call {
            op: op.to_string(),
            cost,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs,
        });
    }

    pub fn mutate(&mut self, op: &str, cost: u64, inputs: &[&str], mutated: &[&str]) {
        self.instrs.push(Instr::Mutate {
            op: op.to_string(),
            cost,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            mutated: mutated.iter().map(|s| s.to_string()).collect(),
        });
    }

    pub fn release(&mut self, t: &str) {
        self.instrs.push(Instr::Release { t: t.to_string() });
    }

    pub fn copy(&mut self, dst: &str, src: &str) {
        self.instrs.push(Instr::Copy { dst: dst.to_string(), src: src.to_string() });
    }

    pub fn copy_from(&mut self, dst: &str, src: &str) {
        self.instrs.push(Instr::CopyFrom { dst: dst.to_string(), src: src.to_string() });
    }

    // ---- JSON (de)serialization: one record per line ----

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &Json::from_pairs(vec![("kind", "header".into()), ("name", self.name.as_str().into())])
                .to_string(),
        );
        out.push('\n');
        for ins in &self.instrs {
            out.push_str(&instr_to_json(ins).to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Log> {
        let values = parse_lines(text).context("parsing log jsonl")?;
        let mut log = Log::default();
        for v in values {
            let kind = v.req("kind")?.as_str().unwrap_or_default().to_string();
            if kind == "header" {
                log.name = v.req("name")?.as_str().unwrap_or_default().to_string();
                continue;
            }
            log.instrs.push(instr_from_json(&kind, &v)?);
        }
        Ok(log)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_jsonl())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Log> {
        Log::from_jsonl(&std::fs::read_to_string(path)?)
    }
}

fn strs(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

fn instr_to_json(ins: &Instr) -> Json {
    match ins {
        Instr::Constant { t, size } => Json::from_pairs(vec![
            ("kind", "constant".into()),
            ("t", t.as_str().into()),
            ("size", (*size).into()),
        ]),
        Instr::Call { op, cost, inputs, outputs } => {
            let outs = Json::Arr(
                outputs
                    .iter()
                    .map(|o| {
                        let mut j = Json::from_pairs(vec![
                            ("t", o.name.as_str().into()),
                            ("size", o.size.into()),
                        ]);
                        if let Some(a) = &o.alias_of {
                            j.set("alias", a.as_str().into());
                        }
                        j
                    })
                    .collect(),
            );
            Json::from_pairs(vec![
                ("kind", "call".into()),
                ("op", op.as_str().into()),
                ("cost", (*cost).into()),
                ("inputs", strs(inputs)),
                ("outputs", outs),
            ])
        }
        Instr::Mutate { op, cost, inputs, mutated } => Json::from_pairs(vec![
            ("kind", "mutate".into()),
            ("op", op.as_str().into()),
            ("cost", (*cost).into()),
            ("inputs", strs(inputs)),
            ("mutated", strs(mutated)),
        ]),
        Instr::Copy { dst, src } => Json::from_pairs(vec![
            ("kind", "copy".into()),
            ("dst", dst.as_str().into()),
            ("src", src.as_str().into()),
        ]),
        Instr::CopyFrom { dst, src } => Json::from_pairs(vec![
            ("kind", "copyfrom".into()),
            ("dst", dst.as_str().into()),
            ("src", src.as_str().into()),
        ]),
        Instr::Release { t } => Json::from_pairs(vec![
            ("kind", "release".into()),
            ("t", t.as_str().into()),
        ]),
    }
}

fn req_str(v: &Json, k: &str) -> Result<String> {
    Ok(v.req(k)?.as_str().context("expected string")?.to_string())
}

fn req_strs(v: &Json, k: &str) -> Result<Vec<String>> {
    v.req(k)?
        .as_arr()
        .context("expected array")?
        .iter()
        .map(|x| Ok(x.as_str().context("expected string")?.to_string()))
        .collect()
}

fn instr_from_json(kind: &str, v: &Json) -> Result<Instr> {
    Ok(match kind {
        "constant" => Instr::Constant {
            t: req_str(v, "t")?,
            size: v.req("size")?.as_u64().context("size")?,
        },
        "call" => {
            let outputs = v
                .req("outputs")?
                .as_arr()
                .context("outputs array")?
                .iter()
                .map(|o| {
                    Ok(OutDecl {
                        name: req_str(o, "t")?,
                        size: o.req("size")?.as_u64().context("size")?,
                        alias_of: o.get("alias").and_then(|a| a.as_str()).map(|s| s.to_string()),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Instr::Call {
                op: req_str(v, "op")?,
                cost: v.req("cost")?.as_u64().context("cost")?,
                inputs: req_strs(v, "inputs")?,
                outputs,
            }
        }
        "mutate" => Instr::Mutate {
            op: req_str(v, "op")?,
            cost: v.req("cost")?.as_u64().context("cost")?,
            inputs: req_strs(v, "inputs")?,
            mutated: req_strs(v, "mutated")?,
        },
        "copy" => Instr::Copy { dst: req_str(v, "dst")?, src: req_str(v, "src")? },
        "copyfrom" => Instr::CopyFrom { dst: req_str(v, "dst")?, src: req_str(v, "src")? },
        "release" => Instr::Release { t: req_str(v, "t")? },
        other => bail!("unknown log instruction kind: {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> Log {
        let mut log = Log::new("sample");
        log.constant("w", 64);
        log.constant("x", 32);
        log.call1("mul", 100, &["x", "w"], "y", 32);
        log.call(
            "split",
            10,
            &["y"],
            vec![OutDecl::sized("a", 16), OutDecl::sized("b", 16), OutDecl::alias("v", "y")],
        );
        log.mutate("add_", 5, &["a", "b"], &["a"]);
        log.copy("a2", "a");
        log.copy_from("b", "a");
        log.release("y");
        log
    }

    #[test]
    fn jsonl_roundtrip() {
        let log = sample_log();
        let text = log.to_jsonl();
        let back = Log::from_jsonl(&text).unwrap();
        assert_eq!(back.name, "sample");
        assert_eq!(back.instrs, log.instrs);
    }

    #[test]
    fn save_load_roundtrip() {
        let log = sample_log();
        let path = std::env::temp_dir().join("dtr_log_test").join("l.jsonl");
        log.save(&path).unwrap();
        let back = Log::load(&path).unwrap();
        assert_eq!(back.instrs, log.instrs);
    }

    #[test]
    fn rejects_unknown_kind() {
        assert!(Log::from_jsonl("{\"kind\":\"bogus\"}").is_err());
    }

    #[test]
    fn alias_declared_in_outputs() {
        let log = sample_log();
        let text = log.to_jsonl();
        assert!(text.contains("\"alias\":\"y\""));
    }
}
