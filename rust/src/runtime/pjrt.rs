//! PJRT runtime: loads HLO-text artifacts, compiles them once on the CPU
//! client, and executes them with host literals. This is the only module
//! that touches the `xla` crate (compiled only under the `pjrt` cargo
//! feature); everything above it speaks through the [`Executor`] trait via
//! [`PjrtExecutor`].

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::executor::{Executor, HostTensor};
use super::manifest::{DType, Manifest, TensorSig};
use crate::util::rng::Rng;

pub struct PjrtRuntime {
    pub client: PjRtClient,
    executables: HashMap<String, PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl PjrtRuntime {
    /// Load + compile every artifact in the manifest. Compilation happens
    /// once at startup; the training path only calls `execute`.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for (name, op) in &manifest.ops {
            let proto = xla::HloModuleProto::from_text_file(
                op.file.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime { client, executables, manifest })
    }

    /// Execute op `name` on host literals; outputs are un-tupled
    /// (aot.py lowers with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("no executable for op '{name}'"))?;
        let result = exe.execute::<Literal>(
            &inputs.iter().map(|l| (*l).clone()).collect::<Vec<_>>(),
        )?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    pub fn op_names(&self) -> Vec<String> {
        self.executables.keys().cloned().collect()
    }
}

/// [`Executor`] adapter over [`PjrtRuntime`]: converts `HostTensor`s to
/// literals per the manifest dtypes (token tensors travel as i32), executes
/// the compiled artifact, and reads results back to the host. The engine is
/// oblivious to which executor it drives.
pub struct PjrtExecutor {
    rt: PjrtRuntime,
}

impl PjrtExecutor {
    pub fn load(artifacts_dir: &Path) -> Result<PjrtExecutor> {
        Ok(PjrtExecutor { rt: PjrtRuntime::load(artifacts_dir)? })
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    fn execute(&mut self, op: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let sig = self.rt.manifest.op(op)?.clone();
        anyhow::ensure!(
            inputs.len() == sig.inputs.len(),
            "{op}: {} inputs given, {} expected",
            inputs.len(),
            sig.inputs.len()
        );
        let lits: Vec<Literal> = inputs
            .iter()
            .zip(&sig.inputs)
            .map(|(t, s)| match s.dtype {
                DType::F32 => f32_literal(&t.data, &s.shape),
                DType::I32 => {
                    let ints: Vec<i32> = t.data.iter().map(|&v| v as i32).collect();
                    i32_literal(&ints, &s.shape)
                }
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&Literal> = lits.iter().collect();
        let outs = self.rt.execute(op, &refs)?;
        anyhow::ensure!(
            outs.len() == sig.outputs.len(),
            "{op}: {} outputs from PJRT, {} expected",
            outs.len(),
            sig.outputs.len()
        );
        outs.into_iter()
            .zip(&sig.outputs)
            .map(|(l, s)| Ok(HostTensor::new(s.shape.clone(), l.to_vec::<f32>()?)))
            .collect()
    }
}

// ------------------------------------------------------ literal utilities

/// Standard-normal f32 literal — delegates to the canonical host-side
/// generator so PJRT and interpreter runs initialize bit-identically.
pub fn randn_literal(rng: &mut Rng, shape: &[usize], scale: f32) -> Result<Literal> {
    let t = super::executor::randn_host(rng, shape, scale);
    reshape(Literal::vec1(&t.data), shape)
}

pub fn zeros_literal(shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    reshape(Literal::vec1(&vec![0f32; n]), shape)
}

pub fn ones_literal(shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    reshape(Literal::vec1(&vec![1f32; n]), shape)
}

/// LayerNorm parameter init: gamma=1 row, beta=0 row -> [2, d]
/// (delegates to the canonical host-side initializer).
pub fn ln_literal(d: usize) -> Result<Literal> {
    let mut rng = Rng::new(0); // unused by the ln path
    let t = super::executor::init_param("ln", &[2, d], &mut rng);
    reshape(Literal::vec1(&t.data), &[2, d])
}

pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
    reshape(Literal::vec1(data), shape)
}

pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    reshape(Literal::vec1(data), shape)
}

fn reshape(l: Literal, shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(l.reshape(&dims)?)
}

/// Size in bytes a literal of this signature occupies (device accounting).
pub fn sig_bytes(sig: &TensorSig) -> u64 {
    sig.bytes()
}

/// Scalar-ish read: first element of an f32 literal.
pub fn first_f32(l: &Literal) -> Result<f32> {
    Ok(l.to_vec::<f32>()?[0])
}

/// Build an init literal for a parameter group by name convention —
/// literally `executor::init_param` converted to a `Literal`, so PJRT and
/// interpreter training start from identical parameters.
pub fn init_param(name: &str, shape: &[usize], rng: &mut Rng) -> Result<Literal> {
    let t = super::executor::init_param(name, shape, rng);
    reshape(Literal::vec1(&t.data), &t.shape)
}

pub fn dtype_zeros(sig: &TensorSig) -> Result<Literal> {
    match sig.dtype {
        DType::F32 => zeros_literal(&sig.shape),
        DType::I32 => i32_literal(&vec![0; sig.elements()], &sig.shape),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<PjrtRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjrtRuntime::load(&dir).unwrap())
    }

    #[test]
    fn literal_round_trips() {
        let mut rng = Rng::new(1);
        let l = randn_literal(&mut rng, &[4, 8], 1.0).unwrap();
        assert_eq!(l.size_bytes(), 4 * 8 * 4);
        let v = l.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), 32);
        // Standard normal-ish: values within a sane envelope.
        assert!(v.iter().all(|x| x.abs() < 6.0));
    }

    #[test]
    fn ln_literal_layout() {
        let l = ln_literal(4).unwrap();
        let v = l.to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1., 1., 1., 1., 0., 0., 0., 0.]);
    }

    #[test]
    fn loads_and_runs_sgd_artifact() {
        let Some(rt) = runtime() else { return };
        let sig = &rt.manifest.op("sgd_wo").unwrap().inputs[0];
        let p = ones_literal(&sig.shape).unwrap();
        let g = ones_literal(&sig.shape).unwrap();
        let out = rt.execute("sgd_wo", &[&p, &g]).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        // p - lr*g with lr=0.1 -> 0.9
        assert!((v[0] - 0.9).abs() < 1e-6, "{}", v[0]);
    }

    #[test]
    fn embed_fwd_gathers_rows() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest.config;
        let tokens: Vec<i32> = (0..(cfg.batch * cfg.seq) as i32)
            .map(|i| i % cfg.vocab as i32)
            .collect();
        let tok = i32_literal(&tokens, &[cfg.batch, cfg.seq]).unwrap();
        // Embedding row v = constant v.
        let mut emb = Vec::with_capacity(cfg.vocab * cfg.d_model);
        for v in 0..cfg.vocab {
            emb.extend(std::iter::repeat(v as f32).take(cfg.d_model));
        }
        let emb = f32_literal(&emb, &[cfg.vocab, cfg.d_model]).unwrap();
        let out = rt.execute("embed_fwd", &[&tok, &emb]).unwrap();
        let x = out[0].to_vec::<f32>().unwrap();
        assert_eq!(x[0], 0.0);
        assert_eq!(x[cfg.d_model], 1.0); // second token -> row 1
    }

    #[test]
    fn adam_matches_formula() {
        let Some(rt) = runtime() else { return };
        let shape = rt.manifest.op("adam_wo").unwrap().inputs[0].shape.clone();
        let p = zeros_literal(&shape).unwrap();
        let g = ones_literal(&shape).unwrap();
        let m = zeros_literal(&shape).unwrap();
        let v = zeros_literal(&shape).unwrap();
        let t = f32_literal(&[1.0], &[1]).unwrap();
        let out = rt.execute("adam_wo", &[&p, &g, &m, &v, &t]).unwrap();
        assert_eq!(out.len(), 3);
        let pv = out[0].to_vec::<f32>().unwrap();
        // First step with unit grad: p ≈ -lr.
        assert!((pv[0] + 1e-3).abs() < 1e-5, "{}", pv[0]);
    }
}
