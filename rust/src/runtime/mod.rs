//! PJRT artifact runtime (the only consumer of the `xla` crate): manifest
//! parsing + executable loading + literal helpers.

pub mod manifest;
pub mod pjrt;

pub use manifest::{DType, Manifest, ModelConfig, OpSig, TensorSig};
pub use pjrt::PjrtRuntime;
