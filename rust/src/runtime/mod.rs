//! The execution layer: manifest (op/shape contract), the pluggable
//! [`Executor`] seam, the hermetic pure-Rust interpreter (default), and —
//! behind the `pjrt` cargo feature — the PJRT artifact runtime, the only
//! consumer of the `xla` crate.

pub mod executor;
pub mod interp;
pub mod kernels;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use executor::{BackendKind, Executor, HostTensor, NullExecutor};
pub use interp::InterpExecutor;
pub use manifest::{DType, Manifest, ModelConfig, OpSig, RnnConfig, TensorSig};
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtExecutor, PjrtRuntime};
