//! Hermetic pure-Rust reference interpreter for the manifest op set — the
//! default [`Executor`]. Implements the transformer-LM ops the engine
//! drives (embedding, pre-norm block with causal softmax attention and
//! tanh-GELU MLP, cross-entropy loss, Adam/SGD updates) over plain
//! [`HostTensor`]s, with hand-derived backward passes.
//!
//! Semantics mirror `python/compile/model.py` + `kernels/ref.py`
//! (layernorm eps 1e-5, scores masked at -1e30, approximate GELU), so the
//! interpreter doubles as a host oracle for the PJRT path. Every op is a
//! pure function of its inputs: DTR replays are bitwise-identical, which
//! the engine tests rely on (budgeted training must match unbudgeted
//! exactly).

use anyhow::{bail, ensure, Result};

use super::executor::{Executor, HostTensor};
use super::kernels::fused;
use super::kernels::gemm::{matmul, matmul_at, matmul_bt};
use super::manifest::{Manifest, ModelConfig, RnnConfig};

const LN_EPS: f32 = 1e-5;
const SQRT_2_OVER_PI: f32 = 0.797_884_56;
const GELU_C: f32 = 0.044715;
const SGD_LR: f32 = 0.1;
const ADAM_LR: f32 = 1e-3;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

pub struct InterpExecutor {
    manifest: Manifest,
    cfg: ModelConfig,
    /// Intra-op worker threads for the kernel layer. 1 (the default) runs
    /// everything inline; any value is bit-identical (threads partition
    /// disjoint output rows, see `runtime/kernels`).
    threads: usize,
    /// Route `block_fwd`/`block_bwd` through the fused layernorm /
    /// flash-attention kernels (`kernels::fused`). Off by default: the
    /// fused attention's online softmax reassociates its reductions, so
    /// fused results are tolerance-equivalent to the reference, not
    /// bitwise — `false` keeps the pre-fusion bit-exact traces.
    fused: bool,
}

impl InterpExecutor {
    pub fn new(cfg: ModelConfig) -> Result<InterpExecutor> {
        Ok(InterpExecutor { manifest: Manifest::synthesize(cfg)?, cfg, threads: 1, fused: false })
    }

    /// Interpreter over the dynamic-model (LSTM/TreeLSTM) op family. The
    /// rnn kernels derive all dimensions from input shapes, and no
    /// transformer op exists in this manifest, so the stored [`ModelConfig`]
    /// is just the manifest's placeholder.
    pub fn rnn(cfg: RnnConfig) -> Result<InterpExecutor> {
        let manifest = Manifest::synthesize_rnn(cfg)?;
        let mc = manifest.config;
        Ok(InterpExecutor { manifest, cfg: mc, threads: 1, fused: false })
    }

    /// Set the intra-op thread count (0 is treated as 1).
    pub fn with_threads(mut self, threads: usize) -> InterpExecutor {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Opt in to the fused block kernels (see the `fused` field).
    pub fn with_fused(mut self, fused: bool) -> InterpExecutor {
        self.fused = fused;
        self
    }

    pub fn fused(&self) -> bool {
        self.fused
    }

    /// Coalesced-request ops: `n` same-class inference requests stacked
    /// into one kernel invocation (`dtr::frontend` coalescing). These are
    /// shape-dynamic — the stacked batch `n*cfg.batch` is not a manifest
    /// shape — so they derive their dimensions from the inputs and
    /// dispatch *before* the manifest signature check. Every transformer
    /// forward kernel is per-sample (GEMM rows are independent
    /// accumulation chains, attention loops per (batch, head), layernorm
    /// per row), so widening the batch is bitwise-identical to running
    /// the members back-to-back. Returns `Ok(None)` for ordinary
    /// manifest ops.
    fn execute_batched(&self, op: &str, inputs: &[&HostTensor]) -> Result<Option<Vec<HostTensor>>> {
        let cfg = self.cfg;
        match op {
            "batched_embed_fwd" => {
                ensure!(inputs.len() == 2, "batched_embed_fwd: 2 inputs expected, got {}", inputs.len());
                let tok = inputs[0];
                ensure!(
                    tok.shape.len() == 2 && tok.shape[1] == cfg.seq && tok.shape[0] > 0,
                    "batched_embed_fwd: stacked tokens must be [n*batch, seq], got {:?}",
                    tok.shape
                );
                let wide = ModelConfig { batch: tok.shape[0], ..cfg };
                embed_fwd(&wide, tok, inputs[1]).map(Some)
            }
            "batched_block_fwd" => {
                ensure!(inputs.len() == 7, "batched_block_fwd: 7 inputs expected, got {}", inputs.len());
                let x = inputs[0];
                ensure!(
                    x.shape.len() == 3 && x.shape[1] == cfg.seq && x.shape[2] == cfg.d_model,
                    "batched_block_fwd: stacked input must be [n*batch, seq, d_model], got {:?}",
                    x.shape
                );
                let wide = ModelConfig { batch: x.shape[0], ..cfg };
                if self.fused {
                    block_fwd_fused(&wide, inputs, self.threads).map(Some)
                } else {
                    block_fwd(&wide, inputs, self.threads).map(Some)
                }
            }
            "batched_slice_rows" => {
                ensure!(inputs.len() == 2, "batched_slice_rows: 2 inputs expected, got {}", inputs.len());
                let (x, idx) = (inputs[0], inputs[1]);
                ensure!(
                    x.shape.len() == 3,
                    "batched_slice_rows: stacked input must be rank 3, got {:?}",
                    x.shape
                );
                ensure!(
                    idx.data.len() == 2 && idx.data[0] >= 0.0 && idx.data[1] > 0.0,
                    "batched_slice_rows: index must be [start_sample, n_samples]"
                );
                let (start, count) = (idx.data[0] as usize, idx.data[1] as usize);
                ensure!(
                    start + count <= x.shape[0],
                    "batched_slice_rows: samples {start}..{} out of {}",
                    start + count,
                    x.shape[0]
                );
                let row = x.shape[1] * x.shape[2];
                let out = x.data[start * row..(start + count) * row].to_vec();
                Ok(Some(vec![HostTensor::new(vec![count, x.shape[1], x.shape[2]], out)]))
            }
            _ => Ok(None),
        }
    }
}

impl Executor for InterpExecutor {
    fn name(&self) -> &'static str {
        "interp"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(&mut self, op: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if let Some(out) = self.execute_batched(op, inputs)? {
            return Ok(out);
        }
        let sig = self.manifest.op(op)?;
        ensure!(
            inputs.len() == sig.inputs.len(),
            "{op}: {} inputs given, {} expected",
            inputs.len(),
            sig.inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&sig.inputs).enumerate() {
            ensure!(
                t.elements() == s.elements(),
                "{op}: input {i} has {} elements, signature says {}",
                t.elements(),
                s.elements()
            );
        }
        let cfg = self.cfg;
        let t = self.threads;
        match op {
            "embed_fwd" => embed_fwd(&cfg, inputs[0], inputs[1]),
            "embed_bwd" => embed_bwd(&cfg, inputs[0], inputs[1]),
            "block_fwd" => {
                if self.fused {
                    block_fwd_fused(&cfg, inputs, t)
                } else {
                    block_fwd(&cfg, inputs, t)
                }
            }
            "block_bwd" => block_bwd(&cfg, inputs, t, self.fused),
            "loss_fwd" => loss_fwd(&cfg, inputs[0], inputs[1], inputs[2], t),
            "loss_bwd" => loss_bwd(&cfg, inputs[0], inputs[1], inputs[2], t),
            "fused_ln_fwd" => fused_ln_fwd(&cfg, inputs, t),
            "fused_attn_fwd" => fused_attn_fwd(&cfg, inputs, t),
            "lstm_cell_fwd" => lstm_cell_fwd(inputs, t),
            "lstm_cell_bwd" => lstm_cell_bwd(inputs, t),
            "tree_leaf_fwd" => tree_leaf_fwd(inputs, t),
            "tree_leaf_bwd" => tree_leaf_bwd(inputs, t),
            "tree_comb_fwd" => tree_comb_fwd(inputs, t),
            "tree_comb_bwd" => tree_comb_bwd(inputs, t),
            "rnn_loss_fwd" => rnn_loss_fwd(inputs, t),
            "rnn_loss_bwd" => rnn_loss_bwd(inputs, t),
            name if name.starts_with("acc_") => acc_step(inputs),
            name if name.starts_with("adam_") => adam_step(inputs),
            name if name.starts_with("sgd_") => sgd_step(inputs),
            other => bail!("interp: unknown op '{other}'"),
        }
    }
}

// ------------------------------------------------------------ linear algebra
//
// The matmuls come from `super::kernels::gemm` — unrolled rank-1 row
// kernels, optionally row-threaded, but bit-identical to the scalar
// reference in `super::kernels::reference` (the pre-PR loop nests) at any
// thread count, so replay determinism and the engine's
// budgeted-equals-unbudgeted bitwise tests hold unchanged.

// ---------------------------------------------------------------- layernorm

/// Per-row layernorm over the last dim. Returns (y, xhat, rstd) — the
/// backward pass consumes xhat and rstd.
fn ln_fwd(x: &[f32], gamma: &[f32], beta: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &x[r * d..r * d + d];
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for c in 0..d {
            let xh = (row[c] - mu) * rs;
            xhat[r * d + c] = xh;
            y[r * d + c] = xh * gamma[c] + beta[c];
        }
    }
    (y, xhat, rstd)
}

/// Returns (dx, dgamma, dbeta).
fn ln_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    for r in 0..rows {
        let dyr = &dy[r * d..r * d + d];
        let xhr = &xhat[r * d..r * d + d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for c in 0..d {
            let dxh = dyr[c] * gamma[c];
            m1 += dxh;
            m2 += dxh * xhr[c];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for c in 0..d {
            let dxh = dyr[c] * gamma[c];
            dx[r * d + c] = rstd[r] * (dxh - m1 - xhr[c] * m2);
            dgamma[c] += dyr[c] * xhr[c];
            dbeta[c] += dyr[c];
        }
    }
    (dx, dgamma, dbeta)
}

// --------------------------------------------------------------------- gelu

#[inline]
fn gelu(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

#[inline]
fn gelu_grad(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    let t = u.tanh();
    let du = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

// ---------------------------------------------------------------- embedding

fn tok_index(v: f32, vocab: usize, op: &str) -> Result<usize> {
    let idx = v as usize;
    ensure!(
        v >= 0.0 && (idx as f32 - v).abs() < 0.5 && idx < vocab,
        "{op}: token id {v} out of range 0..{vocab}"
    );
    Ok(idx)
}

fn embed_fwd(cfg: &ModelConfig, tok: &HostTensor, emb: &HostTensor) -> Result<Vec<HostTensor>> {
    let (b, s, d, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.vocab);
    let mut x = vec![0.0f32; b * s * d];
    for i in 0..b * s {
        let t = tok_index(tok.data[i], v, "embed_fwd")?;
        x[i * d..i * d + d].copy_from_slice(&emb.data[t * d..t * d + d]);
    }
    Ok(vec![HostTensor::new(vec![b, s, d], x)])
}

fn embed_bwd(cfg: &ModelConfig, tok: &HostTensor, dx: &HostTensor) -> Result<Vec<HostTensor>> {
    let (b, s, d, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.vocab);
    let mut demb = vec![0.0f32; v * d];
    for i in 0..b * s {
        let t = tok_index(tok.data[i], v, "embed_bwd")?;
        for c in 0..d {
            demb[t * d + c] += dx.data[i * d + c];
        }
    }
    Ok(vec![HostTensor::new(vec![v, d], demb)])
}

// -------------------------------------------------------- transformer block

/// Forward intermediates the backward pass recomputes (the op is
/// self-contained, like the AOT `block_bwd` which re-runs the forward via
/// `jax.vjp` inside one executable).
struct BlockInter {
    h1: Vec<f32>,
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    qkv: Vec<f32>,
    /// Attention probabilities, `[b, h, s, s]` (zero above the diagonal).
    att: Vec<f32>,
    /// Per-head context re-interleaved to `[b*s, d]`.
    ctx: Vec<f32>,
    /// Attention-sublayer residual output `x + ctx @ wo` — the fused LN2
    /// backward recomputes its row stats from this instead of xhat2/rstd2.
    x1: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    h2: Vec<f32>,
    ff1: Vec<f32>,
    g: Vec<f32>,
    y: Vec<f32>,
}

fn block_forward(cfg: &ModelConfig, x: &[f32], params: &[&HostTensor], t: usize) -> BlockInter {
    let (b, s, d, f, nh) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.n_heads);
    let dh = cfg.d_head();
    let bs = b * s;
    let (ln1, wqkv, wo, ln2, w1, w2) = (
        &params[0].data,
        &params[1].data,
        &params[2].data,
        &params[3].data,
        &params[4].data,
        &params[5].data,
    );

    // Attention sublayer (pre-norm).
    let (h1, xhat1, rstd1) = ln_fwd(x, &ln1[..d], &ln1[d..], bs, d);
    let qkv = matmul(&h1, wqkv, bs, d, 3 * d, t); // [bs, 3d]: q | k | v columns
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let mut att = vec![0.0f32; b * nh * s * s];
    let mut ctx = vec![0.0f32; bs * d];
    for bi in 0..b {
        for hi in 0..nh {
            let qc = hi * dh; // column offset of this head's q slice
            let kc = d + hi * dh;
            let vc = 2 * d + hi * dh;
            let abase = (bi * nh + hi) * s * s;
            for i in 0..s {
                let qrow = &qkv[(bi * s + i) * 3 * d + qc..][..dh];
                // Causal scores for j <= i, then stable softmax over them.
                let arow = &mut att[abase + i * s..abase + i * s + s];
                let mut maxv = f32::NEG_INFINITY;
                for j in 0..=i {
                    let krow = &qkv[(bi * s + j) * 3 * d + kc..][..dh];
                    let mut acc = 0.0f32;
                    for c in 0..dh {
                        acc += qrow[c] * krow[c];
                    }
                    let sc = acc * inv_sqrt;
                    arow[j] = sc;
                    if sc > maxv {
                        maxv = sc;
                    }
                }
                let mut denom = 0.0f32;
                for j in 0..=i {
                    let e = (arow[j] - maxv).exp();
                    arow[j] = e;
                    denom += e;
                }
                for j in 0..=i {
                    arow[j] /= denom;
                }
                // ctx_i = sum_j a_ij * v_j, written into this head's cols.
                let crow = &mut ctx[(bi * s + i) * d + hi * dh..][..dh];
                for j in 0..=i {
                    let a = arow[j];
                    let vrow = &qkv[(bi * s + j) * 3 * d + vc..][..dh];
                    for c in 0..dh {
                        crow[c] += a * vrow[c];
                    }
                }
            }
        }
    }
    let proj = matmul(&ctx, wo, bs, d, d, t);
    let mut x1 = vec![0.0f32; bs * d];
    for i in 0..bs * d {
        x1[i] = x[i] + proj[i];
    }

    // MLP sublayer (pre-norm, tanh-GELU).
    let (h2, xhat2, rstd2) = ln_fwd(&x1, &ln2[..d], &ln2[d..], bs, d);
    let ff1 = matmul(&h2, w1, bs, d, f, t);
    let g: Vec<f32> = ff1.iter().map(|&v| gelu(v)).collect();
    let ff2 = matmul(&g, w2, bs, f, d, t);
    let mut y = vec![0.0f32; bs * d];
    for i in 0..bs * d {
        y[i] = x1[i] + ff2[i];
    }

    BlockInter { h1, xhat1, rstd1, qkv, att, ctx, x1, xhat2, rstd2, h2, ff1, g, y }
}

fn block_fwd(cfg: &ModelConfig, inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let inter = block_forward(cfg, &inputs[0].data, &inputs[1..7], t);
    Ok(vec![HostTensor::new(vec![cfg.batch, cfg.seq, cfg.d_model], inter.y)])
}

/// `block_fwd` routed through the fused kernels (`InterpExecutor::fused`):
/// both layernorms via [`fused::layernorm`] (bitwise-equal accumulation
/// order to `ln_fwd`) and the attention via [`fused::causal_attention`]
/// (flash-style online softmax — tolerance-equivalent, not bitwise). The
/// interleaved `[bs, 3d]` qkv columns are gathered into contiguous
/// per-head `[b*nh, s, dh]` q/k/v slabs for the fused kernel and the
/// context heads re-interleaved back to `[bs, d]` rows afterwards.
fn block_fwd_fused(cfg: &ModelConfig, inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (b, s, d, f, nh) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.n_heads);
    let dh = cfg.d_head();
    let bs = b * s;
    let bh = b * nh;
    let x = &inputs[0].data;
    let (ln1, wqkv, wo, ln2, w1, w2) = (
        &inputs[1].data,
        &inputs[2].data,
        &inputs[3].data,
        &inputs[4].data,
        &inputs[5].data,
        &inputs[6].data,
    );

    // Attention sublayer (pre-norm).
    let h1 = fused::layernorm(x, &ln1[..d], &ln1[d..], bs, d, LN_EPS, t);
    let qkv = matmul(&h1, wqkv, bs, d, 3 * d, t);
    let mut q = vec![0.0f32; bh * s * dh];
    let mut k = vec![0.0f32; bh * s * dh];
    let mut v = vec![0.0f32; bh * s * dh];
    for bi in 0..b {
        for hi in 0..nh {
            for i in 0..s {
                let src = (bi * s + i) * 3 * d + hi * dh;
                let dst = ((bi * nh + hi) * s + i) * dh;
                q[dst..dst + dh].copy_from_slice(&qkv[src..src + dh]);
                k[dst..dst + dh].copy_from_slice(&qkv[src + d..src + d + dh]);
                v[dst..dst + dh].copy_from_slice(&qkv[src + 2 * d..src + 2 * d + dh]);
            }
        }
    }
    let heads = fused::causal_attention(&q, &k, &v, bh, s, dh, t);
    let mut ctx = vec![0.0f32; bs * d];
    for bi in 0..b {
        for hi in 0..nh {
            for i in 0..s {
                let src = ((bi * nh + hi) * s + i) * dh;
                let dst = (bi * s + i) * d + hi * dh;
                ctx[dst..dst + dh].copy_from_slice(&heads[src..src + dh]);
            }
        }
    }
    let proj = matmul(&ctx, wo, bs, d, d, t);
    let mut x1 = vec![0.0f32; bs * d];
    for i in 0..bs * d {
        x1[i] = x[i] + proj[i];
    }

    // MLP sublayer (pre-norm, tanh-GELU).
    let h2 = fused::layernorm(&x1, &ln2[..d], &ln2[d..], bs, d, LN_EPS, t);
    let ff1 = matmul(&h2, w1, bs, d, f, t);
    let g: Vec<f32> = ff1.iter().map(|&u| gelu(u)).collect();
    let ff2 = matmul(&g, w2, bs, f, d, t);
    let mut y = vec![0.0f32; bs * d];
    for i in 0..bs * d {
        y[i] = x1[i] + ff2[i];
    }
    Ok(vec![HostTensor::new(vec![b, s, d], y)])
}

/// Fused layernorm (`kernels::fused::layernorm`) as a standalone manifest
/// op: inputs `(x, gamma_beta)`, output `y` — no xhat/rstd materialized.
fn fused_ln_fwd(cfg: &ModelConfig, inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let gb = &inputs[1].data;
    let y = fused::layernorm(&inputs[0].data, &gb[..d], &gb[d..], b * s, d, LN_EPS, t);
    Ok(vec![HostTensor::new(vec![b, s, d], y)])
}

/// Fused causal attention (`kernels::fused::causal_attention`) as a
/// standalone manifest op over the `[b, nh, s, dh]` per-head layout.
fn fused_attn_fwd(cfg: &ModelConfig, inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (b, s, nh) = (cfg.batch, cfg.seq, cfg.n_heads);
    let dh = cfg.d_head();
    let (q, k, v) = (&inputs[0].data, &inputs[1].data, &inputs[2].data);
    let y = fused::causal_attention(q, k, v, b * nh, s, dh, t);
    Ok(vec![HostTensor::new(vec![b, nh, s, dh], y)])
}

/// Block backward. With `fused_ln` set, the two layernorm backwards run
/// through [`fused::layernorm_bwd`], which recomputes row stats from the
/// pre-norm activations (`x`, `x1`) instead of consuming the stored
/// `xhat`/`rstd` — same accumulation order, so the gradients stay bitwise
/// equal to the reference path; the fused opt-in only perturbs the
/// *forward* attention values.
fn block_bwd(
    cfg: &ModelConfig,
    inputs: &[&HostTensor],
    t: usize,
    fused_ln: bool,
) -> Result<Vec<HostTensor>> {
    let (b, s, d, f, nh) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.n_heads);
    let dh = cfg.d_head();
    let bs = b * s;
    let x = &inputs[0].data;
    let params = &inputs[1..7];
    let dy = &inputs[7].data;
    let (ln1, wqkv, wo, ln2, w1, w2) = (
        &params[0].data,
        &params[1].data,
        &params[2].data,
        &params[3].data,
        &params[4].data,
        &params[5].data,
    );
    let it = block_forward(cfg, x, params, t);

    // y = x1 + gelu(h2 @ w1) @ w2
    let mut dx1 = dy.to_vec();
    let dg = matmul_bt(dy, w2, bs, d, f, t);
    let dw2 = matmul_at(&it.g, dy, bs, f, d, t);
    let mut dff1 = dg;
    for i in 0..bs * f {
        dff1[i] *= gelu_grad(it.ff1[i]);
    }
    let dh2 = matmul_bt(&dff1, w1, bs, f, d, t);
    let dw1 = matmul_at(&it.h2, &dff1, bs, d, f, t);
    let (dx1_ln, dgamma2, dbeta2) = if fused_ln {
        fused::layernorm_bwd(&it.x1, &ln2[..d], &dh2, bs, d, LN_EPS)
    } else {
        ln_bwd(&dh2, &it.xhat2, &it.rstd2, &ln2[..d], bs, d)
    };
    for i in 0..bs * d {
        dx1[i] += dx1_ln[i];
    }

    // x1 = x + ctx @ wo
    let mut dx = dx1.clone();
    let dctx = matmul_bt(&dx1, wo, bs, d, d, t);
    let dwo = matmul_at(&it.ctx, &dx1, bs, d, d, t);

    // Attention backward, per (batch, head).
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let mut dqkv = vec![0.0f32; bs * 3 * d];
    let mut da = vec![0.0f32; s * s];
    let mut ds = vec![0.0f32; s * s];
    for bi in 0..b {
        for hi in 0..nh {
            let qc = hi * dh;
            let kc = d + hi * dh;
            let vc = 2 * d + hi * dh;
            let abase = (bi * nh + hi) * s * s;
            // dA[i,j] = dctx_i . v_j ; dV[j] += sum_i a_ij dctx_i
            for i in 0..s {
                let dcrow = &dctx[(bi * s + i) * d + hi * dh..][..dh];
                let arow = &it.att[abase + i * s..abase + i * s + s];
                for j in 0..=i {
                    let vrow = &it.qkv[(bi * s + j) * 3 * d + vc..][..dh];
                    let mut acc = 0.0f32;
                    for c in 0..dh {
                        acc += dcrow[c] * vrow[c];
                    }
                    da[i * s + j] = acc;
                    let a = arow[j];
                    let dvrow = &mut dqkv[(bi * s + j) * 3 * d + vc..][..dh];
                    for c in 0..dh {
                        dvrow[c] += a * dcrow[c];
                    }
                }
            }
            // dS = A * (dA - sum_j dA*A) per row (softmax jacobian).
            for i in 0..s {
                let arow = &it.att[abase + i * s..abase + i * s + s];
                let mut dot = 0.0f32;
                for j in 0..=i {
                    dot += da[i * s + j] * arow[j];
                }
                for j in 0..=i {
                    ds[i * s + j] = arow[j] * (da[i * s + j] - dot);
                }
            }
            // dQ_i = sum_{j<=i} dS_ij K_j / sqrt(dh);
            // dK_j = sum_{i>=j} dS_ij Q_i / sqrt(dh).
            for i in 0..s {
                let dqrow_base = (bi * s + i) * 3 * d + qc;
                for j in 0..=i {
                    let g = ds[i * s + j] * inv_sqrt;
                    if g != 0.0 {
                        let krow_base = (bi * s + j) * 3 * d + kc;
                        let qrow_base = (bi * s + i) * 3 * d + qc;
                        let dkrow_base = (bi * s + j) * 3 * d + kc;
                        for c in 0..dh {
                            dqkv[dqrow_base + c] += g * it.qkv[krow_base + c];
                            dqkv[dkrow_base + c] += g * it.qkv[qrow_base + c];
                        }
                    }
                }
            }
        }
    }

    // qkv = h1 @ wqkv
    let dh1 = matmul_bt(&dqkv, wqkv, bs, 3 * d, d, t);
    let dwqkv = matmul_at(&it.h1, &dqkv, bs, d, 3 * d, t);
    let (dx_ln, dgamma1, dbeta1) = if fused_ln {
        fused::layernorm_bwd(x, &ln1[..d], &dh1, bs, d, LN_EPS)
    } else {
        ln_bwd(&dh1, &it.xhat1, &it.rstd1, &ln1[..d], bs, d)
    };
    for i in 0..bs * d {
        dx[i] += dx_ln[i];
    }

    let stack2 = |ga: Vec<f32>, be: Vec<f32>| {
        let mut out = ga;
        out.extend(be);
        HostTensor::new(vec![2, d], out)
    };
    Ok(vec![
        HostTensor::new(vec![b, s, d], dx),
        stack2(dgamma1, dbeta1),
        HostTensor::new(vec![d, 3 * d], dwqkv),
        HostTensor::new(vec![d, d], dwo),
        stack2(dgamma2, dbeta2),
        HostTensor::new(vec![d, f], dw1),
        HostTensor::new(vec![f, d], dw2),
    ])
}

// --------------------------------------------------------------------- loss

fn loss_fwd(
    cfg: &ModelConfig,
    x: &HostTensor,
    w_out: &HostTensor,
    tgt: &HostTensor,
    t: usize,
) -> Result<Vec<HostTensor>> {
    let (d, v) = (cfg.d_model, cfg.vocab);
    let n = cfg.batch * cfg.seq;
    let logits = matmul(&x.data, &w_out.data, n, d, v, t);
    let mut total = 0.0f32;
    for i in 0..n {
        let row = &logits[i * v..i * v + v];
        let mut maxv = f32::NEG_INFINITY;
        for &l in row {
            if l > maxv {
                maxv = l;
            }
        }
        let mut denom = 0.0f32;
        for &l in row {
            denom += (l - maxv).exp();
        }
        let t = tok_index(tgt.data[i], v, "loss_fwd")?;
        total += maxv + denom.ln() - row[t];
    }
    Ok(vec![HostTensor::scalar(total / n as f32)])
}

fn loss_bwd(
    cfg: &ModelConfig,
    x: &HostTensor,
    w_out: &HostTensor,
    tgt: &HostTensor,
    t: usize,
) -> Result<Vec<HostTensor>> {
    let (b, s, d, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.vocab);
    let n = b * s;
    let mut dlogits = matmul(&x.data, &w_out.data, n, d, v, t);
    let inv_n = 1.0 / n as f32;
    for i in 0..n {
        let row = &mut dlogits[i * v..i * v + v];
        let mut maxv = f32::NEG_INFINITY;
        for &l in row.iter() {
            if l > maxv {
                maxv = l;
            }
        }
        let mut denom = 0.0f32;
        for l in row.iter_mut() {
            *l = (*l - maxv).exp();
            denom += *l;
        }
        for l in row.iter_mut() {
            *l /= denom;
        }
        let t = tok_index(tgt.data[i], v, "loss_bwd")?;
        row[t] -= 1.0;
        for l in row.iter_mut() {
            *l *= inv_n;
        }
    }
    let dx = matmul_bt(&dlogits, &w_out.data, n, v, d, t);
    let dw_out = matmul_at(&x.data, &dlogits, n, d, v, t);
    Ok(vec![
        HostTensor::new(vec![b, s, d], dx),
        HostTensor::new(vec![d, v], dw_out),
    ])
}

// -------------------------------------------- dynamic-model cells (rnn ops)
//
// The LSTM/TreeLSTM cell kernels for the dynamic workloads (Sec. 4.1).
// All dimensions are derived from input shapes, so the same kernels serve
// any `RnnConfig`. Backward cells recompute the forward intermediates from
// their own inputs (self-contained, like `block_bwd`), keeping every op a
// pure function of its inputs. Gradient formulas are validated against
// finite differences (see the tests below).

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Pre-activation gates `x @ wx + h @ wh + b`, `[B, 4H]` with column blocks
/// i | f | g | o. Returns `(gates, batch, input_dim, hidden_dim)`.
fn lstm_gates(
    x: &HostTensor,
    h: &HostTensor,
    wx: &HostTensor,
    wh: &HostTensor,
    b: &HostTensor,
    t: usize,
) -> (Vec<f32>, usize, usize, usize) {
    let bsz = x.shape[0];
    let id = x.shape[1];
    let hd = h.shape[1];
    let mut gates = matmul(&x.data, &wx.data, bsz, id, 4 * hd, t);
    let gh = matmul(&h.data, &wh.data, bsz, hd, 4 * hd, t);
    for r in 0..bsz {
        for k in 0..4 * hd {
            gates[r * 4 * hd + k] += gh[r * 4 * hd + k] + b.data[k];
        }
    }
    (gates, bsz, id, hd)
}

/// `(h2, c2)` from `(x, h, c, wx, wh, b)`:
/// `c2 = sigma(f)*c + sigma(i)*tanh(g)`, `h2 = sigma(o)*tanh(c2)`.
fn lstm_cell_fwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let c = inputs[2];
    let (gates, bsz, _id, hd) =
        lstm_gates(inputs[0], inputs[1], inputs[3], inputs[4], inputs[5], t);
    let mut h2 = vec![0.0f32; bsz * hd];
    let mut c2 = vec![0.0f32; bsz * hd];
    for r in 0..bsz {
        for k in 0..hd {
            let gi = sigmoid(gates[r * 4 * hd + k]);
            let gf = sigmoid(gates[r * 4 * hd + hd + k]);
            let gg = gates[r * 4 * hd + 2 * hd + k].tanh();
            let go = sigmoid(gates[r * 4 * hd + 3 * hd + k]);
            let cv = gf * c.data[r * hd + k] + gi * gg;
            c2[r * hd + k] = cv;
            h2[r * hd + k] = go * cv.tanh();
        }
    }
    Ok(vec![HostTensor::new(vec![bsz, hd], h2), HostTensor::new(vec![bsz, hd], c2)])
}

/// `(dx, dh, dc, dwx, dwh, db)` from `(x, h, c, wx, wh, b, dh2, dc2)`.
fn lstm_cell_bwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (x, h, c, wx, wh) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
    let (dh2, dc2_in) = (inputs[6], inputs[7]);
    let (gates, bsz, id, hd) = lstm_gates(x, h, wx, wh, inputs[5], t);
    let mut dgates = vec![0.0f32; bsz * 4 * hd];
    let mut dc = vec![0.0f32; bsz * hd];
    for r in 0..bsz {
        for k in 0..hd {
            let gi = sigmoid(gates[r * 4 * hd + k]);
            let gf = sigmoid(gates[r * 4 * hd + hd + k]);
            let gg = gates[r * 4 * hd + 2 * hd + k].tanh();
            let go = sigmoid(gates[r * 4 * hd + 3 * hd + k]);
            let cv = gf * c.data[r * hd + k] + gi * gg;
            let tc = cv.tanh();
            let dcv = dc2_in.data[r * hd + k] + dh2.data[r * hd + k] * go * (1.0 - tc * tc);
            let d_o = dh2.data[r * hd + k] * tc;
            let d_f = dcv * c.data[r * hd + k];
            let d_i = dcv * gg;
            let d_g = dcv * gi;
            dc[r * hd + k] = dcv * gf;
            dgates[r * 4 * hd + k] = d_i * gi * (1.0 - gi);
            dgates[r * 4 * hd + hd + k] = d_f * gf * (1.0 - gf);
            dgates[r * 4 * hd + 2 * hd + k] = d_g * (1.0 - gg * gg);
            dgates[r * 4 * hd + 3 * hd + k] = d_o * go * (1.0 - go);
        }
    }
    let dx = matmul_bt(&dgates, &wx.data, bsz, 4 * hd, id, t);
    let dh = matmul_bt(&dgates, &wh.data, bsz, 4 * hd, hd, t);
    let dwx = matmul_at(&x.data, &dgates, bsz, id, 4 * hd, t);
    let dwh = matmul_at(&h.data, &dgates, bsz, hd, 4 * hd, t);
    let mut db = vec![0.0f32; 4 * hd];
    for r in 0..bsz {
        for k in 0..4 * hd {
            db[k] += dgates[r * 4 * hd + k];
        }
    }
    Ok(vec![
        HostTensor::new(vec![bsz, id], dx),
        HostTensor::new(vec![bsz, hd], dh),
        HostTensor::new(vec![bsz, hd], dc),
        HostTensor::new(vec![id, 4 * hd], dwx),
        HostTensor::new(vec![hd, 4 * hd], dwh),
        HostTensor::new(vec![1, 4 * hd], db),
    ])
}

/// Leaf cell: `h = tanh(x @ wc)`.
fn tree_leaf_fwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (x, wc) = (inputs[0], inputs[1]);
    let (bsz, id) = (x.shape[0], x.shape[1]);
    let hd = wc.shape[1];
    let mut hh = matmul(&x.data, &wc.data, bsz, id, hd, t);
    for v in hh.iter_mut() {
        *v = v.tanh();
    }
    Ok(vec![HostTensor::new(vec![bsz, hd], hh)])
}

/// `(dx, dwc)` from `(x, wc, dh)`.
fn tree_leaf_bwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (x, wc, dh) = (inputs[0], inputs[1], inputs[2]);
    let (bsz, id) = (x.shape[0], x.shape[1]);
    let hd = wc.shape[1];
    let mut dpre = matmul(&x.data, &wc.data, bsz, id, hd, t);
    for (p, &g) in dpre.iter_mut().zip(&dh.data) {
        let th = p.tanh();
        *p = g * (1.0 - th * th);
    }
    let dx = matmul_bt(&dpre, &wc.data, bsz, hd, id, t);
    let dwc = matmul_at(&x.data, &dpre, bsz, id, hd, t);
    Ok(vec![HostTensor::new(vec![bsz, id], dx), HostTensor::new(vec![id, hd], dwc)])
}

/// Combine cell: `h = tanh(hl @ wl + hr @ wr)`.
fn tree_comb_fwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (hl, hr, wl, wr) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    let (bsz, hd) = (hl.shape[0], hl.shape[1]);
    let mut hh = matmul(&hl.data, &wl.data, bsz, hd, hd, t);
    let right = matmul(&hr.data, &wr.data, bsz, hd, hd, t);
    for (v, r) in hh.iter_mut().zip(right) {
        *v = (*v + r).tanh();
    }
    Ok(vec![HostTensor::new(vec![bsz, hd], hh)])
}

/// `(dhl, dhr, dwl, dwr)` from `(hl, hr, wl, wr, dh)`.
fn tree_comb_bwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (hl, hr, wl, wr, dh) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
    let (bsz, hd) = (hl.shape[0], hl.shape[1]);
    let mut dpre = matmul(&hl.data, &wl.data, bsz, hd, hd, t);
    let right = matmul(&hr.data, &wr.data, bsz, hd, hd, t);
    for ((p, r), &g) in dpre.iter_mut().zip(right).zip(&dh.data) {
        let th = (*p + r).tanh();
        *p = g * (1.0 - th * th);
    }
    let dhl = matmul_bt(&dpre, &wl.data, bsz, hd, hd, t);
    let dhr = matmul_bt(&dpre, &wr.data, bsz, hd, hd, t);
    let dwl = matmul_at(&hl.data, &dpre, bsz, hd, hd, t);
    let dwr = matmul_at(&hr.data, &dpre, bsz, hd, hd, t);
    Ok(vec![
        HostTensor::new(vec![bsz, hd], dhl),
        HostTensor::new(vec![bsz, hd], dhr),
        HostTensor::new(vec![hd, hd], dwl),
        HostTensor::new(vec![hd, hd], dwr),
    ])
}

/// Mean cross-entropy of `h @ w_out` against integer targets.
fn rnn_loss_fwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (h, w, tgt) = (inputs[0], inputs[1], inputs[2]);
    let (n, d) = (h.shape[0], h.shape[1]);
    let c = w.shape[1];
    let logits = matmul(&h.data, &w.data, n, d, c, t);
    let mut total = 0.0f32;
    for r in 0..n {
        let row = &logits[r * c..r * c + c];
        let mut maxv = f32::NEG_INFINITY;
        for &l in row {
            if l > maxv {
                maxv = l;
            }
        }
        let mut denom = 0.0f32;
        for &l in row {
            denom += (l - maxv).exp();
        }
        let t = tok_index(tgt.data[r], c, "rnn_loss_fwd")?;
        total += maxv + denom.ln() - row[t];
    }
    Ok(vec![HostTensor::scalar(total / n as f32)])
}

/// `(dh, dw_out)` of the mean cross-entropy.
fn rnn_loss_bwd(inputs: &[&HostTensor], t: usize) -> Result<Vec<HostTensor>> {
    let (h, w, tgt) = (inputs[0], inputs[1], inputs[2]);
    let (n, d) = (h.shape[0], h.shape[1]);
    let c = w.shape[1];
    let mut dlogits = matmul(&h.data, &w.data, n, d, c, t);
    let inv_n = 1.0 / n as f32;
    for r in 0..n {
        let row = &mut dlogits[r * c..r * c + c];
        let mut maxv = f32::NEG_INFINITY;
        for &l in row.iter() {
            if l > maxv {
                maxv = l;
            }
        }
        let mut denom = 0.0f32;
        for l in row.iter_mut() {
            *l = (*l - maxv).exp();
            denom += *l;
        }
        for l in row.iter_mut() {
            *l /= denom;
        }
        let t = tok_index(tgt.data[r], c, "rnn_loss_bwd")?;
        row[t] -= 1.0;
        for l in row.iter_mut() {
            *l *= inv_n;
        }
    }
    let dh = matmul_bt(&dlogits, &w.data, n, c, d, t);
    let dw = matmul_at(&h.data, &dlogits, n, d, c, t);
    Ok(vec![HostTensor::new(vec![n, d], dh), HostTensor::new(vec![d, c], dw)])
}

/// Elementwise gradient accumulation: `out = a + b`.
fn acc_step(inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let (a, b) = (inputs[0], inputs[1]);
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| x + y).collect();
    Ok(vec![HostTensor::new(a.shape.clone(), data)])
}

// --------------------------------------------------------------- optimizers

fn sgd_step(inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let (p, g) = (inputs[0], inputs[1]);
    let data = p.data.iter().zip(&g.data).map(|(&pv, &gv)| pv - SGD_LR * gv).collect();
    Ok(vec![HostTensor::new(p.shape.clone(), data)])
}

fn adam_step(inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let (p, g, m, v, t) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
    let step = t.data[0];
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    let n = p.elements();
    let mut p2 = vec![0.0f32; n];
    let mut m2 = vec![0.0f32; n];
    let mut v2 = vec![0.0f32; n];
    for i in 0..n {
        let gi = g.data[i];
        m2[i] = ADAM_B1 * m.data[i] + (1.0 - ADAM_B1) * gi;
        v2[i] = ADAM_B2 * v.data[i] + (1.0 - ADAM_B2) * gi * gi;
        let mhat = m2[i] / bc1;
        let vhat = v2[i] / bc2;
        p2[i] = p.data[i] - ADAM_LR * mhat / (vhat.sqrt() + ADAM_EPS);
    }
    Ok(vec![
        HostTensor::new(p.shape.clone(), p2),
        HostTensor::new(p.shape.clone(), m2),
        HostTensor::new(p.shape.clone(), v2),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::executor::{init_param, randn_host};
    use crate::util::rng::Rng;

    fn exec(cfg: ModelConfig) -> InterpExecutor {
        InterpExecutor::new(cfg).unwrap()
    }

    #[test]
    fn embed_fwd_gathers_rows() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let n = cfg.batch * cfg.seq;
        let tok = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            (0..n).map(|i| (i % cfg.vocab) as f32).collect(),
        );
        // Embedding row v = constant v.
        let mut emb = Vec::with_capacity(cfg.vocab * cfg.d_model);
        for v in 0..cfg.vocab {
            emb.extend(std::iter::repeat(v as f32).take(cfg.d_model));
        }
        let emb = HostTensor::new(vec![cfg.vocab, cfg.d_model], emb);
        let out = ex.execute("embed_fwd", &[&tok, &emb]).unwrap();
        assert_eq!(out[0].data[0], 0.0);
        assert_eq!(out[0].data[cfg.d_model], 1.0); // second token -> row 1
    }

    #[test]
    fn embed_bwd_scatter_adds() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let n = cfg.batch * cfg.seq;
        // All tokens are id 3: demb row 3 accumulates the whole gradient.
        let tok = HostTensor::new(vec![cfg.batch, cfg.seq], vec![3.0; n]);
        let dx = HostTensor::new(
            vec![cfg.batch, cfg.seq, cfg.d_model],
            vec![1.0; n * cfg.d_model],
        );
        let out = ex.execute("embed_bwd", &[&tok, &dx]).unwrap();
        assert_eq!(out[0].data[3 * cfg.d_model], n as f32);
        assert_eq!(out[0].data[0], 0.0);
    }

    #[test]
    fn sgd_matches_formula() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let shape = [cfg.d_model, cfg.d_model];
        let p = HostTensor::new(shape.to_vec(), vec![1.0; cfg.d_model * cfg.d_model]);
        let g = HostTensor::new(shape.to_vec(), vec![1.0; cfg.d_model * cfg.d_model]);
        let out = ex.execute("sgd_wo", &[&p, &g]).unwrap();
        assert!((out[0].data[0] - 0.9).abs() < 1e-6, "{}", out[0].data[0]);
    }

    #[test]
    fn adam_first_step_is_minus_lr() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let n = cfg.d_model * cfg.d_model;
        let shape = vec![cfg.d_model, cfg.d_model];
        let p = HostTensor::new(shape.clone(), vec![0.0; n]);
        let g = HostTensor::new(shape.clone(), vec![1.0; n]);
        let m = HostTensor::new(shape.clone(), vec![0.0; n]);
        let v = HostTensor::new(shape, vec![0.0; n]);
        let t = HostTensor::scalar(1.0);
        let out = ex.execute("adam_wo", &[&p, &g, &m, &v, &t]).unwrap();
        assert_eq!(out.len(), 3);
        assert!((out[0].data[0] + 1e-3).abs() < 1e-5, "{}", out[0].data[0]);
    }

    #[test]
    fn zero_activations_give_ln_vocab_loss() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let x = HostTensor::zeros(&[cfg.batch, cfg.seq, cfg.d_model]);
        let w = HostTensor::zeros(&[cfg.d_model, cfg.vocab]);
        let tgt = HostTensor::zeros(&[cfg.batch, cfg.seq]);
        let out = ex.execute("loss_fwd", &[&x, &w, &tgt]).unwrap();
        let lnv = (cfg.vocab as f32).ln();
        assert!((out[0].data[0] - lnv).abs() < 1e-4, "{} vs {}", out[0].data[0], lnv);
    }

    #[test]
    fn block_fwd_finite_on_zero_input() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let mut rng = Rng::new(1);
        let x = HostTensor::zeros(&[cfg.batch, cfg.seq, cfg.d_model]);
        let shapes = cfg.param_shapes();
        let ps: Vec<HostTensor> = ["ln", "wqkv", "wo", "ln", "w1", "w2"]
            .iter()
            .map(|&g| init_param(g, &shapes[g], &mut rng))
            .collect();
        let mut ins = vec![&x];
        ins.extend(ps.iter());
        let out = ex.execute("block_fwd", &ins).unwrap();
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    /// Coalescing correctness at the kernel layer: one stacked
    /// embed+block forward over `n` request batches, sliced back apart,
    /// is bitwise what each request's own forward produces.
    #[test]
    fn batched_forward_bitwise_matches_serial() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let mut rng = Rng::new(7);
        let n = 3;
        let per = cfg.batch * cfg.seq;
        let toks: Vec<HostTensor> = (0..n)
            .map(|_| {
                HostTensor::new(
                    vec![cfg.batch, cfg.seq],
                    (0..per).map(|_| rng.index(cfg.vocab) as f32).collect(),
                )
            })
            .collect();
        let emb = randn_host(&mut rng, &[cfg.vocab, cfg.d_model], 0.1);
        let shapes = cfg.param_shapes();
        let ps: Vec<HostTensor> = ["ln", "wqkv", "wo", "ln", "w1", "w2"]
            .iter()
            .map(|&g| init_param(g, &shapes[g], &mut rng))
            .collect();

        // Serial reference: each request through the manifest ops.
        let serial: Vec<HostTensor> = toks
            .iter()
            .map(|tok| {
                let x = ex.execute("embed_fwd", &[tok, &emb]).unwrap().remove(0);
                let mut ins = vec![&x];
                ins.extend(ps.iter());
                ex.execute("block_fwd", &ins).unwrap().remove(0)
            })
            .collect();

        // Batched: one stacked invocation, sliced back per request.
        let stacked: Vec<f32> = toks.iter().flat_map(|t| t.data.iter().copied()).collect();
        let tok_nb = HostTensor::new(vec![n * cfg.batch, cfg.seq], stacked);
        let x = ex.execute("batched_embed_fwd", &[&tok_nb, &emb]).unwrap().remove(0);
        assert_eq!(x.shape, vec![n * cfg.batch, cfg.seq, cfg.d_model]);
        let mut ins = vec![&x];
        ins.extend(ps.iter());
        let y = ex.execute("batched_block_fwd", &ins).unwrap().remove(0);
        for (i, want) in serial.iter().enumerate() {
            let idx = HostTensor::new(vec![2], vec![(i * cfg.batch) as f32, cfg.batch as f32]);
            let got = ex.execute("batched_slice_rows", &[&y, &idx]).unwrap().remove(0);
            assert_eq!(got.shape, want.shape);
            assert!(
                got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "request {i}: batched forward diverged from serial"
            );
        }
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let tok = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            vec![cfg.vocab as f32; cfg.batch * cfg.seq],
        );
        let emb = HostTensor::zeros(&[cfg.vocab, cfg.d_model]);
        assert!(ex.execute("embed_fwd", &[&tok, &emb]).is_err());
    }

    #[test]
    fn replay_is_bitwise_deterministic() {
        let cfg = ModelConfig::tiny();
        let mut ex = exec(cfg);
        let mut rng = Rng::new(9);
        let x = randn_host(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 0.5);
        let shapes = cfg.param_shapes();
        let ps: Vec<HostTensor> = ["ln", "wqkv", "wo", "ln", "w1", "w2"]
            .iter()
            .map(|&g| init_param(g, &shapes[g], &mut rng))
            .collect();
        let mut ins = vec![&x];
        ins.extend(ps.iter());
        let a = ex.execute("block_fwd", &ins).unwrap();
        let b = ex.execute("block_fwd", &ins).unwrap();
        assert_eq!(a[0].data, b[0].data);
    }

    /// The fused opt-in: `block_fwd` under `with_fused(true)` agrees with
    /// the reference path to online-softmax tolerance (the only
    /// reassociated reduction), `with_fused(false)` is bitwise the
    /// reference, and the fused `block_bwd` is bitwise the reference
    /// backward (its layernorm backward shares the accumulation order).
    #[test]
    fn fused_block_matches_reference_within_tolerance() {
        let cfg = ModelConfig::tiny();
        let mut plain = exec(cfg);
        let mut off = InterpExecutor::new(cfg).unwrap().with_fused(false);
        let mut on = InterpExecutor::new(cfg).unwrap().with_fused(true);
        let mut rng = Rng::new(21);
        let x = randn_host(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 0.5);
        let shapes = cfg.param_shapes();
        let ps: Vec<HostTensor> = ["ln", "wqkv", "wo", "ln", "w1", "w2"]
            .iter()
            .map(|&g| init_param(g, &shapes[g], &mut rng))
            .collect();
        let mut ins = vec![&x];
        ins.extend(ps.iter());

        let a = plain.execute("block_fwd", &ins).unwrap();
        let b = off.execute("block_fwd", &ins).unwrap();
        assert_eq!(a[0].data, b[0].data, "fused=false must stay bit-exact");

        let c = on.execute("block_fwd", &ins).unwrap();
        assert_ne!(a[0].data, c[0].data, "fused attention should reassociate");
        for (i, (&r, &f)) in a[0].data.iter().zip(&c[0].data).enumerate() {
            let tol = 1e-4 * r.abs().max(1.0);
            assert!((r - f).abs() <= tol, "elem {i}: ref {r} vs fused {f}");
        }

        let dy = randn_host(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 1.0);
        let mut bins = ins.clone();
        bins.push(&dy);
        let ga = plain.execute("block_bwd", &bins).unwrap();
        let gb = on.execute("block_bwd", &bins).unwrap();
        for (r, f) in ga.iter().zip(&gb) {
            assert_eq!(r.data, f.data, "fused LN backward must stay bitwise");
        }
    }

    /// The full-model analytic gradient must match the finite-difference
    /// directional derivative: for a random ±1 direction `u` over every
    /// parameter, `(L(θ+εu) - L(θ-εu)) / 2ε ≈ ⟨∇L, u⟩`. The directional
    /// form aggregates the whole gradient, so f32 loss noise (~1e-7)
    /// stays orders of magnitude below the O(1) derivative — per-entry
    /// finite differences would drown tiny entries in noise. Any scale or
    /// sign error in the layernorm/attention/GELU/loss backward shifts the
    /// sum far outside the 2% gate (observed agreement is ~2e-4).
    #[test]
    fn gradients_match_directional_derivative() {
        let cfg = ModelConfig {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq: 6,
            batch: 2,
            n_layers: 1,
        };
        let mut ex = exec(cfg);
        let shapes = cfg.param_shapes();
        let mut rng = Rng::new(7);
        // Larger init than training (0.2 vs 0.02) for a strong signal.
        let mut mk = |g: &str| randn_host(&mut rng, &shapes[g], 0.2);
        let ln = init_param("ln", &shapes["ln"], &mut Rng::new(0));
        // Order: emb, ln1, wqkv, wo, ln2, w1, w2, w_out.
        let ps: Vec<HostTensor> = vec![
            mk("emb"),
            ln.clone(),
            mk("wqkv"),
            mk("wo"),
            ln.clone(),
            mk("w1"),
            mk("w2"),
            mk("w_out"),
        ];
        let n = cfg.batch * cfg.seq;
        let mut trng = Rng::new(3);
        let tok = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            (0..n).map(|_| trng.below(cfg.vocab as u64) as f32).collect(),
        );
        let tgt = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            tok.data.iter().map(|&t| ((t as u64 * 31 + 7) % cfg.vocab as u64) as f32).collect(),
        );

        let loss_of = |ex: &mut InterpExecutor, ps: &[HostTensor]| -> f32 {
            let x = ex.execute("embed_fwd", &[&tok, &ps[0]]).unwrap();
            let mut ins: Vec<&HostTensor> = vec![&x[0]];
            ins.extend(ps[1..7].iter());
            let y = ex.execute("block_fwd", &ins).unwrap();
            ex.execute("loss_fwd", &[&y[0], &ps[7], &tgt]).unwrap()[0].data[0]
        };

        // Analytic gradient of every parameter via the backward ops.
        let x = ex.execute("embed_fwd", &[&tok, &ps[0]]).unwrap();
        let mut ins: Vec<&HostTensor> = vec![&x[0]];
        ins.extend(ps[1..7].iter());
        let y = ex.execute("block_fwd", &ins).unwrap();
        let lb = ex.execute("loss_bwd", &[&y[0], &ps[7], &tgt]).unwrap();
        let mut bins: Vec<&HostTensor> = vec![&x[0]];
        bins.extend(ps[1..7].iter());
        bins.push(&lb[0]);
        let bg = ex.execute("block_bwd", &bins).unwrap();
        let demb = ex.execute("embed_bwd", &[&tok, &bg[0]]).unwrap();
        let grads: Vec<&HostTensor> =
            vec![&demb[0], &bg[1], &bg[2], &bg[3], &bg[4], &bg[5], &bg[6], &lb[1]];

        // Random ±1 direction over the whole parameter vector.
        let mut urng = Rng::new(0xD1F);
        let dirs: Vec<HostTensor> = ps
            .iter()
            .map(|p| {
                HostTensor::new(
                    p.shape.clone(),
                    p.data
                        .iter()
                        .map(|_| if urng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
                        .collect(),
                )
            })
            .collect();
        let eps = 1e-3f32;
        let shifted = |sign: f32| -> Vec<HostTensor> {
            ps.iter()
                .zip(&dirs)
                .map(|(p, u)| {
                    HostTensor::new(
                        p.shape.clone(),
                        p.data
                            .iter()
                            .zip(&u.data)
                            .map(|(&pv, &uv)| pv + sign * eps * uv)
                            .collect(),
                    )
                })
                .collect()
        };
        let lp = loss_of(&mut ex, &shifted(1.0));
        let lm = loss_of(&mut ex, &shifted(-1.0));
        let fd = (lp - lm) / (2.0 * eps);
        let ana: f32 = grads
            .iter()
            .zip(&dirs)
            .map(|(g, u)| g.data.iter().zip(&u.data).map(|(&gv, &uv)| gv * uv).sum::<f32>())
            .sum();
        assert!(fd.is_finite() && fd.abs() > 0.01, "degenerate direction: fd={fd}");
        let rel = (fd - ana).abs() / fd.abs().max(ana.abs());
        assert!(rel < 0.02, "directional derivative mismatch: fd={fd} analytic={ana} rel={rel}");
    }

    /// LSTM-cell backward must match the finite-difference directional
    /// derivative of `<h2,u_h> + <c2,u_c>` over a random ±1 direction on
    /// every input (same aggregation argument as the transformer test
    /// above: the directional form keeps f32 noise far below the O(1)
    /// derivative).
    #[test]
    fn lstm_cell_gradients_match_directional_derivative() {
        let rnn = RnnConfig { batch: 3, input: 5, hidden: 4, classes: 3 };
        let mut ex = InterpExecutor::rnn(rnn).unwrap();
        let mut rng = Rng::new(11);
        let ins0: Vec<HostTensor> = vec![
            randn_host(&mut rng, &[3, 5], 0.5),  // x
            randn_host(&mut rng, &[3, 4], 0.5),  // h
            randn_host(&mut rng, &[3, 4], 0.5),  // c
            randn_host(&mut rng, &[5, 16], 0.5), // wx
            randn_host(&mut rng, &[4, 16], 0.5), // wh
            randn_host(&mut rng, &[1, 16], 0.5), // b
        ];
        let u_h = randn_host(&mut rng, &[3, 4], 1.0);
        let u_c = randn_host(&mut rng, &[3, 4], 1.0);

        let obj = |ex: &mut InterpExecutor, ins: &[HostTensor]| -> f32 {
            let refs: Vec<&HostTensor> = ins.iter().collect();
            let out = ex.execute("lstm_cell_fwd", &refs).unwrap();
            let a: f32 = out[0].data.iter().zip(&u_h.data).map(|(&v, &u)| v * u).sum();
            let b: f32 = out[1].data.iter().zip(&u_c.data).map(|(&v, &u)| v * u).sum();
            a + b
        };

        let mut brefs: Vec<&HostTensor> = ins0.iter().collect();
        brefs.push(&u_h);
        brefs.push(&u_c);
        let grads = ex.execute("lstm_cell_bwd", &brefs).unwrap();
        assert_eq!(grads.len(), 6);

        let mut urng = Rng::new(0xD1F);
        let dirs: Vec<HostTensor> = ins0
            .iter()
            .map(|p| {
                HostTensor::new(
                    p.shape.clone(),
                    p.data
                        .iter()
                        .map(|_| if urng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
                        .collect(),
                )
            })
            .collect();
        let eps = 1e-3f32;
        let shifted = |sign: f32| -> Vec<HostTensor> {
            ins0.iter()
                .zip(&dirs)
                .map(|(p, u)| {
                    HostTensor::new(
                        p.shape.clone(),
                        p.data
                            .iter()
                            .zip(&u.data)
                            .map(|(&pv, &uv)| pv + sign * eps * uv)
                            .collect(),
                    )
                })
                .collect()
        };
        let fd = (obj(&mut ex, &shifted(1.0)) - obj(&mut ex, &shifted(-1.0))) / (2.0 * eps);
        let ana: f32 = grads
            .iter()
            .zip(&dirs)
            .map(|(g, u)| g.data.iter().zip(&u.data).map(|(&gv, &uv)| gv * uv).sum::<f32>())
            .sum();
        assert!(fd.is_finite() && fd.abs() > 0.01, "degenerate direction: fd={fd}");
        let rel = (fd - ana).abs() / fd.abs().max(ana.abs());
        assert!(rel < 0.02, "lstm cell: fd={fd} analytic={ana} rel={rel}");
    }

    /// TreeLSTM combine backward vs the same directional finite difference.
    #[test]
    fn tree_comb_gradients_match_directional_derivative() {
        let rnn = RnnConfig { batch: 3, input: 5, hidden: 4, classes: 3 };
        let mut ex = InterpExecutor::rnn(rnn).unwrap();
        let mut rng = Rng::new(23);
        let ins0: Vec<HostTensor> = vec![
            randn_host(&mut rng, &[3, 4], 0.5), // hl
            randn_host(&mut rng, &[3, 4], 0.5), // hr
            randn_host(&mut rng, &[4, 4], 0.5), // wl
            randn_host(&mut rng, &[4, 4], 0.5), // wr
        ];
        let u = randn_host(&mut rng, &[3, 4], 1.0);
        let obj = |ex: &mut InterpExecutor, ins: &[HostTensor]| -> f32 {
            let refs: Vec<&HostTensor> = ins.iter().collect();
            let out = ex.execute("tree_comb_fwd", &refs).unwrap();
            out[0].data.iter().zip(&u.data).map(|(&v, &uv)| v * uv).sum()
        };
        let mut brefs: Vec<&HostTensor> = ins0.iter().collect();
        brefs.push(&u);
        let grads = ex.execute("tree_comb_bwd", &brefs).unwrap();
        assert_eq!(grads.len(), 4);

        let mut urng = Rng::new(0xBEE);
        let dirs: Vec<HostTensor> = ins0
            .iter()
            .map(|p| {
                HostTensor::new(
                    p.shape.clone(),
                    p.data
                        .iter()
                        .map(|_| if urng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
                        .collect(),
                )
            })
            .collect();
        let eps = 1e-3f32;
        let shifted = |sign: f32| -> Vec<HostTensor> {
            ins0.iter()
                .zip(&dirs)
                .map(|(p, uu)| {
                    HostTensor::new(
                        p.shape.clone(),
                        p.data
                            .iter()
                            .zip(&uu.data)
                            .map(|(&pv, &uv)| pv + sign * eps * uv)
                            .collect(),
                    )
                })
                .collect()
        };
        let fd = (obj(&mut ex, &shifted(1.0)) - obj(&mut ex, &shifted(-1.0))) / (2.0 * eps);
        let ana: f32 = grads
            .iter()
            .zip(&dirs)
            .map(|(g, uu)| g.data.iter().zip(&uu.data).map(|(&gv, &uv)| gv * uv).sum::<f32>())
            .sum();
        assert!(fd.is_finite() && fd.abs() > 0.01, "degenerate direction: fd={fd}");
        let rel = (fd - ana).abs() / fd.abs().max(ana.abs());
        assert!(rel < 0.02, "tree comb: fd={fd} analytic={ana} rel={rel}");
    }

    #[test]
    fn rnn_loss_zero_inputs_give_ln_classes() {
        let rnn = RnnConfig::tiny();
        let mut ex = InterpExecutor::rnn(rnn).unwrap();
        let h = HostTensor::zeros(&[rnn.batch, rnn.hidden]);
        let w = HostTensor::zeros(&[rnn.hidden, rnn.classes]);
        let tgt = HostTensor::zeros(&[rnn.batch]);
        let out = ex.execute("rnn_loss_fwd", &[&h, &w, &tgt]).unwrap();
        let lnc = (rnn.classes as f32).ln();
        assert!((out[0].data[0] - lnc).abs() < 1e-5, "{} vs {}", out[0].data[0], lnc);
        // acc op adds elementwise.
        let a = HostTensor::new(vec![1, 64], vec![1.0; 64]);
        let b = HostTensor::new(vec![1, 64], vec![2.0; 64]);
        let s = ex.execute("acc_b", &[&a, &b]).unwrap();
        assert!(s[0].data.iter().all(|&v| v == 3.0));
    }

    /// One full-model gradient-descent step on a fixed batch must lower the
    /// loss — an end-to-end check that every hand-derived gradient points
    /// downhill.
    #[test]
    fn gradient_step_descends() {
        let cfg = ModelConfig {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq: 6,
            batch: 2,
            n_layers: 1,
        };
        let mut ex = exec(cfg);
        let mut rng = Rng::new(7);
        let shapes = cfg.param_shapes();
        let groups = ["ln", "wqkv", "wo", "ln", "w1", "w2"];
        let blk: Vec<HostTensor> =
            groups.iter().map(|&g| init_param(g, &shapes[g], &mut rng)).collect();
        let w_out = init_param("w_out", &shapes["w_out"], &mut rng);
        let emb = init_param("emb", &shapes["emb"], &mut rng);
        let n = cfg.batch * cfg.seq;
        let mut trng = Rng::new(3);
        let tok = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            (0..n).map(|_| trng.below(cfg.vocab as u64) as f32).collect(),
        );
        let tgt = HostTensor::new(
            vec![cfg.batch, cfg.seq],
            tok.data.iter().map(|&t| ((t as u64 * 31 + 7) % cfg.vocab as u64) as f32).collect(),
        );

        let loss_of = |ex: &mut InterpExecutor,
                       emb: &HostTensor,
                       blk: &[HostTensor],
                       w_out: &HostTensor| {
            let x = ex.execute("embed_fwd", &[&tok, emb]).unwrap();
            let mut ins: Vec<&HostTensor> = vec![&x[0]];
            ins.extend(blk.iter());
            let y = ex.execute("block_fwd", &ins).unwrap();
            let l = ex.execute("loss_fwd", &[&y[0], w_out, &tgt]).unwrap();
            (l[0].data[0], x, y)
        };

        let (l0, x, y) = loss_of(&mut ex, &emb, &blk, &w_out);
        let grads = ex.execute("loss_bwd", &[&y[0], &w_out, &tgt]).unwrap();
        let mut ins: Vec<&HostTensor> = vec![&x[0]];
        ins.extend(blk.iter());
        ins.push(&grads[0]);
        let bg = ex.execute("block_bwd", &ins).unwrap();
        let demb = ex.execute("embed_bwd", &[&tok, &bg[0]]).unwrap();

        let lr = 0.5f32;
        let apply = |p: &HostTensor, g: &HostTensor| {
            HostTensor::new(
                p.shape.clone(),
                p.data.iter().zip(&g.data).map(|(&pv, &gv)| pv - lr * gv).collect(),
            )
        };
        let blk2: Vec<HostTensor> =
            blk.iter().zip(&bg[1..7]).map(|(p, g)| apply(p, g)).collect();
        let emb2 = apply(&emb, &demb[0]);
        let w_out2 = apply(&w_out, &grads[1]);
        let (l1, _, _) = loss_of(&mut ex, &emb2, &blk2, &w_out2);
        assert!(l1.is_finite() && l0.is_finite());
        assert!(l1 < l0, "gradient step did not descend: {l0} -> {l1}");
    }
}
