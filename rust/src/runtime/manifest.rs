//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-tree JSON module (no serde offline).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn bytes(&self) -> u64 {
        4
    }

    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> u64 {
        self.elements() as u64 * self.dtype.bytes()
    }

    fn from_json(v: &Json) -> Result<TensorSig> {
        let shape = v
            .req("shape")?
            .as_arr()
            .context("shape array")?
            .iter()
            .map(|x| x.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.req("dtype")?.as_str().context("dtype str")?)?;
        Ok(TensorSig { shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct OpSig {
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Model dimensions baked into the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_layers: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub total_params: u64,
    /// Parameter group name -> shape.
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub ops: BTreeMap<String, OpSig>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = parse(&text).context("parsing manifest.json")?;

        let c = v.req("config")?;
        let dim = |k: &str| -> Result<usize> { c.req(k)?.as_usize().context(k.to_string()) };
        let config = ModelConfig {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_heads: dim("n_heads")?,
            d_ff: dim("d_ff")?,
            seq: dim("seq")?,
            batch: dim("batch")?,
            n_layers: dim("n_layers")?,
        };

        let mut param_shapes = BTreeMap::new();
        for (name, sh) in v.req("param_shapes")?.as_obj().context("param_shapes")? {
            let shape = sh
                .as_arr()
                .context("shape arr")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            param_shapes.insert(name.clone(), shape);
        }

        let mut ops = BTreeMap::new();
        for (name, op) in v.req("ops")?.as_obj().context("ops")? {
            let file = dir.join(op.req("file")?.as_str().context("file")?);
            let inputs = op
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = op
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            ops.insert(name.clone(), OpSig { file, inputs, outputs });
        }

        Ok(Manifest {
            config,
            total_params: v.req("total_params")?.as_u64().context("total_params")?,
            param_shapes,
            ops,
            dir: dir.to_path_buf(),
        })
    }

    pub fn op(&self, name: &str) -> Result<&OpSig> {
        self.ops.get(name).with_context(|| format!("op '{name}' not in manifest"))
    }

    /// Parameter groups per transformer block, in block_fwd argument order.
    pub fn block_param_order() -> [&'static str; 6] {
        ["ln", "wqkv", "wo", "ln", "w1", "w2"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.total_params > 0);
        assert!(m.ops.contains_key("block_fwd"));
        assert!(m.ops.contains_key("block_bwd"));
        assert!(m.ops.contains_key("adam_emb"));
        let bf = m.op("block_fwd").unwrap();
        assert_eq!(bf.inputs.len(), 7);
        assert_eq!(bf.outputs.len(), 1);
        assert_eq!(
            bf.inputs[0].shape,
            vec![m.config.batch, m.config.seq, m.config.d_model]
        );
        // HLO artifact files exist.
        for op in m.ops.values() {
            assert!(op.file.exists(), "{:?} missing", op.file);
        }
    }

    #[test]
    fn tensor_sig_bytes() {
        let s = TensorSig { shape: vec![2, 3, 4], dtype: DType::F32 };
        assert_eq!(s.elements(), 24);
        assert_eq!(s.bytes(), 96);
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
