//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-tree JSON module (no serde offline).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn bytes(&self) -> u64 {
        4
    }

    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> u64 {
        self.elements() as u64 * self.dtype.bytes()
    }

    fn from_json(v: &Json) -> Result<TensorSig> {
        let shape = v
            .req("shape")?
            .as_arr()
            .context("shape array")?
            .iter()
            .map(|x| x.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.req("dtype")?.as_str().context("dtype str")?)?;
        Ok(TensorSig { shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct OpSig {
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Model dimensions baked into the artifacts (or synthesized for the
/// interpreter backend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_layers: usize,
}

impl ModelConfig {
    /// Smallest config exercising every code path; the test fixture.
    pub fn tiny() -> ModelConfig {
        ModelConfig { vocab: 64, d_model: 32, n_heads: 4, d_ff: 64, seq: 16, batch: 4, n_layers: 2 }
    }

    /// Default training config for the hermetic interpreter backend.
    pub fn small() -> ModelConfig {
        ModelConfig {
            vocab: 256,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            seq: 32,
            batch: 8,
            n_layers: 2,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.vocab > 0
                && self.d_model > 0
                && self.n_heads > 0
                && self.d_ff > 0
                && self.seq > 0
                && self.batch > 0
                && self.n_layers > 0,
            "model dimensions must all be positive: {self:?}"
        );
        anyhow::ensure!(
            self.d_model % self.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            self.d_model,
            self.n_heads
        );
        Ok(())
    }

    /// Parameter group name -> shape for this config.
    pub fn param_shapes(&self) -> BTreeMap<String, Vec<usize>> {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        let mut shapes = BTreeMap::new();
        shapes.insert("emb".to_string(), vec![v, d]);
        shapes.insert("ln".to_string(), vec![2, d]);
        shapes.insert("wqkv".to_string(), vec![d, 3 * d]);
        shapes.insert("wo".to_string(), vec![d, d]);
        shapes.insert("w1".to_string(), vec![d, f]);
        shapes.insert("w2".to_string(), vec![f, d]);
        shapes.insert("w_out".to_string(), vec![d, v]);
        shapes
    }

    pub fn total_params(&self) -> u64 {
        let (d, f, v) = (self.d_model as u64, self.d_ff as u64, self.vocab as u64);
        let per_block = 2 * d + d * 3 * d + d * d + 2 * d + d * f + f * d;
        v * d + self.n_layers as u64 * per_block + d * v
    }
}

/// Dimensions of the dynamic-model op family (LSTM cell, TreeLSTM cells,
/// classification readout). Unlike [`ModelConfig`], nothing here fixes the
/// *shape of the computation*: sequence lengths and tree topologies are
/// chosen by the driving program at run time (the paper's dynamic models,
/// Sec. 4.1) — the config only fixes per-op tensor shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RnnConfig {
    pub batch: usize,
    /// Input feature dimension of `x_t` / leaf embeddings.
    pub input: usize,
    /// Hidden state dimension.
    pub hidden: usize,
    /// Readout classes for the cross-entropy loss.
    pub classes: usize,
}

impl RnnConfig {
    /// Smallest config exercising every dynamic code path; the test fixture.
    pub fn tiny() -> RnnConfig {
        RnnConfig { batch: 4, input: 8, hidden: 16, classes: 4 }
    }

    /// Bench-scale config for the dynamic-LSTM perf trajectory.
    pub fn small() -> RnnConfig {
        RnnConfig { batch: 16, input: 32, hidden: 64, classes: 16 }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.batch > 0 && self.input > 0 && self.hidden > 0 && self.classes > 0,
            "rnn dimensions must all be positive: {self:?}"
        );
        Ok(())
    }

    /// Parameter group name -> shape. Groups `wx`/`wh`/`b` belong to the
    /// LSTM cell, `wc`/`wl`/`wr` to the TreeLSTM cells, `wout` to the
    /// shared readout.
    pub fn param_shapes(&self) -> BTreeMap<String, Vec<usize>> {
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let mut shapes = BTreeMap::new();
        shapes.insert("wx".to_string(), vec![i, 4 * h]);
        shapes.insert("wh".to_string(), vec![h, 4 * h]);
        shapes.insert("b".to_string(), vec![1, 4 * h]);
        shapes.insert("wc".to_string(), vec![i, h]);
        shapes.insert("wl".to_string(), vec![h, h]);
        shapes.insert("wr".to_string(), vec![h, h]);
        shapes.insert("wout".to_string(), vec![h, c]);
        shapes
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub total_params: u64,
    /// Parameter group name -> shape.
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub ops: BTreeMap<String, OpSig>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Build the op/shape contract for `cfg` programmatically — the
    /// interpreter and null backends need no artifacts on disk. The op set
    /// and signatures mirror what `python/compile/aot.py` emits.
    pub fn synthesize(cfg: ModelConfig) -> Result<Manifest> {
        cfg.validate()?;
        let (b, s, d, f, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.vocab);
        let f32s = |shape: &[usize]| TensorSig { shape: shape.to_vec(), dtype: DType::F32 };
        let i32s = |shape: &[usize]| TensorSig { shape: shape.to_vec(), dtype: DType::I32 };
        let op = |inputs: Vec<TensorSig>, outputs: Vec<TensorSig>| OpSig {
            file: PathBuf::new(),
            inputs,
            outputs,
        };

        let x = f32s(&[b, s, d]);
        let tok = i32s(&[b, s]);
        let block_params = vec![
            f32s(&[2, d]),
            f32s(&[d, 3 * d]),
            f32s(&[d, d]),
            f32s(&[2, d]),
            f32s(&[d, f]),
            f32s(&[f, d]),
        ];

        let mut ops = BTreeMap::new();
        ops.insert(
            "embed_fwd".to_string(),
            op(vec![tok.clone(), f32s(&[v, d])], vec![x.clone()]),
        );
        ops.insert(
            "embed_bwd".to_string(),
            op(vec![tok.clone(), x.clone()], vec![f32s(&[v, d])]),
        );
        let mut block_in = vec![x.clone()];
        block_in.extend(block_params.iter().cloned());
        ops.insert("block_fwd".to_string(), op(block_in.clone(), vec![x.clone()]));
        let mut bwd_in = block_in;
        bwd_in.push(x.clone());
        let mut bwd_out = vec![x.clone()];
        bwd_out.extend(block_params.iter().cloned());
        ops.insert("block_bwd".to_string(), op(bwd_in, bwd_out));
        ops.insert(
            "loss_fwd".to_string(),
            op(vec![x.clone(), f32s(&[d, v]), tok.clone()], vec![f32s(&[1])]),
        );
        ops.insert(
            "loss_bwd".to_string(),
            op(
                vec![x.clone(), f32s(&[d, v]), tok.clone()],
                vec![x.clone(), f32s(&[d, v])],
            ),
        );
        // Fused kernels ported from the Pallas specs (runtime/kernels),
        // exposed as standalone ops: the engine's train_step stream does
        // not call them, so existing decision traces are unchanged.
        ops.insert(
            "fused_ln_fwd".to_string(),
            op(vec![x.clone(), f32s(&[2, d])], vec![x.clone()]),
        );
        let heads = f32s(&[b, cfg.n_heads, s, cfg.d_head()]);
        ops.insert(
            "fused_attn_fwd".to_string(),
            op(vec![heads.clone(), heads.clone(), heads.clone()], vec![heads]),
        );

        let param_shapes = cfg.param_shapes();
        for (group, shape) in &param_shapes {
            let p = f32s(shape);
            ops.insert(
                format!("sgd_{group}"),
                op(vec![p.clone(), p.clone()], vec![p.clone()]),
            );
            ops.insert(
                format!("adam_{group}"),
                op(
                    vec![p.clone(), p.clone(), p.clone(), p.clone(), f32s(&[1])],
                    vec![p.clone(), p.clone(), p.clone()],
                ),
            );
        }

        Ok(Manifest {
            config: cfg,
            total_params: cfg.total_params(),
            param_shapes,
            ops,
            dir: PathBuf::new(),
        })
    }

    /// Build the op/shape contract for the dynamic-model family: LSTM cell,
    /// TreeLSTM leaf/combine cells, classification readout, per-group
    /// gradient accumulators, and SGD updates. Backward cells are
    /// self-contained (they recompute forward intermediates from the same
    /// inputs), so every op is a pure function of its inputs and DTR
    /// replays are bitwise-identical.
    pub fn synthesize_rnn(cfg: RnnConfig) -> Result<Manifest> {
        cfg.validate()?;
        let (b, i, h, c) = (cfg.batch, cfg.input, cfg.hidden, cfg.classes);
        let f32s = |shape: &[usize]| TensorSig { shape: shape.to_vec(), dtype: DType::F32 };
        let i32s = |shape: &[usize]| TensorSig { shape: shape.to_vec(), dtype: DType::I32 };
        let op = |inputs: Vec<TensorSig>, outputs: Vec<TensorSig>| OpSig {
            file: PathBuf::new(),
            inputs,
            outputs,
        };

        let x = f32s(&[b, i]);
        let hid = f32s(&[b, h]);
        let wx = f32s(&[i, 4 * h]);
        let wh = f32s(&[h, 4 * h]);
        let bias = f32s(&[1, 4 * h]);
        let wc = f32s(&[i, h]);
        let whh = f32s(&[h, h]);
        let wout = f32s(&[h, c]);
        let tgt = i32s(&[b]);

        let mut ops = BTreeMap::new();
        ops.insert(
            "lstm_cell_fwd".to_string(),
            op(
                vec![x.clone(), hid.clone(), hid.clone(), wx.clone(), wh.clone(), bias.clone()],
                vec![hid.clone(), hid.clone()],
            ),
        );
        ops.insert(
            "lstm_cell_bwd".to_string(),
            op(
                vec![
                    x.clone(),
                    hid.clone(),
                    hid.clone(),
                    wx.clone(),
                    wh.clone(),
                    bias.clone(),
                    hid.clone(),
                    hid.clone(),
                ],
                vec![x.clone(), hid.clone(), hid.clone(), wx.clone(), wh.clone(), bias.clone()],
            ),
        );
        ops.insert(
            "tree_leaf_fwd".to_string(),
            op(vec![x.clone(), wc.clone()], vec![hid.clone()]),
        );
        ops.insert(
            "tree_leaf_bwd".to_string(),
            op(vec![x.clone(), wc.clone(), hid.clone()], vec![x.clone(), wc.clone()]),
        );
        ops.insert(
            "tree_comb_fwd".to_string(),
            op(vec![hid.clone(), hid.clone(), whh.clone(), whh.clone()], vec![hid.clone()]),
        );
        ops.insert(
            "tree_comb_bwd".to_string(),
            op(
                vec![hid.clone(), hid.clone(), whh.clone(), whh.clone(), hid.clone()],
                vec![hid.clone(), hid.clone(), whh.clone(), whh.clone()],
            ),
        );
        ops.insert(
            "rnn_loss_fwd".to_string(),
            op(vec![hid.clone(), wout.clone(), tgt.clone()], vec![f32s(&[1])]),
        );
        ops.insert(
            "rnn_loss_bwd".to_string(),
            op(vec![hid.clone(), wout.clone(), tgt.clone()], vec![hid.clone(), wout.clone()]),
        );

        let param_shapes = cfg.param_shapes();
        for (group, shape) in &param_shapes {
            let p = f32s(shape);
            // Per-group gradient accumulation (weight grads sum over
            // timesteps / tree nodes) and the SGD update.
            ops.insert(format!("acc_{group}"), op(vec![p.clone(), p.clone()], vec![p.clone()]));
            ops.insert(format!("sgd_{group}"), op(vec![p.clone(), p.clone()], vec![p.clone()]));
        }

        let total_params: u64 =
            param_shapes.values().map(|s| s.iter().product::<usize>() as u64).sum();
        Ok(Manifest {
            // A placeholder transformer config (never consulted: no
            // transformer op appears in this manifest, and the analytic cost
            // model derives rnn-op costs from signature shapes alone).
            config: ModelConfig {
                vocab: c,
                d_model: h,
                n_heads: 1,
                d_ff: 4 * h,
                seq: 1,
                batch: b,
                n_layers: 1,
            },
            total_params,
            param_shapes,
            ops,
            dir: PathBuf::new(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = parse(&text).context("parsing manifest.json")?;

        let c = v.req("config")?;
        let dim = |k: &str| -> Result<usize> { c.req(k)?.as_usize().context(k.to_string()) };
        let config = ModelConfig {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_heads: dim("n_heads")?,
            d_ff: dim("d_ff")?,
            seq: dim("seq")?,
            batch: dim("batch")?,
            n_layers: dim("n_layers")?,
        };

        let mut param_shapes = BTreeMap::new();
        for (name, sh) in v.req("param_shapes")?.as_obj().context("param_shapes")? {
            let shape = sh
                .as_arr()
                .context("shape arr")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            param_shapes.insert(name.clone(), shape);
        }

        let mut ops = BTreeMap::new();
        for (name, op) in v.req("ops")?.as_obj().context("ops")? {
            let file = dir.join(op.req("file")?.as_str().context("file")?);
            let inputs = op
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = op
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            ops.insert(name.clone(), OpSig { file, inputs, outputs });
        }

        Ok(Manifest {
            config,
            total_params: v.req("total_params")?.as_u64().context("total_params")?,
            param_shapes,
            ops,
            dir: dir.to_path_buf(),
        })
    }

    pub fn op(&self, name: &str) -> Result<&OpSig> {
        self.ops.get(name).with_context(|| format!("op '{name}' not in manifest"))
    }

    /// Parameter groups per transformer block, in block_fwd argument order.
    pub fn block_param_order() -> [&'static str; 6] {
        ["ln", "wqkv", "wo", "ln", "w1", "w2"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.total_params > 0);
        assert!(m.ops.contains_key("block_fwd"));
        assert!(m.ops.contains_key("block_bwd"));
        assert!(m.ops.contains_key("adam_emb"));
        let bf = m.op("block_fwd").unwrap();
        assert_eq!(bf.inputs.len(), 7);
        assert_eq!(bf.outputs.len(), 1);
        assert_eq!(
            bf.inputs[0].shape,
            vec![m.config.batch, m.config.seq, m.config.d_model]
        );
        // HLO artifact files exist.
        for op in m.ops.values() {
            assert!(op.file.exists(), "{:?} missing", op.file);
        }
    }

    #[test]
    fn tensor_sig_bytes() {
        let s = TensorSig { shape: vec![2, 3, 4], dtype: DType::F32 };
        assert_eq!(s.elements(), 24);
        assert_eq!(s.bytes(), 96);
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn synthesized_manifest_matches_engine_contract() {
        let cfg = ModelConfig::tiny();
        let m = Manifest::synthesize(cfg).unwrap();
        assert_eq!(m.total_params, cfg.total_params());
        let bf = m.op("block_fwd").unwrap();
        assert_eq!(bf.inputs.len(), 7);
        assert_eq!(bf.outputs.len(), 1);
        assert_eq!(bf.inputs[0].shape, vec![cfg.batch, cfg.seq, cfg.d_model]);
        let bb = m.op("block_bwd").unwrap();
        assert_eq!(bb.inputs.len(), 8);
        assert_eq!(bb.outputs.len(), 7);
        let lb = m.op("loss_bwd").unwrap();
        assert_eq!(lb.outputs.len(), 2);
        // Optimizer artifacts exist for every parameter group.
        for group in m.param_shapes.keys() {
            assert!(m.ops.contains_key(&format!("sgd_{group}")), "sgd_{group}");
            assert!(m.ops.contains_key(&format!("adam_{group}")), "adam_{group}");
        }
        assert_eq!(m.op("adam_wo").unwrap().inputs.len(), 5);
    }

    #[test]
    fn synthesized_rnn_manifest_contract() {
        let cfg = RnnConfig::tiny();
        let m = Manifest::synthesize_rnn(cfg).unwrap();
        let cf = m.op("lstm_cell_fwd").unwrap();
        assert_eq!(cf.inputs.len(), 6);
        assert_eq!(cf.outputs.len(), 2);
        assert_eq!(cf.inputs[0].shape, vec![cfg.batch, cfg.input]);
        assert_eq!(cf.outputs[0].shape, vec![cfg.batch, cfg.hidden]);
        let cb = m.op("lstm_cell_bwd").unwrap();
        assert_eq!(cb.inputs.len(), 8);
        assert_eq!(cb.outputs.len(), 6);
        assert_eq!(m.op("tree_comb_bwd").unwrap().outputs.len(), 4);
        assert_eq!(m.op("rnn_loss_bwd").unwrap().outputs.len(), 2);
        // Accumulator + SGD ops exist for every parameter group.
        for group in m.param_shapes.keys() {
            assert!(m.ops.contains_key(&format!("acc_{group}")), "acc_{group}");
            assert!(m.ops.contains_key(&format!("sgd_{group}")), "sgd_{group}");
        }
        assert_eq!(
            m.total_params,
            m.param_shapes.values().map(|s| s.iter().product::<usize>() as u64).sum::<u64>()
        );
        assert!(m.config.validate().is_ok(), "placeholder config must stay valid");
    }

    #[test]
    fn model_config_validation() {
        assert!(ModelConfig::tiny().validate().is_ok());
        assert!(ModelConfig::small().validate().is_ok());
        let bad = ModelConfig { n_heads: 3, ..ModelConfig::tiny() };
        assert!(bad.validate().is_err());
        assert_eq!(ModelConfig::tiny().d_head(), 8);
    }
}
