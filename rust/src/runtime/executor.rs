//! The pluggable execution seam: `Executor` is the boundary between the DTR
//! engine (which only sees tensor ids, sizes, and costs) and whatever
//! actually computes operator outputs.
//!
//! The paper's claim is that DTR works "merely by interposing on tensor
//! allocations and operator calls"; this trait is that interposition point.
//! Three implementations exist:
//!
//! * [`crate::runtime::InterpExecutor`] — hermetic pure-Rust reference
//!   interpreter of the manifest op set (default everywhere);
//! * [`NullExecutor`] — accounting-only executor producing zero buffers,
//!   used to prove DTR decisions are backend-independent;
//! * `PjrtExecutor` (behind the `pjrt` cargo feature) — executes
//!   AOT-compiled HLO artifacts through the `xla` crate.

use anyhow::Result;

use super::manifest::{Manifest, ModelConfig, OpSig, RnnConfig};
use crate::util::rng::Rng;

/// A host tensor: shape + row-major f32 data. Integer tensors (token ids)
/// are carried as exactly-representable f32 values; the manifest's
/// `TensorSig` dtype records the logical type.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor { shape: vec![1], data: vec![v] }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }
}

/// Executes manifest operators on host tensors. Implementations own any
/// compiled state (executables, scratch buffers); the DTR engine owns the
/// tensors themselves.
///
/// `Send` is a supertrait so executors can back serving tenants on worker
/// threads (`crate::serve`); compiled state that is not `Send` must be
/// wrapped by the implementation.
pub trait Executor: Send {
    /// Short backend name for logs and CSV output.
    fn name(&self) -> &'static str;

    /// The op/shape contract this executor serves.
    fn manifest(&self) -> &Manifest;

    /// Execute operator `op` on `inputs`, returning one tensor per manifest
    /// output signature. Must be a pure function of its inputs: DTR replays
    /// ops to rematerialize, and replays must reproduce identical values.
    fn execute(&mut self, op: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// Which executor the coordinator should construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Hermetic pure-Rust interpreter (default).
    Interp,
    /// PJRT-compiled HLO artifacts (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Interp => "interp",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "interp" | "interpreter" | "host" => BackendKind::Interp,
            "pjrt" | "xla" => BackendKind::Pjrt,
            _ => return None,
        })
    }
}

/// Accounting-only executor: outputs are zero tensors of the manifest
/// shapes. DTR's decisions (evictions, rematerializations, peak memory)
/// must be identical under this executor and any real one — the
/// backend-equivalence property tested in `tests/prop_invariants.rs`.
pub struct NullExecutor {
    manifest: Manifest,
    pub executed: u64,
}

impl NullExecutor {
    pub fn new(cfg: ModelConfig) -> Result<NullExecutor> {
        Ok(NullExecutor { manifest: Manifest::synthesize(cfg)?, executed: 0 })
    }

    /// Accounting-only executor over the dynamic-model (LSTM/TreeLSTM) op
    /// family.
    pub fn rnn(cfg: RnnConfig) -> Result<NullExecutor> {
        Ok(NullExecutor { manifest: Manifest::synthesize_rnn(cfg)?, executed: 0 })
    }
}

impl Executor for NullExecutor {
    fn name(&self) -> &'static str {
        "null"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(&mut self, op: &str, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.executed += 1;
        let sig = self.manifest.op(op)?;
        Ok(sig.outputs.iter().map(|o| HostTensor::zeros(&o.shape)).collect())
    }
}

// ------------------------------------------------------- host-side helpers

/// Standard-normal f32 tensor via Box–Muller on the deterministic RNG.
/// This is the single source of truth for parameter init: the PJRT
/// literal helpers in `runtime/pjrt.rs` delegate here, so every backend
/// trains from bit-identical initial parameters.
pub fn randn_host(rng: &mut Rng, shape: &[usize], scale: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    let mut data = Vec::with_capacity(n);
    while data.len() < n {
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        data.push((r * th.cos()) as f32 * scale);
        if data.len() < n {
            data.push((r * th.sin()) as f32 * scale);
        }
    }
    HostTensor::new(shape.to_vec(), data)
}

/// Parameter initialization by group convention: layernorm groups get
/// gamma=1 / beta=0 rows, everything else N(0, 0.02).
pub fn init_param(group: &str, shape: &[usize], rng: &mut Rng) -> HostTensor {
    if group.starts_with("ln") {
        let d = shape[1];
        let mut data = vec![1.0f32; d];
        data.extend(std::iter::repeat(0.0f32).take(d));
        HostTensor::new(vec![2, d], data)
    } else {
        randn_host(rng, shape, 0.02)
    }
}

/// Deterministic per-op compute cost (flop estimate from manifest shapes).
/// The engine feeds these to DTR's heuristics instead of wall-clock
/// timings, making budgeted runs reproducible and backend-independent.
pub fn analytic_cost(name: &str, op: &OpSig, cfg: &ModelConfig) -> u64 {
    let (b, s, d, f, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.vocab);
    let el_in: usize = op.inputs.iter().map(|t| t.elements()).sum();
    let el_out: usize = op.outputs.iter().map(|t| t.elements()).sum();
    let touch = (el_in + el_out) as u64;
    let block_flops =
        (2 * b * s * d * 3 * d + 4 * b * s * s * d + 2 * b * s * d * d + 4 * b * s * d * f) as u64;
    // Dynamic-model (rnn) ops derive their flops from signature shapes
    // alone, so one cost model serves any `RnnConfig`.
    let flops = if name == "lstm_cell_fwd" || name == "lstm_cell_bwd" {
        let (bz, i) = (op.inputs[0].shape[0], op.inputs[0].shape[1]);
        let h = op.inputs[1].shape[1];
        let fwd = (2 * bz * i * 4 * h + 2 * bz * h * 4 * h + 10 * bz * h) as u64;
        if name == "lstm_cell_fwd" {
            fwd
        } else {
            3 * fwd
        }
    } else if name == "tree_leaf_fwd" || name == "tree_leaf_bwd" {
        let (bz, i) = (op.inputs[0].shape[0], op.inputs[0].shape[1]);
        let h = op.inputs[1].shape[1];
        let fwd = (2 * bz * i * h) as u64;
        if name == "tree_leaf_fwd" {
            fwd
        } else {
            3 * fwd
        }
    } else if name == "tree_comb_fwd" || name == "tree_comb_bwd" {
        let (bz, h) = (op.inputs[0].shape[0], op.inputs[0].shape[1]);
        let fwd = (4 * bz * h * h) as u64;
        if name == "tree_comb_fwd" {
            fwd
        } else {
            3 * fwd
        }
    } else if name == "rnn_loss_fwd" || name == "rnn_loss_bwd" {
        let (bz, h) = (op.inputs[0].shape[0], op.inputs[0].shape[1]);
        let c = op.inputs[1].shape[1];
        let fwd = (2 * bz * h * c + 3 * bz * c) as u64;
        if name == "rnn_loss_fwd" {
            fwd
        } else {
            2 * fwd
        }
    } else if name.starts_with("acc_") {
        op.inputs[0].elements() as u64
    } else if name.starts_with("embed_") {
        (b * s * d) as u64
    } else if name == "block_fwd" {
        block_flops
    } else if name == "block_bwd" {
        3 * block_flops
    } else if name == "loss_fwd" {
        (2 * b * s * d * v + 3 * b * s * v) as u64
    } else if name == "loss_bwd" {
        (4 * b * s * d * v + 3 * b * s * v) as u64
    } else if name == "fused_ln_fwd" {
        // Two reduction passes + one normalize pass per row.
        (8 * b * s * d) as u64
    } else if name == "fused_attn_fwd" {
        // qk^T and pv contractions over the causal half, online softmax.
        (4 * b * s * s * d) as u64
    } else if name.starts_with("adam_") {
        12 * op.inputs[0].elements() as u64
    } else if name.starts_with("sgd_") {
        2 * op.inputs[0].elements() as u64
    } else {
        0
    };
    flops.max(touch).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accounting() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(HostTensor::scalar(2.5).data, vec![2.5]);
    }

    #[test]
    fn randn_is_deterministic_and_sane() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let x = randn_host(&mut a, &[4, 8], 1.0);
        let y = randn_host(&mut b, &[4, 8], 1.0);
        assert_eq!(x.data, y.data);
        assert!(x.data.iter().all(|v| v.abs() < 6.0));
    }

    #[test]
    fn ln_init_layout() {
        let mut rng = Rng::new(1);
        let t = init_param("ln", &[2, 4], &mut rng);
        assert_eq!(t.data, vec![1., 1., 1., 1., 0., 0., 0., 0.]);
    }

    #[test]
    fn backend_kind_roundtrip() {
        for k in [BackendKind::Interp, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("bogus"), None);
    }

    #[test]
    fn null_executor_produces_manifest_shapes() {
        let cfg = ModelConfig::tiny();
        let mut ex = NullExecutor::new(cfg).unwrap();
        let tok = HostTensor::zeros(&[cfg.batch, cfg.seq]);
        let emb = HostTensor::zeros(&[cfg.vocab, cfg.d_model]);
        let outs = ex.execute("embed_fwd", &[&tok, &emb]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![cfg.batch, cfg.seq, cfg.d_model]);
        assert_eq!(ex.executed, 1);
    }

    #[test]
    fn analytic_costs_positive_and_deterministic() {
        let cfg = ModelConfig::tiny();
        let m = Manifest::synthesize(cfg).unwrap();
        for (name, op) in &m.ops {
            let c1 = analytic_cost(name, op, &cfg);
            let c2 = analytic_cost(name, op, &cfg);
            assert!(c1 > 0, "{name} has zero cost");
            assert_eq!(c1, c2);
        }
        // Relative ordering: block backward dominates forward; loss matmul
        // over the vocab dominates an optimizer elementwise pass.
        let cost = |n: &str| analytic_cost(n, m.op(n).unwrap(), &cfg);
        assert!(cost("block_bwd") > cost("block_fwd"));
        assert!(cost("loss_fwd") > cost("sgd_wo"));
    }

    #[test]
    fn rnn_analytic_costs_positive_and_ordered() {
        let rnn = RnnConfig::tiny();
        let m = Manifest::synthesize_rnn(rnn).unwrap();
        let cfg = m.config;
        for (name, op) in &m.ops {
            assert!(analytic_cost(name, op, &cfg) > 0, "{name} has zero cost");
        }
        let cost = |n: &str| analytic_cost(n, m.op(n).unwrap(), &cfg);
        assert!(cost("lstm_cell_bwd") > cost("lstm_cell_fwd"));
        assert!(cost("tree_comb_bwd") > cost("tree_comb_fwd"));
        assert!(cost("lstm_cell_fwd") > cost("acc_b"));
    }

    #[test]
    fn null_rnn_executor_produces_manifest_shapes() {
        let rnn = RnnConfig::tiny();
        let mut ex = NullExecutor::rnn(rnn).unwrap();
        let x = HostTensor::zeros(&[rnn.batch, rnn.input]);
        let h = HostTensor::zeros(&[rnn.batch, rnn.hidden]);
        let c = HostTensor::zeros(&[rnn.batch, rnn.hidden]);
        let wx = HostTensor::zeros(&[rnn.input, 4 * rnn.hidden]);
        let wh = HostTensor::zeros(&[rnn.hidden, 4 * rnn.hidden]);
        let b = HostTensor::zeros(&[1, 4 * rnn.hidden]);
        let outs = ex.execute("lstm_cell_fwd", &[&x, &h, &c, &wx, &wh, &b]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].shape, vec![rnn.batch, rnn.hidden]);
    }
}
