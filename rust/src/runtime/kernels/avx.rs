//! Hand-vectorized AVX2 rank-1 block behind the `simd` feature.
//!
//! The vector path rounds exactly like the scalar one: each update is a
//! separate `vmulps` + `vaddps` pair (never contracted to an FMA),
//! applied lanewise in the same ascending-`p` order, so every output
//! element's f32 accumulation chain is bit-for-bit the scalar reference
//! chain. AVX2 is detected at runtime — [`usable`] gates dispatch in
//! `gemm::rank1_block` — so `--features simd` binaries still run (on the
//! portable block) on pre-AVX2 x86-64.

use std::arch::x86_64::{
    _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
};

use super::gemm::KU;

/// True when the running CPU can execute [`rank1_block_avx2`].
pub(crate) fn usable() -> bool {
    std::is_x86_feature_detected!("avx2")
}

/// `orow[j] += sum_u av[u] * b[u][j]` with one rounded mul+add per `u` in
/// ascending order — the scalar chain, eight f32 lanes per instruction.
///
/// # Safety
///
/// The caller must ensure AVX2 is available (see [`usable`]) and that
/// every `b[u]` holds at least `orow.len()` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn rank1_block_avx2(orow: &mut [f32], av: &[f32; KU], b: &[&[f32]; KU]) {
    let n = orow.len();
    debug_assert!(b.iter().all(|row| row.len() >= n));
    let va = [
        _mm256_set1_ps(av[0]),
        _mm256_set1_ps(av[1]),
        _mm256_set1_ps(av[2]),
        _mm256_set1_ps(av[3]),
        _mm256_set1_ps(av[4]),
        _mm256_set1_ps(av[5]),
        _mm256_set1_ps(av[6]),
        _mm256_set1_ps(av[7]),
    ];
    let op = orow.as_mut_ptr();
    let mut j = 0usize;
    while j + 8 <= n {
        let mut s = _mm256_loadu_ps(op.add(j));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[0], _mm256_loadu_ps(b[0].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[1], _mm256_loadu_ps(b[1].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[2], _mm256_loadu_ps(b[2].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[3], _mm256_loadu_ps(b[3].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[4], _mm256_loadu_ps(b[4].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[5], _mm256_loadu_ps(b[5].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[6], _mm256_loadu_ps(b[6].as_ptr().add(j))));
        s = _mm256_add_ps(s, _mm256_mul_ps(va[7], _mm256_loadu_ps(b[7].as_ptr().add(j))));
        _mm256_storeu_ps(op.add(j), s);
        j += 8;
    }
    // `n % 8` tail: scalar, same per-element order.
    while j < n {
        let mut s = *op.add(j);
        for u in 0..KU {
            s += av[u] * b[u][j];
        }
        *op.add(j) = s;
        j += 1;
    }
}
