//! The interpreter's kernel layer: unrolled rank-1 row-kernel GEMMs
//! with optional intra-op row threading, the retained scalar reference
//! oracle, and fused ops ported from the in-repo Pallas tiling specs.
//!
//! # Kernel ↔ Pallas-spec map
//!
//! | kernel                        | spec                                  |
//! |-------------------------------|---------------------------------------|
//! | [`gemm::matmul`] (+`_at`/`_bt`) | the `jnp.dot` contractions in `python/compile/model.py`, blocked like the MXU-aligned accumulator rows `python/compile/kernels/*.py` assume (here: one output row built from `KU = 8` unrolled rank-1 updates, `k` kept whole) |
//! | [`fused::layernorm`] / [`fused::layernorm_bwd`] | `python/compile/kernels/layernorm.py` (`_ln_kernel` / `_ln_bwd_kernel`): one pass per row, mean/var/rstd recomputed in-kernel, `dx = rstd * (dy*g - m1 - xhat*m2)` |
//! | [`fused::causal_attention`]   | `python/compile/kernels/attention.py` (`_attn_kernel`): online-softmax flash attention with running `(m, l, acc)` per query row, causal mask `q_pos >= k_pos`, scale `1/sqrt(dh)` — here in the `block_q = block_k = 1` degenerate form |
//! | [`reference`]                 | `python/compile/kernels/ref.py` — the pre-tiling scalar loop nests, kept verbatim as the equivalence oracle |
//!
//! # Exactness contract
//!
//! The row-kernel GEMMs are **bitwise identical** to the scalar reference
//! at any thread count: every output element keeps a single f32
//! accumulator chain over `p` ascending from `0.0` (the unroll widens how
//! many chains advance per pass, never how any one chain is ordered;
//! threads partition disjoint output rows). The fused ops are *not*
//! bitwise equal to the composite forms they replace — online softmax
//! reassociates the reduction — so they are separate manifest ops with
//! tolerance-based equivalence tests (`rust/tests/prop_kernels.rs`).
//!
//! The `simd` cargo feature swaps the portable rank-1 block for a
//! hand-vectorized one — AVX2 in `avx` on x86-64, NEON in `neon` on
//! AArch64 (both runtime-detected, scalar fallback); each vector lane
//! performs the same rounded mul+add sequence, so results stay
//! bit-identical with or without it, and across the two ISAs.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod avx;
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
pub(crate) mod neon;
pub mod fused;
pub mod gemm;
pub mod reference;

/// Work threshold (multiply-adds) below which intra-op threading is not
/// worth the `thread::scope` spawn/join overhead.
pub(crate) const PAR_MIN_FLOPS: usize = 1 << 17;

/// Split `out`'s `m` logical rows of width `n` into contiguous per-thread
/// chunks and run `f(rows, chunk)` on each. Row partitions write disjoint
/// output rows and leave every per-element accumulation chain unchanged,
/// so any thread count is bit-identical to `threads = 1`.
pub(crate) fn par_rows<F>(out: &mut [f32], m: usize, n: usize, threads: usize, flops: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let threads = threads.max(1).min(m.max(1));
    if threads == 1 || flops < PAR_MIN_FLOPS {
        f(0..m, out);
        return;
    }
    let per = m.div_ceil(threads);
    let f = &f;
    std::thread::scope(|scope| {
        for (t, chunk) in out.chunks_mut(per * n).enumerate() {
            let lo = t * per;
            let hi = (lo + per).min(m);
            scope.spawn(move || f(lo..hi, chunk));
        }
    });
}
