//! The retained scalar oracle: the interpreter's pre-tiling loop nests,
//! kept verbatim so the kernel-equivalence property test (and anyone
//! debugging a rounding question) can compare against the exact pre-PR
//! semantics. Mirrors `python/compile/kernels/ref.py`.
//!
//! Also provides the *composite* (two-pass softmax / materialized-xhat)
//! forms of the fused ops in [`super::fused`], which those ops are tested
//! against with per-op tolerances.

/// out[m,n] = a[m,k] @ b[k,n]
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            let brow = &b[p * n..p * n + n];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// out[m,n] = a[k,m]^T @ b[k,n]
pub fn matmul_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let brow = &b[p * n..p * n + n];
        for i in 0..m {
            let av = a[p * m + i];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// out[m,n] = a[m,k] @ b[n,k]^T
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..i * k + k];
        for j in 0..n {
            let brow = &b[j * k..j * k + k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Composite per-row layernorm (materialized mean/var, separate scale
/// application) — the `ln_fwd` math in `runtime/interp.rs`, y only.
pub fn layernorm(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * d];
    for r in 0..rows {
        let row = &x[r * d..r * d + d];
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        for c in 0..d {
            y[r * d + c] = (row[c] - mu) * rs * gamma[c] + beta[c];
        }
    }
    y
}

/// Two-pass (max, then exp/normalize) causal softmax attention over the
/// `[bh, s, dh]` per-head layout — the materialized-probabilities form
/// that `fused::causal_attention`'s online softmax is tested against.
pub fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bh: usize,
    s: usize,
    dh: usize,
) -> Vec<f32> {
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; bh * s * dh];
    let mut scores = vec![0.0f32; s];
    for b in 0..bh {
        let base = b * s * dh;
        for i in 0..s {
            let qrow = &q[base + i * dh..][..dh];
            let mut maxv = f32::NEG_INFINITY;
            for (j, score) in scores.iter_mut().enumerate().take(i + 1) {
                let krow = &k[base + j * dh..][..dh];
                let mut acc = 0.0f32;
                for c in 0..dh {
                    acc += qrow[c] * krow[c];
                }
                *score = acc * inv_sqrt;
                if *score > maxv {
                    maxv = *score;
                }
            }
            let mut denom = 0.0f32;
            for score in scores.iter_mut().take(i + 1) {
                *score = (*score - maxv).exp();
                denom += *score;
            }
            let orow = &mut out[base + i * dh..][..dh];
            for (j, score) in scores.iter().enumerate().take(i + 1) {
                let a = score / denom;
                let vrow = &v[base + j * dh..][..dh];
                for c in 0..dh {
                    orow[c] += a * vrow[c];
                }
            }
        }
    }
    out
}
