//! Rank-1-update row-kernel GEMMs, bit-identical to [`super::reference`].
//!
//! Layout matches the scalar reference: `matmul` is `a[m,k] @ b[k,n]`,
//! `matmul_at` is `a[k,m]^T @ b[k,n]`, `matmul_bt` is `a[m,k] @ b[n,k]^T`.
//! The transposed variants copy the transposed operand into the plain
//! `[m,k] @ [k,n]` layout first (an O(m·k) / O(n·k) copy against an
//! O(m·k·n) contraction) and then run the one row kernel; the products
//! and their summation order are exactly the reference's, so all three
//! are bitwise equal to their scalar counterparts — see the exactness
//! contract in the module docs of [`super`].
//!
//! The hot loop builds one output row at a time from [`KU`] unrolled
//! rank-1 updates per pass (`orow += a[i,p] * b[p,:]` for `KU`
//! consecutive `p`), so the inner loop is one long contiguous
//! multiply-add over the row — the shape every vectorizer handles
//! without SLP or accumulator-array register promotion, which is why it
//! beats both the naive nest and an `MR x NR` register tile on compilers
//! that scalarize small accumulator arrays. The `KU` partial products
//! per element are applied in ascending-`p` order with one rounded
//! mul+add each, so every element keeps the single accumulator chain of
//! the scalar reference (there is deliberately no `k`-blocking: splitting
//! `k` would split the chain and change rounding). The `simd` feature
//! swaps the portable block for a hand-vectorized one — AVX2 in
//! [`super::avx`] on x86-64, NEON in `super::neon` on AArch64 — each of
//! which rounds identically lane by lane.

use super::par_rows;

/// Rank-1 updates applied per row pass (the `p`-loop unroll depth).
/// Eight keeps the stream count inside every compiler's runtime-alias
/// check budget; deeper unrolls measured slower (the 16-stream variant
/// defeats vectorization entirely on GCC).
pub(crate) const KU: usize = 8;

/// `dst[j, i] = src[i, j]` for `src: [rows, cols]`.
fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut dst = vec![0.0f32; src.len()];
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = src[i * cols + j];
        }
    }
    dst
}

/// One unrolled pass: `orow[j] += sum_u av[u] * b[u][j]` with one rounded
/// mul+add per `u` in ascending order — the scalar reference chain.
#[inline]
fn rank1_block(orow: &mut [f32], av: &[f32; KU], b: &[&[f32]; KU]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::avx::usable() {
        // SAFETY: AVX2 presence is runtime-checked by `usable`, and
        // `gemm_rows` builds every `b[u]` with exactly `orow.len()`
        // elements.
        unsafe { super::avx::rank1_block_avx2(orow, av, b) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if super::neon::usable() {
        // SAFETY: NEON presence is runtime-checked by `usable`, and
        // `gemm_rows` builds every `b[u]` with exactly `orow.len()`
        // elements.
        unsafe { super::neon::rank1_block_neon(orow, av, b) };
        return;
    }
    let [b0, b1, b2, b3, b4, b5, b6, b7] = *b;
    let n = orow.len();
    let (b0, b1, b2, b3) = (&b0[..n], &b1[..n], &b2[..n], &b3[..n]);
    let (b4, b5, b6, b7) = (&b4[..n], &b5[..n], &b6[..n], &b7[..n]);
    for j in 0..n {
        let mut s = orow[j];
        s += av[0] * b0[j];
        s += av[1] * b1[j];
        s += av[2] * b2[j];
        s += av[3] * b3[j];
        s += av[4] * b4[j];
        s += av[5] * b5[j];
        s += av[6] * b6[j];
        s += av[7] * b7[j];
        orow[j] = s;
    }
}

/// Row kernel: `chunk[r - rows.start, :] = a[r, :] @ b` for `r` in `rows`,
/// where `a: [m, k]`, `b: [k, n]` and `chunk` holds exactly `rows`. Every
/// output element is one f32 accumulator over `p` ascending from `0.0` —
/// the reference chain.
fn gemm_rows(
    a: &[f32],
    b: &[f32],
    chunk: &mut [f32],
    rows: std::ops::Range<usize>,
    k: usize,
    n: usize,
) {
    for i in rows.clone() {
        let orow = &mut chunk[(i - rows.start) * n..][..n];
        orow.fill(0.0);
        let arow = &a[i * k..][..k];
        let mut p = 0;
        while p + KU <= k {
            let av: [f32; KU] = std::array::from_fn(|u| arow[p + u]);
            let brows: [&[f32]; KU] = std::array::from_fn(|u| &b[(p + u) * n..][..n]);
            rank1_block(orow, &av, &brows);
            p += KU;
        }
        // `k % KU` tail: plain rank-1 updates continue the same chains.
        while p < k {
            let av = arow[p];
            let brow = &b[p * n..][..n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
            p += 1;
        }
    }
}

/// out[m,n] = a[m,k] @ b[k,n]
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads, m * k * n, |rows, chunk| {
        gemm_rows(a, b, chunk, rows, k, n)
    });
    out
}

/// out[m,n] = a[k,m]^T @ b[k,n]
pub fn matmul_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, threads: usize) -> Vec<f32> {
    let at = transpose(a, k, m); // [m, k]
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads, m * k * n, |rows, chunk| {
        gemm_rows(&at, b, chunk, rows, k, n)
    });
    out
}

/// out[m,n] = a[m,k] @ b[n,k]^T
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let bt = transpose(b, n, k); // [k, n]
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads, m * k * n, |rows, chunk| {
        gemm_rows(a, &bt, chunk, rows, k, n)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect()
    }

    #[test]
    fn tiled_matmul_is_bitwise_reference() {
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (8, 16, 32), (17, 33, 19)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            assert_eq!(matmul(&a, &b, m, k, n, 1), reference::matmul(&a, &b, m, k, n));
        }
    }

    #[test]
    fn transposed_variants_are_bitwise_reference() {
        let mut rng = Rng::new(43);
        let (m, k, n) = (9, 21, 13);
        let a_t = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        assert_eq!(matmul_at(&a_t, &b, k, m, n, 1), reference::matmul_at(&a_t, &b, k, m, n));
        let a = randv(&mut rng, m * k);
        let b_t = randv(&mut rng, n * k);
        assert_eq!(matmul_bt(&a, &b_t, m, k, n, 1), reference::matmul_bt(&a, &b_t, m, k, n));
    }

    #[test]
    fn threading_is_bitwise_identical() {
        let mut rng = Rng::new(44);
        // Big enough to clear PAR_MIN_FLOPS so threads really spawn.
        let (m, k, n) = (65, 64, 64);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let one = matmul(&a, &b, m, k, n, 1);
        for threads in [2, 3, 8, 200] {
            assert_eq!(matmul(&a, &b, m, k, n, threads), one, "threads={threads}");
        }
    }
}
