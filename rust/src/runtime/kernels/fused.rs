//! Fused ops ported from the in-repo Pallas tiling specs.
//!
//! * [`layernorm`] / [`layernorm_bwd`] port
//!   `python/compile/kernels/layernorm.py`: one pass per row with
//!   mean/var/rstd recomputed in-kernel (nothing materialized between
//!   passes), backward via
//!   `dx = rstd * (dy*g - mean(dy*g) - xhat * mean(dy*g * xhat))`.
//! * [`causal_attention`] ports `python/compile/kernels/attention.py`:
//!   flash attention's online softmax with a running `(m, l, acc)` triple
//!   per query row — here the `block_q = block_k = 1` degenerate of the
//!   spec's blocked grid, which keeps the recurrence
//!   (`alpha = exp(m - m_new)`, `l = l*alpha + p`, `acc = acc*alpha + p*v`)
//!   but visits one key per step. Causal mask `q_pos >= k_pos`, scale
//!   `1/sqrt(dh)`, masked lanes start from the spec's `NEG_INF`.
//!
//! These reassociate the softmax/variance reductions relative to the
//! composite two-pass forms in [`super::reference`], so equivalence is
//! tolerance-based (see `rust/tests/prop_kernels.rs`), unlike the GEMMs
//! which are bitwise.

use super::par_rows;

/// The Pallas spec's mask value for not-yet-seen lanes (attention.py).
pub const NEG_INF: f32 = -1.0e30;

/// Fused layernorm forward: `y = (x - mean) * rstd * gamma + beta`, one
/// pass per row, nothing materialized but `y`.
pub fn layernorm(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
    threads: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * d];
    par_rows(&mut y, rows, d, threads, rows * d * 8, |span, chunk| {
        for r in span.clone() {
            let row = &x[r * d..r * d + d];
            let mut mu = 0.0f32;
            for &v in row {
                mu += v;
            }
            mu /= d as f32;
            let mut var = 0.0f32;
            for &v in row {
                let c = v - mu;
                var += c * c;
            }
            var /= d as f32;
            let rs = 1.0 / (var + eps).sqrt();
            let orow = &mut chunk[(r - span.start) * d..][..d];
            for c in 0..d {
                orow[c] = (row[c] - mu) * rs * gamma[c] + beta[c];
            }
        }
    });
    y
}

/// Fused layernorm backward (`_ln_bwd_kernel`): recomputes mean/var/xhat
/// from `x` per row, returns `(dx, dgamma, dbeta)`.
pub fn layernorm_bwd(
    x: &[f32],
    gamma: &[f32],
    dy: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    for r in 0..rows {
        let row = &x[r * d..r * d + d];
        let dyr = &dy[r * d..r * d + d];
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for c in 0..d {
            let xh = (row[c] - mu) * rs;
            let dyg = dyr[c] * gamma[c];
            m1 += dyg;
            m2 += dyg * xh;
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for c in 0..d {
            let xh = (row[c] - mu) * rs;
            dx[r * d + c] = rs * (dyr[c] * gamma[c] - m1 - xh * m2);
            dgamma[c] += dyr[c] * xh;
            dbeta[c] += dyr[c];
        }
    }
    (dx, dgamma, dbeta)
}

/// Online-softmax causal attention over the `[bh, s, dh]` per-head
/// layout: `out[b, i] = softmax(q_i . k_{j<=i} / sqrt(dh)) @ v`, computed
/// with the flash recurrence and never materializing the `[s, s]`
/// probability matrix. Threads partition the independent `bh` slabs.
pub fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bh: usize,
    s: usize,
    dh: usize,
    threads: usize,
) -> Vec<f32> {
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; bh * s * dh];
    let flops = bh * s * s * dh;
    par_rows(&mut out, bh, s * dh, threads, flops, |span, chunk| {
        let mut acc = vec![0.0f32; dh];
        for b in span.clone() {
            let base = b * s * dh;
            for i in 0..s {
                let qrow = &q[base + i * dh..][..dh];
                let mut m = NEG_INF;
                let mut l = 0.0f32;
                acc.iter_mut().for_each(|a| *a = 0.0);
                for j in 0..=i {
                    let krow = &k[base + j * dh..][..dh];
                    let mut sc = 0.0f32;
                    for c in 0..dh {
                        sc += qrow[c] * krow[c];
                    }
                    sc *= inv_sqrt;
                    let m_new = m.max(sc);
                    let p = (sc - m_new).exp();
                    let alpha = (m - m_new).exp();
                    l = l * alpha + p;
                    let vrow = &v[base + j * dh..][..dh];
                    for c in 0..dh {
                        acc[c] = acc[c] * alpha + p * vrow[c];
                    }
                    m = m_new;
                }
                let orow = &mut chunk[(b - span.start) * s * dh + i * dh..][..dh];
                for c in 0..dh {
                    orow[c] = acc[c] / l;
                }
            }
        }
    });
    out
}
