//! Hand-vectorized NEON rank-1 block behind the `simd` feature — the
//! aarch64 sibling of [`super::avx`].
//!
//! Same exactness contract as the AVX2 block: each update is a separate
//! `fmul` + `fadd` pair (never contracted to an FMA — `vfmaq_f32` would
//! skip the intermediate rounding and break bitwise equality with the
//! scalar reference), applied lanewise in the same ascending-`p` order,
//! so every output element's f32 accumulation chain is bit-for-bit the
//! scalar chain. NEON is baseline on AArch64 but still runtime-detected
//! — [`usable`] gates dispatch in `gemm::rank1_block` — to keep the
//! dispatch shape identical to the x86-64 path.

use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};

use super::gemm::KU;

/// True when the running CPU can execute [`rank1_block_neon`].
pub(crate) fn usable() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// `orow[j] += sum_u av[u] * b[u][j]` with one rounded mul+add per `u` in
/// ascending order — the scalar chain, four f32 lanes per instruction.
///
/// # Safety
///
/// The caller must ensure NEON is available (see [`usable`]) and that
/// every `b[u]` holds at least `orow.len()` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn rank1_block_neon(orow: &mut [f32], av: &[f32; KU], b: &[&[f32]; KU]) {
    let n = orow.len();
    debug_assert!(b.iter().all(|row| row.len() >= n));
    let va = [
        vdupq_n_f32(av[0]),
        vdupq_n_f32(av[1]),
        vdupq_n_f32(av[2]),
        vdupq_n_f32(av[3]),
        vdupq_n_f32(av[4]),
        vdupq_n_f32(av[5]),
        vdupq_n_f32(av[6]),
        vdupq_n_f32(av[7]),
    ];
    let op = orow.as_mut_ptr();
    let mut j = 0usize;
    while j + 4 <= n {
        let mut s = vld1q_f32(op.add(j));
        s = vaddq_f32(s, vmulq_f32(va[0], vld1q_f32(b[0].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[1], vld1q_f32(b[1].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[2], vld1q_f32(b[2].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[3], vld1q_f32(b[3].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[4], vld1q_f32(b[4].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[5], vld1q_f32(b[5].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[6], vld1q_f32(b[6].as_ptr().add(j))));
        s = vaddq_f32(s, vmulq_f32(va[7], vld1q_f32(b[7].as_ptr().add(j))));
        vst1q_f32(op.add(j), s);
        j += 4;
    }
    // `n % 4` tail: scalar, same per-element order.
    while j < n {
        let mut s = *op.add(j);
        for u in 0..KU {
            s += av[u] * b[u][j];
        }
        *op.add(j) = s;
        j += 1;
    }
}
