//! Workload generators: the paper's evaluation models as Appendix-C.6 logs
//! (`models`), plus direct runtime drivers for the formal-bounds experiments
//! (`linear` for Theorem 3.1 / Fig. 5, `adversarial` for Theorem 3.2).

pub mod adversarial;
pub mod linear;
pub mod models;
pub mod tape;

pub use models::{by_name, ALL_MODELS};
pub use tape::{R, Tape};
