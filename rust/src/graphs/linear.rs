//! Linear feedforward network driver — the Theorem 3.1 / Figure 5 / Appendix
//! A setting: N unit-cost unit-size operators, forward then backward
//! (`t̂_i = f̂_i(t_{i-1}, t̂_{i+1})`), with the banishing-based liveness of
//! Appendix A.2. Drives the runtime directly (not via a log) so the Fig. 5
//! harness can snapshot residency after every operator.

use anyhow::Result;

use crate::dtr::{Config, DeallocPolicy, Heuristic, NullBackend, OutSpec, Runtime, Stats, TensorId};

/// Residency snapshot value for the Fig. 5 matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cell {
    /// Evicted or banished (paper's black).
    Absent,
    /// Forward tensor resident (paper's red).
    Fwd,
    /// Gradient tensor resident (paper's white).
    Grad,
}

/// Result of a traced linear run.
pub struct LinearRun {
    pub stats: Stats,
    /// `trace[step][i]` = state of forward tensor `t_{i+1}` (and gradient
    /// overlay) after `step` operator executions. Empty unless traced.
    pub trace: Vec<Vec<Cell>>,
    /// Total operator executions (forward + backward + remats) — the
    /// Theorem 3.1 metric.
    pub total_ops: u64,
}

/// Execute forward+backward over an N-node chain under budget `b` (in unit
/// tensors) with heuristic `h`. `traced` records the Fig. 5 matrix.
///
/// Liveness follows Appendix A.2: `t_N` banished right after `t̂_N`'s
/// computation needs it no more, `t_{i-1}` after `t̂_i`, `t̂_{i+1}` after
/// `t̂_i`. We use the Banish policy so freed tensors are permanently
/// reclaimed exactly as in the proof.
pub fn run_linear(n: usize, budget: u64, h: Heuristic, traced: bool) -> Result<LinearRun> {
    let cfg = Config {
        budget,
        heuristic: h,
        policy: DeallocPolicy::Banish,
        ..Config::default()
    };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut trace: Vec<Vec<Cell>> = Vec::new();

    // t0: the input, pinned constant of unit size (paper: always resident,
    // not counted against the active budget — we count it, which only makes
    // our bound *harder* to meet).
    let t0 = rt.constant(1);

    let mut fwd: Vec<TensorId> = Vec::with_capacity(n + 1);
    fwd.push(t0);
    let mut grads: Vec<Option<TensorId>> = vec![None; n + 2];

    let snap = |rt: &Runtime<NullBackend>,
                    fwd: &Vec<TensorId>,
                    grads: &Vec<Option<TensorId>>,
                    trace: &mut Vec<Vec<Cell>>| {
        if !traced {
            return;
        }
        let mut row = Vec::with_capacity(n);
        for i in 1..=n {
            let cell = if i < fwd.len() && rt.is_defined(fwd[i]) {
                Cell::Fwd
            } else if grads[i].map_or(false, |g| rt.is_defined(g)) {
                Cell::Grad
            } else {
                Cell::Absent
            };
            row.push(cell);
        }
        trace.push(row);
    };

    // ---- forward: t_i = f_i(t_{i-1}) ----
    for i in 1..=n {
        let t = rt.call(&format!("f{i}"), 1, &[fwd[i - 1]], &[OutSpec::sized(1)])?[0];
        fwd.push(t);
        snap(&rt, &fwd, &grads, &mut trace);
    }

    // ---- backward ----
    // t̂_N = f̂_N(t_{N-1})
    let g = rt.call(&format!("b{n}"), 1, &[fwd[n - 1]], &[OutSpec::sized(1)])?[0];
    grads[n] = Some(g);
    // t_N dead (nothing consumes it in backward).
    rt.release(fwd[n]);
    snap(&rt, &fwd, &grads, &mut trace);

    for i in (1..n).rev() {
        // t̂_i = f̂_i(t_{i-1}, t̂_{i+1})
        let inputs = [fwd[i - 1], grads[i + 1].unwrap()];
        let g = rt.call(&format!("b{i}"), 1, &inputs, &[OutSpec::sized(1)])?[0];
        grads[i] = Some(g);
        // Liveness (Appendix A.2): t_i's last consumer was t̂_{i+1}; t̂_{i+1}
        // itself is dead once t̂_i exists (we only keep the final gradient).
        rt.release(fwd[i]);
        rt.release(grads[i + 1].unwrap());
        snap(&rt, &fwd, &grads, &mut trace);
    }

    let total_ops = rt.stats.remat_count + rt.stats.base_compute;
    Ok(LinearRun { stats: rt.stats.clone(), trace, total_ops })
}

/// The Appendix-A budget: `B = 2⌈√N⌉` unit tensors.
pub fn theorem_budget(n: usize) -> u64 {
    2 * (n as f64).sqrt().ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_pass_is_exactly_n_ops_unbudgeted() {
        let r = run_linear(64, u64::MAX, Heuristic::EStarCount, false).unwrap();
        // N forward + N backward ops, no remats.
        assert_eq!(r.stats.remat_count, 0);
        assert_eq!(r.stats.base_compute, 2 * 64);
    }

    #[test]
    fn theorem31_linear_overhead_constant_factor() {
        // With B = 2⌈√N⌉ and h_{e*}, total ops must be O(N): check the
        // constant stays bounded (paper's proof gives a small constant) and
        // does not grow with N.
        let mut factors = Vec::new();
        for n in [64usize, 256, 1024] {
            let r = run_linear(n, theorem_budget(n), Heuristic::EStarCount, false).unwrap();
            factors.push(r.total_ops as f64 / (2.0 * n as f64));
        }
        for (i, f) in factors.iter().enumerate() {
            assert!(*f < 4.0, "factor[{i}] = {f} too large for O(N) claim");
        }
        // Non-increasing-ish: the factor must not blow up with N.
        assert!(
            factors[2] <= factors[0] * 1.5 + 0.5,
            "overhead factor grows with N: {factors:?}"
        );
    }

    #[test]
    fn tiny_budget_fails_or_thrashes_gracefully() {
        // B = 3 is below any useful checkpoint spacing for N = 64 but the
        // chain itself is executable (2 live + 1 grad); it must either
        // complete with large overhead or OOM cleanly — not panic.
        match run_linear(64, 4, Heuristic::EStarCount, false) {
            Ok(r) => assert!(r.total_ops >= 2 * 64),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("out of memory"), "unexpected error: {msg}");
            }
        }
    }

    #[test]
    fn trace_dimensions() {
        let n = 32;
        let r = run_linear(n, theorem_budget(n), Heuristic::EStarCount, true).unwrap();
        // One snapshot per forward op + one per backward op.
        assert_eq!(r.trace.len(), 2 * n);
        assert!(r.trace.iter().all(|row| row.len() == n));
        // At the end everything is banished except the final gradient.
        let last = r.trace.last().unwrap();
        let grads = last.iter().filter(|c| **c == Cell::Grad).count();
        assert!(grads <= 2);
    }

    #[test]
    fn checkpoints_evenly_spaced_after_forward() {
        // Lemma A.1: at the end of the forward pass the gap between resident
        // tensors is bounded by 2(N-2)/(B-1).
        let n = 256;
        let b = theorem_budget(n);
        let r = run_linear(n, b, Heuristic::EStarCount, true).unwrap();
        let after_fwd = &r.trace[n - 1];
        let resident: Vec<usize> = after_fwd
            .iter()
            .enumerate()
            .filter(|(_, c)| **c == Cell::Fwd)
            .map(|(i, _)| i)
            .collect();
        let bound = 2 * (n - 2) / (b as usize - 1) + 1;
        let mut prev = 0usize;
        for &i in &resident {
            assert!(i - prev <= bound + 1, "gap {} exceeds Lemma A.1 bound {}", i - prev, bound);
            prev = i;
        }
    }
}
