//! Forward-graph builder that auto-derives a reverse-mode backward pass,
//! emitting a complete Appendix-C.6 training log (forward + loss + backward
//! with gradient accumulation at fan-out points, weight gradients held live,
//! and framework-faithful RELEASE events as values die).
//!
//! This synthesizes the PyTorch logs the paper's authors captured (see
//! DESIGN.md §5 Substitutions): DTR's behaviour depends only on the log's
//! structure — DAG shape, tensor sizes, operator costs, deallocation events —
//! which the tape reproduces from each model's architecture.

use super::super::sim::log::{Log, OutDecl};

/// Reference to a value in the tape: a forward node or a constant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum R {
    /// Forward activation (node index).
    N(usize),
    /// Constant (index into the constant table).
    C(usize),
}

#[derive(Debug, Clone)]
struct Node {
    label: String,
    cost: u64,
    size: u64,
    inputs: Vec<R>,
    /// Fraction-of-forward cost for this node's backward op (x1000).
    bwd_cost_permille: u64,
}

#[derive(Debug, Clone)]
struct Const {
    name: String,
    size: u64,
    /// Weights get gradients (held live at the end); data inputs do not.
    is_weight: bool,
}

/// Builder for a training-step log.
pub struct Tape {
    model: String,
    nodes: Vec<Node>,
    consts: Vec<Const>,
}

impl Tape {
    pub fn new(model: &str) -> Self {
        Tape { model: model.to_string(), nodes: Vec::new(), consts: Vec::new() }
    }

    /// Non-rematerializable model parameter (gets a gradient).
    pub fn weight(&mut self, name: &str, size: u64) -> R {
        self.consts.push(Const { name: name.to_string(), size, is_weight: true });
        R::C(self.consts.len() - 1)
    }

    /// Non-rematerializable data input (no gradient).
    pub fn data(&mut self, name: &str, size: u64) -> R {
        self.consts.push(Const { name: name.to_string(), size, is_weight: false });
        R::C(self.consts.len() - 1)
    }

    /// Forward operator producing one activation of `size` bytes.
    pub fn op(&mut self, label: &str, cost: u64, inputs: &[R], size: u64) -> R {
        self.op_full(label, cost, inputs, size, 2000)
    }

    /// Like [`Tape::op`] with an explicit backward/forward cost ratio in
    /// permille (backward ops are typically ~2x forward).
    pub fn op_full(
        &mut self,
        label: &str,
        cost: u64,
        inputs: &[R],
        size: u64,
        bwd_cost_permille: u64,
    ) -> R {
        debug_assert!(!inputs.is_empty());
        self.nodes.push(Node {
            label: label.to_string(),
            cost: cost.max(1),
            size: size.max(1),
            inputs: inputs.to_vec(),
            bwd_cost_permille,
        });
        R::N(self.nodes.len() - 1)
    }

    pub fn size_of(&self, r: R) -> u64 {
        match r {
            R::N(i) => self.nodes[i].size,
            R::C(i) => self.consts[i].size,
        }
    }

    fn fwd_name(&self, r: R) -> String {
        match r {
            R::N(i) => format!("a{i}"),
            R::C(i) => self.consts[i].name.clone(),
        }
    }

    /// Emit the full training log: forward in creation order, a gradient
    /// seed at `loss`, then the backward pass in reverse order.
    pub fn finish(self, loss: R) -> Log {
        let loss_idx = match loss {
            R::N(i) => i,
            R::C(_) => panic!("loss must be a computed node"),
        };
        let n = self.nodes.len();
        let mut log = Log::new(&self.model);

        // --- constants ---
        for c in &self.consts {
            log.constant(&c.name, c.size);
        }

        // --- forward ---
        for (i, node) in self.nodes.iter().enumerate() {
            let inputs: Vec<String> = node.inputs.iter().map(|&r| self.fwd_name(r)).collect();
            let input_refs: Vec<&str> = inputs.iter().map(|s| s.as_str()).collect();
            log.call1(&node.label, node.cost, &input_refs, &format!("a{i}"), node.size);
        }

        // --- backward bookkeeping ---
        // consumers[j] = forward nodes consuming node j.
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for &r in &node.inputs {
                if let R::N(j) = r {
                    consumers[j].push(i);
                }
            }
        }
        // Does node i (transitively) feed the loss? Dead branches get no
        // gradient and their activations are released right after forward.
        let mut feeds_loss = vec![false; n];
        feeds_loss[loss_idx] = true;
        for i in (0..n).rev() {
            if consumers[i].iter().any(|&c| feeds_loss[c]) {
                feeds_loss[i] = true;
            }
        }

        // partials[j] = names of partial gradients accumulated for node j.
        let mut partials: Vec<Vec<String>> = vec![Vec::new(); n];
        // How many backward ops still need activation a_j as input.
        let mut bwd_uses: Vec<usize> = vec![0; n];
        for (i, node) in self.nodes.iter().enumerate() {
            if !feeds_loss[i] {
                continue;
            }
            for &r in &node.inputs {
                if let R::N(j) = r {
                    bwd_uses[j] += 1;
                }
            }
        }

        // Activations that never appear in any backward op can be released
        // as soon as their consumers' forwards are done; to keep the log
        // simple we release them immediately before backward starts (except
        // the loss itself, which stays live per the output condition).
        for i in 0..n {
            if (bwd_uses[i] == 0 && i != loss_idx && consumers[i].is_empty() && !feeds_loss[i])
                || (!feeds_loss[i] && bwd_uses[i] == 0 && i != loss_idx)
            {
                log.release(&format!("a{i}"));
            }
        }

        // --- gradient seed ---
        let seed = "dL".to_string();
        log.call1("grad_seed", 1, &[&format!("a{loss_idx}")], &seed, self.nodes[loss_idx].size);
        partials[loss_idx].push(seed);

        // --- backward, reverse order ---
        let mut grad_counter = 0usize;
        for i in (0..n).rev() {
            if !feeds_loss[i] || partials[i].is_empty() {
                continue;
            }
            let node = &self.nodes[i];

            // Accumulate fan-out partials into one gradient.
            let grad = if partials[i].len() == 1 {
                partials[i][0].clone()
            } else {
                let acc = format!("g{i}_acc");
                let refs: Vec<&str> = partials[i].iter().map(|s| s.as_str()).collect();
                log.call1(
                    &format!("grad_add_{i}"),
                    (node.size / 4).max(1),
                    &refs,
                    &acc,
                    node.size,
                );
                for p in &partials[i] {
                    log.release(p);
                }
                acc
            };

            // Backward op: inputs are the output gradient plus the forward
            // op's inputs; outputs are one gradient per differentiable input.
            let mut in_names = vec![grad.clone()];
            in_names.extend(node.inputs.iter().map(|&r| self.fwd_name(r)));
            let mut outs = Vec::new();
            let mut targets: Vec<Option<usize>> = Vec::new();
            for &r in &node.inputs {
                match r {
                    R::N(j) => {
                        let g = format!("g{}_{}", j, grad_counter);
                        grad_counter += 1;
                        outs.push(OutDecl::sized(&g, self.nodes[j].size));
                        targets.push(Some(j));
                    }
                    R::C(k) if self.consts[k].is_weight => {
                        outs.push(OutDecl::sized(
                            &format!("gw_{}_{}", self.consts[k].name, grad_counter),
                            self.consts[k].size,
                        ));
                        grad_counter += 1;
                        targets.push(None);
                    }
                    R::C(_) => {}
                }
            }
            if outs.is_empty() {
                // Leaf backward with nothing to produce: emit a tiny sink
                // gradient so the op is still recorded.
                outs.push(OutDecl::sized(&format!("gsink_{i}"), 8));
                targets.push(None);
            }
            let bwd_cost = (node.cost * node.bwd_cost_permille / 1000).max(1);
            let in_refs: Vec<&str> = in_names.iter().map(|s| s.as_str()).collect();
            log.call(&format!("{}_bwd", node.label), bwd_cost, &in_refs, outs.clone());

            // Register partial gradients with their target nodes.
            for (o, tgt) in outs.iter().zip(targets) {
                if let Some(j) = tgt {
                    partials[j].push(o.name.clone());
                }
            }

            // This node's own gradient is now fully consumed.
            if i != loss_idx || !partials[i].iter().any(|p| p == "dL") {
                log.release(&grad);
            } else {
                log.release(&grad); // dL released too; loss value itself stays
            }

            // Decrement backward-use counts of this op's activation inputs;
            // release those now dead (mirrors autograd freeing saved tensors).
            for &r in &node.inputs {
                if let R::N(j) = r {
                    bwd_uses[j] -= 1;
                    if bwd_uses[j] == 0 && j != loss_idx {
                        log.release(&format!("a{j}"));
                    }
                }
            }
        }

        // Release the loss activation's gradient chain end: the loss value
        // and weight gradients remain live (output condition).
        log
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::{Config, Heuristic};
    use crate::sim::replay::{baseline, simulate};

    fn mlp(depth: usize) -> Log {
        let mut t = Tape::new("mlp");
        let x = t.data("x", 1024);
        let mut h = x;
        for i in 0..depth {
            let w = t.weight(&format!("w{i}"), 256);
            h = t.op(&format!("fc{i}"), 100, &[h, w], 1024);
        }
        let loss = t.op("loss", 10, &[h], 8);
        t.finish(loss)
    }

    #[test]
    fn mlp_log_replays_unbudgeted() {
        let log = mlp(6);
        let b = baseline(&log);
        assert!(b.total_compute > 600);
        let out = simulate(&log, Config::default());
        assert!(out.ok(), "{:?}", out.failed);
    }

    #[test]
    fn mlp_log_replays_under_budget_all_heuristics() {
        let log = mlp(12);
        let b = baseline(&log);
        let budget = b.constant_bytes + (b.peak_memory - b.constant_bytes) / 2;
        for h in Heuristic::fig2_set() {
            let out = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
            assert!(out.ok(), "{}: {:?}", h.name(), out.failed);
        }
    }

    #[test]
    fn fanout_accumulates_gradients() {
        // Diamond: x -> a -> (b, c) -> d; a's gradient must be accumulated.
        let mut t = Tape::new("diamond");
        let x = t.data("x", 64);
        let w = t.weight("w", 64);
        let a = t.op("a", 10, &[x, w], 64);
        let b = t.op("b", 10, &[a], 64);
        let c = t.op("c", 10, &[a], 64);
        let d = t.op("d", 10, &[b, c], 64);
        let log = t.finish(d);
        let text = log.to_jsonl();
        assert!(text.contains("grad_add"), "fan-out must emit accumulation:\n{text}");
        let out = simulate(&log, Config::default());
        assert!(out.ok(), "{:?}", out.failed);
    }

    #[test]
    fn weight_gradients_stay_live() {
        let log = mlp(4);
        // No RELEASE of any gw_* identifier.
        for ins in &log.instrs {
            if let crate::sim::log::Instr::Release { t } = ins {
                assert!(!t.starts_with("gw_"), "weight gradient {t} was released");
            }
        }
    }

    #[test]
    fn activations_released_after_backward() {
        let log = mlp(4);
        let text = log.to_jsonl();
        // Every intermediate activation a_i (not the loss) must be released.
        let n_act_releases = log
            .instrs
            .iter()
            .filter(|i| matches!(i, crate::sim::log::Instr::Release { t } if t.starts_with('a')))
            .count();
        assert!(n_act_releases >= 4, "expected activation releases, log:\n{text}");
    }

    #[test]
    fn tight_budget_forces_remat_and_succeeds() {
        let log = mlp(16);
        let b = baseline(&log);
        let budget = b.budget_at(0.35);
        let out = simulate(
            &log,
            Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        assert!(out.ok(), "{:?}", out.failed);
        assert!(out.stats.remat_count > 0);
        assert!(out.stats.slowdown() < 3.0, "slowdown {}", out.stats.slowdown());
    }
}
