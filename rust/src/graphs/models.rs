//! Workload generators for the paper's evaluation models (Sec. 4.1): five
//! static architectures (MLP, ResNet, DenseNet, UNet, Transformer) and three
//! dynamic ones (LSTM, TreeLSTM, Unrolled GAN). Each produces a complete
//! single-batch training log via [`Tape`] with FLOP-derived operator costs
//! and f32 tensor sizes, reproducing the structural properties that drive
//! DTR's eviction behaviour: skip connections (ResNet/UNet), dense fan-out
//! (DenseNet), recurrence with shared weights (LSTM), tree-shaped dynamism
//! (TreeLSTM), and differentiable unrolling (Unrolled GAN).

use super::tape::{R, Tape};
use crate::sim::log::Log;

const F32: u64 = 4;
/// Cost unit: ~MFLOPs, floored at 1.
fn mf(flops: u64) -> u64 {
    (flops / 1_000_000).max(1)
}

/// Fully-connected feedforward chain (the Theorem 3.1 shape at DL scale).
pub fn mlp(depth: usize, width: u64, batch: u64) -> Log {
    let mut t = Tape::new("mlp");
    let x = t.data("x", batch * width * F32);
    let mut h = x;
    for i in 0..depth {
        let w = t.weight(&format!("w{i}"), width * width * F32);
        let lin = t.op(&format!("fc{i}"), mf(2 * batch * width * width), &[h, w], batch * width * F32);
        h = t.op(&format!("relu{i}"), mf(batch * width), &[lin], batch * width * F32);
    }
    let loss = t.op("loss", mf(batch * width), &[h], 8);
    t.finish(loss)
}

/// Conv-block helper: conv + bn + relu (sizes for square feature maps).
fn conv_block(t: &mut Tape, tag: &str, input: R, cin: u64, cout: u64, hw: u64, batch: u64) -> R {
    let w = t.weight(&format!("w_{tag}"), cout * cin * 9 * F32);
    let act = batch * cout * hw * hw * F32;
    let flops = 2 * batch * cout * cin * 9 * hw * hw;
    let conv = t.op(&format!("conv_{tag}"), mf(flops), &[input, w], act);
    let g = t.weight(&format!("bn_{tag}"), cout * 2 * F32);
    let bn = t.op(&format!("bn_{tag}"), mf(batch * cout * hw * hw), &[conv, g], act);
    t.op(&format!("relu_{tag}"), mf(batch * cout * hw * hw), &[bn], act)
}

/// ResNet: stages of residual blocks with skip connections (the structure
/// Chen et al.'s segmenting had to be modified to handle; Fig. 3 note).
pub fn resnet(blocks_per_stage: usize, batch: u64) -> Log {
    let mut t = Tape::new("resnet");
    let mut hw = 32u64;
    let mut c = 16u64;
    let x = t.data("x", batch * 3 * hw * hw * F32);
    let mut h = conv_block(&mut t, "stem", x, 3, c, hw, batch);
    for stage in 0..3 {
        for b in 0..blocks_per_stage {
            let tag1 = format!("s{stage}b{b}c1");
            let tag2 = format!("s{stage}b{b}c2");
            let y1 = conv_block(&mut t, &tag1, h, c, c, hw, batch);
            let y2 = conv_block(&mut t, &tag2, y1, c, c, hw, batch);
            // Residual add: fan-out on h (used by both conv path and skip).
            h = t.op(
                &format!("add_s{stage}b{b}"),
                mf(batch * c * hw * hw),
                &[y2, h],
                batch * c * hw * hw * F32,
            );
        }
        if stage < 2 {
            // Downsample: stride-2 conv, double channels.
            let tag = format!("down{stage}");
            hw /= 2;
            c *= 2;
            h = conv_block(&mut t, &tag, h, c / 2, c, hw, batch);
        }
    }
    let pool = t.op("avgpool", mf(batch * c * hw * hw), &[h], batch * c * F32);
    let wfc = t.weight("w_fc", c * 10 * F32);
    let logits = t.op("fc", mf(2 * batch * c * 10), &[pool, wfc], batch * 10 * F32);
    let loss = t.op("loss", mf(batch * 10), &[logits], 8);
    t.finish(loss)
}

/// DenseNet: each layer consumes the concatenation of *all* previous feature
/// maps — maximal fan-out, the hardest case for neighborhood metadata.
pub fn densenet(layers: usize, growth: u64, batch: u64) -> Log {
    let mut t = Tape::new("densenet");
    let hw = 16u64;
    let x = t.data("x", batch * 3 * hw * hw * F32);
    let stem = conv_block(&mut t, "stem", x, 3, growth, hw, batch);
    let mut feats: Vec<(R, u64)> = vec![(stem, growth)];
    for l in 0..layers {
        let cin: u64 = feats.iter().map(|(_, c)| c).sum();
        let inputs: Vec<R> = feats.iter().map(|&(r, _)| r).collect();
        let cat = t.op(
            &format!("concat{l}"),
            mf(batch * cin * hw * hw),
            &inputs,
            batch * cin * hw * hw * F32,
        );
        let out = conv_block(&mut t, &format!("dense{l}"), cat, cin, growth, hw, batch);
        feats.push((out, growth));
    }
    let cin: u64 = feats.iter().map(|(_, c)| c).sum();
    let inputs: Vec<R> = feats.iter().map(|&(r, _)| r).collect();
    let cat = t.op("final_concat", mf(batch * cin * hw * hw), &inputs, batch * cin * hw * hw * F32);
    let pool = t.op("avgpool", mf(batch * cin * hw * hw), &[cat], batch * cin * F32);
    let w = t.weight("w_fc", cin * 10 * F32);
    let logits = t.op("fc", mf(2 * batch * cin * 10), &[pool, w], batch * 10 * F32);
    let loss = t.op("loss", mf(batch * 10), &[logits], 8);
    t.finish(loss)
}

/// UNet: encoder/decoder with long-range skip concatenations — the paper's
/// hardest static model (lowest feasible budgets, Fig. 2/Table 1).
pub fn unet(depth: usize, base_c: u64, batch: u64) -> Log {
    let mut t = Tape::new("unet");
    let mut hw = 64u64;
    let x = t.data("x", batch * 3 * hw * hw * F32);
    let mut h = conv_block(&mut t, "stem", x, 3, base_c, hw, batch);
    let mut c = base_c;
    let mut skips: Vec<(R, u64, u64)> = Vec::new();
    for d in 0..depth {
        let h2 = conv_block(&mut t, &format!("enc{d}"), h, c, c, hw, batch);
        skips.push((h2, c, hw));
        // Downsample.
        hw /= 2;
        let c2 = c * 2;
        h = conv_block(&mut t, &format!("down{d}"), h2, c, c2, hw, batch);
        c = c2;
    }
    h = conv_block(&mut t, "bottleneck", h, c, c, hw, batch);
    for d in (0..depth).rev() {
        let (skip, sc, shw) = skips[d];
        hw = shw;
        // Upsample + concat with the long-range encoder skip.
        let up = t.op(
            &format!("up{d}"),
            mf(batch * c * hw * hw),
            &[h],
            batch * (c / 2) * hw * hw * F32,
        );
        let cat = t.op(
            &format!("skipcat{d}"),
            mf(batch * (c / 2 + sc) * hw * hw),
            &[up, skip],
            batch * (c / 2 + sc) * hw * hw * F32,
        );
        h = conv_block(&mut t, &format!("dec{d}"), cat, c / 2 + sc, sc, hw, batch);
        c = sc;
    }
    let w = t.weight("w_out", c * 2 * 9 * F32);
    let out = t.op("head", mf(2 * batch * c * 2 * 9 * hw * hw), &[h, w], batch * 2 * hw * hw * F32);
    let loss = t.op("loss", mf(batch * hw * hw), &[out], 8);
    t.finish(loss)
}

/// Transformer encoder stack (the Table-1 model, seq-len driven).
pub fn transformer(layers: usize, seq: u64, d_model: u64, batch: u64) -> Log {
    let mut t = Tape::new("transformer");
    let act = batch * seq * d_model * F32;
    let d_ff = d_model * 4;
    let x = t.data("x", act);
    let mut h = x;
    for l in 0..layers {
        // Self-attention.
        let ln1g = t.weight(&format!("ln1_{l}"), d_model * 2 * F32);
        let ln1 = t.op(&format!("ln1_{l}"), mf(batch * seq * d_model), &[h, ln1g], act);
        let wqkv = t.weight(&format!("wqkv{l}"), d_model * 3 * d_model * F32);
        let qkv = t.op(
            &format!("qkv{l}"),
            mf(2 * batch * seq * d_model * 3 * d_model),
            &[ln1, wqkv],
            3 * act,
        );
        let scores = t.op(
            &format!("scores{l}"),
            mf(2 * batch * seq * seq * d_model),
            &[qkv],
            batch * seq * seq * F32,
        );
        let probs = t.op(
            &format!("softmax{l}"),
            mf(batch * seq * seq),
            &[scores],
            batch * seq * seq * F32,
        );
        let attn = t.op(
            &format!("attnv{l}"),
            mf(2 * batch * seq * seq * d_model),
            &[probs, qkv],
            act,
        );
        let wo = t.weight(&format!("wo{l}"), d_model * d_model * F32);
        let proj = t.op(&format!("proj{l}"), mf(2 * batch * seq * d_model * d_model), &[attn, wo], act);
        let res1 = t.op(&format!("res1_{l}"), mf(batch * seq * d_model), &[proj, h], act);
        // MLP.
        let ln2g = t.weight(&format!("ln2_{l}"), d_model * 2 * F32);
        let ln2 = t.op(&format!("ln2_{l}"), mf(batch * seq * d_model), &[res1, ln2g], act);
        let w1 = t.weight(&format!("wff1_{l}"), d_model * d_ff * F32);
        let ff1 = t.op(
            &format!("ff1_{l}"),
            mf(2 * batch * seq * d_model * d_ff),
            &[ln2, w1],
            batch * seq * d_ff * F32,
        );
        let gelu = t.op(&format!("gelu{l}"), mf(batch * seq * d_ff), &[ff1], batch * seq * d_ff * F32);
        let w2 = t.weight(&format!("wff2_{l}"), d_ff * d_model * F32);
        let ff2 = t.op(&format!("ff2_{l}"), mf(2 * batch * seq * d_ff * d_model), &[gelu, w2], act);
        h = t.op(&format!("res2_{l}"), mf(batch * seq * d_model), &[ff2, res1], act);
    }
    let loss = t.op("loss", mf(batch * seq * d_model), &[h], 8);
    t.finish(loss)
}

/// LSTM unrolled over `steps` timesteps with shared weights (dynamic model
/// #1: the trace length depends on the input sequence).
pub fn lstm(steps: usize, hidden: u64, batch: u64) -> Log {
    let mut t = Tape::new("lstm");
    let act = batch * hidden * F32;
    let wx = t.weight("wx", hidden * 4 * hidden * F32);
    let wh = t.weight("wh", hidden * 4 * hidden * F32);
    let mut h = t.data("h0", act);
    let mut c = t.data("c0", act);
    for s in 0..steps {
        let x = t.data(&format!("x{s}"), act);
        let gx = t.op(&format!("gx{s}"), mf(2 * batch * hidden * 4 * hidden), &[x, wx], 4 * act);
        let gh = t.op(&format!("gh{s}"), mf(2 * batch * hidden * 4 * hidden), &[h, wh], 4 * act);
        let gates = t.op(&format!("gates{s}"), mf(4 * batch * hidden), &[gx, gh], 4 * act);
        c = t.op(&format!("cell{s}"), mf(4 * batch * hidden), &[gates, c], act);
        h = t.op(&format!("hid{s}"), mf(2 * batch * hidden), &[gates, c], act);
    }
    let loss = t.op("loss", mf(batch * hidden), &[h], 8);
    t.finish(loss)
}

/// TreeLSTM over a complete binary tree with `leaves` leaves (dynamic model
/// #2: tree-shaped, data-dependent control flow — Table 1's 2^k - 1 nodes).
pub fn treelstm(leaves: usize, hidden: u64, batch: u64) -> Log {
    assert!(leaves.is_power_of_two(), "complete binary tree");
    let mut t = Tape::new("treelstm");
    let act = batch * hidden * F32;
    let wl = t.weight("wl", hidden * hidden * F32);
    let wr = t.weight("wr", hidden * hidden * F32);
    let wc = t.weight("wc", hidden * hidden * F32);
    let mut level: Vec<R> = (0..leaves)
        .map(|i| {
            let x = t.data(&format!("leaf{i}"), act);
            t.op(&format!("embed{i}"), mf(2 * batch * hidden * hidden), &[x, wc], act)
        })
        .collect();
    let mut d = 0usize;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len() / 2);
        for (i, pair) in level.chunks(2).enumerate() {
            let gl = t.op(
                &format!("gl_{d}_{i}"),
                mf(2 * batch * hidden * hidden),
                &[pair[0], wl],
                act,
            );
            let gr = t.op(
                &format!("gr_{d}_{i}"),
                mf(2 * batch * hidden * hidden),
                &[pair[1], wr],
                act,
            );
            let comb = t.op(&format!("comb_{d}_{i}"), mf(4 * batch * hidden), &[gl, gr], act);
            next.push(comb);
        }
        level = next;
        d += 1;
    }
    let loss = t.op("loss", mf(batch * hidden), &[level[0]], 8);
    t.finish(loss)
}

/// Unrolled GAN: the generator is optimized through `unroll` differentiable
/// steps of discriminator updates (dynamic model #3: higher-order structure;
/// the surrogate discriminator parameters are *computed* tensors that every
/// later step depends on).
pub fn unrolled_gan(unroll: usize, width: u64, batch: u64) -> Log {
    let mut t = Tape::new("unrolled_gan");
    let act = batch * width * F32;
    let param = width * width * F32;
    // Generator forward.
    let z = t.data("z", act);
    let wg1 = t.weight("wg1", param);
    let wg2 = t.weight("wg2", param);
    let g1 = t.op("g1", mf(2 * batch * width * width), &[z, wg1], act);
    let g1r = t.op("g1_relu", mf(batch * width), &[g1], act);
    let fake = t.op("g2", mf(2 * batch * width * width), &[g1r, wg2], act);
    // Initial discriminator params (constants) become computed surrogates.
    let wd0 = t.weight("wd0", param);
    let real = t.data("real", act);
    let mut wd: R = wd0;
    for k in 0..unroll {
        // Discriminator forward on real and fake with current surrogate.
        let dr = t.op(&format!("d_real{k}"), mf(2 * batch * width * width), &[real, wd], act);
        let df = t.op(&format!("d_fake{k}"), mf(2 * batch * width * width), &[fake, wd], act);
        let dl = t.op(&format!("d_loss{k}"), mf(batch * width), &[dr, df], act);
        // Differentiable inner update: wd' = wd - lr * dgrad(dl, wd).
        let grad = t.op(
            &format!("d_grad{k}"),
            mf(4 * batch * width * width),
            &[dl, wd],
            param,
        );
        wd = t.op(&format!("d_step{k}"), mf(width * width), &[grad, wd], param);
    }
    // Generator loss through the unrolled discriminator.
    let dfinal = t.op("d_final", mf(2 * batch * width * width), &[fake, wd], act);
    let loss = t.op("g_loss", mf(batch * width), &[dfinal], 8);
    t.finish(loss)
}

/// Named model registry: the Fig. 2 / Fig. 4 suite at paper-like default
/// scales (kept modest so full heuristic sweeps stay fast; harnesses accept
/// `--scale` to grow them).
pub fn by_name(name: &str, scale: u64) -> Option<Log> {
    // Activation memory must dominate weights (as in the paper's batched
    // training workloads) or no budget below the weight+grad floor exists.
    let s = scale.max(1);
    Some(match name {
        "mlp" => mlp(24, 128, 512 * s),
        "resnet" => resnet(6, 8 * s),
        "densenet" => densenet(16, 16, 8 * s),
        "unet" => unet(4, 8, 2 * s),
        "transformer" => transformer(4, 128, 64, 16 * s),
        "lstm" => lstm(32, 64, 64 * s),
        "treelstm" => treelstm(64, 64, 64 * s),
        "unrolled_gan" => unrolled_gan(8, 64, 64 * s),
        _ => return None,
    })
}

pub const ALL_MODELS: [&str; 8] = [
    "mlp",
    "resnet",
    "densenet",
    "unet",
    "transformer",
    "lstm",
    "treelstm",
    "unrolled_gan",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::{Config, Heuristic};
    use crate::sim::replay::{baseline, simulate};

    #[test]
    fn all_models_generate_and_replay() {
        for name in ALL_MODELS {
            let log = by_name(name, 1).unwrap();
            assert!(!log.instrs.is_empty(), "{name} empty");
            let out = simulate(&log, Config::default());
            assert!(out.ok(), "{name}: {:?}", out.failed);
        }
    }

    #[test]
    fn all_models_replay_at_60pct_budget() {
        for name in ALL_MODELS {
            let log = by_name(name, 1).unwrap();
            let b = baseline(&log);
            let budget = b.budget_at(0.6);
            assert!(budget < b.peak_memory, "{name}: no headroom to exercise");
            let out = simulate(
                &log,
                Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() },
            );
            assert!(out.ok(), "{name} @0.6: {:?}", out.failed);
            assert!(out.stats.slowdown() < 2.0, "{name} thrashed: {}", out.stats.slowdown());
        }
    }

    #[test]
    fn structural_signatures() {
        // DenseNet logs must contain wide-fanin concats; UNet long skips;
        // ResNet residual adds; GAN surrogate steps.
        let dense = by_name("densenet", 1).unwrap().to_jsonl();
        assert!(dense.contains("final_concat"));
        let unet = by_name("unet", 1).unwrap().to_jsonl();
        assert!(unet.contains("skipcat"));
        let resnet = by_name("resnet", 1).unwrap().to_jsonl();
        assert!(resnet.contains("add_s"));
        let gan = by_name("unrolled_gan", 1).unwrap().to_jsonl();
        assert!(gan.contains("d_step"));
    }

    #[test]
    fn model_sizes_reasonable() {
        for name in ALL_MODELS {
            let log = by_name(name, 1).unwrap();
            let b = baseline(&log);
            assert!(
                b.calls >= 40,
                "{name} too small: {} calls (want a real model-sized log)",
                b.calls
            );
            assert!(b.peak_memory > 2 * b.constant_bytes / 2, "{name} trivial");
        }
    }

    #[test]
    fn treelstm_requires_power_of_two() {
        let log = treelstm(32, 16, 2);
        assert!(log.instrs.len() > 100);
    }

    #[test]
    fn lstm_scales_with_steps() {
        let a = lstm(8, 32, 4);
        let b = lstm(16, 32, 4);
        assert!(b.instrs.len() > a.instrs.len());
    }
}
