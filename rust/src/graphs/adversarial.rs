//! The Theorem 3.2 adversary: an architecture generator that watches DTR's
//! eviction decisions and always extends the network at the end of a fully
//! evicted path, forcing Ω(N²/B) total work where a static planner needs
//! only Θ(N) (Appendix B, Figure 6).
//!
//! The generator builds `B` linear paths hanging off a common root `t_0`.
//! After DTR's budget forces evictions, some path has no resident tensors;
//! the adversary appends the next node to (the end of) such a path, making
//! DTR rematerialize the whole path first.

use anyhow::Result;

use crate::dtr::{Config, Heuristic, NullBackend, OutSpec, Runtime, Stats, TensorId};

pub struct AdversaryRun {
    pub stats: Stats,
    /// Total tensor operations performed by DTR.
    pub dtr_ops: u64,
    /// Operations a path-at-a-time static schedule needs (= N).
    pub static_ops: u64,
    pub n: usize,
    pub b: usize,
}

impl AdversaryRun {
    /// The Theorem 3.2 overhead ratio.
    pub fn ratio(&self) -> f64 {
        self.dtr_ops as f64 / self.static_ops as f64
    }
}

/// Run the adversary for `n` total nodes against budget `b` (unit tensors)
/// under heuristic `h`.
pub fn run_adversary(n: usize, b: usize, h: Heuristic) -> Result<AdversaryRun> {
    assert!(b >= 2 && n > b);
    let cfg = Config { budget: b as u64 + 1, heuristic: h, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());

    // Root t0 (pinned constant, gets the +1 in the budget).
    let t0 = rt.constant(1);

    // paths[j] = tensors of path j, in order.
    let mut paths: Vec<Vec<TensorId>> = Vec::with_capacity(b);
    for j in 0..b {
        let t = rt.call(&format!("p{j}_0"), 1, &[t0], &[OutSpec::sized(1)])?[0];
        paths.push(vec![t]);
    }

    let mut created = b;
    while created < n {
        // Find a path whose tensors are all evicted; prefer the longest such
        // path (worst case for DTR). Falls back to the path with the fewest
        // resident tensors if none is fully evicted.
        let mut target: Option<usize> = None;
        let mut best_len = 0usize;
        for (j, path) in paths.iter().enumerate() {
            if path.iter().all(|&t| !rt.is_defined(t)) && path.len() >= best_len {
                target = Some(j);
                best_len = path.len();
            }
        }
        let j = match target {
            Some(j) => j,
            None => {
                // No fully evicted path: pick the one with the most evicted
                // suffix (still forces maximal rematerialization).
                (0..paths.len())
                    .max_by_key(|&j| {
                        paths[j].iter().rev().take_while(|&&t| !rt.is_defined(t)).count()
                    })
                    .unwrap()
            }
        };
        let tail = *paths[j].last().unwrap();
        let t = rt.call(
            &format!("p{j}_{}", paths[j].len()),
            1,
            &[tail],
            &[OutSpec::sized(1)],
        )?[0];
        paths[j].push(t);
        created += 1;
    }

    let dtr_ops = rt.stats.base_compute + rt.stats.remat_compute;
    Ok(AdversaryRun {
        stats: rt.stats.clone(),
        dtr_ops,
        // The optimal static planner reorders the graph one path at a time:
        // exactly one computation per node (Appendix B).
        static_ops: n as u64,
        n,
        b,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversary_forces_superlinear_work() {
        let r = run_adversary(256, 8, Heuristic::dtr_eq()).unwrap();
        // Ω(N/B) = 32x in the worst case; demand well above constant factor.
        assert!(r.ratio() > 3.0, "ratio {} not adversarial", r.ratio());
        assert_eq!(r.static_ops, 256);
    }

    #[test]
    fn adversary_hits_every_deterministic_heuristic() {
        for h in [
            Heuristic::dtr(),
            Heuristic::dtr_eq(),
            Heuristic::dtr_local(),
            Heuristic::lru(),
            Heuristic::size(),
            Heuristic::Msps,
        ] {
            let r = run_adversary(128, 8, h).unwrap();
            assert!(r.ratio() > 2.0, "{}: ratio {}", h.name(), r.ratio());
        }
    }

    #[test]
    fn ratio_grows_with_n_over_b() {
        let small = run_adversary(128, 16, Heuristic::lru()).unwrap();
        let large = run_adversary(512, 16, Heuristic::lru()).unwrap();
        assert!(
            large.ratio() > small.ratio(),
            "Ω(N/B): {} vs {}",
            large.ratio(),
            small.ratio()
        );
    }

    #[test]
    fn larger_budget_reduces_ratio() {
        let tight = run_adversary(256, 4, Heuristic::lru()).unwrap();
        let loose = run_adversary(256, 64, Heuristic::lru()).unwrap();
        assert!(loose.ratio() < tight.ratio());
    }
}
