//! The eviction-heuristic family (Sec. 4.1 + Appendix C.3/D.1).
//!
//! Every heuristic is a score over resident storages; DTR evicts the
//! minimum-scoring evictable storage. All of the paper's heuristics are
//! expressible in the parameterized form `h'(s,m,c)(S) = c(S)/[m(S)·s(S)]`
//! with each factor optionally ablated:
//!
//! * `h_DTR`       = Param { cost: EStar,   size: on,  staleness: on  }
//! * `h_DTR^eq`    = Param { cost: EqClass, size: on,  staleness: on  }
//! * `h_DTR^local` = Param { cost: Local,   size: on,  staleness: on  }
//! * `h_LRU`       = Param { cost: None,    size: off, staleness: on  }
//! * `h_size`      = Param { cost: None,    size: on,  staleness: off }
//! * `h_MSPS`      = MSPS (cost over the evicted *remat set*, size only)
//! * `h_rand`      = Random
//! * `h_{e*}`      = EStarCount (Appendix A: |e*(S)|, used in Theorem 3.1)

use super::evicted::{estar_cost, remat_set_cost, EvictedScratch};
use super::graph::Graph;
use super::ids::StorageId;
use super::unionfind::UnionFind;
use crate::util::rng::Rng;

/// Which compute-cost measure feeds the numerator (Appendix D.1's `c`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostKind {
    /// Exact evicted neighborhood `e*` (directed, transitive).
    EStar,
    /// Union-find approximation `ẽ*` (undirected components + split hack).
    EqClass,
    /// Parent-op cost only.
    Local,
    /// Ablated: constant 1.
    NoCost,
}

/// Fully parameterized heuristic spec: `h'(s, m, c)` from Appendix D.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParamSpec {
    pub cost: CostKind,
    pub use_size: bool,
    pub use_staleness: bool,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Heuristic {
    Param(ParamSpec),
    /// Peng et al. 2020 MSPS: (c0 + Σ over evicted remat set) / m.
    Msps,
    /// Uniform random score (metadata-free baseline).
    Random,
    /// |e*(S)| — the reduced heuristic of Appendix A (Theorem 3.1).
    EStarCount,
}

impl Heuristic {
    pub fn dtr() -> Self {
        Heuristic::Param(ParamSpec { cost: CostKind::EStar, use_size: true, use_staleness: true })
    }
    pub fn dtr_eq() -> Self {
        Heuristic::Param(ParamSpec { cost: CostKind::EqClass, use_size: true, use_staleness: true })
    }
    pub fn dtr_local() -> Self {
        Heuristic::Param(ParamSpec { cost: CostKind::Local, use_size: true, use_staleness: true })
    }
    pub fn lru() -> Self {
        Heuristic::Param(ParamSpec { cost: CostKind::NoCost, use_size: false, use_staleness: true })
    }
    pub fn size() -> Self {
        Heuristic::Param(ParamSpec { cost: CostKind::NoCost, use_size: true, use_staleness: false })
    }

    /// Canonical name used in CSV output and CLI flags.
    pub fn name(&self) -> String {
        match self {
            Heuristic::Param(p) => match (p.cost, p.use_size, p.use_staleness) {
                (CostKind::EStar, true, true) => "h_dtr".into(),
                (CostKind::EqClass, true, true) => "h_dtr_eq".into(),
                (CostKind::Local, true, true) => "h_dtr_local".into(),
                (CostKind::NoCost, false, true) => "h_lru".into(),
                (CostKind::NoCost, true, false) => "h_size".into(),
                (c, m, s) => format!(
                    "h_param_c{}_m{}_s{}",
                    match c {
                        CostKind::EStar => "estar",
                        CostKind::EqClass => "eq",
                        CostKind::Local => "local",
                        CostKind::NoCost => "no",
                    },
                    if m { "yes" } else { "no" },
                    if s { "yes" } else { "no" }
                ),
            },
            Heuristic::Msps => "h_msps".into(),
            Heuristic::Random => "h_rand".into(),
            Heuristic::EStarCount => "h_estar_count".into(),
        }
    }

    /// Parse a heuristic name; thin `Option` wrapper over the [`FromStr`]
    /// impl below, which is the single source of truth shared by CLI flags
    /// and CSV output (it round-trips [`Heuristic::name`] exactly,
    /// including the `h_param_*` ablation-grid names).
    pub fn parse(name: &str) -> Option<Heuristic> {
        name.parse().ok()
    }

    /// All heuristics compared in Fig. 2.
    pub fn fig2_set() -> Vec<Heuristic> {
        vec![
            Heuristic::dtr(),
            Heuristic::dtr_eq(),
            Heuristic::dtr_local(),
            Heuristic::lru(),
            Heuristic::size(),
            Heuristic::Msps,
            Heuristic::Random,
        ]
    }

    /// The full ablation grid of Appendix D.1: c ∈ {e*, eq, local, no} ×
    /// s ∈ {yes,no} × m ∈ {yes,no}.
    pub fn ablation_grid() -> Vec<Heuristic> {
        let mut out = Vec::new();
        for cost in [CostKind::EStar, CostKind::EqClass, CostKind::Local, CostKind::NoCost] {
            for use_size in [true, false] {
                for use_staleness in [true, false] {
                    out.push(Heuristic::Param(ParamSpec { cost, use_size, use_staleness }));
                }
            }
        }
        out
    }

    /// Does this heuristic need union-find evicted-component maintenance?
    pub fn needs_uf(&self) -> bool {
        matches!(self, Heuristic::Param(p) if p.cost == CostKind::EqClass)
    }

    /// Is the score independent of the logical clock? Clock-free heuristics
    /// admit an exact lazy min-heap (`policy::LazyHeapIndex`): between
    /// invalidations their relative order never changes. Staleness-bearing
    /// heuristics do *not* — `c/(m·staleness)` reorders as the clock
    /// advances, so their index caches the numerator instead
    /// (`policy::CachedCostScan`).
    pub fn clock_free(&self) -> bool {
        match self {
            Heuristic::Msps | Heuristic::EStarCount => true,
            Heuristic::Param(p) => !p.use_staleness,
            Heuristic::Random => false,
        }
    }

    /// How far a state change at one storage can reach into other storages'
    /// cached numerators (see [`InvalidationScope`]).
    pub fn invalidation_scope(&self) -> InvalidationScope {
        match self {
            Heuristic::Random => InvalidationScope::Constant,
            Heuristic::EStarCount | Heuristic::Msps => InvalidationScope::EvictedRegion,
            Heuristic::Param(p) => match p.cost {
                CostKind::NoCost => InvalidationScope::Constant,
                CostKind::Local => InvalidationScope::SelfOnly,
                CostKind::EqClass => InvalidationScope::EqNeighborhood,
                CostKind::EStar => InvalidationScope::EvictedRegion,
            },
        }
    }
}

/// How far a residency/view/edge change at storage `X` can reach into the
/// cached score numerators of *other* storages — the contract behind the
/// policy indexes' lazy invalidation (Appendix E's "only the evicted
/// neighborhood changes").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvalidationScope {
    /// Numerator is a constant; nothing to invalidate.
    Constant,
    /// Only `X`'s own numerator depends on `X` (local parent-op cost).
    SelfOnly,
    /// `X` itself plus its *direct* resident graph neighbors (ẽ* reads only
    /// direct edges); component-cost changes arrive separately through the
    /// union-find subscription hooks.
    EqNeighborhood,
    /// `X` itself plus the resident frontier of the undirected evicted
    /// region around `X` (exact `e*` / MSPS remat-set traversals).
    EvictedRegion,
}

impl std::str::FromStr for Heuristic {
    type Err = String;

    /// Exact inverse of [`Heuristic::name`] (plus a few short CLI aliases):
    /// every name `name()` can emit — the canonical five, `h_msps`,
    /// `h_rand`, `h_estar_count`, and the full `h_param_c*_m*_s*` ablation
    /// grid — parses back to the same variant.
    fn from_str(s: &str) -> Result<Heuristic, String> {
        let known = match s {
            "h_dtr" | "dtr" => Some(Heuristic::dtr()),
            "h_dtr_eq" | "dtr_eq" | "eq" => Some(Heuristic::dtr_eq()),
            "h_dtr_local" | "dtr_local" | "local" => Some(Heuristic::dtr_local()),
            "h_lru" | "lru" => Some(Heuristic::lru()),
            "h_size" | "size" => Some(Heuristic::size()),
            "h_msps" | "msps" => Some(Heuristic::Msps),
            "h_rand" | "rand" | "random" => Some(Heuristic::Random),
            "h_estar_count" | "estar_count" => Some(Heuristic::EStarCount),
            _ => None,
        };
        if let Some(h) = known {
            return Ok(h);
        }
        if let Some(rest) = s.strip_prefix("h_param_c") {
            let (cost_s, rest) = rest
                .split_once("_m")
                .ok_or_else(|| format!("malformed parameterized heuristic '{s}'"))?;
            let (m_s, s_s) = rest
                .split_once("_s")
                .ok_or_else(|| format!("malformed parameterized heuristic '{s}'"))?;
            let cost = match cost_s {
                "estar" => CostKind::EStar,
                "eq" => CostKind::EqClass,
                "local" => CostKind::Local,
                "no" => CostKind::NoCost,
                other => return Err(format!("unknown cost kind '{other}' in '{s}'")),
            };
            let flag = |v: &str| match v {
                "yes" => Ok(true),
                "no" => Ok(false),
                other => Err(format!("expected yes/no, got '{other}' in '{s}'")),
            };
            return Ok(Heuristic::Param(ParamSpec {
                cost,
                use_size: flag(m_s)?,
                use_staleness: flag(s_s)?,
            }));
        }
        Err(format!("unknown heuristic '{s}'"))
    }
}

/// Mutable context needed to evaluate scores.
pub struct ScoreCtx<'a> {
    pub graph: &'a Graph,
    pub uf: &'a mut UnionFind,
    pub scratch: &'a mut EvictedScratch,
    pub clock: u64,
    pub rng: &'a mut Rng,
    /// Metadata-access counter (Fig. 12).
    pub accesses: &'a mut u64,
    /// Scratch for dedup'ing UF roots during ẽ* queries.
    pub root_buf: &'a mut Vec<u32>,
}

/// Score a storage; lower = evicted first. All scores are strictly positive
/// so ratios remain meaningful.
///
/// Decomposed as `finish_score(h, cached_cost(h, s), …)` so the policy
/// indexes can cache the expensive numerator and reproduce scan scores
/// *bit-exactly* (the index/scan equivalence property depends on this).
pub fn score(h: Heuristic, s: StorageId, ctx: &mut ScoreCtx<'_>) -> f64 {
    *ctx.accesses += 1; // the heuristic evaluation itself (paper counts these)
    if matches!(h, Heuristic::Random) {
        return ctx.rng.f64().max(f64::MIN_POSITIVE);
    }
    let c = cached_cost(h, s, ctx);
    let st = ctx.graph.storage(s);
    finish_score(h, c, st.size, st.last_access, ctx.clock)
}

/// The expensive, *cacheable* numerator of `h` at `s`: the term that only
/// changes when the evicted neighborhood / eq-class costs / local views of
/// `s` change (never with the clock or `last_access`). For `EqClass` the
/// distinct union-find roots observed are left in `ctx.root_buf` so callers
/// can subscribe to component-cost changes.
///
/// Panics for `h_rand`, which has no cacheable component (the factory never
/// routes it to a caching index).
pub fn cached_cost(h: Heuristic, s: StorageId, ctx: &mut ScoreCtx<'_>) -> f64 {
    let st = ctx.graph.storage(s);
    match h {
        Heuristic::Random => unreachable!("h_rand has no cacheable cost"),
        Heuristic::EStarCount => {
            let (_, n) = estar_cost(ctx.graph, s, ctx.scratch, ctx.accesses);
            n as f64 + 1.0
        }
        Heuristic::Msps => {
            st.local_cost as f64 + remat_set_cost(ctx.graph, s, ctx.scratch, ctx.accesses)
        }
        Heuristic::Param(p) => match p.cost {
            CostKind::NoCost => 1.0,
            CostKind::Local => st.local_cost as f64 + 1.0,
            CostKind::EStar => {
                let (ec, _) = estar_cost(ctx.graph, s, ctx.scratch, ctx.accesses);
                st.local_cost as f64 + ec + 1.0
            }
            CostKind::EqClass => st.local_cost as f64 + eq_neighborhood_cost(s, ctx) + 1.0,
        },
    }
}

/// Finish a score from a cached numerator: the cheap per-candidate part
/// (size/staleness denominators). Must stay bit-identical to what `score`
/// computes from a fresh numerator.
pub fn finish_score(h: Heuristic, cost: f64, size: u64, last_access: u64, clock: u64) -> f64 {
    match h {
        Heuristic::Random => unreachable!("h_rand has no cacheable cost"),
        Heuristic::EStarCount => cost,
        Heuristic::Msps => (cost + 1.0) / (size.max(1) as f64),
        Heuristic::Param(p) => {
            let (m, stale) = param_denominators(&p, size, last_access, clock);
            cost / (m as f64 * stale as f64)
        }
    }
}

/// The exact integer denominator factoring of the parameterized score
/// `c / (m · staleness)`: returns `(m, staleness)`, each 1 when ablated.
/// `finish_score` is defined in terms of this factoring, so anything that
/// compares these integers (the differential index's cross-multiplied
/// comparisons) agrees with the scan's `f64` scores wherever `f64` is still
/// injective on the products (the module-level 2^52 caveat).
#[inline]
pub fn param_denominators(p: &ParamSpec, size: u64, last_access: u64, clock: u64) -> (u64, u64) {
    let m = if p.use_size { size.max(1) } else { 1 };
    let stale = if p.use_staleness { clock.saturating_sub(last_access) + 1 } else { 1 };
    (m, stale)
}

/// The staleness-bearing `Param` spec of `h`, if it has one — the heuristic
/// family whose scores re-order with the clock and which the differential
/// index (`policy::DifferentialIndex`) serves.
#[inline]
pub fn staleness_param(h: Heuristic) -> Option<ParamSpec> {
    match h {
        Heuristic::Param(p) if p.use_staleness => Some(p),
        _ => None,
    }
}

/// Exact integer view of a cached `Param` numerator. Every `Param`
/// numerator is `1.0` plus sums of `u64` op costs accumulated in `f64`, so
/// it is a non-negative integral `f64` whenever those sums stay below 2^53
/// (the same caveat the scan's own score arithmetic carries); beyond that
/// the truncating conversion is the documented best effort.
#[inline]
pub fn integral_cost(c: f64) -> u64 {
    debug_assert!(c >= 1.0 && c.fract() == 0.0, "non-integral Param numerator {c}");
    c as u64
}

/// ẽ*(S): sum the running costs of the distinct UF components adjacent to S
/// through evicted deps/dependents — *without* unioning them (Appendix C.2:
/// "no UF unions are performed when querying").
fn eq_neighborhood_cost(s: StorageId, ctx: &mut ScoreCtx<'_>) -> f64 {
    ctx.root_buf.clear();
    let st = ctx.graph.storage(s);
    let mut total = 0.0;
    for list in [&st.deps, &st.dependents] {
        for &n in list.iter() {
            *ctx.accesses += 1;
            let nst = ctx.graph.storage(n);
            if !nst.resident && !nst.banished {
                let root = ctx.uf.find(nst.uf);
                if !ctx.root_buf.contains(&root) {
                    ctx.root_buf.push(root);
                    total += ctx.uf.component_cost(root);
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::graph::Graph;
    use crate::dtr::ids::TensorId;

    fn chain(n: usize, costs: &[u64], sizes: &[u64]) -> (Graph, Vec<StorageId>, UnionFind) {
        let mut g = Graph::new();
        let mut uf = UnionFind::new();
        let mut ss = Vec::new();
        let mut prev: Option<TensorId> = None;
        for i in 0..n {
            let h = uf.make_set();
            let s = g.new_storage(sizes[i], h);
            let t = if let Some(p) = prev {
                let op = g.new_op(&format!("f{i}"), costs[i], vec![p]);
                let t = g.new_tensor(s, Some(op), false);
                g.ops[op.idx()].outputs.push(t);
                t
            } else {
                g.new_tensor(s, None, false)
            };
            g.storage_mut(s).resident = true;
            ss.push(s);
            prev = Some(t);
        }
        (g, ss, uf)
    }

    fn ctx_score(h: Heuristic, g: &Graph, uf: &mut UnionFind, clock: u64, s: StorageId) -> f64 {
        let mut scratch = EvictedScratch::new();
        let mut rng = Rng::new(1);
        let mut acc = 0u64;
        let mut roots = Vec::new();
        let mut ctx = ScoreCtx {
            graph: g,
            uf,
            scratch: &mut scratch,
            clock,
            rng: &mut rng,
            accesses: &mut acc,
            root_buf: &mut roots,
        };
        score(h, s, &mut ctx)
    }

    #[test]
    fn lru_prefers_stalest() {
        let (mut g, ss, mut uf) = chain(3, &[0, 5, 5], &[1, 1, 1]);
        g.storage_mut(ss[1]).last_access = 1;
        g.storage_mut(ss[2]).last_access = 9;
        let s1 = ctx_score(Heuristic::lru(), &g, &mut uf, 10, ss[1]);
        let s2 = ctx_score(Heuristic::lru(), &g, &mut uf, 10, ss[2]);
        assert!(s1 < s2, "stalest tensor must score lowest");
    }

    #[test]
    fn size_prefers_largest() {
        let (g, ss, mut uf) = chain(3, &[0, 5, 5], &[1, 100, 10]);
        let s1 = ctx_score(Heuristic::size(), &g, &mut uf, 10, ss[1]);
        let s2 = ctx_score(Heuristic::size(), &g, &mut uf, 10, ss[2]);
        assert!(s1 < s2, "largest tensor must score lowest");
    }

    #[test]
    fn local_prefers_cheap() {
        let (g, ss, mut uf) = chain(3, &[0, 100, 2], &[1, 1, 1]);
        let cheap = ctx_score(Heuristic::dtr_local(), &g, &mut uf, 10, ss[2]);
        let costly = ctx_score(Heuristic::dtr_local(), &g, &mut uf, 10, ss[1]);
        assert!(cheap < costly);
    }

    #[test]
    fn estar_penalizes_evicted_chains() {
        // Evict middle of a 5-chain; its resident neighbors' e* grows.
        let (mut g, ss, mut uf) = chain(5, &[0, 10, 10, 10, 10], &[1; 5]);
        g.storage_mut(ss[2]).resident = false;
        let with_neighbors = ctx_score(Heuristic::dtr(), &g, &mut uf, 1, ss[1]);
        let isolated = ctx_score(Heuristic::dtr(), &g, &mut uf, 1, ss[4]);
        // ss[1] has evicted neighbor (cost 10) + own cost 10; ss[4] costs 10
        // with an empty neighborhood... but ss[3] borders the evicted ss[2]
        // too. Compare ss[1] (borders evicted) with ss[4] (does not).
        assert!(with_neighbors > isolated);
    }

    #[test]
    fn eqclass_matches_estar_without_splits() {
        // Evict a contiguous run; for chains (undirected = directed closure
        // union) the component cost equals the exact e* cost.
        let (mut g, ss, mut uf) = chain(6, &[0, 7, 7, 7, 7, 7], &[1; 6]);
        for &s in &ss[2..4] {
            // simulate runtime eviction bookkeeping
            g.storage_mut(s).resident = false;
            let h = g.storage(s).uf;
            uf.add_cost(h, g.storage(s).local_cost as f64);
        }
        uf.union(g.storage(ss[2]).uf, g.storage(ss[3]).uf);
        let exact = ctx_score(Heuristic::dtr(), &g, &mut uf, 1, ss[1]);
        let approx = ctx_score(Heuristic::dtr_eq(), &g, &mut uf, 1, ss[1]);
        assert!((exact - approx).abs() < 1e-9, "exact={exact} approx={approx}");
    }

    #[test]
    fn estar_count_is_appendix_a_heuristic() {
        let (mut g, ss, mut uf) = chain(5, &[0, 1, 1, 1, 1], &[1; 5]);
        g.storage_mut(ss[1]).resident = false;
        g.storage_mut(ss[2]).resident = false;
        // ss[3] borders the 2-evicted run → |e*| = 2 → score 3.
        let sc = ctx_score(Heuristic::EStarCount, &g, &mut uf, 1, ss[3]);
        assert_eq!(sc, 3.0);
    }

    #[test]
    fn msps_ignores_staleness() {
        let (mut g, ss, mut uf) = chain(3, &[0, 5, 5], &[1, 1, 1]);
        g.storage_mut(ss[1]).last_access = 0;
        let a = ctx_score(Heuristic::Msps, &g, &mut uf, 10, ss[1]);
        g.storage_mut(ss[1]).last_access = 9;
        let b = ctx_score(Heuristic::Msps, &g, &mut uf, 10, ss[1]);
        assert_eq!(a, b);
    }

    #[test]
    fn names_roundtrip() {
        for h in Heuristic::fig2_set() {
            assert_eq!(Heuristic::parse(&h.name()), Some(h), "{}", h.name());
        }
    }

    /// `FromStr` must invert `name()` over *every* variant: the canonical
    /// set, the extras, and the full 16-cell ablation grid (whose
    /// non-canonical cells use the `h_param_c*_m*_s*` scheme).
    #[test]
    fn fromstr_roundtrips_every_variant_name() {
        let mut all = Heuristic::fig2_set();
        all.extend(Heuristic::ablation_grid());
        all.push(Heuristic::EStarCount);
        for h in all {
            let name = h.name();
            let parsed: Heuristic = name.parse().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(parsed, h, "{name} did not round-trip");
            // And the round-trip is stable: name -> parse -> name is fixed.
            assert_eq!(parsed.name(), name);
        }
        assert!("h_param_cbogus_myes_syes".parse::<Heuristic>().is_err());
        assert!("h_param_ceq_mmaybe_syes".parse::<Heuristic>().is_err());
        assert!("nonsense".parse::<Heuristic>().is_err());
    }

    #[test]
    fn ablation_grid_is_16() {
        assert_eq!(Heuristic::ablation_grid().len(), 16);
    }

    #[test]
    fn random_scores_positive_and_varied() {
        let (g, ss, mut uf) = chain(2, &[0, 1], &[1, 1]);
        let a = ctx_score(Heuristic::Random, &g, &mut uf, 1, ss[1]);
        assert!(a > 0.0);
    }
}
