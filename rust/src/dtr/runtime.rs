//! The DTR runtime: the paper's core algorithm (Figure 1) over the storage
//! model of Appendix C.
//!
//! `Runtime::call` records a new operator and performs it; `perform`
//! recursively (re)materializes undefined inputs, evicts under the budget
//! heuristic to make room for outputs, executes through the pluggable
//! `Backend`, and maintains all metadata: staleness clocks, cached local
//! costs, union-find evicted components, locks, pins, and reference counts.
//! Deallocation events are routed through the configured `DeallocPolicy`.

use anyhow::Result;

use super::backend::Backend;
use super::evicted::EvictedScratch;
use super::graph::Graph;
use super::heuristics::{score, Heuristic, ScoreCtx};
use super::ids::{OpId, StorageId, TensorId};
use super::lease::{GateRef, LocalEvictor};
use super::policy::{make_index, DeallocPolicy, PolicyIndex, PolicyKind, SelectCtx};
use super::unionfind::UnionFind;
use crate::util::rng::Rng;

/// Runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Memory budget in bytes. `u64::MAX` disables eviction.
    pub budget: u64,
    pub heuristic: Heuristic,
    pub policy: DeallocPolicy,
    /// Victim-selection index family (`policy::make_index`): incremental
    /// indexes where exact, the reference scan otherwise.
    pub index: PolicyKind,
    /// Appendix E.2 optimization: only search a random √n sample of the pool.
    pub sqrt_sample: bool,
    /// Appendix E.2 optimization: skip tensors smaller than 1% of the pool's
    /// mean size during victim search.
    pub small_filter: bool,
    /// Seed for `h_rand` and the sampling optimization.
    pub seed: u64,
    /// Measure wall-clock time of the victim-search loop (Fig. 4 profiling).
    pub profile: bool,
    /// Record every eviction victim into `Stats::victims` (diagnostics and
    /// the index/scan equivalence property).
    pub trace_victims: bool,
    /// Pool size at which [`PolicyKind::Auto`]'s scan upgrades to the
    /// differential index (`policy::AUTO_CROSSOVER_POOL` by default) —
    /// overridable so bench sweeps can price the boundary without
    /// recompiling. `0` upgrades at the first pop.
    pub auto_crossover: usize,
    /// Restore eager per-touch epoch migration in the differential index
    /// family instead of the default lazy park-and-batch (`false`). Both
    /// modes are decision-exact; eager exists as the benchmark bar for the
    /// lazy path (`bench_dtr`'s `epoch_migration` section).
    pub eager_migration: bool,
    /// Shared-budget lease (`dtr::lease`): when set, `budget` is ignored
    /// and every allocation reserves bytes through the gate — the fast
    /// path against the shard's lease headroom, the slow path through the
    /// central arbiter (`crate::serve::BudgetArbiter`), which may evict
    /// across shards. `None` (the default) keeps the classic fixed budget.
    pub gate: Option<GateRef>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            budget: u64::MAX,
            heuristic: Heuristic::dtr_eq(),
            policy: DeallocPolicy::EagerEvict,
            index: PolicyKind::Auto,
            sqrt_sample: false,
            small_filter: false,
            seed: 0x5EED,
            profile: false,
            trace_victims: false,
            auto_crossover: super::policy::AUTO_CROSSOVER_POOL,
            eager_migration: false,
            gate: None,
        }
    }
}

impl Config {
    /// This configuration with the budget removed — both the fixed budget
    /// and any shared-budget lease. Probe and envelope-measurement sessions
    /// use this so they never reserve bytes from a serving shard's lease.
    pub fn unbudgeted(&self) -> Config {
        Config { budget: u64::MAX, gate: None, ..self.clone() }
    }
}

/// Counters and gauges exposed to every experiment harness.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    /// Logical clock: accumulated compute cost (base + remat).
    pub clock: u64,
    /// Cost of first-time operator executions.
    pub base_compute: u64,
    /// Cost of rematerializations (the checkpointing overhead).
    pub remat_compute: u64,
    pub remat_count: u64,
    pub evict_count: u64,
    pub banish_count: u64,
    /// Storage/metadata accesses by heuristic evaluation + maintenance
    /// (Fig. 12 / Appendix D.3).
    pub metadata_accesses: u64,
    pub memory: u64,
    pub peak_memory: u64,
    /// Wall time spent inside victim selection (Fig. 4 "eviction loop" +
    /// "cost compute"), ns. Only populated when `cfg.profile`.
    pub eviction_loop_ns: u64,
    /// Subset of `eviction_loop_ns` spent evaluating heuristic scores.
    pub cost_compute_ns: u64,
    /// Number of victim-search passes.
    pub eviction_searches: u64,
    /// Eviction victim sequence (only populated under `Config::trace_victims`).
    pub victims: Vec<StorageId>,
}

impl Stats {
    /// Total compute (the simulator's headline metric).
    pub fn total_compute(&self) -> u64 {
        self.base_compute + self.remat_compute
    }

    /// Decision-level equality: every counter that reflects *what the
    /// runtime decided* (clock, compute, evictions, memory, victim trace)
    /// but not *how cheaply it decided it* — metadata accesses and
    /// wall-clock profiling legitimately differ between an incremental
    /// policy index and the reference scan making identical decisions.
    pub fn same_decisions(&self, o: &Stats) -> bool {
        self.clock == o.clock
            && self.base_compute == o.base_compute
            && self.remat_compute == o.remat_compute
            && self.remat_count == o.remat_count
            && self.evict_count == o.evict_count
            && self.banish_count == o.banish_count
            && self.memory == o.memory
            && self.peak_memory == o.peak_memory
            && self.eviction_searches == o.eviction_searches
            && self.victims == o.victims
    }

    /// Slowdown factor vs. the unbudgeted execution.
    pub fn slowdown(&self) -> f64 {
        if self.base_compute == 0 {
            1.0
        } else {
            self.total_compute() as f64 / self.base_compute as f64
        }
    }
}

/// DTR failure modes.
#[derive(Debug, thiserror::Error)]
pub enum DtrError {
    #[error("out of memory: need {need} free bytes, have {free} (budget {budget}, resident {resident}, no evictable storage)")]
    Oom { need: u64, free: u64, budget: u64, resident: u64 },
    #[error("tensor {0} is an evicted constant and cannot be rematerialized")]
    EvictedConstant(TensorId),
    #[error("tensor {0} depends on banished storage and cannot be rematerialized")]
    Banished(TensorId),
    #[error("rematerialization recursion exceeded {0} frames (thrashing)")]
    TooDeep(usize),
}

/// Output specification for `Runtime::call`.
#[derive(Debug, Clone, Copy)]
pub struct OutSpec {
    /// Size in bytes of the freshly allocated storage; ignored for aliases.
    pub size: u64,
    /// If `Some(i)`, the output is a view of the storage of `inputs[i]`.
    pub alias_of: Option<usize>,
}

impl OutSpec {
    pub fn sized(size: u64) -> Self {
        OutSpec { size, alias_of: None }
    }
    pub fn alias(of_input: usize) -> Self {
        OutSpec { size: 0, alias_of: Some(of_input) }
    }
}

const MAX_REMAT_DEPTH: usize = 1 << 20;

pub struct Runtime<B: Backend> {
    pub cfg: Config,
    pub graph: Graph,
    pub stats: Stats,
    backend: B,
    uf: UnionFind,
    scratch: EvictedScratch,
    rng: Rng,
    /// Evictable storages (resident, unlocked, unpinned).
    pool: Vec<StorageId>,
    /// Running byte total of the pool (the small-filter threshold without an
    /// O(pool) sum per search; checked against a fresh sum in
    /// `check_invariants`).
    pool_bytes: u64,
    /// Victim-selection index (`Config::index`); kept in lockstep with the
    /// pool through the `PolicyIndex` maintenance hooks.
    index: Box<dyn PolicyIndex>,
    /// Storages awaiting banishment (policy = Banish, blocked on evicted
    /// dependents).
    pending_banish: Vec<StorageId>,
    /// Permanently-retired storages (banished) not yet flushed to the index
    /// GC hook ([`PolicyIndex::on_retire`]); auto-flushed in batches so
    /// long-lived serving sessions hold index metadata flat under churn.
    retired: Vec<StorageId>,
    /// Scratch for ẽ* root dedup.
    root_buf: Vec<u32>,
    /// Scratch for double-compute bookkeeping.
    was_defined: Vec<bool>,
    /// Bytes of resident content-addressed shared constants
    /// ([`Runtime::constant_shared`]). Counted in `stats.memory` (they are
    /// physically resident and `check_invariants` ties memory to the graph)
    /// but never charged to the lease gate — the cross-shard store charges
    /// the arbiter's shared ledger exactly once per distinct buffer.
    shared_bytes: u64,
}

impl<B: Backend> Runtime<B> {
    pub fn new(cfg: Config, backend: B) -> Self {
        let rng = Rng::new(cfg.seed);
        let mut index =
            make_index(cfg.heuristic, cfg.index, cfg.sqrt_sample, cfg.auto_crossover, cfg.eager_migration);
        if let Some(g) = &cfg.gate {
            if let Some(slot) = g.0.min_slot() {
                // Fleet-tournament participation: a fresh runtime starts with
                // an empty pool, and anything the previous session left
                // published is now meaningless — reset before the index takes
                // over publishing.
                slot.reset_unbound();
                index.bind_slot(slot);
            }
        }
        Runtime {
            cfg,
            graph: Graph::new(),
            stats: Stats::default(),
            backend,
            uf: UnionFind::new(),
            scratch: EvictedScratch::new(),
            rng,
            pool: Vec::new(),
            pool_bytes: 0,
            index,
            pending_banish: Vec::new(),
            retired: Vec::new(),
            root_buf: Vec::new(),
            was_defined: Vec::new(),
            shared_bytes: 0,
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Name of the active victim-selection index (observability).
    pub fn index_name(&self) -> &'static str {
        self.index.name()
    }

    /// Approximate live metadata entries held by the index (see
    /// [`PolicyIndex::metadata_len`]) — the quantity [`Runtime::compact_index`]
    /// keeps flat under storage churn.
    pub fn index_metadata_len(&self) -> usize {
        self.index.metadata_len()
    }

    /// Flush the retired-storage free list into the index GC hook. Called
    /// automatically once a batch accumulates; callable any time.
    pub fn compact_index(&mut self) {
        if self.retired.is_empty() {
            return;
        }
        let retired = std::mem::take(&mut self.retired);
        self.index.on_retire(&retired, &self.graph);
    }

    // ---------------------------------------------------------------- pool

    #[inline]
    fn pool_add(&mut self, s: StorageId) {
        if self.graph.storage(s).pool_pos == usize::MAX && self.graph.storage(s).evictable() {
            self.graph.storage_mut(s).pool_pos = self.pool.len();
            self.pool.push(s);
            self.pool_bytes += self.graph.storage(s).size;
            self.index.on_insert(s, &self.graph);
        }
    }

    #[inline]
    fn pool_remove(&mut self, s: StorageId) {
        let pos = self.graph.storage(s).pool_pos;
        if pos != usize::MAX {
            let last = *self.pool.last().unwrap();
            self.pool.swap_remove(pos);
            if pos < self.pool.len() {
                self.graph.storage_mut(last).pool_pos = pos;
            }
            self.graph.storage_mut(s).pool_pos = usize::MAX;
            self.pool_bytes -= self.graph.storage(s).size;
            self.index.on_remove(s, &self.graph);
        }
    }

    /// Re-examine pool membership after flag changes.
    fn pool_refresh(&mut self, s: StorageId) {
        if self.graph.storage(s).evictable() {
            self.pool_add(s);
        } else {
            self.pool_remove(s);
        }
    }

    // ------------------------------------------------------------ creation

    /// Register a constant (weights, inputs): resident, pinned, never
    /// rematerializable. Returns its tensor.
    pub fn constant(&mut self, size: u64) -> TensorId {
        let uf = self.uf.make_set();
        let s = self.graph.new_storage(size, uf);
        let t = self.graph.new_tensor(s, None, false);
        self.graph.tensor_mut(t).defined = true;
        let st = self.graph.storage_mut(s);
        st.resident = true;
        st.pinned = true;
        st.refs = 1;
        st.last_access = self.stats.clock;
        // Constants never trigger eviction (matching the fixed-budget
        // path, which registers them unconditionally); under a lease this
        // may overdraw, which the arbiter's ledger surfaces.
        if let Some(g) = &self.cfg.gate {
            g.0.reserve_pinned(size);
            g.0.on_alloc(size);
        }
        self.stats.memory += size;
        self.stats.peak_memory = self.stats.peak_memory.max(self.stats.memory);
        t
    }

    /// Register a **shared** pinned constant: a content-addressed buffer
    /// owned by a cross-shard `WeightStore`, physically shared by every
    /// shard that interned the same bytes. Like [`Runtime::constant`] it is
    /// resident, pinned, and never rematerializable — so it is invisible to
    /// eviction — but its bytes are *not* reserved through this shard's
    /// lease gate: the store already charged the arbiter's shared ledger
    /// exactly once for the single physical copy. The bytes still count in
    /// `stats.memory` (the buffer is genuinely resident in this shard's
    /// address space for accounting purposes), and teardown refunds only
    /// `memory - shared_bytes` to the gate.
    pub fn constant_shared(&mut self, size: u64) -> TensorId {
        let uf = self.uf.make_set();
        let s = self.graph.new_storage(size, uf);
        let t = self.graph.new_tensor(s, None, false);
        self.graph.tensor_mut(t).defined = true;
        let st = self.graph.storage_mut(s);
        st.resident = true;
        st.pinned = true;
        st.shared = true;
        st.refs = 1;
        st.last_access = self.stats.clock;
        self.shared_bytes += size;
        self.stats.memory += size;
        self.stats.peak_memory = self.stats.peak_memory.max(self.stats.memory);
        t
    }

    /// Bytes of resident shared constants (see [`Runtime::constant_shared`]).
    pub fn shared_bytes(&self) -> u64 {
        self.shared_bytes
    }

    /// Record and perform a new operator application. Returns the output
    /// tensors. Each output gets one external reference.
    pub fn call(
        &mut self,
        name: &str,
        cost: u64,
        inputs: &[TensorId],
        outputs: &[OutSpec],
    ) -> Result<Vec<TensorId>> {
        let op = self.graph.new_op(name, cost, inputs.to_vec());
        let mut out_tensors = Vec::with_capacity(outputs.len());
        for spec in outputs {
            let (sid, alias) = match spec.alias_of {
                Some(i) => (self.graph.storage_of(inputs[i]), true),
                None => {
                    let uf = self.uf.make_set();
                    (self.graph.new_storage(spec.size, uf), false)
                }
            };
            let t = self.graph.new_tensor(sid, Some(op), alias);
            out_tensors.push(t);
        }
        self.graph.ops[op.idx()].outputs = out_tensors.clone();
        for &t in &out_tensors {
            let sid = self.graph.storage_of(t);
            self.graph.storage_mut(sid).refs += 1;
        }
        // Recording the operator added dependency edges (and, for aliases,
        // view costs) around each output storage — which counts as evicted
        // until committed. Dirty the affected neighborhoods.
        for &t in &out_tensors {
            let sid = self.graph.storage_of(t);
            self.index.invalidate(sid, &self.graph, &mut self.stats.metadata_accesses);
        }
        self.perform(op, 0)?;
        Ok(out_tensors)
    }

    // ----------------------------------------------------------- execution

    /// Perform (or replay) an operator: the heart of Figure 1.
    fn perform(&mut self, op: OpId, depth: usize) -> Result<()> {
        if depth > MAX_REMAT_DEPTH {
            return Err(DtrError::TooDeep(depth).into());
        }
        let is_remat = depth > 0;
        let inputs = self.graph.op(op).inputs.clone();

        // Lock inputs so nothing we need gets evicted mid-flight.
        for &i in &inputs {
            let sid = self.graph.storage_of(i);
            self.graph.storage_mut(sid).locks += 1;
            self.pool_remove(sid);
        }

        let result = self.perform_locked(op, &inputs, is_remat, depth);

        // Unlock inputs (and return them to the pool if evictable again).
        for &i in &inputs {
            let sid = self.graph.storage_of(i);
            let st = self.graph.storage_mut(sid);
            debug_assert!(st.locks > 0);
            st.locks -= 1;
            self.pool_refresh(sid);
        }
        // A rematerialization may unblock pending banishes; retry only once
        // the locks are released.
        if is_remat && result.is_ok() && !self.pending_banish.is_empty() {
            self.retry_pending_banishes();
        }
        result
    }

    fn perform_locked(
        &mut self,
        op: OpId,
        inputs: &[TensorId],
        is_remat: bool,
        depth: usize,
    ) -> Result<()> {
        // Recursively rematerialize undefined inputs.
        for &i in inputs {
            if !self.graph.tensor(i).defined {
                let parent = match self.graph.tensor(i).op {
                    Some(p) => p,
                    None => return Err(DtrError::EvictedConstant(i).into()),
                };
                let sid = self.graph.storage_of(i);
                if self.graph.storage(sid).banished {
                    return Err(DtrError::Banished(i).into());
                }
                self.perform(parent, depth + 1)?;
            }
        }

        // Allocate output memory (the paper first increments by every
        // output's size, then releases double-computed ephemerals).
        let outputs = self.graph.op(op).outputs.clone();
        let mut need = 0u64;
        self.was_defined.clear();
        for &o in &outputs {
            let t = self.graph.tensor(o);
            self.was_defined.push(t.defined);
            if !t.alias {
                need += self.graph.storage(t.storage).size;
            }
        }
        self.free_for(need)?;
        if let Some(g) = &self.cfg.gate {
            g.0.on_alloc(need);
        }
        self.stats.memory += need;
        self.stats.peak_memory = self.stats.peak_memory.max(self.stats.memory);

        // Execute on the backend.
        let name = self.graph.op(op).name.clone();
        self.backend.execute(&name, inputs, &outputs)?;

        // Commit outputs.
        let uf_enabled = self.cfg.heuristic.needs_uf();
        for (k, &o) in outputs.iter().enumerate() {
            let sid = self.graph.storage_of(o);
            let alias = self.graph.tensor(o).alias;
            if alias {
                // Views occupy no memory; they are definable only once the
                // storage is resident (guaranteed: their base input is a view
                // of the same storage and was just materialized).
                debug_assert!(self.graph.storage(sid).resident);
                self.graph.tensor_mut(o).defined = true;
            } else if self.graph.storage(sid).resident && self.was_defined[k] {
                // Double-computed ephemeral (multi-output replay): free the
                // duplicate immediately.
                let size = self.graph.storage(sid).size;
                self.stats.memory -= size;
                if let Some(g) = &self.cfg.gate {
                    g.0.on_free(size);
                }
            } else {
                let st = self.graph.storage_mut(sid);
                st.resident = true;
                // Stamp the access time before pooling: the staleness index
                // then inserts at (or near) the list tail instead of walking
                // past every fresher entry for a stale stamp. No search runs
                // before the end-of-frame re-stamp, so decisions are
                // unchanged.
                st.last_access = self.stats.clock;
                self.graph.tensor_mut(o).defined = true;
                if uf_enabled && is_remat {
                    // Union-find split approximation: leave the component,
                    // subtracting our cost (Appendix C.2).
                    let handle = self.graph.storage(sid).uf;
                    let cost = self.graph.storage(sid).local_cost as f64;
                    let root = self.uf.sub_cost(handle, cost);
                    self.index.on_component_touched(root);
                    let fresh = self.uf.make_set();
                    self.graph.storage_mut(sid).uf = fresh;
                }
                self.pool_refresh(sid);
                // The storage just turned resident: its neighbors' evicted
                // neighborhoods shrank.
                self.index.invalidate(sid, &self.graph, &mut self.stats.metadata_accesses);
            }
        }

        // Advance the logical clock and update staleness metadata.
        let cost = self.graph.op(op).cost;
        self.stats.clock += cost;
        if is_remat {
            self.stats.remat_compute += cost;
            self.stats.remat_count += 1;
        } else {
            self.stats.base_compute += cost;
        }
        let now = self.stats.clock;
        self.index.on_clock(now);
        for &i in inputs {
            let sid = self.graph.storage_of(i);
            self.graph.storage_mut(sid).last_access = now;
            self.index.on_access(sid, &self.graph, now);
        }
        for &o in &outputs {
            let sid = self.graph.storage_of(o);
            self.graph.storage_mut(sid).last_access = now;
            self.index.on_access(sid, &self.graph, now);
        }

        Ok(())
    }

    // ------------------------------------------------------------ eviction

    /// Make room for `need` additional bytes: under a shared-budget lease,
    /// reserve through the gate (fast path against the shard's headroom,
    /// slow path through the arbiter, which may evict across shards);
    /// under a fixed budget, evict locally until the bytes fit.
    fn free_for(&mut self, need: u64) -> Result<()> {
        if let Some(gate) = self.cfg.gate.clone() {
            if gate.0.try_reserve(need) {
                return Ok(());
            }
            return gate.0.reserve(need, self);
        }
        if self.cfg.budget == u64::MAX {
            return Ok(());
        }
        while self.stats.memory.saturating_add(need) > self.cfg.budget {
            match self.select_victim() {
                Some(v) => self.evict(v),
                None => {
                    return Err(DtrError::Oom {
                        need,
                        free: self.cfg.budget.saturating_sub(self.stats.memory),
                        budget: self.cfg.budget,
                        resident: self.stats.memory,
                    }
                    .into())
                }
            }
        }
        Ok(())
    }

    /// Victim search: delegate the argmin to the configured policy index
    /// (the reference scan or an incremental index — `policy::make_index`).
    fn select_victim(&mut self) -> Option<StorageId> {
        if self.pool.is_empty() {
            return None;
        }
        let t0 = if self.cfg.profile { Some(std::time::Instant::now()) } else { None };
        self.stats.eviction_searches += 1;

        // Optional small-tensor filter threshold: 1% of pool mean size
        // (running byte counter; no per-search O(pool) sum).
        let min_size = if self.cfg.small_filter {
            (self.pool_bytes / self.pool.len() as u64) / 100
        } else {
            0
        };

        let mut cost_ns = 0u64;
        let mut ctx = SelectCtx {
            pool: &self.pool,
            graph: &self.graph,
            uf: &mut self.uf,
            scratch: &mut self.scratch,
            clock: self.stats.clock,
            rng: &mut self.rng,
            accesses: &mut self.stats.metadata_accesses,
            root_buf: &mut self.root_buf,
            heuristic: self.cfg.heuristic,
            min_size,
            sqrt_sample: self.cfg.sqrt_sample,
            profile: self.cfg.profile,
            cost_ns: &mut cost_ns,
        };
        let best = self.index.pop_min(&mut ctx);

        if let Some(t) = t0 {
            self.stats.eviction_loop_ns += t.elapsed().as_nanos() as u64;
            self.stats.cost_compute_ns += cost_ns;
        }
        best
    }

    /// Select and evict a single victim (bench and serving hook). Returns
    /// the evicted storage, or `None` if the pool is empty.
    pub fn evict_one(&mut self) -> Option<StorageId> {
        let v = self.select_victim()?;
        self.evict(v);
        Some(v)
    }

    /// Evict a storage: undefine all views, free the buffer, and maintain
    /// the union-find evicted components.
    pub fn evict(&mut self, s: StorageId) {
        debug_assert!(self.graph.storage(s).evictable(), "evicting non-evictable {s}");
        let tensors = self.graph.storage(s).tensors.clone();
        for &t in &tensors {
            self.graph.tensor_mut(t).defined = false;
        }
        let root = self.graph.storage(s).root;
        self.backend.free(&[root]);
        let size = self.graph.storage(s).size;
        self.stats.memory -= size;
        if let Some(g) = &self.cfg.gate {
            g.0.on_free(size);
        }
        self.graph.storage_mut(s).resident = false;
        self.pool_remove(s);
        self.stats.evict_count += 1;
        if self.cfg.trace_victims {
            self.stats.victims.push(s);
        }

        if self.cfg.heuristic.needs_uf() {
            let handle = self.graph.storage(s).uf;
            let cost = self.graph.storage(s).local_cost as f64;
            let touched = self.uf.add_cost(handle, cost);
            self.index.on_component_touched(touched);
            // Merge with adjacent evicted components (undirected relaxation).
            let deps = self.graph.storage(s).deps.clone();
            let dependents = self.graph.storage(s).dependents.clone();
            for n in deps.into_iter().chain(dependents) {
                self.stats.metadata_accesses += 1;
                let other = self.graph.storage(n);
                if !other.resident && !other.banished {
                    let oh = other.uf;
                    if let Some((kept, absorbed)) = self.uf.union_roots(handle, oh) {
                        self.index.on_components_merged(kept, absorbed);
                    }
                }
            }
        }
        // The storage just turned non-resident: it joined (and possibly
        // bridged) evicted neighborhoods around it.
        self.index.invalidate(s, &self.graph, &mut self.stats.metadata_accesses);
    }

    // -------------------------------------------------------- deallocation

    /// Increment the external reference count (COPY in the log format).
    pub fn retain(&mut self, t: TensorId) {
        let sid = self.graph.storage_of(t);
        self.graph.storage_mut(sid).refs += 1;
    }

    /// Decrement the external reference count (RELEASE); at zero, apply the
    /// deallocation policy.
    pub fn release(&mut self, t: TensorId) {
        let sid = self.graph.storage_of(t);
        {
            let st = self.graph.storage_mut(sid);
            debug_assert!(st.refs > 0, "release underflow on {sid}");
            st.refs = st.refs.saturating_sub(1);
            if st.refs > 0 {
                return;
            }
        }
        match self.cfg.policy {
            DeallocPolicy::Ignore => {}
            DeallocPolicy::EagerEvict => {
                if self.graph.storage(sid).evictable() {
                    self.evict(sid);
                }
            }
            DeallocPolicy::Banish => {
                if !self.try_banish(sid) {
                    self.pending_banish.push(sid);
                }
            }
        }
    }

    /// Banish: permanently free (Appendix C.4). Only legal with no evicted
    /// dependents; pins every dependent (they become non-rematerializable).
    fn try_banish(&mut self, s: StorageId) -> bool {
        if self.graph.storage(s).banished {
            return true;
        }
        if self.graph.has_evicted_dependent(s) {
            return false;
        }
        if self.graph.storage(s).locks > 0 {
            return false;
        }
        if self.graph.storage(s).resident {
            let tensors = self.graph.storage(s).tensors.clone();
            for &t in &tensors {
                self.graph.tensor_mut(t).defined = false;
            }
            let root = self.graph.storage(s).root;
            self.backend.free(&[root]);
            let size = self.graph.storage(s).size;
            self.stats.memory -= size;
            if self.graph.storage(s).shared {
                // Shared constants were never charged to the gate; the
                // store refunds the arbiter's shared ledger when the last
                // holder releases its interned handle.
                self.shared_bytes -= size;
            } else if let Some(g) = &self.cfg.gate {
                g.0.on_free(size);
            }
        }
        let st = self.graph.storage_mut(s);
        st.resident = false;
        st.banished = true;
        self.pool_remove(s);
        self.stats.banish_count += 1;
        // Banishment removes `s` from every evicted neighborhood for good.
        self.index.invalidate(s, &self.graph, &mut self.stats.metadata_accesses);
        // Pin dependents: their parent inputs are gone forever.
        let dependents = self.graph.storage(s).dependents.clone();
        for d in dependents {
            let dst = self.graph.storage_mut(d);
            if !dst.banished {
                dst.pinned = true;
            }
            self.pool_refresh(d);
        }
        // Banished storages never return: batch them into the index GC hook.
        self.retired.push(s);
        if self.retired.len() >= 256 {
            self.compact_index();
        }
        true
    }

    fn retry_pending_banishes(&mut self) {
        let pending = std::mem::take(&mut self.pending_banish);
        for s in pending {
            if !self.try_banish(s) {
                self.pending_banish.push(s);
            }
        }
    }

    // ------------------------------------------------------------- access

    /// Materialize (if needed) and touch a tensor: the prototype's
    /// `decheckpoint()` — used for final outputs and user-side reads.
    pub fn access(&mut self, t: TensorId) -> Result<()> {
        if !self.graph.tensor(t).defined {
            let op = self
                .graph
                .tensor(t)
                .op
                .ok_or(DtrError::EvictedConstant(t))?;
            self.perform(op, 1)?;
        }
        let sid = self.graph.storage_of(t);
        let now = self.stats.clock;
        self.graph.storage_mut(sid).last_access = now;
        self.index.on_access(sid, &self.graph, now);
        Ok(())
    }

    /// Output condition (Appendix C.6): rematerialize and pin every tensor
    /// the program still holds references to (gradients, loss, prediction).
    pub fn pin_live_outputs(&mut self) -> Result<()> {
        let live: Vec<TensorId> = (0..self.graph.tensors.len())
            .map(|i| TensorId(i as u32))
            .filter(|&t| {
                let sid = self.graph.storage_of(t);
                let st = self.graph.storage(sid);
                st.refs > 0 && !st.banished
            })
            .collect();
        for t in live {
            self.access(t)?;
            let sid = self.graph.storage_of(t);
            self.graph.storage_mut(sid).pinned = true;
            self.pool_refresh(sid);
        }
        Ok(())
    }

    // ------------------------------------------------------- introspection

    pub fn is_resident(&self, t: TensorId) -> bool {
        self.graph.storage(self.graph.storage_of(t)).resident
    }

    pub fn is_defined(&self, t: TensorId) -> bool {
        self.graph.tensor(t).defined
    }

    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }

    /// Verify internal accounting (used by tests and the property harness).
    pub fn check_invariants(&self) -> Result<()> {
        let resident_bytes = self.graph.resident_bytes();
        anyhow::ensure!(
            resident_bytes == self.stats.memory,
            "memory accounting drift: tracked {} vs actual {}",
            self.stats.memory,
            resident_bytes
        );
        let pool_sum: u64 = self.pool.iter().map(|&s| self.graph.storage(s).size).sum();
        anyhow::ensure!(
            pool_sum == self.pool_bytes,
            "pool byte counter drift: tracked {} vs actual {}",
            self.pool_bytes,
            pool_sum
        );
        for (i, s) in self.graph.storages.iter().enumerate() {
            anyhow::ensure!(
                s.locks == 0,
                "storage S{} still locked after quiescence",
                i
            );
            if s.pool_pos != usize::MAX {
                anyhow::ensure!(
                    self.pool[s.pool_pos] == StorageId(i as u32),
                    "pool position corrupt for S{}",
                    i
                );
                anyhow::ensure!(s.evictable(), "non-evictable S{} in pool", i);
            } else {
                anyhow::ensure!(
                    !s.evictable() || (self.cfg.budget == u64::MAX && self.cfg.gate.is_none()),
                    "evictable S{} missing from pool",
                    i
                );
            }
        }
        Ok(())
    }

    /// Heuristic score of `s` (used for cross-shard victim comparison; the
    /// metadata accesses it performs are counted, but it does not disturb
    /// any decision-relevant state).
    fn victim_score(&mut self, s: StorageId) -> f64 {
        let mut ctx = ScoreCtx {
            graph: &self.graph,
            uf: &mut self.uf,
            scratch: &mut self.scratch,
            clock: self.stats.clock,
            rng: &mut self.rng,
            accesses: &mut self.stats.metadata_accesses,
            root_buf: &mut self.root_buf,
        };
        score(self.cfg.heuristic, s, &mut ctx)
    }
}

/// The runtime as the *requester's* side of an arbitrated reservation: one
/// victim search per call, so an N=1-tenant serve run issues exactly the
/// same `select_victim`/`evict` sequence as the fixed-budget `free_for`
/// loop (the decision-exactness property pinned in `tests/serve_exact.rs`).
impl<B: Backend> LocalEvictor for Runtime<B> {
    fn peek_scored(&mut self) -> Option<(StorageId, f64, u64)> {
        let v = self.select_victim()?;
        let bytes = self.graph.storage(v).size;
        // `h_rand` draws from the decision RNG inside `score`; peeking must
        // not advance that stream, so random victims compare as score 0
        // (cross-shard arbitration over h_rand is arbitrary anyway).
        let score = if matches!(self.cfg.heuristic, Heuristic::Random) {
            0.0
        } else {
            self.victim_score(v)
        };
        Some((v, score, bytes))
    }

    fn evict_storage(&mut self, s: StorageId) -> u64 {
        let bytes = self.graph.storage(s).size;
        self.evict(s);
        bytes
    }

    fn resident_bytes(&self) -> u64 {
        self.stats.memory
    }
}

impl<B: Backend> Drop for Runtime<B> {
    /// Return every still-resident byte to the shard lease: sessions are
    /// per-step objects, and without this the lease ledger would leak the
    /// pinned constants (which no eviction ever refunds) every step.
    fn drop(&mut self) {
        if let Some(g) = &self.cfg.gate {
            // Shared constants were never charged to this lease: their one
            // physical copy lives in the cross-shard store, whose refcount
            // drop refunds the arbiter's shared ledger separately.
            let leased = self.stats.memory.saturating_sub(self.shared_bytes);
            if leased > 0 {
                g.0.on_free(leased);
            }
            // The tenant is between steps: its published fleet-tournament
            // minimum (if any) names tensors that no longer exist. Empty
            // matches what a remote peek would now see (`RemotePeek::Gone`
            // → the arbiter skips the shard).
            if let Some(slot) = g.0.min_slot() {
                slot.publish_empty();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::backend::NullBackend;

    fn rt(budget: u64, h: Heuristic) -> Runtime<NullBackend> {
        let cfg = Config { budget, heuristic: h, ..Config::default() };
        Runtime::new(cfg, NullBackend::new())
    }

    /// Run a linear chain of n unit ops under `budget` memory units.
    fn run_chain(rtm: &mut Runtime<NullBackend>, n: usize) -> Vec<TensorId> {
        let mut ts = vec![rtm.constant(1)];
        for i in 0..n {
            let t = rtm
                .call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)])
                .unwrap()[0];
            ts.push(t);
        }
        ts
    }

    #[test]
    fn unbudgeted_chain_no_remat() {
        let mut r = rt(u64::MAX, Heuristic::dtr_eq());
        run_chain(&mut r, 32);
        assert_eq!(r.stats.remat_count, 0);
        assert_eq!(r.stats.base_compute, 32);
        assert_eq!(r.stats.memory, 33);
        r.check_invariants().unwrap();
    }

    #[test]
    fn budget_forces_eviction_and_access_remats() {
        let mut r = rt(8, Heuristic::lru());
        let ts = run_chain(&mut r, 32);
        assert!(r.stats.evict_count > 0, "must have evicted under budget");
        assert!(r.stats.memory <= 8);
        // Access an early evicted tensor: recursive remat.
        let victim = ts[5];
        assert!(!r.is_defined(victim));
        r.access(victim).unwrap();
        assert!(r.is_defined(victim));
        assert!(r.stats.remat_count > 0);
        r.check_invariants().unwrap();
    }

    #[test]
    fn all_heuristics_complete_chain() {
        for h in Heuristic::fig2_set() {
            let mut r = rt(10, h);
            let ts = run_chain(&mut r, 64);
            r.access(*ts.last().unwrap()).unwrap();
            assert!(r.stats.memory <= 10, "{} over budget", h.name());
            r.check_invariants().unwrap();
        }
    }

    #[test]
    fn oom_when_budget_below_working_set() {
        // One op needs input (1) + output (1) = 2 units; budget 2 minus the
        // pinned constant leaves 1 unit -> second call cannot fit both its
        // locked input and output.
        let mut r = rt(2, Heuristic::lru());
        let c = r.constant(1);
        let t1 = r.call("f0", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
        let err = r.call("f1", 1, &[t1], &[OutSpec::sized(5)]);
        assert!(err.is_err(), "allocation larger than budget must fail");
    }

    #[test]
    fn constants_never_evicted() {
        let mut r = rt(4, Heuristic::size());
        let c = r.constant(2);
        run_chain_from(&mut r, c, 16);
        assert!(r.is_resident(c), "constant was evicted");
        r.check_invariants().unwrap();
    }

    fn run_chain_from(r: &mut Runtime<NullBackend>, from: TensorId, n: usize) -> Vec<TensorId> {
        let mut ts = vec![from];
        for i in 0..n {
            let t = r.call(&format!("g{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
            ts.push(t);
        }
        ts
    }

    #[test]
    fn eager_eviction_on_release() {
        let mut r = rt(u64::MAX, Heuristic::dtr_eq());
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        let before = r.stats.memory;
        r.release(t1);
        assert_eq!(r.stats.memory, before - 4, "eager policy must evict on last release");
        assert!(!r.is_resident(t1));
    }

    #[test]
    fn ignore_policy_keeps_released() {
        let mut r = Runtime::new(
            Config { policy: DeallocPolicy::Ignore, ..Config::default() },
            NullBackend::new(),
        );
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        r.release(t1);
        assert!(r.is_resident(t1));
    }

    #[test]
    fn banish_frees_and_pins_children() {
        let mut r = Runtime::new(
            Config { policy: DeallocPolicy::Banish, ..Config::default() },
            NullBackend::new(),
        );
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        let t2 = r.call("g", 1, &[t1], &[OutSpec::sized(4)]).unwrap()[0];
        r.release(t1);
        // t2 resident (no evicted dependents) -> banish succeeds.
        assert!(!r.is_resident(t1));
        let s2 = r.graph.storage_of(t2);
        assert!(r.graph.storage(s2).pinned, "child of banished storage must be pinned");
        assert!(r.graph.storage(r.graph.storage_of(t1)).banished);
    }

    #[test]
    fn banish_blocked_by_evicted_dependent() {
        let mut r = Runtime::new(
            Config {
                policy: DeallocPolicy::Banish,
                budget: u64::MAX,
                ..Config::default()
            },
            NullBackend::new(),
        );
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        let t2 = r.call("g", 1, &[t1], &[OutSpec::sized(4)]).unwrap()[0];
        // Manually evict t2 then release t1: banish must be deferred.
        let s2 = r.graph.storage_of(t2);
        r.evict(s2);
        r.release(t1);
        assert!(!r.graph.storage(r.graph.storage_of(t1)).banished);
        assert!(r.is_resident(t1), "banish deferred; storage stays");
        // Rematerialize t2 -> pending banish should fire.
        r.access(t2).unwrap();
        assert!(r.graph.storage(r.graph.storage_of(t1)).banished);
    }

    #[test]
    fn banish_can_free_constants() {
        let mut r = Runtime::new(
            Config { policy: DeallocPolicy::Banish, ..Config::default() },
            NullBackend::new(),
        );
        let c = r.constant(8);
        let _t1 = r.call("f", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
        let before = r.stats.memory;
        r.release(c);
        assert_eq!(r.stats.memory, before - 8, "banish must free the constant");
    }

    #[test]
    fn alias_outputs_occupy_no_memory() {
        let mut r = rt(u64::MAX, Heuristic::dtr_eq());
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        let before = r.stats.memory;
        let v = r.call("view", 0, &[t1], &[OutSpec::alias(0)]).unwrap()[0];
        assert_eq!(r.stats.memory, before);
        assert_eq!(r.graph.storage_of(v), r.graph.storage_of(t1));
        assert!(r.is_defined(v));
    }

    #[test]
    fn evicting_storage_undefines_all_views_and_remats_separately() {
        let mut r = rt(u64::MAX, Heuristic::dtr_eq());
        let c = r.constant(1);
        let t1 = r.call("f", 1, &[c], &[OutSpec::sized(4)]).unwrap()[0];
        let v = r.call("view", 0, &[t1], &[OutSpec::alias(0)]).unwrap()[0];
        let s = r.graph.storage_of(t1);
        r.evict(s);
        assert!(!r.is_defined(t1));
        assert!(!r.is_defined(v));
        // Access the alias: must remat the root (storage) then the view op.
        r.access(v).unwrap();
        assert!(r.is_defined(v));
        assert!(r.is_defined(t1));
        r.check_invariants().unwrap();
    }

    #[test]
    fn multi_output_evicted_separately_rematerialized_together() {
        let mut r = rt(u64::MAX, Heuristic::dtr_eq());
        let c = r.constant(1);
        let outs = r
            .call("multi", 3, &[c], &[OutSpec::sized(2), OutSpec::sized(2)])
            .unwrap();
        let (a, b) = (outs[0], outs[1]);
        r.evict(r.graph.storage_of(a));
        r.evict(r.graph.storage_of(b));
        let mem_before = r.stats.memory;
        r.access(a).unwrap();
        // Replaying `multi` rematerializes both outputs.
        assert!(r.is_defined(a) && r.is_defined(b));
        assert_eq!(r.stats.memory, mem_before + 4);
        // Now evict only b and access a: replay double-computes b and frees
        // the ephemeral immediately (memory returns to resident set size).
        r.evict(r.graph.storage_of(b));
        r.evict(r.graph.storage_of(a));
        r.access(b).unwrap();
        r.check_invariants().unwrap();
    }

    #[test]
    fn deep_chain_recursive_remat() {
        let mut r = rt(6, Heuristic::dtr_eq());
        let ts = run_chain(&mut r, 200);
        // Touch the far end then the beginning: long recursive remats.
        r.access(ts[199]).unwrap();
        r.access(ts[3]).unwrap();
        assert!(r.stats.memory <= 6);
        r.check_invariants().unwrap();
    }

    #[test]
    fn pin_live_outputs_holds_results() {
        let mut r = rt(6, Heuristic::lru());
        let ts = run_chain(&mut r, 32);
        // Release everything but the last two (the "gradients").
        for &t in &ts[1..31] {
            r.release(t);
        }
        r.pin_live_outputs().unwrap();
        assert!(r.is_defined(ts[31]));
        assert!(r.is_defined(ts[32]));
        r.check_invariants().unwrap();
    }

    #[test]
    fn stats_slowdown_sane() {
        let mut r = rt(8, Heuristic::dtr_eq());
        let ts = run_chain(&mut r, 64);
        r.access(ts[1]).unwrap();
        let s = &r.stats;
        assert!(s.slowdown() >= 1.0);
        assert_eq!(s.total_compute(), s.base_compute + s.remat_compute);
    }

    #[test]
    fn sqrt_sampling_still_terminates() {
        let mut r = Runtime::new(
            Config {
                budget: 12,
                sqrt_sample: true,
                small_filter: true,
                ..Config::default()
            },
            NullBackend::new(),
        );
        let ts = run_chain(&mut r, 128);
        r.access(ts[64]).unwrap();
        assert!(r.stats.memory <= 12);
        r.check_invariants().unwrap();
    }

    #[test]
    fn metadata_accesses_ordering() {
        // h_dtr (exact e*) must touch far more metadata than h_local. This
        // is the *scan-path* Fig. 12 semantics: force PolicyKind::Scan so
        // every candidate reruns its traversal.
        let counts: Vec<u64> = [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::dtr_local()]
            .iter()
            .map(|&h| {
                let cfg =
                    Config { budget: 8, heuristic: h, index: PolicyKind::Scan, ..Config::default() };
                let mut r = Runtime::new(cfg, NullBackend::new());
                let ts = run_chain(&mut r, 128);
                r.access(ts[1]).unwrap();
                r.stats.metadata_accesses
            })
            .collect();
        assert!(counts[0] > counts[1], "e* {} <= eq {}", counts[0], counts[1]);
        assert!(counts[1] > counts[2], "eq {} <= local {}", counts[1], counts[2]);
    }

    #[test]
    fn cached_index_touches_less_metadata_than_scan() {
        // The whole point of the E.1 optimizations: identical decisions,
        // fewer metadata accesses.
        let run = |kind: PolicyKind| {
            let cfg = Config {
                budget: 16,
                heuristic: Heuristic::dtr(),
                index: kind,
                trace_victims: true,
                ..Config::default()
            };
            let mut r = Runtime::new(cfg, NullBackend::new());
            let ts = run_chain(&mut r, 192);
            r.access(ts[1]).unwrap();
            r.access(ts[150]).unwrap();
            r.check_invariants().unwrap();
            r.stats.clone()
        };
        let scan = run(PolicyKind::Scan);
        let indexed = run(PolicyKind::Indexed);
        assert!(scan.same_decisions(&indexed), "victim sequences diverged");
        assert!(
            indexed.metadata_accesses < scan.metadata_accesses,
            "indexed {} >= scan {}",
            indexed.metadata_accesses,
            scan.metadata_accesses
        );
    }

    /// The Auto hybrid: a pool below the crossover is served by the plain
    /// scan (zero index metadata), growing past it upgrades to the kinetic
    /// differential index mid-drain, and the full victim sequence is
    /// identical to the reference scan across the upgrade boundary.
    #[test]
    fn auto_index_upgrades_at_the_pool_crossover() {
        use super::policy::AUTO_CROSSOVER_POOL;
        let drive = |kind: PolicyKind| {
            let cfg = Config { heuristic: Heuristic::dtr(), index: kind, ..Config::default() };
            let mut r = Runtime::new(cfg, NullBackend::new());
            // Start below the crossover: the hybrid must stay in scan mode.
            let ts = run_chain(&mut r, AUTO_CROSSOVER_POOL - 32);
            let mut victims = Vec::new();
            for _ in 0..8 {
                victims.push(r.evict_one().expect("pool drained early"));
            }
            let pre_upgrade_meta = r.index_metadata_len();
            // Grow past the crossover and keep draining: the first pop at
            // or past the threshold flips the hybrid over.
            run_chain_from(&mut r, ts[ts.len() - 1], 64);
            assert!(r.pool_len() >= AUTO_CROSSOVER_POOL, "pool never reached the crossover");
            for _ in 0..32 {
                victims.push(r.evict_one().expect("pool drained early"));
            }
            r.check_invariants().unwrap();
            (victims, pre_upgrade_meta, r.index_metadata_len())
        };
        let (scan_victims, _, _) = drive(PolicyKind::Scan);
        let (auto_victims, pre, post) = drive(PolicyKind::Auto);
        assert_eq!(scan_victims, auto_victims, "victim sequences diverged");
        assert_eq!(pre, 0, "hybrid paid index metadata below the crossover");
        assert!(post > 0, "hybrid never upgraded past the crossover");
    }

    #[test]
    fn auto_crossover_config_boundaries() {
        use super::policy::AUTO_CROSSOVER_POOL;
        // The knob prices the scan/differential boundary per run: 0 and 1
        // upgrade at the very first pop, the 512 default stays in scan mode
        // for a small pool — victim sequences identical throughout.
        let drive = |kind: PolicyKind, crossover: usize| {
            let cfg = Config {
                heuristic: Heuristic::dtr(),
                index: kind,
                auto_crossover: crossover,
                ..Config::default()
            };
            let mut r = Runtime::new(cfg, NullBackend::new());
            run_chain(&mut r, 64);
            let mut victims = Vec::new();
            for _ in 0..16 {
                victims.push(r.evict_one().expect("pool drained early"));
            }
            r.check_invariants().unwrap();
            (victims, r.index_metadata_len())
        };
        let (reference, _) = drive(PolicyKind::Scan, AUTO_CROSSOVER_POOL);
        for crossover in [0, 1] {
            let (victims, meta) = drive(PolicyKind::Auto, crossover);
            assert_eq!(victims, reference, "crossover {crossover} diverged");
            assert!(meta > 0, "crossover {crossover} never upgraded");
        }
        let (victims, meta) = drive(PolicyKind::Auto, AUTO_CROSSOVER_POOL);
        assert_eq!(victims, reference, "default crossover diverged");
        assert_eq!(meta, 0, "64-entry pool upgraded below the 512 default");
    }

    #[test]
    fn evict_one_drains_pool_in_policy_order() {
        let mut r = rt(u64::MAX, Heuristic::lru());
        let ts = run_chain(&mut r, 8);
        let pool_before = r.pool_len();
        assert!(pool_before > 0);
        let first = r.evict_one().unwrap();
        // h_lru: the stalest storage is the chain's first output.
        assert_eq!(first, r.graph.storage_of(ts[1]));
        let mut evicted = 1;
        while r.evict_one().is_some() {
            evicted += 1;
        }
        assert_eq!(evicted, pool_before);
        assert_eq!(r.pool_len(), 0);
        assert!(r.evict_one().is_none());
        r.check_invariants().unwrap();
    }

    #[test]
    fn pool_bytes_counter_tracks_membership() {
        let mut r = rt(6, Heuristic::dtr_eq());
        let ts = run_chain(&mut r, 64);
        r.access(ts[32]).unwrap();
        r.check_invariants().unwrap(); // asserts pool_bytes == fresh sum
        for &t in &ts[1..20] {
            r.release(t);
        }
        r.check_invariants().unwrap();
    }

    #[test]
    fn shared_constants_are_resident_pinned_and_never_victims() {
        let mut r = rt(8, Heuristic::lru());
        let w = r.constant_shared(4);
        assert_eq!(r.stats.memory, 4);
        assert_eq!(r.shared_bytes(), 4);
        assert!(r.is_resident(w));
        // Shared weights are invisible to eviction: a chain that forces a
        // steady eviction stream must never pick the shared storage.
        let cfg_victims = {
            let mut rr = Runtime::new(
                Config {
                    budget: 8,
                    heuristic: Heuristic::lru(),
                    trace_victims: true,
                    ..Config::default()
                },
                NullBackend::new(),
            );
            let w = rr.constant_shared(4);
            let ts = run_chain_from(&mut rr, w, 32);
            rr.access(ts[32]).unwrap();
            assert!(rr.stats.evict_count > 0, "budget never bound");
            let ws = rr.graph.storage_of(w);
            assert!(rr.is_resident(w), "shared weight was evicted");
            rr.check_invariants().unwrap();
            (ws, rr.stats.victims.clone())
        };
        assert!(
            !cfg_victims.1.contains(&cfg_victims.0),
            "shared storage appeared in the victim trace"
        );
    }

    #[test]
    fn banishing_a_shared_constant_clears_shared_bytes() {
        let mut r = Runtime::new(
            Config { policy: DeallocPolicy::Banish, ..Config::default() },
            NullBackend::new(),
        );
        let w = r.constant_shared(8);
        let _t = r.call("f", 1, &[w], &[OutSpec::sized(1)]).unwrap()[0];
        assert_eq!(r.shared_bytes(), 8);
        r.release(w);
        assert_eq!(r.shared_bytes(), 0, "banish must release the shared-byte gauge");
        assert_eq!(r.graph.resident_bytes(), r.stats.memory);
        r.check_invariants().unwrap();
    }

    #[test]
    fn indexed_runtime_survives_banish_policy() {
        for h in [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::lru(), Heuristic::size()] {
            let cfg = Config {
                budget: 10,
                heuristic: h,
                policy: DeallocPolicy::Banish,
                index: PolicyKind::Indexed,
                ..Config::default()
            };
            let mut r = Runtime::new(cfg, NullBackend::new());
            let ts = run_chain(&mut r, 48);
            for &t in &ts[1..24] {
                r.release(t);
            }
            r.access(ts[48]).unwrap();
            r.check_invariants().unwrap();
        }
    }
}
