//! Index newtypes for the DTR arenas. Everything is arena-allocated and
//! referenced by dense u32 ids, which keeps the metadata structures flat and
//! cheap to traverse (the eviction loop touches them constantly).

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u32);

        impl $name {
            #[inline]
            pub fn idx(self) -> usize {
                self.0 as usize
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}{}", stringify!($name).chars().next().unwrap(), self.0)
            }
        }
    };
}

id_type!(
    /// A buffer of device memory (the unit DTR evicts/rematerializes).
    StorageId
);
id_type!(
    /// A view of a storage; the unit operators produce and consume.
    TensorId
);
id_type!(
    /// A recorded operator application (the rematerialization closure).
    OpId
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idx_roundtrip() {
        assert_eq!(StorageId(7).idx(), 7);
        assert_eq!(TensorId(0).idx(), 0);
        assert_eq!(OpId(42).idx(), 42);
    }

    #[test]
    fn display() {
        assert_eq!(StorageId(3).to_string(), "S3");
        assert_eq!(TensorId(3).to_string(), "T3");
        assert_eq!(OpId(3).to_string(), "O3");
    }
}
