//! Exact evicted-neighborhood computation (`e*`, Sec. 2 / Appendix C.2) and
//! the MSPS rematerialization set (`e_R`, evicted-ancestor side only).
//!
//! For a resident storage `S`, `e*(S)` is the union of
//!  * the evicted *ancestors* reachable from `S` through evicted `deps`
//!    edges (the storages that must be rematerialized before `S` can be), and
//!  * the evicted *descendants* reachable through evicted `deps^T` edges
//!    (the storages that need `S` resident before they can be recomputed).
//!
//! These are computed by DFS over evicted nodes only; every node visit bumps
//! the graph's metadata-access counter so the Fig. 12 overhead comparison
//! reflects real traversal work. Banished storages are excluded (they are no
//! longer part of the dependency graph).

use super::graph::Graph;
use super::ids::StorageId;

/// Reusable DFS scratch space — allocated once per runtime to keep the hot
/// eviction loop allocation-free.
#[derive(Debug, Default)]
pub struct EvictedScratch {
    stack: Vec<StorageId>,
    /// Visit stamps, lazily grown; `stamp[s] == cur` means visited.
    stamp: Vec<u32>,
    cur: u32,
}

impl EvictedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // Stamp wrapped: reset.
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.cur = 1;
        }
        self.stack.clear();
    }

    #[inline]
    fn visit(&mut self, s: StorageId) -> bool {
        let st = &mut self.stamp[s.idx()];
        if *st == self.cur {
            false
        } else {
            *st = self.cur;
            true
        }
    }
}

#[inline]
fn evicted(g: &Graph, s: StorageId) -> bool {
    let st = g.storage(s);
    !st.resident && !st.banished
}

/// Sum of `local_cost` over the exact evicted neighborhood `e*(s)`, plus the
/// count of member storages. `accesses` is bumped per edge traversal.
pub fn estar_cost(
    g: &Graph,
    s: StorageId,
    scratch: &mut EvictedScratch,
    accesses: &mut u64,
) -> (f64, usize) {
    scratch.begin(g.storages.len());
    // Mark the origin so neither DFS re-enters it.
    scratch.visit(s);
    let mut cost = 0.0f64;
    let mut count = 0usize;

    // Ancestor side: evicted deps, transitively through evicted nodes.
    for &d in &g.storage(s).deps {
        *accesses += 1;
        if evicted(g, d) && scratch.visit(d) {
            scratch.stack.push(d);
        }
    }
    while let Some(x) = scratch.stack.pop() {
        cost += g.storage(x).local_cost as f64;
        count += 1;
        for &d in &g.storage(x).deps {
            *accesses += 1;
            if evicted(g, d) && scratch.visit(d) {
                scratch.stack.push(d);
            }
        }
    }

    // Descendant side: evicted dependents, transitively.
    for &d in &g.storage(s).dependents {
        *accesses += 1;
        if evicted(g, d) && scratch.visit(d) {
            scratch.stack.push(d);
        }
    }
    while let Some(x) = scratch.stack.pop() {
        cost += g.storage(x).local_cost as f64;
        count += 1;
        for &d in &g.storage(x).dependents {
            *accesses += 1;
            if evicted(g, d) && scratch.visit(d) {
                scratch.stack.push(d);
            }
        }
    }

    (cost, count)
}

/// Collect the members of `e*(s)` (for tests and the Theorem 3.1 heuristic
/// `h_{e*}` trace experiments; the hot path uses `estar_cost`).
pub fn estar_members(g: &Graph, s: StorageId, scratch: &mut EvictedScratch) -> Vec<StorageId> {
    let mut acc = 0u64;
    let mut members = Vec::new();
    scratch.begin(g.storages.len());
    scratch.visit(s);
    let push_from = |scratch: &mut EvictedScratch, seeds: &[StorageId]| {
        for &d in seeds {
            if evicted(g, d) && scratch.visit(d) {
                scratch.stack.push(d);
            }
        }
    };
    push_from(scratch, &g.storage(s).deps);
    while let Some(x) = scratch.stack.pop() {
        members.push(x);
        let deps = g.storage(x).deps.clone();
        push_from(scratch, &deps);
    }
    push_from(scratch, &g.storage(s).dependents);
    while let Some(x) = scratch.stack.pop() {
        members.push(x);
        let deps = g.storage(x).dependents.clone();
        push_from(scratch, &deps);
    }
    let _ = &mut acc;
    members
}

/// Collect the resident storages whose cached `e*`/remat-set numerators can
/// change when the state of `s` changes (residency flip, new views/edges,
/// banishment): `s` itself when resident, plus every resident storage
/// adjacent to the *undirected* evicted region reachable from `s`. This is a
/// conservative superset of the directed closures the heuristics traverse —
/// over-invalidation is sound; the policy indexes use it to dirty only a
/// graph neighborhood instead of the whole pool (Appendix E).
pub fn resident_frontier(
    g: &Graph,
    s: StorageId,
    scratch: &mut EvictedScratch,
    accesses: &mut u64,
    out: &mut Vec<StorageId>,
) {
    out.clear();
    scratch.begin(g.storages.len());
    scratch.visit(s);
    if g.storage(s).resident {
        out.push(s);
    }
    for d in g.neighbors(s) {
        *accesses += 1;
        if scratch.visit(d) {
            if evicted(g, d) {
                scratch.stack.push(d);
            } else if g.storage(d).resident {
                out.push(d);
            }
        }
    }
    while let Some(x) = scratch.stack.pop() {
        for d in g.neighbors(x) {
            *accesses += 1;
            if scratch.visit(d) {
                if evicted(g, d) {
                    scratch.stack.push(d);
                } else if g.storage(d).resident {
                    out.push(d);
                }
            }
        }
    }
}

/// MSPS rematerialization set cost: Σ local_cost over the evicted storages
/// that must be rematerialized before `s` can be recomputed (ancestor side
/// of `e*` only) — Peng et al. 2020's heuristic numerator.
pub fn remat_set_cost(
    g: &Graph,
    s: StorageId,
    scratch: &mut EvictedScratch,
    accesses: &mut u64,
) -> f64 {
    scratch.begin(g.storages.len());
    scratch.visit(s);
    let mut cost = 0.0f64;
    for &d in &g.storage(s).deps {
        *accesses += 1;
        if evicted(g, d) && scratch.visit(d) {
            scratch.stack.push(d);
        }
    }
    while let Some(x) = scratch.stack.pop() {
        cost += g.storage(x).local_cost as f64;
        for &d in &g.storage(x).deps {
            *accesses += 1;
            if evicted(g, d) && scratch.visit(d) {
                scratch.stack.push(d);
            }
        }
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::graph::Graph;
    use crate::dtr::ids::TensorId;

    /// Build the Figure-1 example: linear chain t0..t7 where resident set is
    /// {t0, t2, t3, t6}; then e*(t2) = {t1, t4} and e*(t3) = {t1, t4, t5}.
    fn fig1_graph() -> (Graph, Vec<StorageId>) {
        let mut g = Graph::new();
        let mut storages = Vec::new();
        let mut prev: Option<TensorId> = None;
        for i in 0..7 {
            let s = g.new_storage(1, i as u32);
            let t = if let Some(p) = prev {
                let op = g.new_op(&format!("f{i}"), 1, vec![p]);
                let t = g.new_tensor(s, Some(op), false);
                g.ops[op.idx()].outputs.push(t);
                t
            } else {
                g.new_tensor(s, None, false)
            };
            storages.push(s);
            prev = Some(t);
        }
        // Residency per Fig 1: t0, t2, t3, t6 resident (indices 0..6 here:
        // our storages[i] is t_i).
        for (i, &s) in storages.iter().enumerate() {
            g.storage_mut(s).resident = matches!(i, 0 | 2 | 3 | 6);
        }
        (g, storages)
    }

    #[test]
    fn fig1_evicted_neighborhoods() {
        let (g, ss) = fig1_graph();
        let mut scratch = EvictedScratch::new();
        let mut acc = 0u64;
        // Note: the paper's Fig. 1 network is branched; our rebuild here is a
        // pure chain, so the expected sets follow chain semantics.
        // e*(t2): evicted ancestor t1 (stop at resident t0); descendant side
        // stops immediately at resident t3 -> {t1}.
        let (c2, n2) = estar_cost(&g, ss[2], &mut scratch, &mut acc);
        assert_eq!(n2, 1);
        assert_eq!(c2, 1.0);
        let m2 = estar_members(&g, ss[2], &mut scratch);
        assert_eq!(m2, vec![ss[1]]);
        // e*(t3): ancestor side empty (t2 resident); evicted descendants
        // {t4, t5} (stop at resident t6).
        let mut m3 = estar_members(&g, ss[3], &mut scratch);
        m3.sort();
        assert_eq!(m3, vec![ss[4], ss[5]]);
        let (c3, n3) = estar_cost(&g, ss[3], &mut scratch, &mut acc);
        assert_eq!((c3, n3), (2.0, 2));
    }

    #[test]
    fn estar_empty_when_neighbors_resident() {
        let (mut g, ss) = fig1_graph();
        for &s in &ss {
            g.storage_mut(s).resident = true;
        }
        let mut scratch = EvictedScratch::new();
        let mut acc = 0u64;
        for &s in &ss {
            let (c, n) = estar_cost(&g, s, &mut scratch, &mut acc);
            assert_eq!((c, n), (0.0, 0));
        }
    }

    #[test]
    fn banished_excluded() {
        let (mut g, ss) = fig1_graph();
        g.storage_mut(ss[4]).banished = true;
        let mut scratch = EvictedScratch::new();
        let mut acc = 0u64;
        // t3's descendants: t4 banished → stops traversal; t5 unreachable.
        let (_, n) = estar_cost(&g, ss[3], &mut scratch, &mut acc);
        assert_eq!(n, 0);
    }

    #[test]
    fn remat_set_is_ancestor_side_only() {
        let (g, ss) = fig1_graph();
        let mut scratch = EvictedScratch::new();
        let mut acc = 0u64;
        // t6 resident; its evicted ancestors are t5, t4 (stop at resident t3).
        let c = remat_set_cost(&g, ss[6], &mut scratch, &mut acc);
        assert_eq!(c, 2.0);
        // t2: ancestor side is just t1.
        let c2 = remat_set_cost(&g, ss[2], &mut scratch, &mut acc);
        assert_eq!(c2, 1.0);
    }

    #[test]
    fn accesses_grow_with_neighborhood() {
        let (g, ss) = fig1_graph();
        let mut scratch = EvictedScratch::new();
        let mut small = 0u64;
        let mut large = 0u64;
        estar_cost(&g, ss[6], &mut scratch, &mut small); // neighborhood {t4,t5}
        // Evict more first: compare vs a node with empty neighborhood.
        estar_cost(&g, ss[0], &mut scratch, &mut large);
        assert!(small > large);
    }
}
