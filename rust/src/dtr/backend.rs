//! Compute backends. The DTR runtime is backend-agnostic: the simulator uses
//! `NullBackend` (pure cost accounting, Appendix C), while the real engine
//! plugs in `crate::exec::ExecBackend`, which holds actual host buffers and
//! delegates operator execution to a pluggable `crate::runtime::Executor`
//! (pure-Rust interpreter by default, PJRT under the `pjrt` feature).

use super::ids::TensorId;
use anyhow::Result;

/// Executes operator replays and owns the concrete buffers.
///
/// Buffers are keyed by *root tensor id*: one buffer per storage. Alias
/// views carry no data of their own (size 0), matching the paper's
/// storage/tensor split.
///
/// `Send` is a supertrait: a `Runtime<B>` must be movable to (and lockable
/// from) worker threads so sessions can shard over threads under one
/// arbitrated budget (`crate::serve`).
pub trait Backend: Send {
    /// Execute operator `name`, reading buffers for `inputs` and producing
    /// buffers for `outputs` (root tensors only need storage; alias outputs
    /// may be ignored by the backend).
    fn execute(&mut self, name: &str, inputs: &[TensorId], outputs: &[TensorId]) -> Result<()>;

    /// Drop buffers for evicted root tensors.
    fn free(&mut self, roots: &[TensorId]);
}

/// Accounting-only backend: the simulator's "device".
#[derive(Debug, Default)]
pub struct NullBackend {
    pub executed: u64,
    pub freed: u64,
}

impl NullBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Backend for NullBackend {
    fn execute(&mut self, _name: &str, _inputs: &[TensorId], _outputs: &[TensorId]) -> Result<()> {
        self.executed += 1;
        Ok(())
    }

    fn free(&mut self, roots: &[TensorId]) {
        self.freed += roots.len() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_backend_counts() {
        let mut b = NullBackend::new();
        b.execute("f", &[], &[]).unwrap();
        b.free(&[TensorId(0), TensorId(1)]);
        assert_eq!(b.executed, 1);
        assert_eq!(b.freed, 2);
    }
}
