//! Union-find over evicted components with per-component running cost sums —
//! the data structure behind the paper's `h_DTR^eq` heuristic (Sec. 4.1,
//! Appendix C.2 "Relaxed (Union-Find) evicted neighborhood").
//!
//! Supported operations:
//!  * `make_set()` — fresh empty component (cost 0);
//!  * `union(a, b)` — merge components, summing costs;
//!  * `add_cost` / `sub_cost` — adjust a component's running sum;
//!  * `find` — representative (with path halving).
//!
//! Splitting is *not* supported (that is the point of the approximation):
//! when a storage is rematerialized the caller subtracts its local cost from
//! its old component and maps the storage to a fresh empty set, leaving
//! "phantom dependencies" behind, exactly as described in the paper.
//!
//! Every parent-chain hop is reported to an access counter so the Fig. 12
//! metadata-overhead experiment can count storage/metadata touches.

#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    /// Running cost sum; meaningful at component roots only.
    cost: Vec<f64>,
    /// Metadata-access counter (Fig. 12 / Appendix D.3).
    pub accesses: u64,
}

impl UnionFind {
    pub fn new() -> Self {
        UnionFind { parent: Vec::new(), rank: Vec::new(), cost: Vec::new(), accesses: 0 }
    }

    /// Create a fresh singleton component with zero cost; returns its handle.
    pub fn make_set(&mut self) -> u32 {
        let id = self.parent.len() as u32;
        self.parent.push(id);
        self.rank.push(0);
        self.cost.push(0.0);
        id
    }

    /// Find with path halving.
    pub fn find(&mut self, mut x: u32) -> u32 {
        loop {
            self.accesses += 1;
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
    }

    /// Merge the components of `a` and `b`, summing their running costs.
    /// Returns the new root.
    pub fn union(&mut self, a: u32, b: u32) -> u32 {
        match self.union_roots(a, b) {
            Some((kept, _)) => kept,
            None => self.find(a),
        }
    }

    /// Like [`UnionFind::union`], but reports what happened: `Some((kept,
    /// absorbed))` root pair when two distinct components merged, `None` if
    /// they were already one. Lets eviction indexes invalidate per-component
    /// subscriptions without redundant `find` traversals.
    pub fn union_roots(&mut self, a: u32, b: u32) -> Option<(u32, u32)> {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return None;
        }
        self.accesses += 1;
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        self.cost[hi as usize] += self.cost[lo as usize];
        self.cost[lo as usize] = 0.0;
        Some((hi, lo))
    }

    /// Running cost sum of `x`'s component.
    pub fn component_cost(&mut self, x: u32) -> f64 {
        let r = self.find(x);
        self.cost[r as usize]
    }

    /// Add `c` to `x`'s component; returns the component root so eviction
    /// indexes can invalidate cached ẽ* sums subscribed to it.
    pub fn add_cost(&mut self, x: u32, c: f64) -> u32 {
        let r = self.find(x);
        self.cost[r as usize] += c;
        r
    }

    /// Subtract `c` from `x`'s component (the splitting approximation:
    /// rematerialization removes a cost but not the connectivity). Returns
    /// the component root, like [`UnionFind::add_cost`].
    pub fn sub_cost(&mut self, x: u32, c: f64) -> u32 {
        let r = self.find(x);
        self.cost[r as usize] -= c;
        // Numerical hygiene: running sums can drift slightly negative after
        // long simulate/remat interleavings; clamp at zero.
        if self.cost[r as usize] < 0.0 {
            self.cost[r as usize] = 0.0;
        }
        r
    }

    pub fn same_set(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }
}

impl Default for UnionFind {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_has_zero_cost() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        assert_eq!(uf.component_cost(a), 0.0);
    }

    #[test]
    fn union_sums_costs() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let b = uf.make_set();
        uf.add_cost(a, 2.0);
        uf.add_cost(b, 3.0);
        uf.union(a, b);
        assert_eq!(uf.component_cost(a), 5.0);
        assert_eq!(uf.component_cost(b), 5.0);
        assert!(uf.same_set(a, b));
    }

    #[test]
    fn union_idempotent() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let b = uf.make_set();
        uf.add_cost(a, 1.0);
        uf.union(a, b);
        uf.union(b, a);
        assert_eq!(uf.component_cost(a), 1.0);
    }

    #[test]
    fn sub_cost_models_split_approximation() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let b = uf.make_set();
        let c = uf.make_set();
        uf.add_cost(a, 1.0);
        uf.add_cost(b, 2.0);
        uf.add_cost(c, 4.0);
        uf.union(a, b);
        uf.union(b, c);
        assert_eq!(uf.component_cost(a), 7.0);
        // "Rematerialize" b: subtract its cost, move it to a fresh set.
        uf.sub_cost(b, 2.0);
        let b2 = uf.make_set();
        assert_eq!(uf.component_cost(a), 5.0);
        assert_eq!(uf.component_cost(b2), 0.0);
        // Phantom connectivity: a and c remain merged even though b split them.
        assert!(uf.same_set(a, c));
    }

    #[test]
    fn cost_never_negative() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        uf.add_cost(a, 1.0);
        uf.sub_cost(a, 5.0);
        assert_eq!(uf.component_cost(a), 0.0);
    }

    #[test]
    fn chain_unions_transitive() {
        let mut uf = UnionFind::new();
        let hs: Vec<u32> = (0..64).map(|_| uf.make_set()).collect();
        for w in hs.windows(2) {
            uf.union(w[0], w[1]);
        }
        for &h in &hs {
            assert!(uf.same_set(hs[0], h));
        }
    }

    #[test]
    fn accesses_counted() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let before = uf.accesses;
        uf.find(a);
        assert!(uf.accesses > before);
    }
}
