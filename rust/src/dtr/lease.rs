//! The budget-lease seam: how a `Runtime` participates in a *shared* memory
//! budget instead of owning a fixed one.
//!
//! The paper's §5 prototype interposes on a single allocator; a serving
//! deployment (`crate::serve`) runs many tenants against **one** global byte
//! budget, so the budget check in [`Runtime::free_for`] splits in two:
//!
//! * **fast path** — [`BudgetGate::try_reserve`]: a lock-free reservation
//!   against the shard's current *lease* (byte allowance). No arbitration,
//!   no cross-thread traffic; this is the common case.
//! * **slow path** — [`BudgetGate::reserve`]: the shard's lease is
//!   exhausted, so the gate escalates to the central arbiter, which may
//!   grant unleased budget, revoke idle leases, or reclaim bytes by
//!   evicting the *globally* least-valuable evictable tensor — possibly
//!   from another shard ([`RemoteEvictor`]), possibly from the requester
//!   itself ([`LocalEvictor`], passed in by `&mut` because the requesting
//!   thread already holds its own runtime).
//!
//! The traits live in `dtr` (not `serve`) so the runtime stays ignorant of
//! arbitration policy: a `Runtime` only knows how to reserve, refund, and
//! surrender victims. `crate::serve::BudgetArbiter` is the one production
//! implementation; tests can plug in anything.
//!
//! Deadlock discipline: a remote reclaim may only use `try_lock` on another
//! shard's runtime ([`RuntimeHandle`]), and the requester's own runtime is
//! reached exclusively through the `&mut dyn LocalEvictor` argument — so no
//! thread ever *blocks* on a runtime mutex while holding another.

use std::fmt;
use std::sync::{Arc, Mutex, TryLockError, Weak};

use anyhow::Result;

use super::backend::Backend;
use super::ids::StorageId;
use super::policy::MinSlot;
use super::runtime::Runtime;

/// The requester's own runtime, surrendered to the arbiter for the duration
/// of one slow-path reservation. Implemented by [`Runtime`].
pub trait LocalEvictor {
    /// Run one victim search and return the would-be victim with its
    /// heuristic score and size — without evicting it. The caller either
    /// evicts it via [`LocalEvictor::evict_storage`] or discards the peek
    /// (a better victim existed on another shard).
    fn peek_scored(&mut self) -> Option<(StorageId, f64, u64)>;

    /// Evict a specific storage (previously peeked); returns its size.
    fn evict_storage(&mut self, s: StorageId) -> u64;

    /// Bytes currently resident (for OOM diagnostics).
    fn resident_bytes(&self) -> u64;
}

/// Result of peeking another shard's victim candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RemotePeek {
    /// The shard's runtime is locked by its own thread right now.
    Busy,
    /// The shard's runtime has been dropped (between serving steps).
    Gone,
    /// The shard has nothing evictable.
    Empty,
    /// The shard's least-valuable evictable storage.
    Candidate { score: f64, bytes: u64 },
}

/// Result of asking another shard to evict its top victim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RemoteReclaim {
    Busy,
    Gone,
    Empty,
    /// Evicted; this many bytes were freed (credited to the *owner's*
    /// headroom — the arbiter revokes them on its next pass).
    Freed(u64),
}

/// A cross-shard eviction handle: lets the arbiter reclaim memory from a
/// shard it does not own. Implementations must never block on the shard's
/// runtime lock.
pub trait RemoteEvictor: Send + Sync {
    fn peek(&self) -> RemotePeek;
    fn reclaim_top(&self) -> RemoteReclaim;
}

/// [`RemoteEvictor`] over a shared runtime, as handed out by
/// `api::Session`: a weak reference (sessions are per-step; a tenant
/// between steps is simply `Gone`) plus `try_lock`-only access.
pub struct RuntimeHandle<B: Backend> {
    rt: Weak<Mutex<Runtime<B>>>,
}

impl<B: Backend> RuntimeHandle<B> {
    pub fn new(rt: Weak<Mutex<Runtime<B>>>) -> RuntimeHandle<B> {
        RuntimeHandle { rt }
    }
}

impl<B: Backend> RemoteEvictor for RuntimeHandle<B> {
    fn peek(&self) -> RemotePeek {
        let Some(arc) = self.rt.upgrade() else { return RemotePeek::Gone };
        match arc.try_lock() {
            Ok(mut rt) => match rt.peek_scored() {
                Some((_, score, bytes)) => RemotePeek::Candidate { score, bytes },
                None => RemotePeek::Empty,
            },
            Err(TryLockError::WouldBlock) => RemotePeek::Busy,
            Err(TryLockError::Poisoned(_)) => RemotePeek::Gone,
        }
    }

    fn reclaim_top(&self) -> RemoteReclaim {
        let Some(arc) = self.rt.upgrade() else { return RemoteReclaim::Gone };
        match arc.try_lock() {
            Ok(mut rt) => match rt.peek_scored() {
                Some((s, _, _)) => RemoteReclaim::Freed(rt.evict_storage(s)),
                None => RemoteReclaim::Empty,
            },
            Err(TryLockError::WouldBlock) => RemoteReclaim::Busy,
            Err(TryLockError::Poisoned(_)) => RemoteReclaim::Gone,
        }
    }
}

/// A shard's view of a shared budget. All byte deltas of the runtime's
/// resident set flow through here so the lease ledger can never drift from
/// the runtime's own accounting (`Stats::memory`).
pub trait BudgetGate: Send + Sync {
    /// Short name for diagnostics (`Debug` on [`GateRef`]).
    fn name(&self) -> &'static str {
        "gate"
    }

    /// Fast path: atomically take `bytes` from the shard's current lease
    /// headroom. Returns false if the lease is exhausted (caller escalates
    /// to [`BudgetGate::reserve`]).
    fn try_reserve(&self, bytes: u64) -> bool;

    /// Slow path: arbitrate. On success `bytes` are reserved; on failure
    /// the global pool is genuinely exhausted (a true OOM).
    fn reserve(&self, bytes: u64, local: &mut dyn LocalEvictor) -> Result<()>;

    /// Reserve bytes for a pinned constant. Constants never trigger
    /// eviction in DTR (the paper's runtime registers them unconditionally;
    /// feasibility floors are the caller's concern), so this may overdraw
    /// the lease — the overdraft is visible to the arbiter's ledger.
    fn reserve_pinned(&self, bytes: u64);

    /// The runtime's resident set grew by `bytes` (the reservation was
    /// already taken); gauge update only.
    fn on_alloc(&self, bytes: u64);

    /// The runtime's resident set shrank by `bytes`: refund the lease
    /// headroom (eviction, banishment, ephemeral double-compute frees, and
    /// the runtime's final drop all land here).
    fn on_free(&self, bytes: u64);

    /// (Re)register the cross-shard eviction handle for the shard's
    /// *current* runtime. Sessions are per-step objects, so this is called
    /// once per session construction.
    fn bind(&self, remote: Arc<dyn RemoteEvictor>);

    /// The shard's leaf in the fleet-wide eviction tournament, if the gate
    /// participates in one (`serve::BudgetArbiter` under
    /// `GlobalIndexKind::Shared`). The runtime hands this slot to its
    /// victim-selection index (`PolicyIndex::bind_slot`) so every local
    /// minimum change is published for the arbiter to read lock-free;
    /// gates outside a fleet return `None` and the runtime publishes
    /// nothing.
    fn min_slot(&self) -> Option<Arc<MinSlot>> {
        None
    }
}

/// The budget-side contract of content-addressed pinned-weight sharing
/// (`api::store::WeightStore`): one *global* ledger charged exactly once
/// per distinct pinned buffer, however many shards intern it.
///
/// This is deliberately not part of [`BudgetGate`]: shared weights belong
/// to no single shard's lease. The store charges the ledger when a buffer
/// is first interned and refunds it when the **last** holder releases it;
/// the arbiter subtracts the shared total from the grantable pool so the
/// freed budget flows to activations instead of duplicate weights (Coop's
/// pooled-memory lesson — see the `serve` module docs).
pub trait PinnedLedger: Send + Sync {
    /// A distinct pinned buffer of `bytes` entered the shared store.
    fn charge_shared(&self, bytes: u64);

    /// The last holder of a shared buffer released it.
    fn refund_shared(&self, bytes: u64);
}

/// Ledger that ignores charges — for stores used outside a serving pool
/// (single-tenant runs and unit tests of the store mechanics).
#[derive(Debug, Default)]
pub struct NullLedger;

impl PinnedLedger for NullLedger {
    fn charge_shared(&self, _bytes: u64) {}
    fn refund_shared(&self, _bytes: u64) {}
}

/// Cloneable, `Debug`-able handle to a [`BudgetGate`], carried inside
/// [`super::Config`]. Cloning a `Config` (one session per training step)
/// keeps pointing at the same shard lease.
#[derive(Clone)]
pub struct GateRef(pub Arc<dyn BudgetGate>);

impl GateRef {
    pub fn new(gate: Arc<dyn BudgetGate>) -> GateRef {
        GateRef(gate)
    }
}

impl fmt::Debug for GateRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "GateRef({})", self.0.name())
    }
}
