//! Storage/Tensor/Operator arenas and the storage-level dependency graph
//! (Appendix C.1/C.2 of the paper).
//!
//! In the paper's model (mirroring PyTorch): a *storage* is a buffer of
//! device memory, a *tensor* is a view of a storage, and an *operator* is a
//! pure function from tensors to tensors. DTR evicts and rematerializes at
//! storage granularity; `deps(S)`/`deps^T(S)` are the storage-level
//! dependency edges induced by the parent operators of every view of `S`.

use super::ids::{OpId, StorageId, TensorId};

/// A recorded operator application — the rematerialization closure: replay
/// `op` on `inputs` to recompute `outputs`.
#[derive(Debug, Clone)]
pub struct Operator {
    pub name: String,
    /// Logical compute cost (the simulator's time unit; nanoseconds when the
    /// log carries measured times, FLOP-derived units for generated logs).
    pub cost: u64,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

/// A view of a storage. `defined` tracks whether this view is currently
/// materialized: a tensor becomes undefined when its storage is evicted and
/// is re-defined only when its own parent operator is replayed (the paper's
/// `defined(t)` condition — view metadata is destroyed with the storage).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub storage: StorageId,
    /// Parent operator. Constants have no parent (not rematerializable).
    pub op: Option<OpId>,
    pub defined: bool,
    /// True iff this tensor is not the root view of its storage.
    pub alias: bool,
}

/// A buffer of memory plus DTR metadata.
#[derive(Debug, Clone)]
pub struct Storage {
    pub size: u64,
    pub root: TensorId,
    pub tensors: Vec<TensorId>,
    pub resident: bool,
    /// Lock count held by in-flight (re)materializations.
    pub locks: u32,
    /// Pinned storages are unevictable: constants, banish-neighbors, and
    /// final outputs. Pinned storages may still be banished.
    pub pinned: bool,
    /// Content-addressed shared constant (`Runtime::constant_shared`): the
    /// bytes live in a cross-shard `WeightStore` and are charged to the
    /// arbiter's shared ledger, not to this runtime's lease gate. Shared
    /// storages are always pinned, so they are invisible to eviction; the
    /// flag only routes the gate accounting on banish/teardown.
    pub shared: bool,
    pub banished: bool,
    /// External (user program) reference count.
    pub refs: u32,
    /// Logical time of last access (max over views).
    pub last_access: u64,
    /// Cached `cost(S)` = Σ cost(op(t)) over views t (Appendix C.2); updated
    /// when views are added.
    pub local_cost: u64,
    /// Storage-level dependencies (dedup'd, excludes self).
    pub deps: Vec<StorageId>,
    /// Storage-level dependents (dedup'd, excludes self).
    pub dependents: Vec<StorageId>,
    /// Union-find handle for the relaxed evicted neighborhood.
    pub uf: u32,
    /// Position in the evictable pool (`usize::MAX` when not pooled).
    pub pool_pos: usize,
}

impl Storage {
    #[inline]
    pub fn evictable(&self) -> bool {
        self.resident && self.locks == 0 && !self.pinned && !self.banished
    }
}

/// The arena. Also tracks `metadata_accesses` for the Fig. 12 experiment:
/// every dependency-edge traversal performed for heuristic evaluation or
/// metadata maintenance bumps the counter.
#[derive(Debug, Default)]
pub struct Graph {
    pub ops: Vec<Operator>,
    pub tensors: Vec<Tensor>,
    pub storages: Vec<Storage>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn op(&self, id: OpId) -> &Operator {
        &self.ops[id.idx()]
    }

    #[inline]
    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id.idx()]
    }

    #[inline]
    pub fn tensor_mut(&mut self, id: TensorId) -> &mut Tensor {
        &mut self.tensors[id.idx()]
    }

    #[inline]
    pub fn storage(&self, id: StorageId) -> &Storage {
        &self.storages[id.idx()]
    }

    #[inline]
    pub fn storage_mut(&mut self, id: StorageId) -> &mut Storage {
        &mut self.storages[id.idx()]
    }

    #[inline]
    pub fn storage_of(&self, t: TensorId) -> StorageId {
        self.tensors[t.idx()].storage
    }

    /// Allocate a new storage whose root view is created by the caller
    /// immediately after (root is patched in by `new_tensor`).
    pub fn new_storage(&mut self, size: u64, uf: u32) -> StorageId {
        let id = StorageId(self.storages.len() as u32);
        self.storages.push(Storage {
            size,
            root: TensorId(u32::MAX),
            tensors: Vec::new(),
            resident: false,
            locks: 0,
            pinned: false,
            shared: false,
            banished: false,
            refs: 0,
            last_access: 0,
            local_cost: 0,
            deps: Vec::new(),
            dependents: Vec::new(),
            uf,
            pool_pos: usize::MAX,
        });
        id
    }

    /// Register a tensor view of `storage` produced by `op` (None for
    /// constants). Maintains the storage-level dependency edges and the
    /// cached local cost.
    pub fn new_tensor(&mut self, storage: StorageId, op: Option<OpId>, alias: bool) -> TensorId {
        let id = TensorId(self.tensors.len() as u32);
        self.tensors.push(Tensor { storage, op, defined: false, alias });
        if self.storages[storage.idx()].root.0 == u32::MAX {
            self.storages[storage.idx()].root = id;
        }
        self.storages[storage.idx()].tensors.push(id);
        if let Some(op_id) = op {
            let cost = self.ops[op_id.idx()].cost;
            self.storages[storage.idx()].local_cost += cost;
            // Storage-level dependency edges from this view's parent op.
            let input_storages: Vec<StorageId> = self.ops[op_id.idx()]
                .inputs
                .iter()
                .map(|&t| self.tensors[t.idx()].storage)
                .collect();
            for s in input_storages {
                if s != storage && !self.storages[storage.idx()].deps.contains(&s) {
                    self.storages[storage.idx()].deps.push(s);
                    self.storages[s.idx()].dependents.push(storage);
                }
            }
        }
        id
    }

    pub fn new_op(
        &mut self,
        name: &str,
        cost: u64,
        inputs: Vec<TensorId>,
    ) -> OpId {
        let id = OpId(self.ops.len() as u32);
        self.ops.push(Operator { name: name.to_string(), cost, inputs, outputs: Vec::new() });
        id
    }

    /// Iterate the storage-level neighborhood of `s`: deps, then dependents
    /// (the undirected edge set the eviction indexes dirty along).
    pub fn neighbors(&self, s: StorageId) -> impl Iterator<Item = StorageId> + '_ {
        let st = &self.storages[s.idx()];
        st.deps.iter().chain(st.dependents.iter()).copied()
    }

    /// Is every view of this storage's op-cone banished-safe, i.e. does `S`
    /// have an evicted (non-banished) dependent? Banishing requires none
    /// (Appendix C.4: `deps_e^T(S) = ∅`).
    pub fn has_evicted_dependent(&self, s: StorageId) -> bool {
        self.storages[s.idx()]
            .dependents
            .iter()
            .any(|&d| {
                let st = &self.storages[d.idx()];
                !st.banished && !st.resident
            })
    }

    /// Total bytes of resident storages (accounting check).
    pub fn resident_bytes(&self) -> u64 {
        self.storages.iter().filter(|s| s.resident).map(|s| s.size).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup_chain() -> (Graph, Vec<TensorId>) {
        // c0 -> t1 -> t2 with simple ops.
        let mut g = Graph::new();
        let s0 = g.new_storage(4, 0);
        let t0 = g.new_tensor(s0, None, false);
        let op1 = g.new_op("f1", 10, vec![t0]);
        let s1 = g.new_storage(4, 1);
        let t1 = g.new_tensor(s1, Some(op1), false);
        g.ops[op1.idx()].outputs.push(t1);
        let op2 = g.new_op("f2", 20, vec![t1]);
        let s2 = g.new_storage(4, 2);
        let t2 = g.new_tensor(s2, Some(op2), false);
        g.ops[op2.idx()].outputs.push(t2);
        (g, vec![t0, t1, t2])
    }

    #[test]
    fn dependency_edges_maintained() {
        let (g, ts) = setup_chain();
        let s1 = g.storage_of(ts[1]);
        let s0 = g.storage_of(ts[0]);
        let s2 = g.storage_of(ts[2]);
        assert_eq!(g.storage(s1).deps, vec![s0]);
        assert_eq!(g.storage(s1).dependents, vec![s2]);
        assert_eq!(g.storage(s0).dependents, vec![s1]);
        assert!(g.storage(s2).dependents.is_empty());
    }

    #[test]
    fn local_cost_cached() {
        let (g, ts) = setup_chain();
        assert_eq!(g.storage(g.storage_of(ts[1])).local_cost, 10);
        assert_eq!(g.storage(g.storage_of(ts[2])).local_cost, 20);
        // Constant has no parent op → zero cost.
        assert_eq!(g.storage(g.storage_of(ts[0])).local_cost, 0);
    }

    #[test]
    fn alias_adds_view_cost_and_no_self_dep() {
        let (mut g, ts) = setup_chain();
        let s1 = g.storage_of(ts[1]);
        // View op: input t1, output aliases storage s1.
        let vop = g.new_op("view", 1, vec![ts[1]]);
        let tv = g.new_tensor(s1, Some(vop), true);
        g.ops[vop.idx()].outputs.push(tv);
        let st = g.storage(s1);
        // cost(S) = 10 (f1) + 1 (view)
        assert_eq!(st.local_cost, 11);
        // deps(S) must not include S itself.
        assert!(!st.deps.contains(&s1));
        assert_eq!(st.tensors.len(), 2);
        assert_eq!(st.root, ts[1]);
    }

    #[test]
    fn evicted_dependent_detection() {
        let (mut g, ts) = setup_chain();
        let s1 = g.storage_of(ts[1]);
        let s2 = g.storage_of(ts[2]);
        g.storage_mut(s2).resident = false;
        assert!(g.has_evicted_dependent(s1));
        g.storage_mut(s2).resident = true;
        assert!(!g.has_evicted_dependent(s1));
        // Banished dependents don't count.
        g.storage_mut(s2).resident = false;
        g.storage_mut(s2).banished = true;
        assert!(!g.has_evicted_dependent(s1));
    }

    #[test]
    fn evictable_conditions() {
        let (mut g, ts) = setup_chain();
        let s1 = g.storage_of(ts[1]);
        g.storage_mut(s1).resident = true;
        assert!(g.storage(s1).evictable());
        g.storage_mut(s1).locks = 1;
        assert!(!g.storage(s1).evictable());
        g.storage_mut(s1).locks = 0;
        g.storage_mut(s1).pinned = true;
        assert!(!g.storage(s1).evictable());
    }
}
