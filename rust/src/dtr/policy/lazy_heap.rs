//! Incremental index for *clock-free* heuristics (`h_MSPS`, `h_{e*}`, and
//! the staleness-ablated cells of the Appendix D.1 grid): Appendix E.1's
//! score caching as a lazy min-heap with stale-entry skipping.
//!
//! Without a staleness factor, a storage's score is constant between
//! invalidations, so a min-heap over cached `(score, id)` keys is exact.
//! Invalidation is lazy in both directions: a dirtied storage is queued and
//! re-keyed (a fresh generation pushed) only when the next `pop_min` runs,
//! and superseded or removed entries are skipped when they surface at the
//! top (generation mismatch / not-in-pool). Dirtying follows the same
//! neighborhood scopes as [`super::CachedCostScan`] — evicted-region DFS
//! for `e*`/MSPS numerators, union-find component subscriptions for
//! eq-class cells.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::super::graph::Graph;
use super::super::heuristics::{finish_score, Heuristic, InvalidationScope};
use super::super::ids::StorageId;
use super::{Dirtier, EqSubs, PolicyIndex, SelectCtx};

/// Heap entry: min `(score, id)` first (BinaryHeap is a max-heap, so `Ord`
/// is reversed). `gen` stamps validity against `Slot::gen`.
#[derive(Clone, Copy)]
struct Entry {
    score: f64,
    id: u32,
    gen: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: the max-heap surfaces the lowest (score, id).
        other
            .score
            .total_cmp(&self.score)
            .then_with(|| other.id.cmp(&self.id))
    }
}

#[derive(Clone, Copy, Default)]
struct Slot {
    in_pool: bool,
    dirty: bool,
    gen: u64,
    score: f64,
}

pub struct LazyHeapIndex {
    h: Heuristic,
    eq: bool,
    heap: BinaryHeap<Entry>,
    slots: Vec<Slot>,
    dirty_list: Vec<StorageId>,
    dirtier: Dirtier,
    subs: EqSubs,
}

fn queue_dirty(slots: &mut Vec<Slot>, dirty_list: &mut Vec<StorageId>, s: StorageId) {
    let i = s.idx();
    if slots.len() <= i {
        slots.resize(i + 1, Slot::default());
    }
    if slots[i].in_pool && !slots[i].dirty {
        slots[i].dirty = true;
        dirty_list.push(s);
    }
}

impl LazyHeapIndex {
    pub fn new(h: Heuristic) -> Self {
        debug_assert!(h.clock_free(), "{} is not clock-free", h.name());
        LazyHeapIndex {
            h,
            eq: h.invalidation_scope() == InvalidationScope::EqNeighborhood,
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            dirty_list: Vec::new(),
            dirtier: Dirtier::new(h),
            subs: EqSubs::default(),
        }
    }

    fn slot(&mut self, s: StorageId) -> usize {
        let i = s.idx();
        if self.slots.len() <= i {
            self.slots.resize(i + 1, Slot::default());
        }
        i
    }

    /// Re-key every queued dirty entry (fresh generation into the heap).
    fn refresh(&mut self, ctx: &mut SelectCtx<'_>) {
        while let Some(s) = self.dirty_list.pop() {
            let i = s.idx();
            if !self.slots[i].in_pool || !self.slots[i].dirty {
                continue;
            }
            let c = ctx.cached_cost_of(s);
            if self.eq {
                self.subs.bump(s);
                self.subs.subscribe(s, ctx.root_buf);
            }
            let st = ctx.graph.storage(s);
            let score = finish_score(self.h, c, st.size, st.last_access, ctx.clock);
            let slot = &mut self.slots[i];
            slot.dirty = false;
            slot.gen += 1;
            slot.score = score;
            self.heap.push(Entry { score, id: s.0, gen: slot.gen });
        }
    }

    fn entry_valid(&self, e: &Entry) -> bool {
        self.slots
            .get(e.id as usize)
            .map_or(false, |sl| sl.in_pool && !sl.dirty && sl.gen == e.gen)
    }

    /// Rebuild from live slots if lazy deletion let the heap balloon.
    fn maybe_compact(&mut self, pool: &[StorageId]) {
        if self.heap.len() > 4 * pool.len() + 64 {
            self.heap.clear();
            for &s in pool {
                let sl = &self.slots[s.idx()];
                if sl.in_pool && !sl.dirty {
                    self.heap.push(Entry { score: sl.score, id: s.0, gen: sl.gen });
                }
            }
        }
    }
}

impl PolicyIndex for LazyHeapIndex {
    fn name(&self) -> &'static str {
        "lazy_heap"
    }

    fn on_insert(&mut self, s: StorageId, _g: &Graph) {
        let i = self.slot(s);
        if !self.slots[i].in_pool {
            self.slots[i].in_pool = true;
            self.slots[i].dirty = false;
            queue_dirty(&mut self.slots, &mut self.dirty_list, s);
        }
    }

    fn on_remove(&mut self, s: StorageId, _g: &Graph) {
        let i = self.slot(s);
        self.slots[i].in_pool = false;
        self.slots[i].dirty = false;
        if self.eq {
            self.subs.bump(s);
        }
    }

    fn on_access(&mut self, _s: StorageId, _g: &Graph, _clock: u64) {
        // Clock-free scores ignore last_access.
    }

    fn invalidate(&mut self, s: StorageId, g: &Graph, accesses: &mut u64) {
        self.dirtier.collect(s, g, accesses);
        for &t in &self.dirtier.buf {
            queue_dirty(&mut self.slots, &mut self.dirty_list, t);
        }
    }

    fn on_component_touched(&mut self, root: u32) {
        let slots = &mut self.slots;
        let dirty_list = &mut self.dirty_list;
        self.subs.touched(root, |s| queue_dirty(slots, dirty_list, s));
    }

    fn on_components_merged(&mut self, kept: u32, absorbed: u32) {
        let slots = &mut self.slots;
        let dirty_list = &mut self.dirty_list;
        self.subs.merged(kept, absorbed, |s| queue_dirty(slots, dirty_list, s));
    }

    fn on_retire(&mut self, retired: &[StorageId], _g: &Graph) {
        for &s in retired {
            let i = self.slot(s);
            debug_assert!(!self.slots[i].in_pool, "retired storage still pooled");
            // Supersede any live heap entry and subscription generation;
            // stale heap entries drain through the usual lazy skipping.
            self.slots[i].gen += 1;
            self.subs.bump(s);
        }
        self.subs.sweep();
    }

    fn metadata_len(&self) -> usize {
        self.heap.len() + self.dirty_list.len() + self.subs.len()
    }

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        self.refresh(ctx);
        self.maybe_compact(ctx.pool);
        // Skip stale generations; the first valid entry is the argmin. With
        // the small-tensor filter on, set aside valid-but-small entries and
        // restore them afterwards; if everything is small, the scan's
        // starved fallback is the unfiltered argmin — the first one set
        // aside.
        let mut set_aside: Vec<Entry> = Vec::new();
        let mut found: Option<StorageId> = None;
        while let Some(&e) = self.heap.peek() {
            if !self.entry_valid(&e) {
                self.heap.pop();
                continue;
            }
            *ctx.accesses += 1;
            let s = StorageId(e.id);
            if ctx.min_size > 0 && ctx.graph.storage(s).size < ctx.min_size {
                set_aside.push(e);
                self.heap.pop();
                continue;
            }
            found = Some(s);
            break;
        }
        let result = found.or_else(|| set_aside.first().map(|e| StorageId(e.id)));
        for e in set_aside {
            self.heap.push(e);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::evicted::EvictedScratch;
    use crate::dtr::ids::TensorId;
    use crate::dtr::unionfind::UnionFind;
    use crate::util::rng::Rng;

    /// Linear chain with unit sizes and given costs, all resident.
    fn chain(costs: &[u64]) -> (Graph, Vec<StorageId>, UnionFind) {
        let mut g = Graph::new();
        let mut uf = UnionFind::new();
        let mut ss = Vec::new();
        let mut prev: Option<TensorId> = None;
        for (i, &c) in costs.iter().enumerate() {
            let h = uf.make_set();
            let s = g.new_storage(1, h);
            let t = if let Some(p) = prev {
                let op = g.new_op(&format!("f{i}"), c, vec![p]);
                let t = g.new_tensor(s, Some(op), false);
                g.ops[op.idx()].outputs.push(t);
                t
            } else {
                g.new_tensor(s, None, false)
            };
            g.storage_mut(s).resident = true;
            ss.push(s);
            prev = Some(t);
        }
        (g, ss, uf)
    }

    fn pop(
        idx: &mut LazyHeapIndex,
        g: &Graph,
        uf: &mut UnionFind,
        pool: &[StorageId],
        h: Heuristic,
    ) -> Option<StorageId> {
        let mut scratch = EvictedScratch::new();
        let mut rng = Rng::new(1);
        let mut acc = 0u64;
        let mut roots = Vec::new();
        let mut cost_ns = 0u64;
        let mut ctx = SelectCtx {
            pool,
            graph: g,
            uf,
            scratch: &mut scratch,
            clock: 10,
            rng: &mut rng,
            accesses: &mut acc,
            root_buf: &mut roots,
            heuristic: h,
            min_size: 0,
            sqrt_sample: false,
            profile: false,
            cost_ns: &mut cost_ns,
        };
        idx.pop_min(&mut ctx)
    }

    #[test]
    fn msps_pops_cheapest_and_tracks_invalidation() {
        let h = Heuristic::Msps;
        let (mut g, ss, mut uf) = chain(&[0, 50, 3, 40]);
        let mut idx = LazyHeapIndex::new(h);
        let pool: Vec<StorageId> = ss[1..].to_vec();
        for &s in &pool {
            idx.on_insert(s, &g);
        }
        // Cheapest local cost wins (no evictions yet): ss[2] (cost 3).
        assert_eq!(pop(&mut idx, &g, &mut uf, &pool, h), Some(ss[2]));
        // Evict ss[2]: its dependent ss[3] now carries its remat cost.
        g.storage_mut(ss[2]).resident = false;
        idx.on_remove(ss[2], &g);
        let mut acc = 0u64;
        idx.invalidate(ss[2], &g, &mut acc);
        let pool2 = vec![ss[1], ss[3]];
        // ss[3] now scores 40 + 3 (evicted ancestor) vs ss[1]'s 50.
        assert_eq!(pop(&mut idx, &g, &mut uf, &pool2, h), Some(ss[3]));
        // A stale heap entry for ss[2] must be skipped, and re-keying must
        // have happened only for the dirtied neighborhood.
        assert!(acc > 0);
    }

    #[test]
    fn estar_count_prefers_empty_neighborhood() {
        let h = Heuristic::EStarCount;
        let (mut g, ss, mut uf) = chain(&[0, 1, 1, 1, 1]);
        g.storage_mut(ss[2]).resident = false;
        let pool = vec![ss[1], ss[3], ss[4]];
        let mut idx = LazyHeapIndex::new(h);
        for &s in &pool {
            idx.on_insert(s, &g);
        }
        let mut acc = 0u64;
        idx.invalidate(ss[2], &g, &mut acc);
        // ss[4] has |e*| = 0; ss[1] and ss[3] border the evicted ss[2].
        assert_eq!(pop(&mut idx, &g, &mut uf, &pool, h), Some(ss[4]));
    }
}
