//! Eviction-policy subsystem: victim selection behind the [`PolicyIndex`]
//! seam, plus the deallocation policies ([`DeallocPolicy`]).
//!
//! The paper's prototype (§3.2, Appendix E) notes that a naive greedy
//! runtime pays O(pool) *per eviction* — every search rescans every
//! evictable storage and recomputes its heuristic from scratch — and
//! describes the runtime optimizations that remove this cost: caching
//! heuristic scores and lazily invalidating only the neighborhood a change
//! can reach (E.1), tracking evicted-component metadata through a union-find
//! (Appendix C.2), and approximating the search itself (√n sampling and the
//! small-tensor filter, E.2). This module is those optimizations as a
//! pluggable index family:
//!
//! | index                 | heuristics                  | paper mechanism |
//! |-----------------------|-----------------------------|-----------------|
//! | [`ScanIndex`]         | everything (reference)      | the unoptimized O(pool) argmin; also hosts the E.2 √n-sample + small-filter *search strategies* |
//! | [`StalenessListIndex`]| `h_LRU`                     | staleness is monotone in access order, so an intrusive list ordered by `last_access` pops the argmin in O(1) |
//! | [`SizeHeapIndex`]     | `h_size`                    | sizes are immutable, so a lazy max-size heap with stale-entry skipping is exact |
//! | [`LazyHeapIndex`]     | clock-free scores: `h_MSPS`, `h_{e*}`, staleness-ablated grid cells | E.1 score caching as a lazy min-heap: invalidation re-keys only the dirtied graph/eq-class neighborhood; stale generations are skipped on pop |
//! | [`CachedCostScan`]    | staleness-bearing grid cells (fallback under [`PolicyKind::Cached`]) | E.1 cost caching: the expensive `e*`/ẽ*/local numerator is cached and invalidated per neighborhood; the staleness denominator is recomputed in a cheap O(pool) pass |
//! | [`DifferentialIndex`] | `h_DTR`, `h_DTR^eq`, `h_DTR^local`, `h_LRU`-shaped cells, staleness-bearing grid cells | epoch tiers over the factored score + a kinetic tournament: `pop_min` in O(log) amortized, no O(pool) pass |
//! | [`AutoIndex`]         | staleness-bearing cells under [`PolicyKind::Auto`] | [`ScanIndex`] until the pool reaches [`AUTO_CROSSOVER_POOL`], then a one-way decision-exact upgrade to [`DifferentialIndex`] — small serve pools skip the kinetic bookkeeping entirely |
//! | [`FleetTournament`]   | cross-*shard* layer (not a [`PolicyIndex`]) | Coop's pooled-reclaim lesson + PAPER §5 central-allocator interposition: one tournament whose leaves are each shard's published tier-minimum, so the serving arbiter's global victim choice is O(log shards) instead of one peek per peer |
//!
//! Why `h_DTR` is *not* a plain heap: its score `c(S)/[m(S)·staleness(S)]`
//! re-orders as the clock advances (a cheap-but-fresh storage overtakes an
//! expensive-but-stale one), so no clock-independent key exists and a
//! cached-key min-heap would return wrong victims. The expensive part of the
//! score is the numerator's evicted-neighborhood traversal, and that is what
//! gets cached: evicting or rematerializing a storage dirties only the
//! resident frontier of its evicted region ([`InvalidationScope`]), driven
//! for ẽ* by union-find component subscriptions
//! ([`PolicyIndex::on_component_touched`]).
//!
//! [`CachedCostScan`] stops there and still pays an O(pool) staleness pass
//! per eviction. [`DifferentialIndex`] removes that last linear pass by
//! applying the differential-dataflow arrangement idea (SNIPPETS.md
//! Snippets 2–3: maintain indexed state under streams of updates, doing
//! work only where inputs changed) to the score's factorization: storages
//! sharing one `last_access` epoch divide by the same staleness, so their
//! relative order is frozen forever — each epoch keeps an ordered *tier*
//! keyed on the exact rational `c/m`, and a kinetic tournament over the
//! O(#epochs) tier minima schedules, per pairwise match, the one future
//! clock at which its winner flips (the score difference is linear in the
//! clock). Numerator invalidations become differential re-keys of just the
//! dirtied storages; `on_access` migrates one storage to the newest epoch;
//! an arbitrary clock advance replays only the expired certificates. See
//! `differential.rs` for the PAPER Appendix E mapping in detail.
//!
//! Every index is **decision-exact**: it must produce the *identical victim
//! sequence* as [`ScanIndex`] for its heuristic (ties broken by lowest
//! [`StorageId`]), differing only in metadata-access counts and wall time.
//! `tests/prop_policy_equiv.rs` pins this property over random training
//! tapes. `h_rand` and √n sampling are inherently RNG-stream-coupled, so
//! [`make_index`] routes them to the scan (under [`PolicyKind::Indexed`]
//! the exact indexes take precedence and sampling is a no-op).
//!
//! Caveat: scan ties are detected on IEEE-equal `f64` scores while the
//! specialized indexes compare the underlying integers, so equivalence
//! additionally assumes clocks/sizes below 2^52 (where `1/x` is still
//! injective in `f64`) — 52 days of nanosecond clock.
//!
//! ## The fleet layer (`fleet.rs`)
//!
//! Per-shard indexes answer "what is *my* cheapest tensor"; the serving
//! arbiter needs "what is the *fleet's* cheapest tensor" (Coop argues
//! eviction silos waste exactly the memory multi-tenancy is supposed to
//! pool, and PAPER §5 interposes DTR at the central allocator for the same
//! reason). [`MinSlot`] is the publish seam: a seqlock-protected
//! `(score, id)` cell each [`DifferentialIndex`] refreshes whenever its
//! local minimum changes, and [`FleetTournament`] is the arbiter-side
//! tournament tree over those slots — O(log shards) per global victim
//! query, generation-stamped so shard churn can never resurrect a dead
//! shard's leaf. The published score is bitwise-identical to
//! `heuristics::finish_score` (the numerator is a lossless integer), which
//! is what makes the shared path decision-exact against the peek loop it
//! replaces (`tests/serve_exact.rs`).

mod auto;
mod cached;
mod dealloc;
mod differential;
mod fleet;
mod lazy_heap;
mod scan;
mod size_heap;
mod staleness;

use std::sync::Arc;
use std::time::Instant;

pub use auto::{AutoIndex, AUTO_CROSSOVER_POOL};
pub use cached::CachedCostScan;
pub use dealloc::DeallocPolicy;
pub use differential::DifferentialIndex;
pub use fleet::{FleetTournament, Leaf, MinSlot, SlotRead};
pub use lazy_heap::LazyHeapIndex;
pub use scan::ScanIndex;
pub use size_heap::SizeHeapIndex;
pub use staleness::StalenessListIndex;

use super::evicted::{resident_frontier, EvictedScratch};
use super::graph::Graph;
use super::heuristics::{
    cached_cost, score, staleness_param, CostKind, Heuristic, InvalidationScope, ScoreCtx,
};
use super::ids::StorageId;
use super::unionfind::UnionFind;
use crate::util::rng::Rng;

/// Policy-selection knob (`Config::index`): which victim-selection index
/// family the runtime builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Default: an exact incremental index where one exists for the
    /// configured heuristic, the reference scan otherwise (and whenever √n
    /// sampling is requested, whose semantics are scan-coupled).
    Auto,
    /// Always the reference linear scan.
    Scan,
    /// Prefer the exact index even when √n sampling is requested (the
    /// index's exact argmin supersedes the sampled approximation).
    Indexed,
    /// Force the O(pool)-per-pop [`CachedCostScan`] for the staleness-bearing
    /// family (the oracle-adjacent fallback the differential index is
    /// benchmarked and tested against); other heuristics route as `Indexed`.
    Cached,
    /// Force the [`DifferentialIndex`] for *every* staleness-bearing
    /// heuristic — including `h_LRU`-shaped cells that `Auto` gives the
    /// specialized staleness list; other heuristics route as `Indexed`.
    Differential,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Auto => "auto",
            PolicyKind::Scan => "scan",
            PolicyKind::Indexed => "indexed",
            PolicyKind::Cached => "cached",
            PolicyKind::Differential => "differential",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "auto" => PolicyKind::Auto,
            "scan" => PolicyKind::Scan,
            "indexed" | "index" => PolicyKind::Indexed,
            "cached" | "cached_scan" => PolicyKind::Cached,
            "differential" | "diff" => PolicyKind::Differential,
            _ => return None,
        })
    }

    pub fn all() -> [PolicyKind; 5] {
        [
            PolicyKind::Auto,
            PolicyKind::Scan,
            PolicyKind::Indexed,
            PolicyKind::Cached,
            PolicyKind::Differential,
        ]
    }
}

/// Everything a victim search may read or account against, borrowed
/// disjointly from the runtime for the duration of one `pop_min`.
pub struct SelectCtx<'a> {
    /// The evictable pool (membership source of truth; scan iteration order).
    pub pool: &'a [StorageId],
    pub graph: &'a Graph,
    pub uf: &'a mut UnionFind,
    pub scratch: &'a mut EvictedScratch,
    pub clock: u64,
    pub rng: &'a mut Rng,
    /// Metadata-access counter (Fig. 12).
    pub accesses: &'a mut u64,
    /// Scratch for dedup'ing UF roots during ẽ* queries; after a
    /// [`SelectCtx::cached_cost_of`] call on an eq-class heuristic it holds
    /// the distinct component roots the query observed.
    pub root_buf: &'a mut Vec<u32>,
    pub heuristic: Heuristic,
    /// Small-tensor filter threshold in bytes (0 = filter off).
    pub min_size: u64,
    /// Appendix E.2 √n sampling requested (honored by the scan only).
    pub sqrt_sample: bool,
    /// Measure heuristic-evaluation wall time into `cost_ns`.
    pub profile: bool,
    pub cost_ns: &'a mut u64,
}

impl SelectCtx<'_> {
    /// Full score of `s` (fresh numerator), with profiling accounting.
    pub fn score_of(&mut self, s: StorageId) -> f64 {
        let t0 = if self.profile { Some(Instant::now()) } else { None };
        let h = self.heuristic;
        let mut sctx = ScoreCtx {
            graph: self.graph,
            uf: &mut *self.uf,
            scratch: &mut *self.scratch,
            clock: self.clock,
            rng: &mut *self.rng,
            accesses: &mut *self.accesses,
            root_buf: &mut *self.root_buf,
        };
        let v = score(h, s, &mut sctx);
        if let Some(t) = t0 {
            *self.cost_ns += t.elapsed().as_nanos() as u64;
        }
        v
    }

    /// Cacheable numerator of `s` (see `heuristics::cached_cost`), with
    /// profiling accounting. For eq-class heuristics the observed component
    /// roots are left in `self.root_buf`.
    pub fn cached_cost_of(&mut self, s: StorageId) -> f64 {
        let t0 = if self.profile { Some(Instant::now()) } else { None };
        let h = self.heuristic;
        let mut sctx = ScoreCtx {
            graph: self.graph,
            uf: &mut *self.uf,
            scratch: &mut *self.scratch,
            clock: self.clock,
            rng: &mut *self.rng,
            accesses: &mut *self.accesses,
            root_buf: &mut *self.root_buf,
        };
        let v = cached_cost(h, s, &mut sctx);
        if let Some(t) = t0 {
            *self.cost_ns += t.elapsed().as_nanos() as u64;
        }
        v
    }
}

/// Incremental victim-selection index. The runtime reports every pool
/// membership change, access, and heuristic-relevant state change; in
/// exchange `pop_min` must return exactly the storage the reference scan
/// would pick (lowest score, ties by lowest id).
///
/// `pop_min` *peeks*: the caller is expected to evict the returned storage
/// immediately, which removes it through [`PolicyIndex::on_remove`].
pub trait PolicyIndex: Send {
    fn name(&self) -> &'static str;

    /// `s` entered the evictable pool.
    fn on_insert(&mut self, s: StorageId, g: &Graph);

    /// `s` left the evictable pool (evicted, locked, pinned, or banished).
    fn on_remove(&mut self, s: StorageId, g: &Graph);

    /// `s`'s `last_access` advanced to `clock` (it may or may not be pooled).
    fn on_access(&mut self, s: StorageId, g: &Graph, clock: u64);

    /// The logical clock advanced (staleness denominators shift globally).
    /// No current index needs it — the staleness list encodes order, and
    /// the cached-cost scan recomputes denominators per pass — but kinetic
    /// or epoch-batched indexes slot in here without touching the runtime.
    fn on_clock(&mut self, _clock: u64) {}

    /// The heuristic-relevant state around `s` changed: residency flip,
    /// new views/edges from a freshly recorded operator, or banishment.
    /// Indexes expand `s` to their [`InvalidationScope`] and drop any cached
    /// values that could depend on it. `accesses` counts maintenance
    /// traversals (Fig. 12).
    fn invalidate(&mut self, s: StorageId, g: &Graph, accesses: &mut u64);

    /// A union-find component's running cost changed (evict/remat
    /// add_cost/sub_cost on `root`).
    fn on_component_touched(&mut self, _root: u32) {}

    /// Two evicted components merged (`absorbed` into `kept`).
    fn on_components_merged(&mut self, _kept: u32, _absorbed: u32) {}

    /// A batch of storages was permanently retired (banished and pinned out
    /// of circulation forever): the index may drop every cache, dirty flag,
    /// and subscription it holds for them. Driven by the runtime's retired
    /// list (`Runtime::compact_index`), this is the GC hook that keeps
    /// long-lived serving sessions' index metadata flat under churn.
    fn on_retire(&mut self, _retired: &[StorageId], _g: &Graph) {}

    /// Approximate count of live metadata entries (dirty queues, heap
    /// entries, tier members, subscriptions) — the quantity `on_retire`
    /// compaction must hold flat. Excludes id-indexed slab vectors, which
    /// are proportional to the graph arena, not to index churn.
    fn metadata_len(&self) -> usize {
        0
    }

    /// Attach a fleet publish slot: indexes that can maintain their exact
    /// local minimum incrementally publish it here on every change, so the
    /// serving arbiter can read the fleet-wide argmin without touching this
    /// runtime. Indexes without an incremental minimum ignore the slot —
    /// their shard's leaf stays `NeedsPeek` and the arbiter falls back to
    /// the peek path for it, which is always correct.
    fn bind_slot(&mut self, _slot: Arc<MinSlot>) {}

    /// The current argmin under `ctx`, or `None` if the pool is empty or
    /// fully filtered with no fallback. Does not structurally remove the
    /// winner — the caller evicts it, triggering `on_remove`.
    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId>;
}

/// Build the victim-selection index for a heuristic under the given knob.
/// Default (`Auto`): indexed where an exact index exists, scan otherwise.
/// `auto_crossover` prices the [`AutoIndex`] scan/differential boundary
/// (`Config::auto_crossover`); `eager_migration` restores per-touch epoch
/// re-keying in the differential family (`Config::eager_migration`) in
/// place of the default lazy parking.
pub fn make_index(
    h: Heuristic,
    kind: PolicyKind,
    sqrt_sample: bool,
    auto_crossover: usize,
    eager_migration: bool,
) -> Box<dyn PolicyIndex> {
    let want_index = match kind {
        PolicyKind::Scan => false,
        PolicyKind::Auto => !sqrt_sample,
        PolicyKind::Indexed | PolicyKind::Cached | PolicyKind::Differential => true,
    };
    if !want_index || matches!(h, Heuristic::Random) {
        return Box::new(ScanIndex::new());
    }
    if kind == PolicyKind::Differential && staleness_param(h).is_some() {
        // Forced: every staleness-bearing cell, even the `h_LRU` shape the
        // staleness list would otherwise take (useful for equivalence tests
        // and benches of the kinetic machinery itself).
        return Box::new(DifferentialIndex::new(h).with_eager(eager_migration));
    }
    match h {
        Heuristic::Param(p) if p.cost == CostKind::NoCost && !p.use_size && p.use_staleness => {
            Box::new(StalenessListIndex::new())
        }
        Heuristic::Param(p) if p.cost == CostKind::NoCost && p.use_size && !p.use_staleness => {
            Box::new(SizeHeapIndex::new())
        }
        _ if h.clock_free() => Box::new(LazyHeapIndex::new(h)),
        Heuristic::Param(_) if kind == PolicyKind::Cached => Box::new(CachedCostScan::new(h)),
        Heuristic::Param(_) if kind == PolicyKind::Auto => {
            Box::new(AutoIndex::new(h, auto_crossover, eager_migration))
        }
        Heuristic::Param(_) => Box::new(DifferentialIndex::new(h).with_eager(eager_migration)),
        _ => Box::new(ScanIndex::new()),
    }
}

/// Shared lazy-invalidation helper: expands a changed storage to the set of
/// pool entries whose cached numerator must be recomputed, according to the
/// heuristic's [`InvalidationScope`].
pub(crate) struct Dirtier {
    scope: InvalidationScope,
    scratch: EvictedScratch,
    /// Output of the last [`Dirtier::collect`] call.
    pub(crate) buf: Vec<StorageId>,
}

impl Dirtier {
    pub(crate) fn new(h: Heuristic) -> Self {
        Dirtier {
            scope: h.invalidation_scope(),
            scratch: EvictedScratch::new(),
            buf: Vec::new(),
        }
    }

    /// Collect into [`Dirtier::buf`] the storages whose cached numerator may
    /// have changed when `s` changed.
    pub(crate) fn collect(&mut self, s: StorageId, g: &Graph, accesses: &mut u64) {
        self.buf.clear();
        match self.scope {
            InvalidationScope::Constant => {}
            InvalidationScope::SelfOnly => self.buf.push(s),
            InvalidationScope::EqNeighborhood => {
                // ẽ* reads only direct edges: s plus its resident direct
                // neighbors. Component-cost changes arrive separately
                // through the union-find subscription hooks.
                self.buf.push(s);
                for n in g.neighbors(s) {
                    *accesses += 1;
                    if g.storage(n).resident {
                        self.buf.push(n);
                    }
                }
            }
            InvalidationScope::EvictedRegion => {
                resident_frontier(g, s, &mut self.scratch, accesses, &mut self.buf);
                if !g.storage(s).resident {
                    // `s` itself may re-enter the pool before recomputation;
                    // make sure its own slot is dirtied too.
                    self.buf.push(s);
                }
            }
        }
    }
}

/// Shared eq-class subscription bookkeeping: which pool entries' cached ẽ*
/// sums read which union-find component roots. Generation tags make stale
/// subscriptions self-cleaning.
#[derive(Default)]
pub(crate) struct EqSubs {
    subs: std::collections::HashMap<u32, SubList>,
    gen: Vec<u64>,
}

/// Per-root subscriber list with a doubling compaction watermark: stale
/// generations are pruned only when the list doubles past the last live
/// size, keeping subscription amortized O(1) even for roots with thousands
/// of live subscribers.
#[derive(Default)]
struct SubList {
    entries: Vec<(u32, u64)>,
    watermark: usize,
}

impl EqSubs {
    fn slot(&mut self, s: StorageId) -> usize {
        let i = s.idx();
        if self.gen.len() <= i {
            self.gen.resize(i + 1, 0);
        }
        i
    }

    /// Invalidate any previous subscriptions of `s` (fresh cache incoming or
    /// entry leaving the pool).
    pub(crate) fn bump(&mut self, s: StorageId) {
        let i = self.slot(s);
        self.gen[i] += 1;
    }

    /// Register `s`'s fresh cache as depending on `roots`. Long-lived roots
    /// accumulate superseded-generation entries (they are otherwise pruned
    /// only when the root is touched), so compact a list in place once it
    /// doubles past its live watermark — untouched components stay bounded
    /// without O(list) work per subscription.
    pub(crate) fn subscribe(&mut self, s: StorageId, roots: &[u32]) {
        let i = self.slot(s);
        let g = self.gen[i];
        for &r in roots {
            let gen = &self.gen;
            let list = self.subs.entry(r).or_default();
            if list.entries.len() >= 64 && list.entries.len() >= list.watermark {
                list.entries
                    .retain(|&(sid, sg)| gen.get(StorageId(sid).idx()).copied() == Some(sg));
                list.watermark = 2 * list.entries.len().max(32);
            }
            list.entries.push((s.0, g));
        }
    }

    /// A component's cost changed: drain its live subscribers into `mark`.
    pub(crate) fn touched(&mut self, root: u32, mut mark: impl FnMut(StorageId)) {
        if let Some(list) = self.subs.remove(&root) {
            for (sid, g) in list.entries {
                let s = StorageId(sid);
                if self.gen.get(s.idx()).copied() == Some(g) {
                    mark(s);
                }
            }
        }
    }

    /// Components merged: both cost sums changed; drain both subscriber
    /// lists (survivors re-subscribe on their next recomputation).
    pub(crate) fn merged(&mut self, kept: u32, absorbed: u32, mut mark: impl FnMut(StorageId)) {
        for r in [kept, absorbed] {
            self.touched(r, &mut mark);
        }
    }

    /// Full GC sweep ([`PolicyIndex::on_retire`] path): drop every
    /// superseded-generation entry and every emptied root list. Unlike the
    /// per-subscribe watermark compaction, this also reclaims roots that are
    /// never touched again (permanently retired storages).
    pub(crate) fn sweep(&mut self) {
        let gen = &self.gen;
        self.subs.retain(|_, list| {
            list.entries
                .retain(|&(sid, sg)| gen.get(StorageId(sid).idx()).copied() == Some(sg));
            list.watermark = 2 * list.entries.len().max(32);
            !list.entries.is_empty()
        });
    }

    /// Total subscription entries held (live and not-yet-pruned).
    pub(crate) fn len(&self) -> usize {
        self.subs.values().map(|l| l.entries.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("bogus"), None);
    }

    #[test]
    fn factory_routes_exactly() {
        let route =
            |h: Heuristic, k: PolicyKind, sq: bool| make_index(h, k, sq, AUTO_CROSSOVER_POOL, false).name();
        // Reference scan: forced, sampled, or h_rand.
        assert_eq!(route(Heuristic::lru(), PolicyKind::Scan, false), "scan");
        assert_eq!(route(Heuristic::lru(), PolicyKind::Auto, true), "scan");
        assert_eq!(route(Heuristic::Random, PolicyKind::Indexed, false), "scan");
        // Exact indexes under Auto.
        assert_eq!(route(Heuristic::lru(), PolicyKind::Auto, false), "staleness_list");
        assert_eq!(route(Heuristic::size(), PolicyKind::Auto, false), "size_heap");
        // The staleness-bearing family gets the scan-until-crossover
        // hybrid under Auto: small serve pools never pay the kinetic
        // bookkeeping, large training pools upgrade at the first pop.
        assert_eq!(route(Heuristic::dtr(), PolicyKind::Auto, false), "auto_differential");
        assert_eq!(route(Heuristic::dtr_eq(), PolicyKind::Auto, false), "auto_differential");
        assert_eq!(route(Heuristic::dtr_local(), PolicyKind::Auto, false), "auto_differential");
        assert_eq!(route(Heuristic::Msps, PolicyKind::Auto, false), "lazy_heap");
        assert_eq!(route(Heuristic::EStarCount, PolicyKind::Auto, false), "lazy_heap");
        // Indexed overrides sampling.
        assert_eq!(route(Heuristic::lru(), PolicyKind::Indexed, true), "staleness_list");
        // Cached pins the O(pool) fallback for the family; other heuristics
        // keep their exact index.
        assert_eq!(route(Heuristic::dtr(), PolicyKind::Cached, false), "cached_cost_scan");
        assert_eq!(route(Heuristic::dtr_eq(), PolicyKind::Cached, false), "cached_cost_scan");
        assert_eq!(route(Heuristic::lru(), PolicyKind::Cached, false), "staleness_list");
        // Differential forces the kinetic index onto every staleness-bearing
        // cell, including the h_LRU shape.
        assert_eq!(route(Heuristic::lru(), PolicyKind::Differential, false), "differential");
        assert_eq!(route(Heuristic::dtr(), PolicyKind::Differential, false), "differential");
        assert_eq!(route(Heuristic::size(), PolicyKind::Differential, false), "size_heap");
        assert_eq!(route(Heuristic::Msps, PolicyKind::Differential, false), "lazy_heap");
        // Every ablation cell routes somewhere deterministic.
        for h in Heuristic::ablation_grid() {
            let name = route(h, PolicyKind::Auto, false);
            assert_ne!(name, "scan", "{} should have an exact index", h.name());
        }
    }
}
