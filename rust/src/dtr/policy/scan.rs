//! The reference victim search: argmin of the heuristic over the whole
//! evictable pool, recomputing every score from scratch — the unoptimized
//! O(pool)-per-eviction baseline the incremental indexes are measured
//! against, and the only correct home for the RNG-coupled strategies
//! (`h_rand` scoring, Appendix E.2 √n sampling).
//!
//! The E.2 search approximations live here as *index-level strategies*
//! rather than runtime special cases: one `consider` path serves the full
//! scan, the sampled scan, and the filter-starved fallback, so the scoring
//! logic exists exactly once.

use super::super::graph::Graph;
use super::super::ids::StorageId;
use super::{PolicyIndex, SelectCtx};

#[derive(Default)]
pub struct ScanIndex;

impl ScanIndex {
    pub fn new() -> Self {
        ScanIndex
    }

    /// Score `s` and fold it into `best` (lowest score wins; ties broken by
    /// lowest storage id). `filtered` applies the small-tensor threshold.
    fn consider(
        ctx: &mut SelectCtx<'_>,
        s: StorageId,
        filtered: bool,
        best: &mut Option<(f64, StorageId)>,
    ) {
        if filtered && ctx.graph.storage(s).size < ctx.min_size {
            return;
        }
        let sc = ctx.score_of(s);
        if best.map_or(true, |(b, bs)| sc < b || (sc == b && s.0 < bs.0)) {
            *best = Some((sc, s));
        }
    }

    fn scan(ctx: &mut SelectCtx<'_>, filtered: bool, best: &mut Option<(f64, StorageId)>) {
        let pool = ctx.pool;
        for &s in pool {
            Self::consider(ctx, s, filtered, best);
        }
    }
}

impl PolicyIndex for ScanIndex {
    fn name(&self) -> &'static str {
        "scan"
    }

    fn on_insert(&mut self, _s: StorageId, _g: &Graph) {}
    fn on_remove(&mut self, _s: StorageId, _g: &Graph) {}
    fn on_access(&mut self, _s: StorageId, _g: &Graph, _clock: u64) {}
    fn invalidate(&mut self, _s: StorageId, _g: &Graph, _accesses: &mut u64) {}

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        if ctx.pool.is_empty() {
            return None;
        }
        let mut best: Option<(f64, StorageId)> = None;

        if ctx.sqrt_sample && ctx.pool.len() > 4 {
            let pool = ctx.pool;
            let n = pool.len();
            let k = (n as f64).sqrt().ceil() as usize;
            let picks = ctx.rng.sample_indices(n, k.min(n));
            for idx in picks {
                Self::consider(ctx, pool[idx], true, &mut best);
            }
            // Fallback: if the sample was entirely filtered out, scan fully.
            if best.is_none() {
                Self::scan(ctx, true, &mut best);
            }
        } else {
            Self::scan(ctx, true, &mut best);
        }

        // Final fallback when the size filter starved the search.
        if best.is_none() && ctx.min_size > 0 {
            Self::scan(ctx, false, &mut best);
        }

        best.map(|(_, s)| s)
    }
}
