//! Incremental `h_size` index: a lazy max-size heap (paper §3.2 — the
//! "evict the biggest tensor" policy needs no rescans because sizes are
//! immutable).
//!
//! `h_size`'s score is `1/max(1, size)`: a fixed key per storage. The heap
//! orders by `(max(1, size) descending, id ascending)` — exactly the scan's
//! `(score, id)` order — and deletes lazily: entries for storages that left
//! the pool are skipped when they surface (stale-entry skipping). The
//! small-tensor filter is a no-op for this heuristic: if the largest
//! storage is below the threshold, every storage is, and the scan's
//! starved fallback picks the same argmin the unfiltered heap does.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::super::graph::Graph;
use super::super::ids::StorageId;
use super::{PolicyIndex, SelectCtx};

pub struct SizeHeapIndex {
    /// Max-heap over `(effective size, Reverse(id))`.
    heap: BinaryHeap<(u64, Reverse<u32>)>,
    in_pool: Vec<bool>,
}

impl Default for SizeHeapIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl SizeHeapIndex {
    pub fn new() -> Self {
        SizeHeapIndex { heap: BinaryHeap::new(), in_pool: Vec::new() }
    }

    fn slot(&mut self, s: StorageId) -> usize {
        let i = s.idx();
        if self.in_pool.len() <= i {
            self.in_pool.resize(i + 1, false);
        }
        i
    }

    /// Drop dead entries once they outnumber the live pool (keeps the heap
    /// linear in pool size despite lazy deletion).
    fn maybe_compact(&mut self, pool_len: usize) {
        if self.heap.len() > 2 * pool_len + 64 {
            let in_pool = &self.in_pool;
            let entries: Vec<_> = self
                .heap
                .drain()
                .filter(|&(_, Reverse(id))| in_pool.get(id as usize).copied().unwrap_or(false))
                .collect();
            self.heap = BinaryHeap::from(entries);
        }
    }
}

impl PolicyIndex for SizeHeapIndex {
    fn name(&self) -> &'static str {
        "size_heap"
    }

    fn on_insert(&mut self, s: StorageId, g: &Graph) {
        let size = g.storage(s).size.max(1);
        let i = self.slot(s);
        if !self.in_pool[i] {
            self.in_pool[i] = true;
            self.heap.push((size, Reverse(s.0)));
        }
    }

    fn on_remove(&mut self, s: StorageId, _g: &Graph) {
        let i = self.slot(s);
        self.in_pool[i] = false;
    }

    fn on_access(&mut self, _s: StorageId, _g: &Graph, _clock: u64) {}
    fn invalidate(&mut self, _s: StorageId, _g: &Graph, _accesses: &mut u64) {}

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        self.maybe_compact(ctx.pool.len());
        while let Some(&(_, Reverse(id))) = self.heap.peek() {
            if self.in_pool.get(id as usize).copied().unwrap_or(false) {
                *ctx.accesses += 1;
                return Some(StorageId(id));
            }
            self.heap.pop();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::evicted::EvictedScratch;
    use crate::dtr::heuristics::Heuristic;
    use crate::dtr::unionfind::UnionFind;
    use crate::util::rng::Rng;

    fn pop(idx: &mut SizeHeapIndex, g: &Graph, pool: &[StorageId]) -> Option<StorageId> {
        let mut uf = UnionFind::new();
        let mut scratch = EvictedScratch::new();
        let mut rng = Rng::new(1);
        let mut acc = 0u64;
        let mut roots = Vec::new();
        let mut cost_ns = 0u64;
        let mut ctx = SelectCtx {
            pool,
            graph: g,
            uf: &mut uf,
            scratch: &mut scratch,
            clock: 0,
            rng: &mut rng,
            accesses: &mut acc,
            root_buf: &mut roots,
            heuristic: Heuristic::size(),
            min_size: 0,
            sqrt_sample: false,
            profile: false,
            cost_ns: &mut cost_ns,
        };
        idx.pop_min(&mut ctx)
    }

    #[test]
    fn pops_largest_with_lazy_deletion_and_id_ties() {
        let mut g = Graph::new();
        let sizes = [4u64, 100, 100, 7];
        let ss: Vec<StorageId> = sizes
            .iter()
            .map(|&sz| {
                let s = g.new_storage(sz, 0);
                g.new_tensor(s, None, false);
                g.storage_mut(s).resident = true;
                s
            })
            .collect();
        let mut idx = SizeHeapIndex::new();
        for &s in &ss {
            idx.on_insert(s, &g);
        }
        // Tie on 100 bytes -> lowest id.
        assert_eq!(pop(&mut idx, &g, &ss), Some(ss[1]));
        idx.on_remove(ss[1], &g);
        assert_eq!(pop(&mut idx, &g, &ss), Some(ss[2]));
        idx.on_remove(ss[2], &g);
        assert_eq!(pop(&mut idx, &g, &ss), Some(ss[3]));
        // Re-insertion after leaving the pool is found again.
        idx.on_insert(ss[2], &g);
        assert_eq!(pop(&mut idx, &g, &ss), Some(ss[2]));
    }

    #[test]
    fn zero_sized_ties_with_one_byte() {
        // score uses max(1, size): a 0-byte and a 1-byte storage tie, so the
        // lower id must win regardless of raw size.
        let mut g = Graph::new();
        let s1 = g.new_storage(1, 0);
        g.new_tensor(s1, None, false);
        g.storage_mut(s1).resident = true;
        let s0 = g.new_storage(0, 1);
        g.new_tensor(s0, None, false);
        g.storage_mut(s0).resident = true;
        let mut idx = SizeHeapIndex::new();
        idx.on_insert(s0, &g);
        idx.on_insert(s1, &g);
        assert_eq!(pop(&mut idx, &g, &[s1, s0]), Some(s1));
    }
}
