//! Fleet-wide differential eviction: **one** kinetic tournament shared
//! across all serve shards.
//!
//! PAPER §5's prototype interposes on a single allocator; Coop's
//! pooled-reclaim lesson (PAPERS.md) extends that to serving fleets —
//! eviction decisions should be made against the whole memory pool, not
//! per-silo. Our `GlobalReclaim` arbiter used to rediscover the globally
//! least-valuable tensor by peeking *every* peer shard per eviction round
//! (an O(shards) fan-out of `try_lock`ed per-shard victim searches). But
//! each shard's [`super::DifferentialIndex`] already maintains exactly the
//! structure needed to answer the global question: its kinetic tournament
//! root *is* the shard's current min score. This module lifts that
//! tournament one level:
//!
//! * [`MinSlot`] — a seqlock-published `(state, score, id)` triple, one per
//!   shard, written by the shard's index on every mutation that changes its
//!   local minimum and read by the arbiter **without touching the shard's
//!   runtime lock**. The published score is bit-identical to the score the
//!   scan's `f64` arithmetic would compute (`heuristics::finish_score`),
//!   because the differential index caches the lossless integral numerator.
//! * [`FleetTournament`] — a segment tree over the slots, keyed by
//!   `(score, shard)` so ties resolve exactly like the peek loop's
//!   first-peer-wins order. Slots announce changes on a shared dirty queue
//!   (deduplicated per slot), so a drain re-reads only the slots that moved
//!   and repairs each leaf's root path in O(log shards).
//!
//! `GlobalReclaim`'s victim choice becomes one tournament read; the peek
//! loop survives only as the `--global-index scan` fallback/benchmark bar,
//! and as the per-shard escape hatch for slots that cannot vouch for
//! themselves ([`SlotRead::Stale`] / [`SlotRead::Unbound`]).
//!
//! Churn safety: every (re)bind of a shard slot bumps a generation
//! counter, and dirty-queue entries carry the generation they were
//! published under — a replayed certificate from a departed tenant's slot
//! can never resurrect a dead shard's leaf ([`FleetTournament::drain`]
//! drops it and counts it in [`FleetTournament::dead_drops`]).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

const NIL: u32 = u32::MAX;

const ST_UNBOUND: u8 = 0;
const ST_EMPTY: u8 = 1;
const ST_STALE: u8 = 2;
const ST_VALID: u8 = 3;

/// One consistent read of a [`MinSlot`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlotRead {
    /// No publishing index is bound (scan/heap/auto-below-crossover shards,
    /// or a runtime between sessions): the arbiter must peek.
    Unbound,
    /// A publishing index is bound and its pool is empty: skip the shard
    /// (exactly what the peek loop does with `RemotePeek::Empty`).
    Empty,
    /// The published minimum is outdated (pending invalidations or a parked
    /// epoch migration): the arbiter must peek; the peek itself heals the
    /// slot (the shard's `pop_min` republishes).
    Stale,
    /// The shard's exact current minimum score and its storage id.
    Valid { score: f64, id: u32 },
}

/// A shard's published tier-minimum: a small seqlock written by the shard's
/// [`super::PolicyIndex`] (under the shard's own runtime lock — there is
/// exactly one writer at a time) and read lock-free by the arbiter.
///
/// Writes announce themselves on the owning [`FleetTournament`]'s dirty
/// queue, deduplicated by the `queued` flag: a slot sits in the queue at
/// most once until the next drain re-reads it, so the queue is bounded by
/// the shard count however chatty the publishers are.
pub struct MinSlot {
    seq: AtomicU32,
    state: AtomicU8,
    bits: AtomicU64,
    id: AtomicU32,
    queued: AtomicBool,
    shard: u32,
    generation: u32,
    queue: Arc<Mutex<Vec<(u32, u32)>>>,
}

impl MinSlot {
    fn new(shard: u32, generation: u32, queue: Arc<Mutex<Vec<(u32, u32)>>>) -> MinSlot {
        MinSlot {
            seq: AtomicU32::new(0),
            state: AtomicU8::new(ST_UNBOUND),
            bits: AtomicU64::new(0),
            id: AtomicU32::new(NIL),
            queued: AtomicBool::new(false),
            shard,
            generation,
            queue,
        }
    }

    /// A slot attached to nothing — for unit tests of publishing indexes
    /// outside a serve fleet.
    pub fn detached() -> Arc<MinSlot> {
        Arc::new(MinSlot::new(0, 0, Arc::new(Mutex::new(Vec::new()))))
    }

    /// The shard slot index this slot publishes for.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }

    fn write(&self, state: u8, bits: u64, id: u32) {
        // Single-writer (runtime lock held); skip no-op publishes so a
        // quiescent shard never churns the dirty queue.
        if self.state.load(Ordering::Acquire) == state
            && self.bits.load(Ordering::Acquire) == bits
            && self.id.load(Ordering::Acquire) == id
        {
            return;
        }
        let s0 = self.seq.load(Ordering::Relaxed);
        self.seq.store(s0.wrapping_add(1), Ordering::Release); // odd: torn
        self.bits.store(bits, Ordering::Release);
        self.id.store(id, Ordering::Release);
        self.state.store(state, Ordering::Release);
        self.seq.store(s0.wrapping_add(2), Ordering::Release); // even: clean
        if !self.queued.swap(true, Ordering::AcqRel) {
            self.queue.lock().expect("fleet queue poisoned").push((self.shard, self.generation));
        }
    }

    /// Publish the shard's exact current minimum.
    pub fn publish_min(&self, score: f64, id: u32) {
        self.write(ST_VALID, score.to_bits(), id);
    }

    /// Publish "nothing evictable" (empty pool, or the shard's runtime was
    /// torn down between steps).
    pub fn publish_empty(&self) {
        self.write(ST_EMPTY, 0, NIL);
    }

    /// The published minimum can no longer be trusted (pending dirty
    /// entries or a parked epoch migration); the arbiter falls back to a
    /// peek until the shard's next `pop_min` republishes.
    pub fn mark_stale(&self) {
        self.write(ST_STALE, 0, NIL);
    }

    /// Reset to the non-publishing state (a fresh session bound an index
    /// that may not publish at all).
    pub fn reset_unbound(&self) {
        self.write(ST_UNBOUND, 0, NIL);
    }

    /// One consistent snapshot; retries while a write is in flight, and
    /// degrades to [`SlotRead::Stale`] (a safe "go peek") if a writer keeps
    /// the slot torn across every retry.
    pub fn read(&self) -> SlotRead {
        for _ in 0..64 {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let state = self.state.load(Ordering::Acquire);
            let bits = self.bits.load(Ordering::Acquire);
            let id = self.id.load(Ordering::Acquire);
            if self.seq.load(Ordering::Acquire) != s1 {
                continue;
            }
            return match state {
                ST_UNBOUND => SlotRead::Unbound,
                ST_EMPTY => SlotRead::Empty,
                ST_STALE => SlotRead::Stale,
                _ => SlotRead::Valid { score: f64::from_bits(bits), id },
            };
        }
        SlotRead::Stale
    }
}

/// What the fleet tournament currently believes about one shard's leaf.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Leaf {
    /// No slot bound (never registered, or retired).
    Vacant,
    /// The slot cannot vouch for its minimum (unbound or stale): the
    /// arbiter must peek this shard through its runtime handle.
    NeedsPeek,
    /// The shard published an empty pool: skip it.
    Empty,
    /// The shard's exact published minimum score.
    Min(f64),
}

/// The cross-shard tournament: a power-of-two segment tree whose leaf `j`
/// is shard `j`'s published minimum, ordered by `(score, shard)` — the same
/// strict-`<` first-peer-wins order the scan loop induces. All queries are
/// O(log shards); a drain repairs one root path per moved slot.
pub struct FleetTournament {
    queue: Arc<Mutex<Vec<(u32, u32)>>>,
    /// Current generation per shard slot; stale dirty-queue entries (from a
    /// slot bound before the last churn on this shard index) are dropped.
    gens: Vec<u32>,
    slots: Vec<Option<Arc<MinSlot>>>,
    leaves: Vec<Leaf>,
    cap: usize,
    /// 1-based segment tree of winning shard indices (`NIL` = no candidate
    /// in the subtree). With `cap == 1` the lone leaf is the root.
    tree: Vec<u32>,
    /// Shards whose leaf is [`Leaf::NeedsPeek`], ascending.
    needs_peek: Vec<u32>,
    dead_drops: u64,
    drain_buf: Vec<(u32, u32)>,
}

impl Default for FleetTournament {
    fn default() -> Self {
        Self::new()
    }
}

impl FleetTournament {
    pub fn new() -> FleetTournament {
        FleetTournament {
            queue: Arc::new(Mutex::new(Vec::new())),
            gens: Vec::new(),
            slots: Vec::new(),
            leaves: Vec::new(),
            cap: 0,
            tree: Vec::new(),
            needs_peek: Vec::new(),
            dead_drops: 0,
            drain_buf: Vec::new(),
        }
    }

    fn score(&self, shard: u32) -> f64 {
        match self.leaves[shard as usize] {
            Leaf::Min(s) => s,
            // Tree cells only ever name Min leaves; make a logic error lose
            // every match instead of corrupting a victim choice.
            _ => f64::INFINITY,
        }
    }

    /// Winner of a two-child match: lower `(score, shard)` lexicographically.
    /// Published scores are finite non-negative (`c/(m·stale)` over positive
    /// integers), so plain `f64` comparison is total here.
    fn min_of(&self, x: u32, y: u32) -> u32 {
        match (x, y) {
            (NIL, y) => y,
            (x, NIL) => x,
            // `x` comes from the left subtree, so on a score tie `x` (the
            // lower shard index) keeps the match — first-peer-wins.
            (x, y) => {
                if self.score(y) < self.score(x) {
                    y
                } else {
                    x
                }
            }
        }
    }

    fn rebuild(&mut self) {
        self.tree = vec![NIL; 2 * self.cap];
        for (i, leaf) in self.leaves.iter().enumerate() {
            if matches!(leaf, Leaf::Min(_)) {
                self.tree[self.cap + i] = i as u32;
            }
        }
        for n in (1..self.cap).rev() {
            self.tree[n] = self.min_of(self.tree[2 * n], self.tree[2 * n + 1]);
        }
    }

    fn ensure(&mut self, shard: usize) {
        if shard >= self.gens.len() {
            self.gens.resize(shard + 1, 0);
            self.slots.resize(shard + 1, None);
            self.leaves.resize(shard + 1, Leaf::Vacant);
        }
        if shard >= self.cap {
            let mut cap = self.cap.max(1);
            while cap <= shard {
                cap *= 2;
            }
            self.cap = cap;
            self.rebuild();
        }
    }

    /// Re-seed leaf `shard`'s tree cell and repair its root path.
    fn reseat(&mut self, shard: usize, participate: bool) {
        self.tree[self.cap + shard] = if participate { shard as u32 } else { NIL };
        let mut n = (self.cap + shard) >> 1;
        while n >= 1 {
            self.tree[n] = self.min_of(self.tree[2 * n], self.tree[2 * n + 1]);
            n >>= 1;
        }
    }

    fn set_leaf(&mut self, shard: usize, leaf: Leaf) {
        self.leaves[shard] = leaf;
        self.reseat(shard, matches!(leaf, Leaf::Min(_)));
        let needs = matches!(leaf, Leaf::NeedsPeek);
        match (needs, self.needs_peek.iter().position(|&j| j as usize == shard)) {
            (true, None) => {
                self.needs_peek.push(shard as u32);
                self.needs_peek.sort_unstable();
            }
            (false, Some(k)) => {
                self.needs_peek.remove(k);
            }
            _ => {}
        }
    }

    /// Bind a fresh slot for shard index `shard` (join, or slot recycle
    /// after churn). Bumps the generation so anything the *previous*
    /// occupant of this index still publishes is dropped on drain.
    pub fn bind(&mut self, shard: usize) -> Arc<MinSlot> {
        self.ensure(shard);
        self.gens[shard] = self.gens[shard].wrapping_add(1);
        let slot =
            Arc::new(MinSlot::new(shard as u32, self.gens[shard], Arc::clone(&self.queue)));
        self.slots[shard] = Some(Arc::clone(&slot));
        self.set_leaf(shard, Leaf::NeedsPeek);
        slot
    }

    /// Retire a departed shard's leaf (leave/reap). Its slot may live on in
    /// orphaned `Arc`s held by a dying runtime; anything they publish is
    /// generation-filtered on drain.
    pub fn retire(&mut self, shard: usize) {
        if shard >= self.gens.len() {
            return;
        }
        self.gens[shard] = self.gens[shard].wrapping_add(1);
        self.slots[shard] = None;
        self.set_leaf(shard, Leaf::Vacant);
    }

    /// Absorb every pending slot publish: re-read each dirtied slot once
    /// and repair its leaf's root path. Entries from dead generations are
    /// dropped (and counted) — a departed tenant can never re-enter the
    /// tree.
    pub fn drain(&mut self) {
        let mut buf = std::mem::take(&mut self.drain_buf);
        buf.clear();
        {
            let mut q = self.queue.lock().expect("fleet queue poisoned");
            std::mem::swap(&mut buf, &mut q);
        }
        for (sh, gen) in buf.drain(..) {
            let j = sh as usize;
            if j >= self.gens.len() || gen != self.gens[j] {
                self.dead_drops += 1;
                continue;
            }
            let Some(slot) = self.slots[j].clone() else {
                self.dead_drops += 1;
                continue;
            };
            // Clear the dedup flag *before* reading: a publish racing this
            // drain re-queues the slot, so the next drain re-reads it.
            slot.queued.store(false, Ordering::Release);
            let leaf = match slot.read() {
                SlotRead::Unbound | SlotRead::Stale => Leaf::NeedsPeek,
                SlotRead::Empty => Leaf::Empty,
                SlotRead::Valid { score, .. } => Leaf::Min(score),
            };
            self.set_leaf(j, leaf);
        }
        self.drain_buf = buf;
    }

    /// The tournament's current belief about shard `shard`.
    pub fn leaf(&self, shard: usize) -> Leaf {
        self.leaves.get(shard).copied().unwrap_or(Leaf::Vacant)
    }

    /// Shards whose published minimum cannot be trusted and must be peeked
    /// through their runtime handles (ascending shard order).
    pub fn peek_list(&self) -> &[u32] {
        &self.needs_peek
    }

    /// The globally minimal published `(shard, score)`, or `None` if no
    /// shard currently publishes a valid minimum.
    pub fn best(&self) -> Option<(usize, f64)> {
        if self.cap == 0 {
            return None;
        }
        let w = self.tree[1];
        if w == NIL {
            None
        } else {
            Some((w as usize, self.score(w)))
        }
    }

    /// [`FleetTournament::best`] with `shard`'s own leaf masked out — the
    /// requester's local candidate competes separately (the `ls <= rs`
    /// local-wins tie in the arbiter), exactly like the peek loop excludes
    /// the requester from its peer list. O(log shards): two root-path
    /// repairs.
    pub fn best_excluding(&mut self, shard: usize) -> Option<(usize, f64)> {
        if self.cap == 0 {
            return None;
        }
        if shard >= self.leaves.len() || !matches!(self.leaves[shard], Leaf::Min(_)) {
            return self.best();
        }
        self.reseat(shard, false);
        let best = self.best();
        self.reseat(shard, true);
        best
    }

    /// Dirty-queue entries dropped because their generation was dead —
    /// replayed publishes from departed tenants (churn-safety telemetry).
    pub fn dead_drops(&self) -> u64 {
        self.dead_drops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_publish_read_roundtrip() {
        let t = &mut FleetTournament::new();
        let s = t.bind(0);
        assert_eq!(s.read(), SlotRead::Unbound);
        s.publish_min(0.25, 7);
        assert_eq!(s.read(), SlotRead::Valid { score: 0.25, id: 7 });
        s.mark_stale();
        assert_eq!(s.read(), SlotRead::Stale);
        s.publish_empty();
        assert_eq!(s.read(), SlotRead::Empty);
        s.reset_unbound();
        assert_eq!(s.read(), SlotRead::Unbound);
    }

    #[test]
    fn redundant_publish_does_not_requeue() {
        let t = &mut FleetTournament::new();
        let s = t.bind(0);
        s.publish_min(1.0, 3);
        t.drain();
        assert_eq!(t.leaf(0), Leaf::Min(1.0));
        // Identical republish: the slot skips the write entirely, so the
        // queue stays empty and the leaf stays put.
        s.publish_min(1.0, 3);
        assert_eq!(t.queue.lock().unwrap().len(), 0);
        // A changed value queues exactly once however often it's republished.
        s.publish_min(0.5, 3);
        s.publish_min(0.25, 3);
        assert_eq!(t.queue.lock().unwrap().len(), 1);
        t.drain();
        assert_eq!(t.leaf(0), Leaf::Min(0.25), "drain reads the latest value");
    }

    #[test]
    fn tournament_orders_by_score_then_shard() {
        let mut t = FleetTournament::new();
        let s0 = t.bind(0);
        let s1 = t.bind(1);
        let s2 = t.bind(2);
        s0.publish_min(2.0, 10);
        s1.publish_min(0.5, 11);
        s2.publish_min(1.0, 12);
        t.drain();
        assert_eq!(t.best(), Some((1, 0.5)));
        assert_eq!(t.best_excluding(1), Some((2, 1.0)));
        assert_eq!(t.best_excluding(0), Some((1, 0.5)));
        // Score tie resolves to the lower shard index (first-peer-wins).
        s2.publish_min(0.5, 12);
        t.drain();
        assert_eq!(t.best(), Some((1, 0.5)));
        // best_excluding restores the masked leaf.
        assert_eq!(t.best_excluding(1), Some((2, 0.5)));
        assert_eq!(t.best(), Some((1, 0.5)));
    }

    #[test]
    fn stale_and_empty_leaves_route_to_peeks_and_skips() {
        let mut t = FleetTournament::new();
        let s0 = t.bind(0);
        let s1 = t.bind(1);
        s0.publish_min(1.0, 1);
        s1.publish_min(2.0, 2);
        t.drain();
        assert!(t.peek_list().is_empty());
        s0.mark_stale();
        s1.publish_empty();
        t.drain();
        assert_eq!(t.leaf(0), Leaf::NeedsPeek);
        assert_eq!(t.leaf(1), Leaf::Empty);
        assert_eq!(t.peek_list(), &[0]);
        assert_eq!(t.best(), None, "no valid publisher left");
        // Healing: the next publish clears the peek obligation.
        s0.publish_min(0.75, 1);
        t.drain();
        assert!(t.peek_list().is_empty());
        assert_eq!(t.best(), Some((0, 0.75)));
    }

    #[test]
    fn churn_retires_leaves_and_drops_dead_generation_replays() {
        let mut t = FleetTournament::new();
        let s0 = t.bind(0);
        let s1 = t.bind(1);
        s0.publish_min(5.0, 1);
        s1.publish_min(1.0, 2);
        t.drain();
        assert_eq!(t.best(), Some((1, 1.0)));
        // Shard 1 leaves; its slot Arc lives on in the departing runtime.
        t.retire(1);
        assert_eq!(t.best(), Some((0, 5.0)), "retired leaf leaves the tree");
        // The orphan keeps publishing (teardown publishes EMPTY, but even a
        // bogus minimum must not resurrect the leaf).
        s1.publish_min(0.001, 3);
        let drops = t.dead_drops();
        t.drain();
        assert!(t.dead_drops() > drops, "dead-generation replay was dropped");
        assert_eq!(t.leaf(1), Leaf::Vacant);
        assert_eq!(t.best(), Some((0, 5.0)), "winner never names a dead shard");
        // A new tenant recycles the slot index with a fresh generation.
        let s1b = t.bind(1);
        s1b.publish_min(0.5, 9);
        t.drain();
        assert_eq!(t.best(), Some((1, 0.5)));
        // And the old orphan still can't interfere.
        s1.publish_min(0.0001, 3);
        t.drain();
        assert_eq!(t.best(), Some((1, 0.5)));
        assert_eq!(t.leaf(1), Leaf::Min(0.5));
    }

    #[test]
    fn tournament_grows_past_initial_capacity() {
        let mut t = FleetTournament::new();
        let slots: Vec<_> = (0..9).map(|j| t.bind(j)).collect();
        for (j, s) in slots.iter().enumerate() {
            s.publish_min(10.0 - j as f64, j as u32);
        }
        t.drain();
        assert_eq!(t.best(), Some((8, 2.0)));
        assert_eq!(t.best_excluding(8), Some((7, 3.0)));
    }
}
