//! Deallocation policies (Sec. 2 "Deallocation" + Appendix C.5/D.2).
//!
//! When the source program drops its last external reference to a storage,
//! DTR can: ignore the event (keep the storage as a normal eviction
//! candidate), *eagerly evict* it (the paper's default — preempts a
//! desirable eviction, cannot free constants), or *banish* it (permanently
//! free, can free constants, but pins all dependents forever).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeallocPolicy {
    /// Disregard deallocations entirely.
    Ignore,
    /// Evict as soon as all external references are dropped (paper default).
    EagerEvict,
    /// Permanently free when no evicted dependents remain; pins dependents.
    Banish,
}

impl DeallocPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            DeallocPolicy::Ignore => "ignore",
            DeallocPolicy::EagerEvict => "eager",
            DeallocPolicy::Banish => "banish",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ignore" => DeallocPolicy::Ignore,
            "eager" | "eager_evict" => DeallocPolicy::EagerEvict,
            "banish" => DeallocPolicy::Banish,
            _ => return None,
        })
    }

    pub fn all() -> [DeallocPolicy; 3] {
        [DeallocPolicy::Ignore, DeallocPolicy::EagerEvict, DeallocPolicy::Banish]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in DeallocPolicy::all() {
            assert_eq!(DeallocPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DeallocPolicy::parse("bogus"), None);
    }
}
