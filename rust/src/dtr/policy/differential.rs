//! Differential score index for the staleness-bearing `h_DTR` family
//! (`h_DTR`, `h_DTR^eq`, `h_DTR^local`, `h_LRU`, and every staleness-bearing
//! ablation cell): sub-linear `pop_min` where [`super::CachedCostScan`]
//! still pays an O(pool) arithmetic pass per eviction.
//!
//! The score `c(S)/[m(S)·staleness(S)]` re-orders as the clock advances, so
//! no single cached key is heap-able. But it *factors*: the numerator `c/m`
//! is clock-independent (and already cached, Appendix E.1), and the
//! denominator `staleness = clock − last_access + 1` is shared by every
//! storage in one `last_access` epoch. Two consequences, exploited here:
//!
//! 1. **Within an epoch the order is frozen forever.** Storages sharing one
//!    `last_access` divide by the same staleness, so their relative order
//!    is the order of the exact rationals `c/m` (ties by lowest id) — an
//!    ordered *tier* per epoch ([`Key`] in a `BTreeSet`), maintained
//!    differentially: only storages whose numerator an invalidation
//!    actually touched are re-keyed (the differential-dataflow arrangement
//!    lesson — do work only where inputs changed), and `on_access` migrates
//!    a storage to the newest epoch in O(log n).
//! 2. **Across epochs the order changes, but predictably.** The comparison
//!    of two tier minima at clock `t` is the sign of the exact integer line
//!    `diff(t) = c₁m₂(t−a₂+1) − c₂m₁(t−a₁+1)`, which crosses zero at most
//!    once as `t` grows. A kinetic tournament tree over the O(#epochs) tier
//!    representatives stores each pairwise winner together with a
//!    *certificate* — the first integer clock at which that winner flips,
//!    from exact ceiling division — in a priority queue. `pop_min` replays
//!    only the certificates that expired since the last search, so an
//!    arbitrary clock advance costs O(flips · log), not O(pool), and a
//!    quiescent clock costs nothing. The tournament is the "hierarchical
//!    merging" that keeps the top level logarithmic even when every storage
//!    sits in its own epoch (the chain-workload worst case, where a flat
//!    scan over tier minima would degenerate to O(pool) again).
//!
//! Decision-exactness: all comparisons are exact integer cross-products
//! (`u128`/`i128`), which agree with the scan's `f64` scores under the
//! module-level 2^52 caveat; if a product would overflow even 128 bits the
//! comparison falls back to exactly the scan's `f64` arithmetic, and
//! certificates degrade to conservative next-tick re-checks. Ties break by
//! lowest [`StorageId`], like every other index.

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap, HashMap};
use std::sync::Arc;

use super::super::graph::Graph;
use super::super::heuristics::{integral_cost, staleness_param, Heuristic, InvalidationScope};
use super::super::ids::StorageId;
use super::fleet::MinSlot;
use super::{Dirtier, EqSubs, PolicyIndex, SelectCtx};

const NIL: u32 = u32::MAX;

/// Within-tier ordering key: the clock-independent rational `c/m` compared
/// exactly by cross-multiplication (both factors fit in `u64`, so the
/// products always fit in `u128`), ties by lowest id — the same order the
/// scan's `(f64 score, id)` induces for storages sharing one epoch.
#[derive(Clone, Copy)]
struct Key {
    c: u64,
    m: u64,
    id: u32,
}

impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        let l = self.c as u128 * other.m as u128;
        let r = other.c as u128 * self.m as u128;
        l.cmp(&r).then_with(|| self.id.cmp(&other.id))
    }
}

/// A tier representative: the minimum `(c/m, id)` member of one epoch, the
/// only member that can win the cross-epoch tournament (any other member
/// shares its staleness and loses to it within the tier's frozen order).
#[derive(Clone, Copy, Debug)]
struct Rep {
    c: u64,
    m: u64,
    /// The tier's epoch (`last_access`).
    a: u64,
    id: u32,
}

/// Exact `(score, id)` comparison of two representatives at clock `t`:
/// `c₁/(m₁s₁) < c₂/(m₂s₂) ⟺ c₁m₂s₂ < c₂m₁s₁` over exact integers. On
/// `u128` overflow (products past 2^128 — far beyond where `f64` scores
/// are injective) compare the way the scan itself does.
fn cmp_reps(x: &Rep, y: &Rep, t: u64) -> Ordering {
    let sx = t.saturating_sub(x.a) as u128 + 1;
    let sy = t.saturating_sub(y.a) as u128 + 1;
    let a = x.c as u128 * y.m as u128;
    let b = y.c as u128 * x.m as u128;
    match (a.checked_mul(sy), b.checked_mul(sx)) {
        (Some(l), Some(r)) => l.cmp(&r).then_with(|| x.id.cmp(&y.id)),
        _ => {
            let fx = x.c as f64 / (x.m as f64 * sx as f64);
            let fy = y.c as f64 / (y.m as f64 * sy as f64);
            fx.total_cmp(&fy).then_with(|| x.id.cmp(&y.id))
        }
    }
}

/// The certificate: the first integer clock `> t` at which the winner
/// between `x` and `y` changes, or `u64::MAX` if it never does.
///
/// `diff(t) = P·t + Q` with `P = c₁m₂ − c₂m₁` and
/// `Q = c₁m₂(1−a₂) − c₂m₁(1−a₁)`; `diff < 0` means `x` wins, `> 0` means
/// `y`, `== 0` falls to the lower id. `diff` is linear, so the winner flips
/// at most once — at the exact ceiling of the rational root, nudged one
/// tick past an integer root whose id-tie the current winner still takes.
/// Any intermediate overflow degrades to a conservative `t + 1` re-check.
fn cert_time(x: &Rep, y: &Rep, t: u64) -> u64 {
    let amax = x.a.max(y.a);
    if amax > t {
        // Not yet in the linear region (an epoch from the future can only
        // be transient); re-examine once both staleness terms are linear.
        return amax;
    }
    let (a, b) = match (
        i128::try_from(x.c as u128 * y.m as u128),
        i128::try_from(y.c as u128 * x.m as u128),
    ) {
        (Ok(a), Ok(b)) => (a, b),
        _ => return t.saturating_add(1),
    };
    let p = a - b;
    if p == 0 {
        // diff(t) is constant: proportional numerators never re-order.
        return u64::MAX;
    }
    let q = match a
        .checked_mul(1 - y.a as i128)
        .and_then(|l| b.checked_mul(1 - x.a as i128).and_then(|r| l.checked_sub(r)))
    {
        Some(q) => q,
        None => return t.saturating_add(1),
    };
    let x_now = cmp_reps(x, y, t) == Ordering::Less;
    if (p < 0 && x_now) || (p > 0 && !x_now) {
        // Already past the crossing: the asymptotic winner holds forever.
        return u64::MAX;
    }
    // First integer t' where diff reaches the far side: ceil division with
    // a positive denominator (p > 0 ⟹ diff rises through −q/p; p < 0 ⟹
    // diff falls through q/(−p)).
    let (num, den) = if p > 0 { (-q, p) } else { (q, -p) };
    let rem = num.rem_euclid(den);
    let t0 = num.div_euclid(den) + i128::from(rem != 0);
    let flip = if rem == 0 {
        // Exact integer root: the scores tie there and the lower id wins;
        // if that is still the current winner, the flip lands a tick later.
        let tie_x = x.id < y.id;
        if tie_x == x_now {
            t0 + 1
        } else {
            t0
        }
    } else {
        t0
    };
    u64::try_from(flip.max(t as i128 + 1)).unwrap_or(u64::MAX)
}

#[derive(Clone, Copy)]
struct Slot {
    in_pool: bool,
    /// Cached numerator invalid (fresh slots start dirty).
    dirty: bool,
    /// Present in `dirty_list` (dedup).
    queued: bool,
    /// Accessed since placement but not yet migrated to its new epoch tier
    /// (lazy migration; present in `pending`).
    parked: bool,
    /// Tier arena index holding this storage, or `NIL`.
    tier: u32,
    /// Cached integral numerator (valid when `!dirty`).
    c: u64,
    /// Size denominator factor (immutable per storage).
    m: u64,
}

impl Default for Slot {
    fn default() -> Self {
        Slot { in_pool: false, dirty: true, queued: false, parked: false, tier: NIL, c: 1, m: 1 }
    }
}

/// The score a [`MinSlot`] publishes, pinned to `heuristics::finish_score`
/// for the staleness-bearing Param family: `c` is the lossless integral
/// numerator ([`integral_cost`]), `m` the size denominator (`size.max(1)`
/// or 1), so `c as f64` reproduces the cached `f64` numerator exactly and
/// this expression — same operands, same association — is bit-identical to
/// the score a `try_lock` peek of the shard would compute.
fn published_score(c: u64, m: u64, stale: u64) -> f64 {
    c as f64 / (m as f64 * stale as f64)
}

struct Tier {
    a: u64,
    leaf: u32,
    members: BTreeSet<Key>,
}

pub struct DifferentialIndex {
    eq: bool,
    use_size: bool,
    slots: Vec<Slot>,
    dirty_list: Vec<StorageId>,
    dirtier: Dirtier,
    subs: EqSubs,
    touch_buf: Vec<StorageId>,
    // Epoch tiers.
    tiers: Vec<Tier>,
    free_tiers: Vec<u32>,
    by_epoch: HashMap<u64, u32>,
    // Kinetic tournament: a power-of-two segment layout. Leaves live at
    // `tree[cap + i]` (tier index or NIL); internal node `n` holds the
    // winning tier of its subtree, computed at some time ≤ `now` and kept
    // current by certificates. With `cap == 1` the lone leaf *is* the root.
    cap: usize,
    next_leaf: usize,
    free_leaves: Vec<u32>,
    tree: Vec<u32>,
    /// Certificate generation per internal node (stale-entry skipping).
    ngen: Vec<u32>,
    /// (fail_time, node, generation) min-heap.
    certs: BinaryHeap<Reverse<(u64, u32, u32)>>,
    /// Latest clock observed (hooks do not all carry one).
    now: u64,
    /// Storages parked by a lazy `on_access` (epoch migration deferred to
    /// the next `pop_min`; dedup via `Slot::parked`).
    pending: Vec<StorageId>,
    /// Restore the pre-fleet eager per-touch migration (bench comparison).
    eager: bool,
    /// The shard's published-minimum slot in the fleet tournament, when
    /// this index serves a shard of an arbitrated pool.
    fleet_slot: Option<Arc<MinSlot>>,
}

impl DifferentialIndex {
    pub fn new(h: Heuristic) -> Self {
        let p = staleness_param(h).expect("differential index requires a staleness-bearing Param");
        DifferentialIndex {
            eq: h.invalidation_scope() == InvalidationScope::EqNeighborhood,
            use_size: p.use_size,
            slots: Vec::new(),
            dirty_list: Vec::new(),
            dirtier: Dirtier::new(h),
            subs: EqSubs::default(),
            touch_buf: Vec::new(),
            tiers: Vec::new(),
            free_tiers: Vec::new(),
            by_epoch: HashMap::new(),
            cap: 0,
            next_leaf: 0,
            free_leaves: Vec::new(),
            tree: Vec::new(),
            ngen: Vec::new(),
            certs: BinaryHeap::new(),
            now: 0,
            pending: Vec::new(),
            eager: false,
            fleet_slot: None,
        }
    }

    /// Restore eager per-touch epoch migration (the pre-fleet behavior):
    /// `on_access` re-keys immediately instead of parking for the next
    /// `pop_min`. Kept for the `epoch_migration` bench rows and as a
    /// regression bar; both modes are decision-exact.
    pub fn with_eager(mut self, eager: bool) -> Self {
        self.eager = eager;
        self
    }

    fn slot(&mut self, s: StorageId) -> usize {
        let i = s.idx();
        if self.slots.len() <= i {
            self.slots.resize(i + 1, Slot::default());
        }
        i
    }

    fn rep(&self, ti: u32) -> Rep {
        let tier = &self.tiers[ti as usize];
        let k = tier.members.iter().next().expect("representative of empty tier");
        Rep { c: k.c, m: k.m, a: tier.a, id: k.id }
    }

    // ------------------------------------------------------- tournament

    /// Rebuild at double capacity (certificates are regenerated wholesale).
    fn grow(&mut self, t: u64) {
        let newcap = (self.cap * 2).max(1);
        let mut tree = vec![NIL; 2 * newcap];
        tree[newcap..newcap + self.cap].copy_from_slice(&self.tree[self.cap..2 * self.cap]);
        self.tree = tree;
        self.cap = newcap;
        self.ngen = vec![0; newcap];
        self.certs.clear();
        for node in (1..newcap).rev() {
            self.recompute_node(node, t);
        }
    }

    fn alloc_leaf(&mut self, t: u64) -> u32 {
        if let Some(l) = self.free_leaves.pop() {
            return l;
        }
        if self.next_leaf == self.cap {
            self.grow(t);
        }
        let l = self.next_leaf as u32;
        self.next_leaf += 1;
        l
    }

    /// Recompute one internal node's winner from its children at time `t`,
    /// bumping its generation and (for a genuine two-way match) scheduling
    /// the certificate for the first clock at which the winner flips.
    fn recompute_node(&mut self, node: usize, t: u64) {
        let l = self.tree[2 * node];
        let r = self.tree[2 * node + 1];
        self.ngen[node] = self.ngen[node].wrapping_add(1);
        self.tree[node] = match (l, r) {
            (NIL, NIL) => NIL,
            (x, NIL) => x,
            (NIL, y) => y,
            (x, y) => {
                let rx = self.rep(x);
                let ry = self.rep(y);
                let ft = cert_time(&rx, &ry, t);
                if ft != u64::MAX {
                    self.certs.push(Reverse((ft, node as u32, self.ngen[node])));
                }
                if cmp_reps(&rx, &ry, t) == Ordering::Less {
                    x
                } else {
                    y
                }
            }
        };
    }

    /// Recompute the path from a leaf to the root after its tier's
    /// representative (or occupancy) changed.
    fn update_from_leaf(&mut self, leaf: u32, t: u64) {
        let mut node = (self.cap + leaf as usize) >> 1;
        while node >= 1 {
            self.recompute_node(node, t);
            node >>= 1;
        }
    }

    /// Replay every certificate that expired by time `t`: recompute the
    /// failed match, and only if its winner actually changed, cascade the
    /// recomputation up the tree (ancestor certificates are invalidated by
    /// their generation bump).
    fn advance(&mut self, t: u64) {
        while let Some(&Reverse((ft, node, gen))) = self.certs.peek() {
            if ft > t {
                break;
            }
            self.certs.pop();
            let node = node as usize;
            if self.ngen[node] != gen {
                continue;
            }
            let old = self.tree[node];
            self.recompute_node(node, t);
            if self.tree[node] != old {
                let mut n = node >> 1;
                while n >= 1 {
                    self.recompute_node(n, t);
                    n >>= 1;
                }
            }
        }
    }

    /// Rebuild the certificate heap if lazy invalidation let it balloon.
    fn maybe_compact_certs(&mut self, t: u64) {
        if self.certs.len() > 8 * self.cap + 64 {
            self.certs.clear();
            for node in (1..self.cap).rev() {
                self.recompute_node(node, t);
            }
        }
    }

    // ------------------------------------------------------------ tiers

    fn tier_for_epoch(&mut self, a: u64, t: u64) -> u32 {
        if let Some(&ti) = self.by_epoch.get(&a) {
            return ti;
        }
        let leaf = self.alloc_leaf(t);
        let ti = if let Some(ti) = self.free_tiers.pop() {
            let tier = &mut self.tiers[ti as usize];
            debug_assert!(tier.members.is_empty());
            tier.a = a;
            tier.leaf = leaf;
            ti
        } else {
            self.tiers.push(Tier { a, leaf, members: BTreeSet::new() });
            (self.tiers.len() - 1) as u32
        };
        self.by_epoch.insert(a, ti);
        ti
    }

    /// Insert a clean, pooled storage into the tier of epoch `a`.
    fn place(&mut self, s: StorageId, a: u64, t: u64) {
        let i = s.idx();
        debug_assert!(self.slots[i].in_pool && !self.slots[i].dirty);
        debug_assert_eq!(self.slots[i].tier, NIL);
        let ti = self.tier_for_epoch(a, t);
        let key = Key { c: self.slots[i].c, m: self.slots[i].m, id: s.0 };
        self.slots[i].tier = ti;
        let tier = &mut self.tiers[ti as usize];
        let old_rep = tier.members.iter().next().copied();
        tier.members.insert(key);
        let new_rep = tier.members.iter().next().copied();
        if old_rep.map(|k| k.id) != new_rep.map(|k| k.id) {
            let leaf = self.tiers[ti as usize].leaf;
            self.tree[self.cap + leaf as usize] = ti;
            self.update_from_leaf(leaf, t);
        }
    }

    /// Remove a storage from its tier (no-op if unplaced), destroying the
    /// tier when it empties.
    fn unplace(&mut self, s: StorageId, t: u64) {
        let i = self.slot(s);
        let ti = self.slots[i].tier;
        if ti == NIL {
            return;
        }
        self.slots[i].tier = NIL;
        // A pending lazy migration is moot once the storage leaves its tier
        // (evicted, dirtied, retired); the flush skips unparked entries.
        self.slots[i].parked = false;
        let key = Key { c: self.slots[i].c, m: self.slots[i].m, id: s.0 };
        let tier = &mut self.tiers[ti as usize];
        let old_rep = tier.members.iter().next().copied();
        let removed = tier.members.remove(&key);
        debug_assert!(removed, "tier member missing on unplace");
        if tier.members.is_empty() {
            let (leaf, a) = (tier.leaf, tier.a);
            self.by_epoch.remove(&a);
            self.free_tiers.push(ti);
            self.free_leaves.push(leaf);
            self.tree[self.cap + leaf as usize] = NIL;
            self.update_from_leaf(leaf, t);
        } else {
            let new_rep = tier.members.iter().next().copied();
            if old_rep.map(|k| k.id) != new_rep.map(|k| k.id) {
                let leaf = self.tiers[ti as usize].leaf;
                self.update_from_leaf(leaf, t);
            }
        }
    }

    /// A storage's numerator may have changed: pull it out of its tier
    /// *eagerly* (a stale numerator can err in either direction, unlike a
    /// stale epoch) and queue the re-key for the next `pop_min`.
    fn mark_dirty(&mut self, s: StorageId) {
        let t = self.now;
        let i = self.slot(s);
        self.unplace(s, t);
        self.slots[i].dirty = true;
        if self.slots[i].in_pool && !self.slots[i].queued {
            self.slots[i].queued = true;
            self.dirty_list.push(s);
        }
    }

    fn current_winner(&self) -> Option<StorageId> {
        if self.cap == 0 {
            return None;
        }
        let ti = self.tree[1];
        if ti == NIL {
            None
        } else {
            Some(StorageId(self.rep(ti).id))
        }
    }

    /// Batch-migrate every parked storage to its current epoch — the lazy
    /// half of `on_access`, run at the head of `pop_min` before any score
    /// is consulted. A burst of touches to one storage costs one migration
    /// here instead of one O(log) re-key per touch, and repeated touches
    /// coalesce to the *final* `last_access`.
    fn flush_parked(&mut self, g: &Graph, t: u64) {
        while let Some(s) = self.pending.pop() {
            let i = s.idx();
            if !self.slots[i].parked {
                continue; // left its tier (evicted/dirtied) since parking
            }
            self.slots[i].parked = false;
            let ti = self.slots[i].tier;
            if ti == NIL {
                continue;
            }
            let a = g.storage(s).last_access;
            if self.tiers[ti as usize].a != a {
                self.unplace(s, t);
                self.place(s, a, t);
            }
        }
    }

    /// Push this shard's exact current minimum into its fleet slot (no-op
    /// without one). Trust rules, in order:
    ///
    /// * pending dirty re-keys → [`MinSlot::mark_stale`] (a dirtied
    ///   numerator can err in either direction);
    /// * empty tournament → [`MinSlot::publish_empty`] (every pooled
    ///   storage is either placed or on the dirty list, so an empty tree
    ///   with no dirt means an empty pool);
    /// * parked winner → stale: a parked entry's structure epoch lags its
    ///   true `last_access`, so its structure score *under*states the true
    ///   score — it can only err toward winning, never toward hiding a
    ///   cheaper victim, hence any *non*-parked winner is the exact argmin
    ///   but a parked one cannot vouch for itself;
    /// * otherwise → the winner's exact score at the current clock.
    fn republish(&mut self) {
        let Some(slot) = &self.fleet_slot else { return };
        if !self.dirty_list.is_empty() {
            slot.mark_stale();
            return;
        }
        if self.cap == 0 || self.tree[1] == NIL {
            slot.publish_empty();
            return;
        }
        let rep = self.rep(self.tree[1]);
        if self.slots[StorageId(rep.id).idx()].parked {
            slot.mark_stale();
            return;
        }
        let stale = self.now.saturating_sub(rep.a) + 1;
        slot.publish_min(published_score(rep.c, rep.m, stale), rep.id);
    }
}

impl PolicyIndex for DifferentialIndex {
    fn name(&self) -> &'static str {
        "differential"
    }

    fn on_insert(&mut self, s: StorageId, g: &Graph) {
        let t = self.now;
        let i = self.slot(s);
        if self.slots[i].in_pool {
            return;
        }
        self.slots[i].in_pool = true;
        self.slots[i].m = if self.use_size { g.storage(s).size.max(1) } else { 1 };
        if self.slots[i].dirty {
            if !self.slots[i].queued {
                self.slots[i].queued = true;
                self.dirty_list.push(s);
            }
        } else {
            // A returning storage's cached numerator is still valid (same
            // policy as CachedCostScan: membership never enters the
            // numerator, and invalidations land regardless of pool state).
            self.place(s, g.storage(s).last_access, t);
        }
        self.republish();
    }

    fn on_remove(&mut self, s: StorageId, _g: &Graph) {
        let t = self.now;
        let i = self.slot(s);
        self.slots[i].in_pool = false;
        self.unplace(s, t);
        // Cache and eq-class subscriptions stay live (see `on_insert`).
        self.republish();
    }

    fn on_access(&mut self, s: StorageId, g: &Graph, clock: u64) {
        self.now = self.now.max(clock);
        let i = self.slot(s);
        let ti = self.slots[i].tier;
        if ti == NIL || self.tiers[ti as usize].a == g.storage(s).last_access {
            return;
        }
        if self.eager {
            let now = self.now;
            self.unplace(s, now);
            self.place(s, g.storage(s).last_access, now);
            self.republish();
            return;
        }
        // Lazy epoch migration: park the touched storage and batch-migrate
        // at the next `pop_min` (`flush_parked`). Decision-exact by
        // construction — scores are only consulted at pop, after the flush.
        if !self.slots[i].parked {
            self.slots[i].parked = true;
            self.pending.push(s);
        }
        // Parking changes no tree structure, so the published minimum moves
        // only if the touched storage *is* the current winner (its true
        // score just rose past its structure score).
        if self.fleet_slot.is_some() && self.current_winner() == Some(s) {
            if let Some(slot) = &self.fleet_slot {
                slot.mark_stale();
            }
        }
    }

    fn on_clock(&mut self, clock: u64) {
        self.now = self.now.max(clock);
        if self.fleet_slot.is_some() {
            // A publishing shard keeps its slot current as the moving clock
            // re-orders scores: replay expired certificates (exact at any
            // clock; amortized the same work a later pop_min would do) and
            // republish the root. Non-publishing indexes keep the lazy
            // replay-at-pop behavior below.
            let t = self.now;
            self.advance(t);
            self.republish();
        }
        // Otherwise certificates are replayed lazily at the next `pop_min`.
    }

    fn bind_slot(&mut self, slot: Arc<MinSlot>) {
        self.fleet_slot = Some(slot);
        self.republish();
    }

    fn invalidate(&mut self, s: StorageId, g: &Graph, accesses: &mut u64) {
        self.dirtier.collect(s, g, accesses);
        let buf = std::mem::take(&mut self.dirtier.buf);
        for &d in &buf {
            self.mark_dirty(d);
        }
        self.dirtier.buf = buf;
        self.republish();
    }

    fn on_component_touched(&mut self, root: u32) {
        let mut buf = std::mem::take(&mut self.touch_buf);
        buf.clear();
        self.subs.touched(root, |s| buf.push(s));
        for &s in &buf {
            self.mark_dirty(s);
        }
        self.touch_buf = buf;
        self.republish();
    }

    fn on_components_merged(&mut self, kept: u32, absorbed: u32) {
        let mut buf = std::mem::take(&mut self.touch_buf);
        buf.clear();
        self.subs.merged(kept, absorbed, |s| buf.push(s));
        for &s in &buf {
            self.mark_dirty(s);
        }
        self.touch_buf = buf;
        self.republish();
    }

    fn on_retire(&mut self, retired: &[StorageId], _g: &Graph) {
        for &s in retired {
            let i = self.slot(s);
            debug_assert!(!self.slots[i].in_pool, "retired storage still pooled");
            self.unplace(s, self.now);
            self.slots[i].dirty = true;
            self.subs.bump(s);
        }
        self.subs.sweep();
        // GC the certificate heap as well: superseded certificates otherwise
        // linger until the lazy size-triggered compaction, which would make
        // post-compaction metadata counts oscillate instead of staying flat.
        let t = self.now;
        self.certs.clear();
        for node in (1..self.cap).rev() {
            self.recompute_node(node, t);
        }
        self.republish();
    }

    fn metadata_len(&self) -> usize {
        let members: usize = self.tiers.iter().map(|t| t.members.len()).sum();
        members
            + self.by_epoch.len()
            + self.dirty_list.len()
            + self.certs.len()
            + self.subs.len()
            + self.pending.len()
    }

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        if ctx.pool.is_empty() {
            return None;
        }
        self.now = self.now.max(ctx.clock);
        let t = self.now;
        // 0. Batch-migrate parked epochs (lazy `on_access`): after this,
        // tiers match the eager index's state exactly.
        self.flush_parked(ctx.graph, t);
        // 1. Differential re-key: only the storages whose numerator an
        // invalidation actually touched, each O(log n) to re-place.
        while let Some(s) = self.dirty_list.pop() {
            let i = s.idx();
            self.slots[i].queued = false;
            if !self.slots[i].in_pool || !self.slots[i].dirty {
                continue;
            }
            let c = ctx.cached_cost_of(s);
            if self.eq {
                self.subs.bump(s);
                self.subs.subscribe(s, ctx.root_buf);
            }
            self.slots[i].c = integral_cost(c);
            self.slots[i].dirty = false;
            self.place(s, ctx.graph.storage(s).last_access, t);
        }
        // 2. Replay expired certificates up to the current clock.
        self.advance(t);
        self.maybe_compact_certs(t);
        // 3. The root's representative is the exact pool argmin. With the
        // small-tensor filter, set aside small winners and restore them
        // afterwards; if everything is small, the scan's starved fallback
        // is the unfiltered argmin — the first one set aside.
        if ctx.min_size == 0 {
            let winner = self.current_winner();
            // The pop healed every stale source (parked epochs flushed,
            // dirt re-keyed, certificates replayed): republish so a STALE
            // fleet slot returns to VALID — a remote peek heals the shard.
            self.republish();
            return winner;
        }
        let mut set_aside: Vec<StorageId> = Vec::new();
        let mut found: Option<StorageId> = None;
        while let Some(s) = self.current_winner() {
            *ctx.accesses += 1;
            if ctx.graph.storage(s).size >= ctx.min_size {
                found = Some(s);
                break;
            }
            self.unplace(s, t);
            set_aside.push(s);
        }
        let result = found.or_else(|| set_aside.first().copied());
        for s in set_aside {
            let a = ctx.graph.storage(s).last_access;
            self.place(s, a, t);
        }
        // Published min is the *unfiltered* argmin; under a small-tensor
        // filter the arbiter's shared choice may differ from a filtered
        // peek (documented exactness scope: default `min_size == 0`).
        self.republish();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Direct rational winner at time `t` (brute-force oracle).
    fn oracle_winner(x: &Rep, y: &Rep, t: u64) -> bool {
        cmp_reps(x, y, t) == Ordering::Less
    }

    /// `cert_time` must name the *first* integer clock where the winner
    /// differs from the winner at `t0`, over random small representatives
    /// (where a brute-force sweep is exact).
    #[test]
    fn cert_time_matches_brute_force_sweep() {
        let mut rng = Rng::new(42);
        for case in 0..4000 {
            let mk = |rng: &mut Rng, id: u32| Rep {
                c: 1 + rng.below(40),
                m: 1 + rng.below(12),
                a: rng.below(30),
                id,
            };
            let x = mk(&mut rng, 1 + rng.below(100) as u32);
            let mut y = mk(&mut rng, 1 + rng.below(100) as u32);
            if y.id == x.id {
                y.id += 1;
            }
            let t0 = x.a.max(y.a) + rng.below(20);
            let w0 = oracle_winner(&x, &y, t0);
            let ct = cert_time(&x, &y, t0);
            let mut first_change = u64::MAX;
            for t in t0 + 1..t0 + 4000 {
                if oracle_winner(&x, &y, t) != w0 {
                    first_change = t;
                    break;
                }
            }
            if first_change == u64::MAX {
                // Winner stable over the sweep horizon: the certificate must
                // not fire inside it.
                assert!(
                    ct > t0 + 3999,
                    "case {case}: cert {ct} fired but winner stable ({x:?} vs {y:?} at {t0})"
                );
            } else {
                assert_eq!(
                    ct, first_change,
                    "case {case}: cert mismatch ({x:?} vs {y:?} at {t0})"
                );
            }
        }
    }

    /// The id tie on an exact integer crossing must resolve like the scan:
    /// lower id wins the tie, so the flip lands one tick after the tie if
    /// the current winner also holds the lower id.
    #[test]
    fn cert_time_handles_exact_ties() {
        // x: c=2, m=1, a=4; y: c=4, m=1, a=2. Scores equal when
        // 2(t−2+1) = 4(t−4+1) ⟺ 2t−2 = 4t−12 ⟺ t = 5.
        let x = Rep { c: 2, m: 1, a: 4, id: 1 };
        let y = Rep { c: 4, m: 1, a: 2, id: 9 };
        // At t=4: x = 2/1, y = 4/3 → y wins; at t=5 tie → x (lower id);
        // y never wins again (x's staleness grows slower... check: t=6,
        // x = 2/3, y = 4/5 → x). So from t=4 the first change is t=5.
        assert_eq!(cmp_reps(&x, &y, 4), Ordering::Greater);
        assert_eq!(cert_time(&x, &y, 4), 5);
        // From t=5 (x winning on the tie), the winner never changes back.
        assert_eq!(cmp_reps(&x, &y, 5), Ordering::Less);
        assert_eq!(cert_time(&x, &y, 5), u64::MAX);
    }

    #[test]
    fn key_orders_by_exact_rational_then_id() {
        let a = Key { c: 1, m: 3, id: 5 }; // 1/3
        let b = Key { c: 2, m: 6, id: 4 }; // 1/3, lower id
        let c = Key { c: 1, m: 2, id: 1 }; // 1/2
        assert_eq!(a.cmp(&c), Ordering::Less);
        assert_eq!(b.cmp(&a), Ordering::Less, "equal rationals tie-break by id");
        let mut set = BTreeSet::new();
        set.insert(a);
        set.insert(b);
        set.insert(c);
        assert_eq!(set.iter().next().unwrap().id, 4);
    }
}
