//! Pool-size-adaptive victim selection: the reference scan until the pool
//! is large enough for the kinetic differential index to pay for itself,
//! then a one-way upgrade.
//!
//! The differential index (`differential.rs`) makes `pop_min` sub-linear,
//! but every insert/remove/access pays tournament bookkeeping the O(pool)
//! scan never does. On the serve fleet's small per-shard pools that
//! overhead is pure loss; on a training shard under a tight budget the
//! pool grows into the thousands and the scan's per-eviction pass is the
//! loss instead. `AutoIndex` holds both: it *is* the scan while the pool
//! stays below [`AUTO_CROSSOVER_POOL`], and at the first `pop_min` that
//! sees a pool at or past the crossover it builds a fresh
//! [`DifferentialIndex`] and replays `on_insert` for the live pool.
//!
//! The rebuild is decision-exact by construction: a fresh differential
//! slot starts `dirty`, so every replayed entry lands on the dirty list
//! and has its numerator recomputed through [`SelectCtx`] at the very
//! `pop_min` that triggered the upgrade, and staleness epochs are read
//! from `Graph::storage(s).last_access` — none of the invalidations or
//! accesses the scan ignored are needed, because nothing was cached yet.
//!
//! The upgrade is one-way. A pool that shrinks back under the crossover
//! keeps the differential index: its steady-state maintenance is cheap at
//! small pools (the bookkeeping constant, not the build), while
//! downgrade/re-upgrade hysteresis would pay the O(pool) rebuild on every
//! oscillation around the threshold.

use std::sync::Arc;

use super::super::graph::Graph;
use super::super::heuristics::Heuristic;
use super::super::ids::StorageId;
use super::differential::DifferentialIndex;
use super::fleet::MinSlot;
use super::scan::ScanIndex;
use super::{PolicyIndex, SelectCtx};

/// Default pool size at which `pop_min` upgrades from the scan to the
/// differential index (`Config::auto_crossover` overrides it per run, via
/// JSON `auto_crossover` or `--auto-crossover`, so bench sweeps can price
/// the boundary without recompiling; `0` upgrades at the first pop, and a
/// huge value pins the scan forever).
///
/// Backed by the `eviction_scaling` section of `BENCH_dtr.json`
/// (`benches/bench_dtr.rs`): the reference scan costs ~2.0 ns x pool per
/// eviction across the sweep (2.3 us at the 1k pool scaling linearly to
/// 1.9 ms at 1M), while the differential index is flat at 0.6-2.1 us per
/// eviction for the three staleness-bearing heuristics. Equating the two
/// puts the break-even pool at roughly 300 (`h_dtr_local`, cheapest
/// numerator) to 900 (`h_dtr`, exact e*); 512 sits mid-family, and the
/// 256-entry bench tier pins the scan side of the crossover in CI.
pub const AUTO_CROSSOVER_POOL: usize = 512;

/// Scan-until-crossover hybrid for the staleness-bearing `h_DTR` family.
pub struct AutoIndex {
    h: Heuristic,
    scan: ScanIndex,
    /// Upgrade threshold (normally [`AUTO_CROSSOVER_POOL`]).
    crossover: usize,
    /// Epoch-migration mode handed to the differential index on upgrade.
    eager: bool,
    /// Fleet publish slot to forward on upgrade. While still in the scan
    /// phase the slot stays wherever the binder left it (`Unbound` →
    /// the arbiter peeks this shard), which is correct: the scan has no
    /// incremental minimum to publish.
    slot: Option<Arc<MinSlot>>,
    /// `Some` once the pool first reached the crossover.
    upgraded: Option<DifferentialIndex>,
}

impl AutoIndex {
    pub fn new(h: Heuristic, crossover: usize, eager: bool) -> Self {
        AutoIndex { h, scan: ScanIndex::new(), crossover, eager, slot: None, upgraded: None }
    }

    /// Build a fresh differential index over the live pool. Each replayed
    /// entry is one maintenance traversal under Fig. 12 accounting.
    fn upgrade(&mut self, ctx: &mut SelectCtx<'_>) -> &mut DifferentialIndex {
        let mut d = DifferentialIndex::new(self.h).with_eager(self.eager);
        d.on_clock(ctx.clock);
        for &s in ctx.pool {
            d.on_insert(s, ctx.graph);
        }
        if let Some(slot) = self.slot.take() {
            d.bind_slot(slot);
        }
        *ctx.accesses += ctx.pool.len() as u64;
        self.upgraded.insert(d)
    }
}

impl PolicyIndex for AutoIndex {
    fn name(&self) -> &'static str {
        "auto_differential"
    }

    fn on_insert(&mut self, s: StorageId, g: &Graph) {
        match &mut self.upgraded {
            Some(d) => d.on_insert(s, g),
            None => self.scan.on_insert(s, g),
        }
    }

    fn on_remove(&mut self, s: StorageId, g: &Graph) {
        match &mut self.upgraded {
            Some(d) => d.on_remove(s, g),
            None => self.scan.on_remove(s, g),
        }
    }

    fn on_access(&mut self, s: StorageId, g: &Graph, clock: u64) {
        match &mut self.upgraded {
            Some(d) => d.on_access(s, g, clock),
            None => self.scan.on_access(s, g, clock),
        }
    }

    fn on_clock(&mut self, clock: u64) {
        if let Some(d) = &mut self.upgraded {
            d.on_clock(clock);
        }
    }

    fn invalidate(&mut self, s: StorageId, g: &Graph, accesses: &mut u64) {
        match &mut self.upgraded {
            Some(d) => d.invalidate(s, g, accesses),
            None => self.scan.invalidate(s, g, accesses),
        }
    }

    fn on_component_touched(&mut self, root: u32) {
        if let Some(d) = &mut self.upgraded {
            d.on_component_touched(root);
        }
    }

    fn on_components_merged(&mut self, kept: u32, absorbed: u32) {
        if let Some(d) = &mut self.upgraded {
            d.on_components_merged(kept, absorbed);
        }
    }

    fn on_retire(&mut self, retired: &[StorageId], g: &Graph) {
        if let Some(d) = &mut self.upgraded {
            d.on_retire(retired, g);
        }
    }

    fn metadata_len(&self) -> usize {
        self.upgraded.as_ref().map_or(0, |d| d.metadata_len())
    }

    fn bind_slot(&mut self, slot: Arc<MinSlot>) {
        match &mut self.upgraded {
            Some(d) => d.bind_slot(slot),
            None => self.slot = Some(slot),
        }
    }

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        if let Some(d) = &mut self.upgraded {
            return d.pop_min(ctx);
        }
        if ctx.pool.len() >= self.crossover {
            return self.upgrade(ctx).pop_min(ctx);
        }
        self.scan.pop_min(ctx)
    }
}
