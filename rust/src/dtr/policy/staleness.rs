//! Incremental `h_LRU` index: an intrusive doubly-linked list ordered by
//! `last_access` (paper §3.2 / Appendix E.1 — staleness bookkeeping without
//! rescanning the pool).
//!
//! `h_LRU`'s score is `1/(clock − last_access + 1)`: although the *value*
//! changes every clock tick, the *order* between two storages never does —
//! it is exactly the order of their `last_access` stamps. Accesses arrive in
//! nondecreasing clock order, so "detach + append at tail" keeps the list
//! sorted and `pop_min` reads the head: O(1) per maintenance event versus
//! the scan's O(pool) per eviction.
//!
//! Equal `last_access` stamps (zero-cost ops don't advance the clock) form
//! contiguous runs; `pop_min` resolves a run by lowest storage id, matching
//! the reference scan's tie-break. The small-tensor filter walks runs in
//! staleness order and falls back to the unfiltered argmin when starved,
//! mirroring the scan's fallback.

use super::super::graph::Graph;
use super::super::ids::StorageId;
use super::{PolicyIndex, SelectCtx};

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy)]
struct Node {
    prev: u32,
    next: u32,
    la: u64,
    in_list: bool,
}

const EMPTY: Node = Node { prev: NIL, next: NIL, la: 0, in_list: false };

pub struct StalenessListIndex {
    nodes: Vec<Node>,
    head: u32,
    tail: u32,
}

impl Default for StalenessListIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl StalenessListIndex {
    pub fn new() -> Self {
        StalenessListIndex { nodes: Vec::new(), head: NIL, tail: NIL }
    }

    fn slot(&mut self, s: StorageId) -> usize {
        let i = s.idx();
        if self.nodes.len() <= i {
            self.nodes.resize(i + 1, EMPTY);
        }
        i
    }

    fn detach(&mut self, i: usize) {
        if !self.nodes[i].in_list {
            return;
        }
        let Node { prev, next, .. } = self.nodes[i];
        if prev == NIL {
            self.head = next;
        } else {
            self.nodes[prev as usize].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.nodes[next as usize].prev = prev;
        }
        self.nodes[i] = Node { la: self.nodes[i].la, ..EMPTY };
    }

    /// Insert keeping ascending `la` order (stable: equal stamps go after
    /// existing ones). Walks backward from the tail — re-insertions after an
    /// unlock carry the newest stamps, so the walk is almost always empty.
    fn insert_sorted(&mut self, i: usize, la: u64) {
        debug_assert!(!self.nodes[i].in_list);
        let mut after = self.tail;
        while after != NIL && self.nodes[after as usize].la > la {
            after = self.nodes[after as usize].prev;
        }
        let next = if after == NIL { self.head } else { self.nodes[after as usize].next };
        self.nodes[i] = Node { prev: after, next, la, in_list: true };
        let iu = i as u32;
        if after == NIL {
            self.head = iu;
        } else {
            self.nodes[after as usize].next = iu;
        }
        if next == NIL {
            self.tail = iu;
        } else {
            self.nodes[next as usize].prev = iu;
        }
    }
}

impl PolicyIndex for StalenessListIndex {
    fn name(&self) -> &'static str {
        "staleness_list"
    }

    fn on_insert(&mut self, s: StorageId, g: &Graph) {
        let la = g.storage(s).last_access;
        let i = self.slot(s);
        if !self.nodes[i].in_list {
            self.insert_sorted(i, la);
        }
    }

    fn on_remove(&mut self, s: StorageId, _g: &Graph) {
        let i = self.slot(s);
        self.detach(i);
    }

    fn on_access(&mut self, s: StorageId, _g: &Graph, clock: u64) {
        let i = self.slot(s);
        if self.nodes[i].in_list {
            debug_assert!(self.tail == i as u32 || self.nodes[self.tail as usize].la <= clock);
            self.detach(i);
            self.insert_sorted(i, clock);
        }
    }

    fn invalidate(&mut self, _s: StorageId, _g: &Graph, _accesses: &mut u64) {}

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        if self.head == NIL {
            return None;
        }
        // Walk runs of equal staleness in order; the first run containing a
        // filter-qualifying entry yields the argmin (lowest id within it).
        let mut p = self.head;
        let mut head_run_best: Option<u32> = None;
        let mut first_run = true;
        while p != NIL {
            let run_la = self.nodes[p as usize].la;
            let mut run_best: Option<u32> = None;
            while p != NIL && self.nodes[p as usize].la == run_la {
                *ctx.accesses += 1;
                if ctx.graph.storage(StorageId(p)).size >= ctx.min_size {
                    run_best = Some(run_best.map_or(p, |b| b.min(p)));
                }
                if first_run {
                    head_run_best = Some(head_run_best.map_or(p, |b| b.min(p)));
                }
                p = self.nodes[p as usize].next;
            }
            if let Some(b) = run_best {
                return Some(StorageId(b));
            }
            first_run = false;
        }
        // Size filter starved the whole pool: the scan's fallback is the
        // unfiltered argmin — the lowest id in the stalest run.
        head_run_best.map(StorageId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtr::evicted::EvictedScratch;
    use crate::dtr::heuristics::Heuristic;
    use crate::dtr::unionfind::UnionFind;
    use crate::util::rng::Rng;

    fn graph_with(sizes_la: &[(u64, u64)]) -> (Graph, Vec<StorageId>) {
        let mut g = Graph::new();
        let ss: Vec<StorageId> = sizes_la
            .iter()
            .map(|&(size, la)| {
                let s = g.new_storage(size, 0);
                g.new_tensor(s, None, false);
                g.storage_mut(s).resident = true;
                g.storage_mut(s).last_access = la;
                s
            })
            .collect();
        (g, ss)
    }

    fn pop(idx: &mut StalenessListIndex, g: &Graph, pool: &[StorageId], min_size: u64) -> Option<StorageId> {
        let mut uf = UnionFind::new();
        let mut scratch = EvictedScratch::new();
        let mut rng = Rng::new(1);
        let mut acc = 0u64;
        let mut roots = Vec::new();
        let mut cost_ns = 0u64;
        let mut ctx = SelectCtx {
            pool,
            graph: g,
            uf: &mut uf,
            scratch: &mut scratch,
            clock: 100,
            rng: &mut rng,
            accesses: &mut acc,
            root_buf: &mut roots,
            heuristic: Heuristic::lru(),
            min_size,
            sqrt_sample: false,
            profile: false,
            cost_ns: &mut cost_ns,
        };
        idx.pop_min(&mut ctx)
    }

    #[test]
    fn pops_stalest_then_reorders_on_access() {
        let (g, ss) = graph_with(&[(1, 5), (1, 2), (1, 9)]);
        let mut idx = StalenessListIndex::new();
        for &s in &ss {
            idx.on_insert(s, &g);
        }
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[1]));
        idx.on_access(ss[1], &g, 50);
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[0]));
        idx.on_remove(ss[0], &g);
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[2]));
    }

    #[test]
    fn equal_stamps_break_by_lowest_id() {
        let (g, ss) = graph_with(&[(1, 7), (1, 7), (1, 7)]);
        let mut idx = StalenessListIndex::new();
        // Insert out of id order; tie must still resolve to the lowest id.
        idx.on_insert(ss[2], &g);
        idx.on_insert(ss[0], &g);
        idx.on_insert(ss[1], &g);
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[0]));
    }

    #[test]
    fn filter_walks_runs_and_falls_back_when_starved() {
        let (g, ss) = graph_with(&[(1, 2), (100, 5), (1, 9)]);
        let mut idx = StalenessListIndex::new();
        for &s in &ss {
            idx.on_insert(s, &g);
        }
        // Threshold 10: the stalest entry is too small; next run qualifies.
        assert_eq!(pop(&mut idx, &g, &ss, 10), Some(ss[1]));
        // Threshold 1000: everything filtered -> unfiltered argmin.
        assert_eq!(pop(&mut idx, &g, &ss, 1000), Some(ss[0]));
    }

    #[test]
    fn out_of_order_insert_keeps_sorted() {
        let (g, ss) = graph_with(&[(1, 9), (1, 1), (1, 5)]);
        let mut idx = StalenessListIndex::new();
        for &s in &ss {
            idx.on_insert(s, &g);
        }
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[1]));
        idx.on_remove(ss[1], &g);
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[2]));
        idx.on_remove(ss[2], &g);
        assert_eq!(pop(&mut idx, &g, &ss, 0), Some(ss[0]));
    }
}
