//! Incremental index for the staleness-bearing cost heuristics (`h_DTR`,
//! `h_DTR^eq`, `h_DTR^local` and their ablation-grid relatives): Appendix
//! E.1's score caching with lazy neighborhood invalidation.
//!
//! The score `c(S)/[m(S)·staleness(S)]` re-orders as the clock advances, so
//! no heap over cached keys can be exact (see the module docs of
//! [`super`]). What *is* cacheable is the expensive numerator `c(S)` — the
//! `e*` DFS, the ẽ* union-find query, or the local parent cost — which only
//! changes when the evicted neighborhood of `S` does. This index caches the
//! numerator per storage and recomputes lazily: evicting, rematerializing,
//! or recording an operator dirties only the resident frontier of the
//! affected evicted region ([`Dirtier`]); for ẽ*, component-cost changes
//! propagate through union-find subscriptions ([`EqSubs`] — the paper's
//! eq-class metadata). `pop_min` is then a cheap O(pool) pass of
//! multiply/divide over cached numerators instead of O(pool) graph
//! traversals.
//!
//! Retained as the oracle-adjacent fallback under [`super::PolicyKind::Cached`]:
//! [`super::DifferentialIndex`] removes this index's remaining O(pool) pass,
//! and this scan — sharing the numerator cache but none of the kinetic
//! machinery — is what it is benchmarked and equivalence-tested against.

use super::super::graph::Graph;
use super::super::heuristics::{finish_score, Heuristic, InvalidationScope};
use super::super::ids::StorageId;
use super::{Dirtier, EqSubs, PolicyIndex, SelectCtx};

pub struct CachedCostScan {
    h: Heuristic,
    eq: bool,
    cost: Vec<f64>,
    dirty: Vec<bool>,
    dirtier: Dirtier,
    subs: EqSubs,
}

fn mark(cost: &mut Vec<f64>, dirty: &mut Vec<bool>, s: StorageId) {
    let i = s.idx();
    if cost.len() <= i {
        cost.resize(i + 1, 0.0);
        dirty.resize(i + 1, true);
    }
    dirty[i] = true;
}

impl CachedCostScan {
    pub fn new(h: Heuristic) -> Self {
        CachedCostScan {
            h,
            eq: h.invalidation_scope() == InvalidationScope::EqNeighborhood,
            cost: Vec::new(),
            dirty: Vec::new(),
            dirtier: Dirtier::new(h),
            subs: EqSubs::default(),
        }
    }

    /// One argmin pass over the pool, assuming all numerators are fresh.
    fn pass(&mut self, ctx: &mut SelectCtx<'_>, filtered: bool) -> Option<(f64, StorageId)> {
        let mut best: Option<(f64, StorageId)> = None;
        let pool = ctx.pool;
        for &s in pool {
            debug_assert!(!self.dirty[s.idx()]);
            *ctx.accesses += 1;
            let st = ctx.graph.storage(s);
            if filtered && st.size < ctx.min_size {
                continue;
            }
            let sc = finish_score(self.h, self.cost[s.idx()], st.size, st.last_access, ctx.clock);
            if best.map_or(true, |(b, bs)| sc < b || (sc == b && s.0 < bs.0)) {
                best = Some((sc, s));
            }
        }
        best
    }
}

impl PolicyIndex for CachedCostScan {
    fn name(&self) -> &'static str {
        "cached_cost_scan"
    }

    fn on_insert(&mut self, s: StorageId, _g: &Graph) {
        // Ensure a slot exists (fresh slots start dirty). A *returning*
        // storage's cached numerator is still valid: membership does not
        // enter the numerator, and invalidations/component hooks land
        // regardless of pool state — so the lock/unlock churn of every
        // operator call does not force e*/ẽ* recomputation of its inputs.
        let i = s.idx();
        if self.cost.len() <= i {
            self.cost.resize(i + 1, 0.0);
            self.dirty.resize(i + 1, true);
        }
    }

    fn on_remove(&mut self, _s: StorageId, _g: &Graph) {
        // Keep the cache and its eq-class subscriptions live (see
        // `on_insert`); out-of-pool storages keep receiving invalidations.
    }

    fn on_access(&mut self, _s: StorageId, _g: &Graph, _clock: u64) {
        // Staleness lives in the denominator, recomputed every pass.
    }

    fn invalidate(&mut self, s: StorageId, g: &Graph, accesses: &mut u64) {
        self.dirtier.collect(s, g, accesses);
        for &t in &self.dirtier.buf {
            mark(&mut self.cost, &mut self.dirty, t);
        }
    }

    fn on_component_touched(&mut self, root: u32) {
        let cost = &mut self.cost;
        let dirty = &mut self.dirty;
        self.subs.touched(root, |s| mark(cost, dirty, s));
    }

    fn on_components_merged(&mut self, kept: u32, absorbed: u32) {
        let cost = &mut self.cost;
        let dirty = &mut self.dirty;
        self.subs.merged(kept, absorbed, |s| mark(cost, dirty, s));
    }

    fn on_retire(&mut self, retired: &[StorageId], _g: &Graph) {
        for &s in retired {
            // The storage can never return to the pool: poison its cache
            // slot and supersede its subscription generation, then sweep the
            // subscription lists so roots never touched again release their
            // entries too.
            mark(&mut self.cost, &mut self.dirty, s);
            self.subs.bump(s);
        }
        self.subs.sweep();
    }

    fn metadata_len(&self) -> usize {
        // The cost/dirty slabs are id-indexed (graph-arena-proportional) and
        // excluded by the trait contract; churn-driven state is the
        // subscription entries.
        self.subs.len()
    }

    fn pop_min(&mut self, ctx: &mut SelectCtx<'_>) -> Option<StorageId> {
        if ctx.pool.is_empty() {
            return None;
        }
        // Refresh every dirty numerator first; the argmin passes below are
        // then pure arithmetic over cached values.
        let pool = ctx.pool;
        for &s in pool {
            if self.cost.len() <= s.idx() {
                mark(&mut self.cost, &mut self.dirty, s);
            }
            if self.dirty[s.idx()] {
                let c = ctx.cached_cost_of(s);
                self.cost[s.idx()] = c;
                self.dirty[s.idx()] = false;
                if self.eq {
                    self.subs.bump(s);
                    self.subs.subscribe(s, ctx.root_buf);
                }
            }
        }
        let mut best = self.pass(ctx, true);
        if best.is_none() && ctx.min_size > 0 {
            best = self.pass(ctx, false);
        }
        best.map(|(_, s)| s)
    }
}
