//! Dynamic Tensor Rematerialization: the paper's core runtime.
//!
//! See DESIGN.md §2–3. Public surface:
//! * [`Runtime`] — the online eviction/rematerialization algorithm (Fig. 1);
//! * [`Heuristic`] — the eviction-score family of Sec. 4.1 / Appendix D;
//! * [`policy`] — victim selection behind the [`policy::PolicyIndex`] seam
//!   (incremental indexes vs. the reference scan, [`PolicyKind`]) and the
//!   deallocation policies ([`DeallocPolicy`], Sec. 2);
//! * [`lease`] — the shared-budget seam: an optional [`BudgetGate`] in
//!   [`Config`] replaces the fixed budget with a revocable lease on a
//!   global pool, arbitrated by `crate::serve` (cross-shard eviction);
//! * [`Backend`] — pluggable compute: accounting-only for simulation, PJRT
//!   for real execution.

pub mod backend;
pub mod evicted;
pub mod graph;
pub mod heuristics;
pub mod ids;
pub mod lease;
pub mod policy;
pub mod runtime;
pub mod unionfind;

pub use backend::{Backend, NullBackend};
pub use graph::{Graph, Operator, Storage, Tensor};
pub use heuristics::{CostKind, Heuristic, InvalidationScope, ParamSpec};
pub use ids::{OpId, StorageId, TensorId};
pub use lease::{
    BudgetGate, GateRef, LocalEvictor, NullLedger, PinnedLedger, RemoteEvictor, RemotePeek,
    RemoteReclaim, RuntimeHandle,
};
pub use policy::{DeallocPolicy, PolicyIndex, PolicyKind};
pub use runtime::{Config, DtrError, OutSpec, Runtime, Stats};
