//! # dtr — Dynamic Tensor Rematerialization (ICLR 2021)
//!
//! A full reproduction of *Dynamic Tensor Rematerialization* (Kirisame et
//! al., ICLR 2021) as a three-layer rust + JAX + Pallas system:
//!
//! * **rust (this crate)** — the DTR runtime (greedy online checkpointing
//!   under a memory budget), the Appendix-C simulator, workload generators
//!   for the paper's eight models, static-checkpointing baselines
//!   (Chen √N, Revolve/Treeverse, optimal), and a real training engine that
//!   executes AOT-compiled HLO artifacts through PJRT with DTR managing the
//!   actual buffers.
//! * **JAX (`python/compile/model.py`)** — the transformer ops (fwd/bwd),
//!   lowered once to HLO text; never imported at run time.
//! * **Pallas (`python/compile/kernels/`)** — fused attention + layernorm
//!   kernels inside the JAX ops.
//!
//! Quickstart: see `examples/quickstart.rs`; experiments: `dtr-repro --help`.

pub mod baselines;
pub mod coordinator;
pub mod dtr;
pub mod exec;
pub mod graphs;
pub mod repro;
pub mod runtime;
pub mod sim;
pub mod util;
