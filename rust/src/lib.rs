//! # dtr — Dynamic Tensor Rematerialization (ICLR 2021)
//!
//! A full reproduction of *Dynamic Tensor Rematerialization* (Kirisame et
//! al., ICLR 2021) as a rust system with a backend-pluggable execution
//! layer:
//!
//! * **DTR runtime** (`dtr::`) — greedy online checkpointing under a memory
//!   budget: eviction heuristics (Sec. 4.1 / Appendix D), deallocation
//!   policies, the Appendix-C simulator contract.
//! * **Execution layer** (`runtime::`) — the [`runtime::Executor`] trait is
//!   the seam between DTR (which only sees tensor ids, sizes, and costs)
//!   and real compute. Implementations:
//!   - [`runtime::InterpExecutor`] — hermetic pure-Rust interpreter of the
//!     transformer op set (matmul/attention/layernorm/GELU/cross-entropy +
//!     hand-derived backward, Adam/SGD). The default: `cargo test` runs
//!     real training end-to-end with zero external dependencies.
//!   - `runtime::PjrtExecutor` (cargo feature `pjrt`, off by default) —
//!     executes AOT-compiled HLO artifacts through the `xla` crate. Offline
//!     builds type-check against the in-tree stub in `rust/vendor/xla`;
//!     swap that path dependency for the real crate to run on XLA.
//!   - [`runtime::NullExecutor`] — accounting-only; DTR's decisions must be
//!     identical under it and any real executor (backend-equivalence
//!     property in `tests/prop_invariants.rs`).
//! * **Engine + coordinator** (`exec::`, `coordinator::`) — a real
//!   transformer-LM training step driven through DTR, with deterministic
//!   analytic op costs so budgeted runs reproduce exactly.
//! * **Experiments** (`repro::`, `sim::`, `graphs::`, `baselines::`) — the
//!   paper's figures/tables over the simulator and the engine.
//!
//! The JAX/Pallas sources (`python/compile/`) define the op semantics the
//! interpreter mirrors and lower the PJRT artifacts; Python is never needed
//! at run time.
//!
//! Quickstart: see `examples/quickstart.rs`; experiments: `dtr-repro --help`.

pub mod baselines;
pub mod coordinator;
pub mod dtr;
pub mod exec;
pub mod graphs;
pub mod repro;
pub mod runtime;
pub mod sim;
pub mod util;
