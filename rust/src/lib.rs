//! # dtr — Dynamic Tensor Rematerialization (ICLR 2021)
//!
//! A full reproduction of *Dynamic Tensor Rematerialization* (Kirisame et
//! al., ICLR 2021) as a rust system with a backend-pluggable execution
//! layer:
//!
//! * **Public API** ([`api`]) — **the entry point for user programs**: an
//!   [`api::Session`] facade over the DTR runtime plus RAII [`api::Tensor`]
//!   handles. `Clone` retains, `Drop` releases through the deallocation
//!   policy, `Session::call` interposes every operator, and
//!   `Session::constant`/`Session::get` handle host I/O — the paper's
//!   "interposition on tensor allocations and operator calls" as an API
//!   that cannot leak pins or double-release. Because programs drive the
//!   session online, dynamic models (data-dependent LSTMs, per-sample
//!   TreeLSTMs; see [`exec::dynamic`]) train under a budget with zero
//!   ahead-of-time planning.
//! * **DTR runtime** (`dtr::`) — greedy online checkpointing under a memory
//!   budget: eviction heuristics (Sec. 4.1 / Appendix D), deallocation
//!   policies, the Appendix-C simulator contract.
//! * **Execution layer** (`runtime::`) — the [`runtime::Executor`] trait is
//!   the seam between DTR (which only sees tensor ids, sizes, and costs)
//!   and real compute. Implementations:
//!   - [`runtime::InterpExecutor`] — hermetic pure-Rust interpreter of the
//!     transformer op set (matmul/attention/layernorm/GELU/cross-entropy +
//!     hand-derived backward, Adam/SGD). The default: `cargo test` runs
//!     real training end-to-end with zero external dependencies.
//!   - `runtime::PjrtExecutor` (cargo feature `pjrt`, off by default) —
//!     executes AOT-compiled HLO artifacts through the `xla` crate. Offline
//!     builds type-check against the in-tree stub in `rust/vendor/xla`;
//!     swap that path dependency for the real crate to run on XLA.
//!   - [`runtime::NullExecutor`] — accounting-only; DTR's decisions must be
//!     identical under it and any real executor (backend-equivalence
//!     property in `tests/prop_invariants.rs`).
//! * **Engine + coordinator** (`exec::`, `coordinator::`) — a real
//!   transformer-LM training step driven through DTR, with deterministic
//!   analytic op costs so budgeted runs reproduce exactly.
//! * **Serving** ([`serve`]) — N concurrent tenants (sessions on worker
//!   threads, each with its own runtime and policy index) sharded over
//!   **one** global byte budget: a central [`serve::BudgetArbiter`] hands
//!   out revocable leases and reclaims by evicting the globally
//!   least-valuable tensor across shards. N=1 serving is decision-exact
//!   vs. a plain session.
//! * **Request front-end** ([`frontend`]) — an event-loop layer that
//!   multiplexes N concurrent client streams of short requests (inference
//!   / fine-tune / probe) onto the shard fleet: bounded per-class queues
//!   with shed-on-overload admission control, a batching scheduler, and an
//!   event bus reporting requests/sec and p50/p95/p99 latency per class.
//! * **Experiments** (`repro::`, `sim::`, `graphs::`, `baselines::`) — the
//!   paper's figures/tables over the simulator and the engine.
//!
//! The JAX/Pallas sources (`python/compile/`) define the op semantics the
//! interpreter mirrors and lower the PJRT artifacts; Python is never needed
//! at run time.
//!
//! Quickstart: see `examples/quickstart.rs`; experiments: `dtr-repro --help`.

// Style-lint posture for the `cargo clippy -- -D warnings` CI gate: the
// numeric kernels and arena-index code deliberately use index loops and
// multi-argument signatures that mirror the math they implement; the gate
// is kept for correctness/suspicious/perf lints.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::type_complexity,
    clippy::many_single_char_names,
    clippy::module_inception,
    clippy::uninlined_format_args,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::needless_bool,
    clippy::comparison_chain
)]

pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod dtr;
pub mod exec;
pub mod frontend;
pub mod graphs;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
