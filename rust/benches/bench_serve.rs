//! Serving-throughput bench: aggregate steps/sec and rematerialization
//! overhead vs tenant count (1/2/4/8) under one global budget, for both
//! arbitration policies (static-split vs global-reclaim). Custom harness
//! (criterion is not in the offline crate cache).
//!
//! `--json PATH` writes the scaling table as a JSON report
//! (`make bench-json` -> `BENCH_serve.json`) — the serving arm of the perf
//! trajectory next to `BENCH_dtr.json`. Rows with `completed <
//! requested` mark tenants that OOMed under their policy (static-split
//! boxes tenants into `total/N` shares; global-reclaim lets hot tenants
//! borrow idle bytes), so the comparison is throughput *and* admission.
//!
//! A second section drives the request front-end (`dtr::frontend`):
//! bursty open-loop clients over 1/2/4 tenant-class mixes, reporting
//! requests/sec and p50/p99 latency per arbiter policy (JSON key
//! `frontend`). Empty or zeroed percentiles fail the run unless
//! `--allow-empty` is passed — same contract as the scaling section.
//!
//! A fourth section (JSON key `global_evict`) times the arbiter's
//! global-eviction *decision* — `pick_victim`, the exact capture the
//! reservation slow path runs — over populated fleets of 1/2/4/8 shards,
//! shared fleet tournament (`GlobalIndexKind::Shared`, one O(log N)
//! read over published minima) vs the retained peek-every-shard scan
//! (`GlobalIndexKind::Scan`, N runtime locks + victim searches). The
//! write fails unless shared ≤ scan at 4+ tenants — the sub-linearity
//! claim — with the usual `--allow-empty` escape.
//!
//! A third section (JSON key `dedup`) measures the content-addressed
//! pinned-weight store: pinned parameter bytes at rest and inference
//! throughput for a same-model fleet at 1/2/4/8 tenants, shared
//! (`ServePool::with_dedup`) vs private copies, under a budget sized for
//! one pinned copy plus `n` working sets. The run fails unless the shared
//! mode pins strictly fewer bytes than private at the largest fleet.

use dtr::api::{Session, Tensor};
use dtr::dtr::{Config, NullBackend};
use dtr::frontend::{frontend_budget, serve_bursty, FrontendConfig};
use dtr::serve::{
    fleet_budget, run_tenants, tenant_envelope, ArbiterPolicy, GlobalIndexKind, ServePool,
    TenantDriver, TenantKind, TenantSpec,
};

struct Row {
    tenants: usize,
    arbiter: &'static str,
    requested: usize,
    completed: usize,
    steps_per_sec: f64,
    slowdown: f64,
    evictions: u64,
    budget: u64,
}

fn run_point(n: usize, policy: ArbiterPolicy, steps: usize, budget: u64) -> Row {
    let specs = TenantSpec::fleet(n);
    let pool = ServePool::new(budget, policy, n);
    let base = Config::default();
    let t0 = std::time::Instant::now();
    let reports = run_tenants(&pool, &specs, &base, steps).expect("tenant threads");
    let wall_s = t0.elapsed().as_secs_f64();
    pool.check_invariants().expect("ledger");
    let completed: usize = reports.iter().map(|r| r.completed).sum();
    let base_c: u64 = reports.iter().map(|r| r.stats.base_compute).sum();
    let remat_c: u64 = reports.iter().map(|r| r.stats.remat_compute).sum();
    let evictions: u64 = reports.iter().map(|r| r.stats.evict_count).sum();
    Row {
        tenants: n,
        arbiter: policy.name(),
        requested: steps * n,
        completed,
        steps_per_sec: completed as f64 / wall_s.max(1e-9),
        slowdown: if base_c == 0 { 1.0 } else { (base_c + remat_c) as f64 / base_c as f64 },
        evictions,
        budget,
    }
}

struct FrontRow {
    classes: usize,
    arbiter: &'static str,
    submitted: usize,
    completed: usize,
    rejected: usize,
    requests_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// One front-end point: bursty open-loop clients over `n` tenant classes,
/// requests/sec and latency percentiles from the event bus.
fn run_frontend_point(n: usize, policy: ArbiterPolicy, per_class: usize) -> FrontRow {
    let cfg = FrontendConfig::mixed(n);
    let budget = frontend_budget(&cfg.classes, 70).expect("envelope measurement");
    let shards: usize = cfg.classes.iter().map(|c| c.shards).sum();
    let pool = ServePool::new(budget, policy, shards);
    let report =
        serve_bursty(&pool, &cfg, &Config::default(), per_class, 0xBE7C).expect("frontend run");
    pool.check_invariants().expect("ledger");
    let t = &report.total;
    FrontRow {
        classes: n,
        arbiter: policy.name(),
        submitted: t.submitted,
        completed: t.completed,
        rejected: t.rejected,
        requests_per_sec: t.requests_per_sec,
        p50_ms: t.p50_ns as f64 / 1e6,
        p99_ms: t.p99_ns as f64 / 1e6,
    }
}

struct DedupRow {
    tenants: usize,
    mode: &'static str,
    /// Bytes the fleet's pinned parameters cost at rest: the arbiter's
    /// shared ledger (one copy, measured) with dedup on, `n` private
    /// copies with it off.
    pinned_param_bytes: u64,
    steps_per_sec: f64,
    completed: usize,
    requested: usize,
    budget: u64,
}

/// Dedup capacity point: `n` tenants of the SAME base model serve `steps`
/// inference requests each (round-robin, single caller thread — identical
/// compute either mode) under a budget sized for ONE pinned copy plus `n`
/// working sets. Shared mode fits by construction; private mode overdrafts
/// `(n-1)` extra weight copies out of the evictable pool, which is the
/// capacity cost the shared store removes.
fn run_dedup_point(n: usize, dedup: bool, one_copy: u64, steps: usize) -> DedupRow {
    let (peak, floor) = tenant_envelope(TenantKind::Transformer, 0x5EED).expect("envelope");
    let budget = floor + (peak - floor) * n as u64;
    let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, n).with_dedup(dedup);
    let mut drivers: Vec<TenantDriver> = (0..n)
        .map(|i| {
            let cfg = Config { gate: Some(pool.lease()), ..Config::default() };
            TenantDriver::build_with_store(
                TenantKind::Transformer,
                cfg,
                0x5EED + i as u64,
                pool.store().cloned(),
            )
            .expect("tenant build")
        })
        .collect();
    let pinned_param_bytes =
        if dedup { pool.shared_bytes() } else { one_copy * n as u64 };
    let mut completed = 0usize;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        for d in drivers.iter_mut() {
            if d.infer().is_ok() {
                completed += 1;
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    drop(drivers);
    pool.check_invariants().expect("ledger");
    DedupRow {
        tenants: n,
        mode: if dedup { "shared" } else { "private" },
        pinned_param_bytes,
        steps_per_sec: completed as f64 / wall_s.max(1e-9),
        completed,
        requested: steps * n,
        budget,
    }
}

struct EvictRow {
    tenants: usize,
    mode: &'static str,
    ns_per_decision: f64,
    decisions: usize,
    /// Decisions that produced a victim certificate (a requester has no
    /// peers at `tenants == 1`, so hits are only demanded for 2+).
    hits: usize,
}

/// Global-eviction decision latency: `pick_victim` timed over a fleet of
/// `n` populated accounting shards, under one `GlobalIndexKind`. The
/// budget is generous — nothing actually evicts; the measured quantity is
/// the victim *choice*: one tournament read over published minima
/// (shared) vs `n` runtime locks + index peeks (scan).
fn run_global_evict_point(n: usize, kind: GlobalIndexKind, decisions: usize) -> EvictRow {
    let pool =
        ServePool::new(16 << 20, ArbiterPolicy::GlobalReclaim, n).with_global_index(kind);
    let sessions: Vec<Session<NullBackend>> = (0..n)
        .map(|_| {
            Session::accounting(Config {
                // Skip the auto index's scan pool so the publishing
                // differential tournament is what runs from op one.
                auto_crossover: 0,
                gate: Some(pool.lease()),
                ..Config::default()
            })
        })
        .collect();
    let mut lives: Vec<Vec<Tensor>> =
        sessions.iter().map(|s| vec![s.constant_sized(8)]).collect();
    for (sh, s) in sessions.iter().enumerate() {
        for i in 0..64u64 {
            let t = s
                .call_sized(
                    &format!("w{sh}_{i}"),
                    1 + i % 4,
                    &[lives[sh].last().expect("seeded")],
                    &[8 + i % 16],
                )
                .expect("warm op under generous budget")
                .remove(0);
            lives[sh].push(t);
        }
    }
    let arb = pool.arbiter();
    // Warm pick: the first drain folds every publish queued during setup.
    let _ = arb.pick_victim(0);
    let mut hits = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..decisions {
        if arb.pick_victim(i % n).is_some() {
            hits += 1;
        }
    }
    let ns_per_decision = t0.elapsed().as_nanos() as f64 / decisions.max(1) as f64;
    drop(lives);
    drop(sessions);
    pool.check_invariants().expect("ledger");
    EvictRow { tenants: n, mode: kind.name(), ns_per_decision, decisions, hits }
}

/// One tenant's worth of pinned parameter bytes, measured off a throwaway
/// dedup pool (the exact quantity the shared ledger is charged).
fn measure_one_copy() -> u64 {
    let pool = ServePool::new(64 << 20, ArbiterPolicy::GlobalReclaim, 1).with_dedup(true);
    let cfg = Config { gate: Some(pool.lease()), ..Config::default() };
    let _d = TenantDriver::build_with_store(
        TenantKind::Transformer,
        cfg,
        0x5EED,
        pool.store().cloned(),
    )
    .expect("tenant build");
    pool.shared_bytes()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = args.iter().any(|a| a == "--quick");
    let allow_empty = args.iter().any(|a| a == "--allow-empty");
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 3usize } else { 6 });

    println!(
        "# bench_serve — multi-tenant throughput vs tenant count{}\n",
        if quick { " (quick)" } else { "" }
    );
    let tenant_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let mut rows = Vec::new();
    for &n in tenant_counts {
        // The budget depends only on the fleet, not the policy: measure
        // the tenant envelopes once per point.
        let budget = fleet_budget(&TenantSpec::fleet(n), 70).expect("envelope measurement");
        for policy in ArbiterPolicy::all() {
            let r = run_point(n, policy, steps, budget);
            println!(
                "tenants={:<2} [{:<14}] {:>7.2} steps/s  slowdown {:>5.2}  \
                 {}/{} steps  {} evictions  budget {} B",
                r.tenants,
                r.arbiter,
                r.steps_per_sec,
                r.slowdown,
                r.completed,
                r.requested,
                r.evictions,
                r.budget
            );
            rows.push(r);
        }
    }

    // Front-end section: requests/sec + latency percentiles vs class count,
    // per arbiter policy (the serving-path numbers behind ROADMAP item 1).
    println!("\n# bench_serve — front-end requests/sec vs tenant-class count\n");
    let per_class = if quick { 8 } else { 16 };
    let mut front_rows = Vec::new();
    for &n in &[1usize, 2, 4] {
        for policy in ArbiterPolicy::all() {
            let r = run_frontend_point(n, policy, per_class);
            println!(
                "classes={:<2} [{:<14}] {:>8.2} req/s  p50 {:>7.2} ms  p99 {:>7.2} ms  \
                 {}/{} completed  {} shed",
                r.classes,
                r.arbiter,
                r.requests_per_sec,
                r.p50_ms,
                r.p99_ms,
                r.completed,
                r.submitted,
                r.rejected
            );
            front_rows.push(r);
        }
    }

    // Dedup capacity section: pinned parameter bytes at rest + inference
    // throughput for a same-model fleet, shared (content-addressed
    // WeightStore) vs private per-tenant copies, under a budget sized for
    // ONE pinned copy plus n working sets.
    println!("\n# bench_serve — dedup capacity: pinned weight bytes, shared vs private\n");
    let one_copy = measure_one_copy();
    let dedup_steps = if quick { 4 } else { 8 };
    let mut dedup_rows = Vec::new();
    for &n in tenant_counts {
        for &dedup in &[true, false] {
            let r = run_dedup_point(n, dedup, one_copy, dedup_steps);
            println!(
                "tenants={:<2} [{:<7}] pinned {:>9} B  {:>7.2} steps/s  {}/{} completed  \
                 budget {} B",
                r.tenants,
                r.mode,
                r.pinned_param_bytes,
                r.steps_per_sec,
                r.completed,
                r.requested,
                r.budget
            );
            dedup_rows.push(r);
        }
    }

    // Global-eviction decision latency: shared fleet tournament vs the
    // retained peek scan, same fleet sizes as the scaling section.
    println!("\n# bench_serve — global-evict ns/decision, shared tournament vs peek scan\n");
    let decisions = if quick { 2_000 } else { 10_000 };
    let mut evict_rows = Vec::new();
    for &n in tenant_counts {
        for kind in GlobalIndexKind::all() {
            let r = run_global_evict_point(n, kind, decisions);
            println!(
                "tenants={:<2} [{:<6}] {:>9.1} ns/decision  {}/{} certificates",
                r.tenants, r.mode, r.ns_per_decision, r.hits, r.decisions
            );
            evict_rows.push(r);
        }
    }

    if let Some(path) = json_out {
        if rows.is_empty() && !allow_empty {
            eprintln!(
                "bench_serve: refusing to write an empty results array to {path} \
                 (pass --allow-empty to override)"
            );
            std::process::exit(1);
        }
        // Same contract for the front-end section: empty or zeroed
        // percentiles mean the serving numbers are vacuous — fail loudly
        // rather than publish them.
        let vacuous = front_rows.is_empty()
            || front_rows.iter().any(|r| r.completed == 0 || r.p99_ms <= 0.0);
        if vacuous && !allow_empty {
            eprintln!(
                "bench_serve: front-end section has empty percentile results for {path} \
                 (pass --allow-empty to override)"
            );
            std::process::exit(1);
        }
        // The dedup section's acceptance bar: at the largest fleet, the
        // shared store must pin strictly fewer bytes than private copies
        // (the whole capacity claim), and every request must have run.
        let max_n = dedup_rows.iter().map(|r| r.tenants).max().unwrap_or(0);
        let shared_pin = dedup_rows
            .iter()
            .find(|r| r.tenants == max_n && r.mode == "shared")
            .map(|r| r.pinned_param_bytes);
        let private_pin = dedup_rows
            .iter()
            .find(|r| r.tenants == max_n && r.mode == "private")
            .map(|r| r.pinned_param_bytes);
        let no_win = match (shared_pin, private_pin) {
            (Some(s), Some(p)) => s == 0 || s >= p,
            _ => true,
        };
        if no_win && !allow_empty {
            eprintln!(
                "bench_serve: dedup section shows no capacity win at {max_n} tenants \
                 (shared {shared_pin:?} vs private {private_pin:?} pinned bytes) for {path} \
                 (pass --allow-empty to override)"
            );
            std::process::exit(1);
        }
        // Global-evict acceptance bar: rows must exist, every 2+-tenant
        // decision must have produced a certificate, and the shared
        // tournament must not lose to the peek scan at 4+ tenants (the
        // sub-linearity claim the section exists to demonstrate).
        let ge_vacuous = evict_rows.is_empty()
            || evict_rows.iter().any(|r| r.tenants >= 2 && r.hits < r.decisions);
        let ge_no_win = evict_rows
            .iter()
            .filter(|s| s.tenants >= 4 && s.mode == "shared")
            .any(|s| {
                match evict_rows.iter().find(|p| p.tenants == s.tenants && p.mode == "scan") {
                    Some(p) => s.ns_per_decision > p.ns_per_decision,
                    None => true,
                }
            });
        if (ge_vacuous || ge_no_win) && !allow_empty {
            eprintln!(
                "bench_serve: global_evict section is vacuous or shows the shared \
                 tournament losing to the peek scan at 4+ tenants for {path} \
                 (pass --allow-empty to override)"
            );
            std::process::exit(1);
        }
        let mut s = String::from(
            "{\n  \"bench\": \"serve_scaling\",\n  \"unit\": \"aggregate_steps_per_sec\",\n  \"quick\": ",
        );
        s.push_str(if quick { "true" } else { "false" });
        s.push_str(",\n  \"results\": [\n");
        for (i, r) in rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"tenants\": {}, \"arbiter\": \"{}\", \"steps_per_sec\": {:.3}, \
                 \"slowdown\": {:.4}, \"completed\": {}, \"requested\": {}, \
                 \"evictions\": {}, \"budget\": {}}}{}\n",
                r.tenants,
                r.arbiter,
                r.steps_per_sec,
                r.slowdown,
                r.completed,
                r.requested,
                r.evictions,
                r.budget,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n  \"frontend\": [\n");
        for (i, r) in front_rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"classes\": {}, \"arbiter\": \"{}\", \"requests_per_sec\": {:.3}, \
                 \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"submitted\": {}, \
                 \"completed\": {}, \"rejected\": {}}}{}\n",
                r.classes,
                r.arbiter,
                r.requests_per_sec,
                r.p50_ms,
                r.p99_ms,
                r.submitted,
                r.completed,
                r.rejected,
                if i + 1 == front_rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n  \"global_evict\": [\n");
        for (i, r) in evict_rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"tenants\": {}, \"mode\": \"{}\", \"ns_per_decision\": {:.1}, \
                 \"decisions\": {}, \"hits\": {}}}{}\n",
                r.tenants,
                r.mode,
                r.ns_per_decision,
                r.decisions,
                r.hits,
                if i + 1 == evict_rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n  \"dedup\": [\n");
        for (i, r) in dedup_rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"tenants\": {}, \"mode\": \"{}\", \"pinned_param_bytes\": {}, \
                 \"steps_per_sec\": {:.3}, \"completed\": {}, \"requested\": {}, \
                 \"budget\": {}}}{}\n",
                r.tenants,
                r.mode,
                r.pinned_param_bytes,
                r.steps_per_sec,
                r.completed,
                r.requested,
                r.budget,
                if i + 1 == dedup_rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, s).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
