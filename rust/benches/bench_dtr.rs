//! Microbenchmarks of the DTR hot paths: victim selection per heuristic,
//! union-find maintenance, exact-e* DFS, and full chain replays. Custom
//! harness (criterion is not in the offline crate cache): median of
//! repeated runs with warmup, printed as `name  median  iters`.

use std::time::Instant;

use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, Runtime};
use dtr::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    println!("{name:<52} median {:>12}  p95 {:>12}  ({iters} iters)", fmt_ns(median), fmt_ns(p95));
}

fn fmt_ns(ns: u64) -> String {
    if ns > 10_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns > 10_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Replay a chain of `n` unit ops under `budget` with heuristic `h`,
/// touching random earlier tensors to force rematerialization traffic.
fn chain_workload(n: usize, budget: u64, h: Heuristic, touches: usize) {
    let cfg = Config { budget, heuristic: h, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut rng = Rng::new(7);
    let mut ts = vec![rt.constant(1)];
    for i in 0..n {
        let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
        ts.push(t);
    }
    for _ in 0..touches {
        let t = ts[1 + rng.index(n)];
        rt.access(t).unwrap();
    }
}

fn main() {
    println!("# bench_dtr — DTR core hot paths\n");

    for h in [
        Heuristic::dtr(),
        Heuristic::dtr_eq(),
        Heuristic::dtr_local(),
        Heuristic::lru(),
    ] {
        bench(&format!("chain n=1024 b=48 touches=64  [{}]", h.name()), 20, || {
            chain_workload(1024, 48, h, 64);
        });
    }

    // Eviction-search scaling with pool size (the prototype's O(pool) scan).
    for n in [256usize, 1024, 4096] {
        bench(&format!("chain n={n} b=n/16 touches=16 [h_dtr_eq]"), 10, || {
            chain_workload(n, (n / 16) as u64, Heuristic::dtr_eq(), 16);
        });
    }

    // Appendix E.2 optimizations on a large pool.
    for (label, sqrt_sample, small_filter) in
        [("full-scan", false, false), ("sqrt-sample", true, false), ("sqrt+small-filter", true, true)]
    {
        bench(&format!("chain n=4096 b=256 touches=32 [{label}]"), 10, || {
            let cfg = Config {
                budget: 256,
                heuristic: Heuristic::dtr_eq(),
                sqrt_sample,
                small_filter,
                ..Config::default()
            };
            let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
            let mut ts = vec![rt.constant(1)];
            for i in 0..4096 {
                let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
                ts.push(t);
            }
            let mut rng = Rng::new(3);
            for _ in 0..32 {
                let t = ts[1 + rng.index(4096)];
                rt.access(t).unwrap();
            }
        });
    }

    // Union-find throughput.
    bench("union-find: 100k make/union/cost ops", 20, || {
        let mut uf = dtr::dtr::unionfind::UnionFind::new();
        let hs: Vec<u32> = (0..100_000).map(|_| uf.make_set()).collect();
        for w in hs.chunks(2) {
            if w.len() == 2 {
                uf.add_cost(w[0], 1.0);
                uf.union(w[0], w[1]);
            }
        }
        let mut total = 0.0;
        for &h in hs.iter().step_by(97) {
            total += uf.component_cost(h);
        }
        std::hint::black_box(total);
    });
}
