//! Microbenchmarks of the DTR hot paths: victim selection per heuristic,
//! eviction scaling of the policy indexes vs the reference scan, union-find
//! maintenance, and full chain replays. Custom harness (criterion is not in
//! the offline crate cache): median of repeated runs with warmup, printed as
//! `name  median  p95  iters`.
//!
//! `--json PATH` additionally writes the eviction-scaling section as a JSON
//! report (`make bench-json` -> `BENCH_dtr.json`): ns/eviction at pool
//! sizes 1k/10k/100k for scan vs indexed `h_lru`/`h_size`/`h_dtr` — the
//! perf trajectory of the §3.2/Appendix E runtime optimizations. The
//! indexed runs are decision-identical to the scan runs (the equivalence
//! property), so ns/eviction compares equal work.

use std::time::Instant;

use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, PolicyKind, Runtime};
use dtr::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> u64 {
    // Warmup.
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    println!("{name:<52} median {:>12}  p95 {:>12}  ({iters} iters)", fmt_ns(median), fmt_ns(p95));
    median
}

fn fmt_ns(ns: u64) -> String {
    if ns > 10_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns > 10_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Replay a chain of `n` unit ops under `budget` with heuristic `h`,
/// touching random earlier tensors to force rematerialization traffic.
fn chain_workload(n: usize, budget: u64, h: Heuristic, touches: usize) {
    let cfg = Config { budget, heuristic: h, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut rng = Rng::new(7);
    let mut ts = vec![rt.constant(1)];
    for i in 0..n {
        let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
        ts.push(t);
    }
    for _ in 0..touches {
        let t = ts[1 + rng.index(n)];
        rt.access(t).unwrap();
    }
}

/// Build an unbudgeted chain of `pool` evictable unit storages with varied
/// sizes/costs, ready for direct `evict_one` driving.
fn build_pool(pool: usize, h: Heuristic, kind: PolicyKind) -> Runtime<NullBackend> {
    let cfg = Config { heuristic: h, index: kind, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut prev = rt.constant(1);
    for i in 0..pool {
        let size = 1 + (i as u64 % 13);
        let cost = 1 + (i as u64 % 7);
        prev = rt.call(&format!("f{i}"), cost, &[prev], &[OutSpec::sized(size)]).unwrap()[0];
    }
    rt
}

struct ScalingRow {
    pool: usize,
    heuristic: String,
    index: &'static str,
    index_name: &'static str,
    ns_per_eviction: u64,
}

/// ns/eviction of `evictions` back-to-back victim selections at a given
/// pool size — the per-eviction cost the paper's Appendix E optimizations
/// target. The pool build is excluded from the timed region; the median
/// over `iters` fresh runtimes is reported. Decision-exact across `kind`,
/// so rows compare equal work.
fn eviction_scaling(
    pool: usize,
    h: Heuristic,
    kind: PolicyKind,
    evictions: usize,
    iters: usize,
) -> ScalingRow {
    let mut index_name = "";
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..=iters {
        let mut rt = build_pool(pool, h, kind);
        index_name = rt.index_name();
        let t0 = Instant::now();
        for _ in 0..evictions {
            rt.evict_one().expect("pool drained early");
        }
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.remove(0); // warmup
    samples.sort();
    let ns = samples[samples.len() / 2] / evictions as u64;
    println!(
        "evict: pool={pool} k={evictions} [{} / {}] {:>12}/eviction",
        h.name(),
        kind.name(),
        fmt_ns(ns)
    );
    ScalingRow { pool, heuristic: h.name(), index: kind.name(), index_name, ns_per_eviction: ns }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    println!("# bench_dtr — DTR core hot paths\n");

    for h in [
        Heuristic::dtr(),
        Heuristic::dtr_eq(),
        Heuristic::dtr_local(),
        Heuristic::lru(),
    ] {
        bench(&format!("chain n=1024 b=48 touches=64  [{}]", h.name()), 20, || {
            chain_workload(1024, 48, h, 64);
        });
    }

    // Eviction-search scaling with pool size (the prototype's O(pool) scan).
    for n in [256usize, 1024, 4096] {
        bench(&format!("chain n={n} b=n/16 touches=16 [h_dtr_eq]"), 10, || {
            chain_workload(n, (n / 16) as u64, Heuristic::dtr_eq(), 16);
        });
    }

    // Appendix E.2 optimizations on a large pool.
    for (label, sqrt_sample, small_filter) in
        [("full-scan", false, false), ("sqrt-sample", true, false), ("sqrt+small-filter", true, true)]
    {
        bench(&format!("chain n=4096 b=256 touches=32 [{label}]"), 10, || {
            let cfg = Config {
                budget: 256,
                heuristic: Heuristic::dtr_eq(),
                sqrt_sample,
                small_filter,
                ..Config::default()
            };
            let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
            let mut ts = vec![rt.constant(1)];
            for i in 0..4096 {
                let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
                ts.push(t);
            }
            let mut rng = Rng::new(3);
            for _ in 0..32 {
                let t = ts[1 + rng.index(4096)];
                rt.access(t).unwrap();
            }
        });
    }

    // Eviction scaling: per-eviction victim-selection cost, reference scan
    // vs incremental policy index (`dtr::policy`), at growing pool sizes.
    // The acceptance bar for the indexes: >= 5x faster than the scan for
    // h_lru / h_size / h_dtr at the 10k pool.
    println!("\n# eviction scaling — scan vs policy index (ns/eviction)\n");
    let mut rows: Vec<ScalingRow> = Vec::new();
    for &pool in &[1_000usize, 10_000, 100_000] {
        // Keep the scan's O(pool * evictions) cost bounded at 100k.
        let evictions = (pool / 2).min(512);
        let iters = if pool >= 100_000 { 2 } else { 3 };
        for h in [Heuristic::lru(), Heuristic::size(), Heuristic::dtr()] {
            for kind in [PolicyKind::Scan, PolicyKind::Auto] {
                rows.push(eviction_scaling(pool, h, kind, evictions, iters));
            }
        }
    }
    println!();
    for w in rows.chunks(2) {
        if let [scan, indexed] = w {
            let speedup = scan.ns_per_eviction as f64 / indexed.ns_per_eviction.max(1) as f64;
            println!(
                "pool={:<7} {:<8} scan {:>9} ns/evict | {} {:>9} ns/evict | {speedup:>6.1}x",
                scan.pool, scan.heuristic, scan.ns_per_eviction, indexed.index_name,
                indexed.ns_per_eviction
            );
        }
    }

    if let Some(path) = json_out {
        let mut s = String::from("{\n  \"bench\": \"dtr_eviction_scaling\",\n  \"unit\": \"ns_per_eviction\",\n  \"results\": [\n");
        for (i, r) in rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"pool\": {}, \"heuristic\": \"{}\", \"index\": \"{}\", \"resolved_index\": \"{}\", \"ns_per_eviction\": {}}}{}\n",
                r.pool,
                r.heuristic,
                r.index,
                r.index_name,
                r.ns_per_eviction,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, s).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    // Union-find throughput.
    bench("union-find: 100k make/union/cost ops", 20, || {
        let mut uf = dtr::dtr::unionfind::UnionFind::new();
        let hs: Vec<u32> = (0..100_000).map(|_| uf.make_set()).collect();
        for w in hs.chunks(2) {
            if w.len() == 2 {
                uf.add_cost(w[0], 1.0);
                uf.union(w[0], w[1]);
            }
        }
        let mut total = 0.0;
        for &h in hs.iter().step_by(97) {
            total += uf.component_cost(h);
        }
        std::hint::black_box(total);
    });
}
