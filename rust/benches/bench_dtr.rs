//! Microbenchmarks of the DTR hot paths: victim selection per heuristic,
//! eviction scaling of the policy indexes vs the reference scan, union-find
//! maintenance, and full chain replays. Custom harness (criterion is not in
//! the offline crate cache): median of repeated runs with warmup, printed as
//! `name  median  p95  iters`.
//!
//! `--json PATH` additionally writes the kernel and eviction-scaling
//! sections as a JSON report (`make bench-json` -> `BENCH_dtr.json`):
//!
//! * `section: "kernels"` — ns/call of the interpreter GEMMs, scalar
//!   reference vs the rank-1 row kernels (`runtime/kernels/gemm.rs`)
//!   at the transformer training shapes, at threads ∈ {1, 2, 4} (plus
//!   all-core when the box has more) — the measured intra-op threading
//!   trajectory.
//! * `section: "eviction_scaling"` — ns/eviction at growing pool sizes,
//!   per heuristic (`h_lru`/`h_size` and each member of the staleness
//!   family `h_dtr`/`h_dtr_eq`/`h_dtr_local`), reference scan vs the
//!   cached-numerator scan vs the differential (kinetic-tournament) index —
//!   the perf trajectory of the §3.2/Appendix E runtime optimizations. The
//!   staleness family gets an extra large-pool tier (100k quick, 1M full)
//!   where the differential index must beat `CachedCostScan` by ≥5x. All
//!   rows are decision-identical across kinds (the equivalence property),
//!   so ns/eviction compares equal work.
//! * `section: "epoch_migration"` — ns/op of a burst-heavy
//!   access-then-evict stream on the differential index, lazy epoch
//!   migration (`Config::default()`: touched storages park and
//!   batch-migrate at the next `pop_min`) vs eager
//!   (`eager_migration: true`: every touch re-seats its tier
//!   immediately). Decision-identical by construction — lazy only defers
//!   *where* the bookkeeping happens.
//!
//! `--quick` shrinks every section to CI size (small pools, few iters) so
//! the JSON trajectory can be regenerated on every push; `--json` exits
//! non-zero if the results array would be empty unless `--allow-empty` is
//! passed (an empty trajectory artifact is a bug, not a report).

use std::time::Instant;

use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, PolicyKind, Runtime};
use dtr::runtime::kernels::{gemm, reference};
use dtr::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> u64 {
    // Warmup.
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    println!("{name:<52} median {:>12}  p95 {:>12}  ({iters} iters)", fmt_ns(median), fmt_ns(p95));
    median
}

fn fmt_ns(ns: u64) -> String {
    if ns > 10_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns > 10_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Replay a chain of `n` unit ops under `budget` with heuristic `h`,
/// touching random earlier tensors to force rematerialization traffic.
fn chain_workload(n: usize, budget: u64, h: Heuristic, touches: usize) {
    let cfg = Config { budget, heuristic: h, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut rng = Rng::new(7);
    let mut ts = vec![rt.constant(1)];
    for i in 0..n {
        let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
        ts.push(t);
    }
    for _ in 0..touches {
        let t = ts[1 + rng.index(n)];
        rt.access(t).unwrap();
    }
}

/// Build an unbudgeted chain of `pool` evictable unit storages with varied
/// sizes/costs, ready for direct `evict_one` driving.
fn build_pool(pool: usize, h: Heuristic, kind: PolicyKind) -> Runtime<NullBackend> {
    let cfg = Config { heuristic: h, index: kind, ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut prev = rt.constant(1);
    for i in 0..pool {
        let size = 1 + (i as u64 % 13);
        let cost = 1 + (i as u64 % 7);
        prev = rt.call(&format!("f{i}"), cost, &[prev], &[OutSpec::sized(size)]).unwrap()[0];
    }
    rt
}

struct ScalingRow {
    pool: usize,
    heuristic: String,
    index: &'static str,
    index_name: &'static str,
    ns_per_eviction: u64,
}

struct KernelRow {
    op: &'static str,
    m: usize,
    k: usize,
    n: usize,
    variant: &'static str,
    threads: usize,
    ns_per_call: u64,
}

/// ns/call of the interpreter GEMMs at the exact shapes the transformer
/// training step issues at `ModelConfig::small()` (qkv/mlp/loss
/// projections and their backward contractions): the retained scalar
/// reference vs the rank-1 row kernel, single-thread and all-core.
/// All variants are bitwise-equal (the kernel-equivalence property), so
/// ns/call compares identical work.
fn bench_gemm_kernels(quick: bool) -> Vec<KernelRow> {
    println!("\n# interpreter GEMMs — scalar reference vs row kernels\n");
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let iters = if quick { 7 } else { 21 };
    // (op, m, k, n) with the kernel-layout convention: `matmul_at` takes
    // a:[k,m], `matmul_bt` takes b:[n,k]. dwqkv/dh1/dx are the backward
    // contractions of the qkv, mlp, and loss projections.
    let shapes: &[(&'static str, usize, usize, usize)] = &[
        ("matmul", 256, 64, 192),    // qkv projection
        ("matmul", 256, 128, 64),    // mlp contraction
        ("matmul", 256, 64, 256),    // loss logits
        ("matmul_at", 64, 256, 192), // dwqkv
        ("matmul_bt", 256, 192, 64), // dh1
        ("matmul_bt", 256, 256, 64), // dx
    ];
    let mut rng = Rng::new(11);
    let mut randv = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect()
    };
    let mut rows = Vec::new();
    for &(op, m, k, n) in shapes {
        let (asz, bsz) = match op {
            "matmul" => (m * k, k * n),
            "matmul_at" => (k * m, k * n),
            _ => (m * k, n * k),
        };
        let a = randv(asz);
        let b = randv(bsz);
        let run = |variant: &str, threads: usize| -> Vec<f32> {
            match (op, variant) {
                ("matmul", "scalar") => reference::matmul(&a, &b, m, k, n),
                ("matmul", _) => gemm::matmul(&a, &b, m, k, n, threads),
                ("matmul_at", "scalar") => reference::matmul_at(&a, &b, k, m, n),
                ("matmul_at", _) => gemm::matmul_at(&a, &b, k, m, n, threads),
                ("matmul_bt", "scalar") => reference::matmul_bt(&a, &b, m, k, n),
                (_, _) => gemm::matmul_bt(&a, &b, m, k, n, threads),
            }
        };
        // threads ∈ {1, 2, 4} are the recorded trajectory (row partitioning
        // is bit-identical at any count, so oversubscribing a small box
        // still measures honestly); all-core rides along when different.
        let mut variants: Vec<(&'static str, usize)> =
            vec![("scalar", 1), ("tiled", 1), ("tiled", 2), ("tiled", 4)];
        if cores > 1 && cores != 2 && cores != 4 {
            variants.push(("tiled", cores));
        }
        let mut scalar_ns = 0u64;
        for (variant, threads) in variants {
            let ns = bench(&format!("{op} {m}x{k}x{n} [{variant} t={threads}]"), iters, || {
                std::hint::black_box(run(variant, threads));
            });
            if variant == "scalar" {
                scalar_ns = ns;
            } else {
                let speedup = scalar_ns as f64 / ns.max(1) as f64;
                println!("    -> {speedup:.2}x over scalar");
            }
            rows.push(KernelRow { op, m, k, n, variant, threads, ns_per_call: ns });
        }
    }
    rows
}

/// ns/eviction of `evictions` back-to-back victim selections at a given
/// pool size — the per-eviction cost the paper's Appendix E optimizations
/// target. The pool build is excluded from the timed region; the median
/// over `iters` fresh runtimes is reported. Decision-exact across `kind`,
/// so rows compare equal work.
fn eviction_scaling(
    pool: usize,
    h: Heuristic,
    kind: PolicyKind,
    evictions: usize,
    iters: usize,
) -> ScalingRow {
    let mut index_name = "";
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..=iters {
        let mut rt = build_pool(pool, h, kind);
        index_name = rt.index_name();
        let t0 = Instant::now();
        for _ in 0..evictions {
            rt.evict_one().expect("pool drained early");
        }
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.remove(0); // warmup
    samples.sort();
    let ns = samples[samples.len() / 2] / evictions as u64;
    println!(
        "evict: pool={pool} k={evictions} [{} / {}] {:>12}/eviction",
        h.name(),
        kind.name(),
        fmt_ns(ns)
    );
    ScalingRow { pool, heuristic: h.name(), index: kind.name(), index_name, ns_per_eviction: ns }
}

struct MigrationRow {
    pool: usize,
    mode: &'static str,
    burst: usize,
    ns_per_op: u64,
}

/// ns/op of a burst-heavy stream on the differential index: `bursts`
/// rounds of `burst` accesses over a 16-storage hot window followed by
/// one eviction, lazy vs eager epoch migration. The burst shape is the
/// serving access pattern the lazy path exists for — many re-touches
/// between victim selections park as O(1) no-ops and batch-migrate once
/// at the pop, instead of `burst` immediate tier re-seats per round.
fn epoch_migration(pool: usize, eager: bool, bursts: usize, burst: usize, iters: usize) -> MigrationRow {
    let ops = bursts * (burst + 1);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..=iters {
        let cfg = Config {
            heuristic: Heuristic::dtr_eq(),
            index: PolicyKind::Differential,
            eager_migration: eager,
            ..Config::default()
        };
        let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
        let mut prev = rt.constant(1);
        let mut ts = vec![prev];
        for i in 0..pool {
            let size = 1 + (i as u64 % 13);
            let cost = 1 + (i as u64 % 7);
            prev = rt.call(&format!("f{i}"), cost, &[prev], &[OutSpec::sized(size)]).unwrap()[0];
            ts.push(prev);
        }
        let mut rng = Rng::new(17);
        let t0 = Instant::now();
        for _ in 0..bursts {
            // Hot window: a burst re-touches a small working set many
            // times between victim selections (the serving shape).
            // Re-touching a parked storage is an O(1) no-op under lazy
            // migration; eager re-seats its tier on every single touch.
            let hot = 1 + rng.index(pool - 16);
            for j in 0..burst {
                rt.access(ts[hot + (j % 16)]).unwrap();
            }
            rt.evict_one().expect("pool drained early");
        }
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.remove(0); // warmup
    samples.sort();
    let ns = samples[samples.len() / 2] / ops as u64;
    let mode = if eager { "eager" } else { "lazy" };
    println!(
        "migrate: pool={pool} bursts={bursts}x{burst} [{mode:<5}] {:>12}/op",
        fmt_ns(ns)
    );
    MigrationRow { pool, mode, burst, ns_per_op: ns }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = args.iter().any(|a| a == "--quick");
    let allow_empty = args.iter().any(|a| a == "--allow-empty");

    println!("# bench_dtr — DTR core hot paths{}\n", if quick { " (quick)" } else { "" });

    let chain_iters = if quick { 5 } else { 20 };
    for h in [
        Heuristic::dtr(),
        Heuristic::dtr_eq(),
        Heuristic::dtr_local(),
        Heuristic::lru(),
    ] {
        bench(&format!("chain n=1024 b=48 touches=64  [{}]", h.name()), chain_iters, || {
            chain_workload(1024, 48, h, 64);
        });
    }

    // Eviction-search scaling with pool size (the prototype's O(pool) scan).
    for n in [256usize, 1024, 4096] {
        bench(&format!("chain n={n} b=n/16 touches=16 [h_dtr_eq]"), chain_iters.min(10), || {
            chain_workload(n, (n / 16) as u64, Heuristic::dtr_eq(), 16);
        });
    }

    let kernel_rows = bench_gemm_kernels(quick);

    // Appendix E.2 optimizations on a large pool.
    for (label, sqrt_sample, small_filter) in
        [("full-scan", false, false), ("sqrt-sample", true, false), ("sqrt+small-filter", true, true)]
    {
        bench(&format!("chain n=4096 b=256 touches=32 [{label}]"), 10, || {
            let cfg = Config {
                budget: 256,
                heuristic: Heuristic::dtr_eq(),
                sqrt_sample,
                small_filter,
                ..Config::default()
            };
            let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
            let mut ts = vec![rt.constant(1)];
            for i in 0..4096 {
                let t = rt.call(&format!("f{i}"), 1, &[ts[i]], &[OutSpec::sized(1)]).unwrap()[0];
                ts.push(t);
            }
            let mut rng = Rng::new(3);
            for _ in 0..32 {
                let t = ts[1 + rng.index(4096)];
                rt.access(t).unwrap();
            }
        });
    }

    // Eviction scaling: per-eviction victim-selection cost at growing pool
    // sizes, broken out per heuristic so the scoreboard attributes wins to
    // the family that changed. Acceptance bars: the exact indexes >= 5x
    // over the reference scan at the 10k pool, and the differential index
    // >= 5x over CachedCostScan for the staleness family at the 100k tier.
    println!("\n# eviction scaling — scan vs policy indexes (ns/eviction)\n");
    let mut rows: Vec<ScalingRow> = Vec::new();
    let family = [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::dtr_local()];
    let mut plan: Vec<(usize, Heuristic, &[PolicyKind])> = Vec::new();
    // The 256 tier sits below AUTO_CROSSOVER_POOL: the Auto hybrid must
    // price like the scan there (no kinetic bookkeeping), which is the
    // measurement backing the crossover constant in `policy/auto.rs`.
    let base: &[usize] =
        if quick { &[256, 1_000, 10_000] } else { &[256, 1_000, 10_000, 100_000] };
    for &pool in base {
        for h in [Heuristic::lru(), Heuristic::size()] {
            plan.push((pool, h, &[PolicyKind::Scan, PolicyKind::Auto]));
        }
        for h in family {
            plan.push((pool, h, &[PolicyKind::Scan, PolicyKind::Cached, PolicyKind::Auto]));
        }
    }
    // The acceptance tier for the staleness family: differential vs the
    // cached scan it supersedes, at pools where the scan's O(pool) pass is
    // the bottleneck. Quick mode still covers 100k (the CI guard below
    // requires differential rows there); full mode adds 1M.
    let big = if quick { 100_000 } else { 1_000_000 };
    for h in family {
        plan.push((big, h, &[PolicyKind::Cached, PolicyKind::Differential]));
    }
    for (pool, h, kinds) in plan {
        // Keep the scans' O(pool * evictions) cost bounded at large pools.
        let evictions = (pool / 2).min(if quick { 128 } else { 512 });
        let iters = if pool >= 1_000_000 {
            1
        } else if pool >= 100_000 || quick {
            2
        } else {
            3
        };
        for &kind in kinds {
            rows.push(eviction_scaling(pool, h, kind, evictions, iters));
        }
    }
    println!();
    // Group rows by (pool, heuristic): the group's first row (the slowest
    // reference kind requested) is the baseline for the speedup column.
    let mut i = 0;
    while i < rows.len() {
        let mut j = i + 1;
        while j < rows.len()
            && rows[j].pool == rows[i].pool
            && rows[j].heuristic == rows[i].heuristic
        {
            j += 1;
        }
        let base = &rows[i];
        for r in &rows[i + 1..j] {
            let speedup = base.ns_per_eviction as f64 / r.ns_per_eviction.max(1) as f64;
            println!(
                "pool={:<8} {:<11} {:<16} {:>9} ns/evict | {:<16} {:>9} ns/evict | {speedup:>7.1}x",
                base.pool,
                base.heuristic,
                base.index_name,
                base.ns_per_eviction,
                r.index_name,
                r.ns_per_eviction
            );
        }
        i = j;
    }

    // Lazy vs eager epoch migration on burst-heavy access (the serving
    // shape: many touches per victim selection).
    println!("\n# epoch migration — lazy (park + batch at pop) vs eager (ns/op)\n");
    let mig_pool = if quick { 20_000 } else { 100_000 };
    let (mig_bursts, mig_burst) = if quick { (128, 64) } else { (256, 128) };
    let mut migration_rows = Vec::new();
    for &eager in &[false, true] {
        migration_rows.push(epoch_migration(mig_pool, eager, mig_bursts, mig_burst, 2));
    }

    if let Some(path) = json_out {
        let mut entries: Vec<String> = Vec::new();
        for r in &kernel_rows {
            entries.push(format!(
                "    {{\"section\": \"kernels\", \"op\": \"{}\", \"m\": {}, \"k\": {}, \
                 \"n\": {}, \"variant\": \"{}\", \"threads\": {}, \"ns_per_call\": {}}}",
                r.op, r.m, r.k, r.n, r.variant, r.threads, r.ns_per_call
            ));
        }
        for r in &rows {
            entries.push(format!(
                "    {{\"section\": \"eviction_scaling\", \"pool\": {}, \"heuristic\": \"{}\", \
                 \"index\": \"{}\", \"resolved_index\": \"{}\", \"ns_per_eviction\": {}}}",
                r.pool, r.heuristic, r.index, r.index_name, r.ns_per_eviction
            ));
        }
        for r in &migration_rows {
            entries.push(format!(
                "    {{\"section\": \"epoch_migration\", \"pool\": {}, \"mode\": \"{}\", \
                 \"burst\": {}, \"ns_per_op\": {}}}",
                r.pool, r.mode, r.burst, r.ns_per_op
            ));
        }
        if entries.is_empty() && !allow_empty {
            eprintln!("bench_dtr: refusing to write an empty results array to {path} \
                       (pass --allow-empty to override)");
            std::process::exit(1);
        }
        // The differential index's large-pool rows are the point of the
        // trajectory: an artifact without them is a bug, not a report.
        let has_diff_big = rows
            .iter()
            .any(|r| r.index_name == "differential" && r.pool >= 100_000);
        if !has_diff_big && !allow_empty {
            eprintln!("bench_dtr: no differential eviction_scaling rows at the 100k+ pool \
                       tier in {path} (pass --allow-empty to override)");
            std::process::exit(1);
        }
        let mut s = String::from(
            "{\n  \"bench\": \"dtr_perf\",\n  \"unit\": \"ns\",\n  \"quick\": ",
        );
        s.push_str(if quick { "true" } else { "false" });
        s.push_str(",\n  \"results\": [\n");
        s.push_str(&entries.join(",\n"));
        s.push_str("\n  ]\n}\n");
        std::fs::write(&path, s).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    // Union-find throughput.
    bench("union-find: 100k make/union/cost ops", 20, || {
        let mut uf = dtr::dtr::unionfind::UnionFind::new();
        let hs: Vec<u32> = (0..100_000).map(|_| uf.make_set()).collect();
        for w in hs.chunks(2) {
            if w.len() == 2 {
                uf.add_cost(w[0], 1.0);
                uf.union(w[0], w[1]);
            }
        }
        let mut total = 0.0;
        for &h in hs.iter().step_by(97) {
            total += uf.component_cost(h);
        }
        std::hint::black_box(total);
    });
}
