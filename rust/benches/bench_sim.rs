//! End-to-end simulator benchmarks: one per paper experiment family —
//! per-model simulated batch under each main heuristic (Fig. 2 rows),
//! the static-baseline comparison workload (Fig. 3), the adversarial
//! generator (Thm 3.2), and the Theorem 3.1 sweep. Reports wall time of the
//! *simulation itself* (the paper quotes "milliseconds per budget" for DTR
//! vs minutes for Checkmate's ILP — this validates that claim for our
//! implementation).

use std::time::Instant;

use dtr::baselines::optimal_chain_ops;
use dtr::dtr::{Config, Heuristic, PolicyKind};
use dtr::graphs::adversarial::run_adversary;
use dtr::graphs::linear::{run_linear, theorem_budget};
use dtr::graphs::models::{by_name, ALL_MODELS};
use dtr::sim::replay::{baseline, simulate};

fn time<F: FnMut() -> R, R>(name: &str, iters: usize, mut f: F) {
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort();
    println!(
        "{name:<58} median {:>10.3} ms  ({iters} iters)",
        samples[samples.len() / 2] as f64 / 1e6
    );
}

fn main() {
    println!("# bench_sim — simulator end-to-end (paper-experiment workloads)\n");

    // Fig. 2 rows: per-model simulated batch at 0.5 budget, reference scan
    // vs the incremental policy index (identical decisions, §3.2 runtime
    // optimizations on/off).
    for model in ALL_MODELS {
        let log = by_name(model, 1).unwrap();
        let b = baseline(&log);
        let budget = b.budget_at(0.5);
        for h in [Heuristic::dtr_eq(), Heuristic::dtr()] {
            for kind in [PolicyKind::Scan, PolicyKind::Auto] {
                time(&format!("fig2: {model} @0.5 [{} / {}]", h.name(), kind.name()), 10, || {
                    simulate(
                        &log,
                        Config { budget, heuristic: h, index: kind, ..Config::default() },
                    )
                });
            }
        }
    }

    // Fig. 3: DTR on a 512-chain vs the Revolve DP solve time.
    time("fig3: dtr h_dtr chain n=512 b=2sqrt(n)", 10, || {
        run_linear(512, theorem_budget(512), Heuristic::dtr(), false).unwrap()
    });
    time("fig3: revolve DP optimum n=512 b=48 (the 'ILP' solve)", 10, || {
        optimal_chain_ops(512, 48).unwrap()
    });

    // Thm 3.1 sweep cost.
    time("thm31: h_e* chain n=4096 b=2sqrt(n)", 5, || {
        run_linear(4096, theorem_budget(4096), Heuristic::EStarCount, false).unwrap()
    });

    // Thm 3.2 adversary.
    time("thm32: adversary n=512 b=8 [h_dtr_eq]", 5, || {
        run_adversary(512, 8, Heuristic::dtr_eq()).unwrap()
    });
}
