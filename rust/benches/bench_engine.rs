//! Real-engine benchmarks (Table 1 / Fig. 4 end-to-end): per-step wall time
//! of the transformer training step at several budgets, with the DTR
//! runtime-overhead fraction. Hermetic: runs on the pure-Rust interpreter
//! executor, so `cargo bench` works anywhere with zero external deps.

use std::time::Instant;

use dtr::dtr::{Config, Heuristic};
use dtr::exec::{Engine, Optimizer};
use dtr::runtime::ModelConfig;

fn main() {
    println!("# bench_engine — real training step under DTR budgets (interp backend)\n");

    let model = ModelConfig::small();
    let mut engine = Engine::interp(
        model,
        Config { profile: true, ..Config::default() },
        Optimizer::Sgd,
    )
    .expect("engine");
    let peak = engine.measure_peak().expect("peak");
    let pinned = engine.pinned_bytes();
    println!(
        "model: {} params; unbudgeted peak {:.1} MiB ({:.1} MiB pinned)\n",
        engine.total_params(),
        peak as f64 / (1 << 20) as f64,
        pinned as f64 / (1 << 20) as f64,
    );

    // Sweep fractions of the non-pinned headroom (100% = never evicts under
    // pressure; lower = more rematerialization).
    let pcts = [100u64, 90, 80, 70, 60];
    let budgets = engine.budgets_from_peak(peak, &pcts);
    for (&pct, &budget) in pcts.iter().zip(&budgets) {
        engine.dtr_cfg = Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            profile: true,
            ..Config::default()
        };
        // Warmup + 5 measured steps.
        let _ = engine.train_step();
        let mut walls = Vec::new();
        let mut overhead = Vec::new();
        let mut remats = 0u64;
        let mut failed = false;
        for _ in 0..5 {
            let t0 = Instant::now();
            match engine.train_step() {
                Ok(r) => {
                    walls.push(t0.elapsed().as_nanos() as u64);
                    overhead.push(r.stats.eviction_loop_ns);
                    remats += r.stats.remat_count;
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        if failed || walls.is_empty() {
            println!("headroom {pct:>3}%  OOM");
            continue;
        }
        walls.sort();
        let median = walls[walls.len() / 2];
        let ov: u64 = overhead.iter().sum::<u64>() / overhead.len() as u64;
        println!(
            "headroom {pct:>3}%  step {:>8.2} ms  eviction-loop {:>8.3} ms ({:.2}%)  remats/step {:.1}",
            median as f64 / 1e6,
            ov as f64 / 1e6,
            100.0 * ov as f64 / median as f64,
            remats as f64 / walls.len() as f64,
        );
    }
}
