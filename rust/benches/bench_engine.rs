//! Real-engine benchmarks (Table 1 / Fig. 4 end-to-end): per-step wall time
//! of the transformer training step at several budgets, with the DTR
//! runtime-overhead fraction. Requires `make artifacts`; prints a notice
//! and exits cleanly when they are absent (so `cargo bench` works anywhere).

use std::path::PathBuf;
use std::time::Instant;

use dtr::dtr::{Config, Heuristic};
use dtr::exec::{Engine, Optimizer};

fn main() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("# bench_engine: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    println!("# bench_engine — real training step under DTR budgets\n");

    let mut engine = Engine::new(
        &artifacts,
        Config { profile: true, ..Config::default() },
        Optimizer::Sgd,
    )
    .expect("engine");
    let peak = engine.measure_peak().expect("peak");
    println!(
        "model: {} params; unbudgeted peak {:.1} MiB\n",
        engine.total_params(),
        peak as f64 / (1 << 20) as f64
    );

    for ratio in [1.0f64, 0.9, 0.8, 0.7] {
        engine.dtr_cfg = Config {
            budget: (peak as f64 * ratio) as u64,
            heuristic: Heuristic::dtr_eq(),
            profile: true,
            ..Config::default()
        };
        // Warmup + 5 measured steps.
        let _ = engine.train_step();
        let mut walls = Vec::new();
        let mut overhead = Vec::new();
        let mut remats = 0u64;
        let mut failed = false;
        for _ in 0..5 {
            let t0 = Instant::now();
            match engine.train_step() {
                Ok(r) => {
                    walls.push(t0.elapsed().as_nanos() as u64);
                    overhead.push(r.stats.eviction_loop_ns);
                    remats += r.stats.remat_count;
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        if failed {
            println!("budget {ratio:>4.1}x  OOM");
            continue;
        }
        walls.sort();
        let median = walls[walls.len() / 2];
        let ov: u64 = overhead.iter().sum::<u64>() / overhead.len() as u64;
        println!(
            "budget {ratio:>4.1}x  step {:>8.1} ms  eviction-loop {:>8.3} ms ({:.2}%)  remats/step {:.1}",
            median as f64 / 1e6,
            ov as f64 / 1e6,
            100.0 * ov as f64 / median as f64,
            remats as f64 / walls.len() as f64,
        );
    }
}
