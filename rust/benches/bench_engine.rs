//! Real-engine benchmarks (Table 1 / Fig. 4 end-to-end): per-step wall time
//! of the transformer training step at several budgets, with the DTR
//! runtime-overhead fraction. Hermetic: runs on the pure-Rust interpreter
//! executor, so `cargo bench` works anywhere with zero external deps.

use std::time::Instant;

use dtr::dtr::{Config, Heuristic};
use dtr::exec::dynamic::{headroom_budget, LstmTrainer};
use dtr::exec::{Engine, Optimizer};
use dtr::runtime::{ModelConfig, RnnConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    println!(
        "# bench_engine — real training step under DTR budgets (interp backend){}\n",
        if quick { " (quick)" } else { "" }
    );
    let measured = if quick { 2usize } else { 5 };

    let model = ModelConfig::small();
    let mut engine = Engine::interp(
        model,
        Config { profile: true, ..Config::default() },
        Optimizer::Sgd,
    )
    .expect("engine");
    let peak = engine.measure_peak().expect("peak");
    let pinned = engine.pinned_bytes();
    println!(
        "model: {} params; unbudgeted peak {:.1} MiB ({:.1} MiB pinned)\n",
        engine.total_params(),
        peak as f64 / (1 << 20) as f64,
        pinned as f64 / (1 << 20) as f64,
    );

    // Sweep fractions of the non-pinned headroom (100% = never evicts under
    // pressure; lower = more rematerialization).
    let pcts: &[u64] = if quick { &[100, 80] } else { &[100, 90, 80, 70, 60] };
    let budgets = engine.budgets_from_peak(peak, pcts);
    for (&pct, &budget) in pcts.iter().zip(&budgets) {
        engine.dtr_cfg = Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            profile: true,
            ..Config::default()
        };
        // Warmup + measured steps.
        let _ = engine.train_step();
        let mut walls = Vec::new();
        let mut overhead = Vec::new();
        let mut remats = 0u64;
        let mut failed = false;
        for _ in 0..measured {
            let t0 = Instant::now();
            match engine.train_step() {
                Ok(r) => {
                    walls.push(t0.elapsed().as_nanos() as u64);
                    overhead.push(r.stats.eviction_loop_ns);
                    remats += r.stats.remat_count;
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        if failed || walls.is_empty() {
            println!("headroom {pct:>3}%  OOM");
            continue;
        }
        walls.sort();
        let median = walls[walls.len() / 2];
        let ov: u64 = overhead.iter().sum::<u64>() / overhead.len() as u64;
        println!(
            "headroom {pct:>3}%  step {:>8.2} ms  eviction-loop {:>8.3} ms ({:.2}%)  remats/step {:.1}",
            median as f64 / 1e6,
            ov as f64 / 1e6,
            100.0 * ov as f64 / median as f64,
            remats as f64 / walls.len() as f64,
        );
    }

    // --- intra-op threading: the TrainConfig::threads knob at full
    // headroom. Decision traces and results are bit-identical at any
    // thread count; only the wall clock moves. ---
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    if cores > 1 {
        println!("\n# intra-op threading — step wall time vs TrainConfig::threads\n");
        let mut t1_median = 0u64;
        for threads in [1usize, cores] {
            let mut e = Engine::interp_threaded(model, threads, Config::default(), Optimizer::Sgd)
                .expect("threaded engine");
            let _ = e.train_step(); // warmup
            let mut walls = Vec::new();
            for _ in 0..measured {
                let t0 = Instant::now();
                e.train_step().expect("unbudgeted step");
                walls.push(t0.elapsed().as_nanos() as u64);
            }
            walls.sort();
            let median = walls[walls.len() / 2];
            if threads == 1 {
                t1_median = median;
                println!("threads {threads:>2}  step {:>8.2} ms", median as f64 / 1e6);
            } else {
                println!(
                    "threads {threads:>2}  step {:>8.2} ms  ({:.2}x over threads=1)",
                    median as f64 / 1e6,
                    t1_median as f64 / median.max(1) as f64
                );
            }
        }
    }

    // --- dynamic-LSTM variant: per-batch random sequence lengths through
    // the `dtr::api` session path (the workload class static planners
    // cannot schedule) ---
    println!("\n# dynamic LSTM — data-dependent unroll lengths under DTR budgets\n");
    let rnn = RnnConfig::small();
    let mk = |budget: u64| -> LstmTrainer {
        let cfg =
            Config { budget, heuristic: Heuristic::dtr_eq(), profile: true, ..Config::default() };
        let mut t = LstmTrainer::interp(rnn, cfg).expect("lstm trainer");
        t.min_len = 8;
        t.max_len = 24;
        t
    };
    let (peak, floor) = mk(u64::MAX).measure_envelope(5).expect("envelope");
    println!(
        "dynamic envelope: floor {:.2} MiB, peak {:.2} MiB\n",
        floor as f64 / (1 << 20) as f64,
        peak as f64 / (1 << 20) as f64,
    );
    let lstm_pcts: &[u64] = if quick { &[100, 60] } else { &[100, 80, 60, 40] };
    for &pct in lstm_pcts {
        let mut t = mk(headroom_budget(peak, floor, pct));
        let _ = t.train_step(); // warmup
        let mut walls = Vec::new();
        let mut overhead = Vec::new();
        let mut remats = 0u64;
        let mut units = 0u64;
        let mut failed = false;
        for _ in 0..measured {
            match t.train_step() {
                Ok(r) => {
                    walls.push(r.wall_ns);
                    overhead.push(r.stats.eviction_loop_ns);
                    remats += r.stats.remat_count;
                    units += r.units;
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        if failed || walls.is_empty() {
            println!("headroom {pct:>3}%  OOM");
            continue;
        }
        walls.sort();
        let median = walls[walls.len() / 2];
        let ov: u64 = overhead.iter().sum::<u64>() / overhead.len() as u64;
        println!(
            "headroom {pct:>3}%  step {:>8.2} ms  eviction-loop {:>8.3} ms ({:.2}%)  remats/step {:.1}  mean-len {:.1}",
            median as f64 / 1e6,
            ov as f64 / 1e6,
            100.0 * ov as f64 / median as f64,
            remats as f64 / walls.len() as f64,
            units as f64 / walls.len() as f64,
        );
    }
}
