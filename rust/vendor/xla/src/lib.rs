//! Offline stub of the `xla` crate: exactly the API surface `dtr`'s `pjrt`
//! feature consumes, so `cargo build --features pjrt` type-checks without
//! the real PJRT bindings (unavailable offline).
//!
//! Host-side `Literal` construction and inspection are fully functional
//! (they are plain buffers); everything that would touch a PJRT client
//! returns an [`Error`] at runtime with an actionable message. To execute
//! compiled HLO artifacts for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at the real crate — no `dtr` source changes needed.

// Vendored stub: exempt from the workspace clippy gate.
#![allow(clippy::all)]

use std::fmt;

/// Stub error type, shaped like the real crate's (`std::error::Error`,
/// `Send + Sync`) so `anyhow` context chains compose identically.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real xla crate (PJRT bindings are not vendored \
         offline); point the `xla` path dependency at the real crate"
    )))
}

/// Element types the in-tree code stores in literals.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Marker trait for element types accepted by [`Literal::vec1`] /
/// [`Literal::to_vec`].
pub trait NativeType: Sized + Clone {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// A host tensor literal (dims + flat data), functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} ({} elements) from {} elements",
                dims,
                n,
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Destructure a tuple literal; the stub never produces tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("tuple literals")
    }
}

/// Parsed HLO module handle (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parsing HLO text")
    }
}

/// Computation handle built from a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by executions.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Clone>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled module")
    }
}

/// PJRT client handle; construction fails in the stub.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating a PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling a computation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.size_bytes(), 16);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_is_unavailable_with_actionable_error() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("real xla crate"), "{err}");
    }
}
