//! Regression: index metadata must stay FLAT under storage churn.
//!
//! Before the `PolicyIndex::on_retire` GC hook, `CachedCostScan` (and any
//! index sharing its "keep caches live across pool exits" policy) leaked
//! state for permanently-dropped storages: `EqSubs` subscription entries of
//! banished storages lived until their component root happened to be
//! touched again — which for a retired region is never — so a long-lived
//! serving session's index memory grew with total storages ever created
//! instead of with the live set. The runtime now batches banished storages
//! into a retired free list and flushes them through `on_retire`
//! (`Runtime::compact_index`), which supersedes their cache generations and
//! sweeps the subscription lists.
//!
//! The test drives a sliding-window chain under a tight budget with the
//! Banish dealloc policy — every released storage eventually retires, and
//! the budget pressure forces evictions so the eq-class subscription
//! machinery is actually exercised — and asserts the index's churn-driven
//! metadata (`Runtime::index_metadata_len`) at 8x the warm-up iteration
//! count has not grown past a small constant factor of the warm measure.

use dtr::dtr::{
    Config, DeallocPolicy, Heuristic, NullBackend, OutSpec, PolicyKind, Runtime, TensorId,
};

/// Run `iters` sliding-window chain steps; sample `index_metadata_len`
/// after a final compaction at each probe point.
fn churn_metadata(h: Heuristic, kind: PolicyKind, probes: &[usize]) -> Vec<usize> {
    let cfg = Config {
        budget: 128,
        heuristic: h,
        policy: DeallocPolicy::Banish,
        index: kind,
        ..Config::default()
    };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let mut window: Vec<TensorId> = vec![rt.constant(8)];
    let mut out = Vec::new();
    let iters = *probes.last().unwrap();
    for i in 0..iters {
        let prev = *window.last().unwrap();
        let cost = 1 + (i as u64 % 7);
        let size = 8 + (i as u64 % 5) * 4;
        let t = rt
            .call(&format!("f{i}"), cost, &[prev], &[OutSpec::sized(size)])
            .unwrap_or_else(|e| panic!("{} [{}] step {i}: {e:?}", h.name(), kind.name()))[0];
        window.push(t);
        if window.len() > 10 {
            rt.release(window.remove(0));
        }
        if probes.contains(&(i + 1)) {
            rt.compact_index();
            out.push(rt.index_metadata_len());
        }
    }
    out
}

#[test]
fn churn_holds_index_metadata_flat() {
    let probes = [500usize, 1000, 2000, 4000];
    for h in [Heuristic::dtr_eq(), Heuristic::dtr(), Heuristic::dtr_local()] {
        for kind in [PolicyKind::Cached, PolicyKind::Differential] {
            let sizes = churn_metadata(h, kind, &probes);
            let warm = sizes[0].max(16);
            let last = *sizes.last().unwrap();
            assert!(
                last <= 2 * warm,
                "{} [{}]: index metadata grew with churn: probes {probes:?} -> {sizes:?}",
                h.name(),
                kind.name()
            );
        }
    }
}

/// The same property for the clock-free lazy heap (EStar numerator without
/// staleness), whose heap + subscriptions flow through the same hooks.
#[test]
fn churn_holds_lazy_heap_metadata_flat() {
    use dtr::dtr::{CostKind, ParamSpec};
    let h = Heuristic::Param(ParamSpec {
        cost: CostKind::EqClass,
        use_size: true,
        use_staleness: false,
    });
    let sizes = churn_metadata(h, PolicyKind::Indexed, &[500, 1000, 2000, 4000]);
    let warm = sizes[0].max(16);
    assert!(
        *sizes.last().unwrap() <= 2 * warm,
        "lazy_heap: index metadata grew with churn: {sizes:?}"
    );
}
