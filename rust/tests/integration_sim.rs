//! Integration: workload generators -> JSONL logs -> simulator -> figures,
//! exercising the full simulation pipeline across modules.

use dtr::dtr::{Config, DeallocPolicy, Heuristic};
use dtr::graphs::models::{by_name, ALL_MODELS};
use dtr::sim::log::Log;
use dtr::sim::replay::{baseline, simulate};

#[test]
fn logs_roundtrip_through_jsonl_and_simulate_identically() {
    for model in ["resnet", "treelstm", "unrolled_gan"] {
        let log = by_name(model, 1).unwrap();
        let text = log.to_jsonl();
        let back = Log::from_jsonl(&text).unwrap();
        let b = baseline(&log);
        let cfg = Config { budget: b.budget_at(0.5), ..Config::default() };
        let a = simulate(&log, cfg.clone());
        let bb = simulate(&back, cfg);
        assert!(a.ok() && bb.ok());
        assert_eq!(a.stats.total_compute(), bb.stats.total_compute(), "{model}");
        assert_eq!(a.stats.remat_count, bb.stats.remat_count, "{model}");
    }
}

#[test]
fn simulation_is_deterministic() {
    let log = by_name("lstm", 1).unwrap();
    let b = baseline(&log);
    let cfg = Config { budget: b.budget_at(0.4), heuristic: Heuristic::dtr(), ..Config::default() };
    let x = simulate(&log, cfg.clone());
    let y = simulate(&log, cfg);
    assert_eq!(x.stats.total_compute(), y.stats.total_compute());
    assert_eq!(x.stats.evict_count, y.stats.evict_count);
    assert_eq!(x.stats.metadata_accesses, y.stats.metadata_accesses);
}

#[test]
fn slowdown_monotone_in_budget_roughly() {
    // More memory should never make things *much* worse (greedy heuristics
    // are not strictly monotone, but the trend must hold).
    let log = by_name("mlp", 1).unwrap();
    let b = baseline(&log);
    let mut prev = f64::INFINITY;
    for ratio in [0.3, 0.5, 0.7, 0.9, 1.0] {
        let out = simulate(
            &log,
            Config { budget: b.budget_at(ratio), heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        assert!(out.ok(), "ratio {ratio}: {:?}", out.failed);
        let s = out.stats.slowdown();
        assert!(s <= prev * 1.25 + 0.05, "slowdown jumped at ratio {ratio}: {s} vs {prev}");
        prev = s.min(prev);
    }
}

#[test]
fn full_budget_means_no_remat() {
    for model in ALL_MODELS {
        let log = by_name(model, 1).unwrap();
        let b = baseline(&log);
        let out = simulate(&log, Config { budget: b.peak_memory, ..Config::default() });
        assert!(out.ok(), "{model}: {:?}", out.failed);
        assert_eq!(out.stats.remat_count, 0, "{model} rematerialized at full budget");
        assert_eq!(out.stats.total_compute(), b.total_compute, "{model}");
    }
}

#[test]
fn informed_heuristics_dominate_random_on_average() {
    // Aggregate Fig. 2 claim across models at a moderate budget.
    let mut eq_total = 0.0;
    let mut rand_total = 0.0;
    for model in ["mlp", "resnet", "lstm", "densenet"] {
        let log = by_name(model, 1).unwrap();
        let b = baseline(&log);
        let budget = b.budget_at(0.35);
        let run = |h: Heuristic| {
            let o = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
            o.ok().then(|| o.stats.slowdown()).unwrap_or(10.0)
        };
        eq_total += run(Heuristic::dtr_eq());
        rand_total += run(Heuristic::Random);
    }
    assert!(
        eq_total <= rand_total,
        "h_dtr_eq total {eq_total} worse than h_rand {rand_total}"
    );
}

#[test]
fn policies_all_complete_at_moderate_budget() {
    let log = by_name("resnet", 1).unwrap();
    let b = baseline(&log);
    for policy in DeallocPolicy::all() {
        let out = simulate(
            &log,
            Config {
                budget: b.budget_at(0.6),
                heuristic: Heuristic::dtr(),
                policy,
                ..Config::default()
            },
        );
        assert!(out.ok(), "{}: {:?}", policy.name(), out.failed);
    }
}

#[test]
fn dealloc_awareness_beats_ignoring() {
    // Appendix D.2: eager eviction / banishing beat ignoring deallocations.
    let log = by_name("mlp", 1).unwrap();
    let b = baseline(&log);
    // `ignore` keeps dead tensors around, raising pressure: compare at the
    // same absolute budget (relative to the eager-policy peak).
    let budget = b.budget_at(0.45);
    let run = |policy: DeallocPolicy| {
        let o = simulate(
            &log,
            Config { budget, heuristic: Heuristic::dtr(), policy, ..Config::default() },
        );
        o.ok().then(|| o.stats.total_compute()).unwrap_or(u64::MAX)
    };
    let eager = run(DeallocPolicy::EagerEvict);
    let ignore = run(DeallocPolicy::Ignore);
    assert!(eager <= ignore, "eager {eager} worse than ignore {ignore}");
}

#[test]
fn sqrt_sampling_approximation_stays_close() {
    // Appendix E.2: the √n sampling optimization must not blow up overhead
    // at moderate budgets.
    let log = by_name("resnet", 1).unwrap();
    let b = baseline(&log);
    let budget = b.budget_at(0.5);
    let full = simulate(
        &log,
        Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() },
    );
    let sampled = simulate(
        &log,
        Config { budget, heuristic: Heuristic::dtr_eq(), sqrt_sample: true, ..Config::default() },
    );
    assert!(full.ok() && sampled.ok());
    let (f, s) = (full.stats.slowdown(), sampled.stats.slowdown());
    assert!(s <= f * 2.0 + 0.2, "sampling degraded too much: {s} vs {f}");
}
