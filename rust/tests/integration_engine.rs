//! Integration: the real engine stack over the hermetic interpreter
//! executor — coordinator -> DTR runtime -> Executor. No artifacts or
//! external dependencies required; these run everywhere `cargo test` does.

use dtr::coordinator::{train, TrainConfig};
use dtr::dtr as dtr_core;
use dtr::dtr::Heuristic;
use dtr::exec::{Engine, Optimizer};
use dtr::runtime::ModelConfig;

fn engine(opt: Optimizer) -> Engine {
    Engine::interp(ModelConfig::tiny(), dtr_core::Config::default(), opt).unwrap()
}

#[test]
fn trainer_end_to_end_under_budget() {
    // SGD keeps the pinned-constant floor low (no Adam m/v state), so a
    // 0.9-of-peak budget is comfortably feasible while still forcing
    // evictions.
    let cfg = TrainConfig {
        model: ModelConfig::tiny(),
        steps: 6,
        budget_ratio: Some(0.9),
        heuristic: Heuristic::dtr_eq(),
        optimizer: Optimizer::Sgd,
        log_every: 100,
        curve_out: None,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    assert_eq!(report.losses.len(), 6);
    assert!(report.peak_budgeted <= report.budget, "budget violated");
    assert!(
        report.losses.last().unwrap() < report.losses.first().unwrap(),
        "loss must descend: {:?}",
        report.losses
    );
    assert!(report.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn heuristics_agree_numerically_on_real_training() {
    // Different eviction heuristics change *what* is rematerialized but can
    // never change the numbers (pure ops, exact replay). Walk the budget
    // ladder down until both heuristics complete.
    let run = |h: Heuristic, budget: u64| -> Option<Vec<f32>> {
        let mut e = engine(Optimizer::Sgd);
        e.dtr_cfg =
            dtr_core::Config { budget, heuristic: h, ..dtr_core::Config::default() };
        (0..2).map(|_| e.train_step().ok().map(|r| r.loss)).collect()
    };
    let rungs = engine(Optimizer::Sgd).headroom_budgets(&[90, 80, 70]).unwrap();
    for budget in rungs {
        let (a, b) = (run(Heuristic::dtr_eq(), budget), run(Heuristic::lru(), budget));
        if let (Some(a), Some(b)) = (a, b) {
            assert_eq!(a, b, "heuristic changed numerics at budget {budget}");
            return;
        }
    }
    panic!("no budget rung completed under both heuristics");
}

#[test]
fn engine_reports_evictions_under_pressure_but_not_at_full_memory() {
    let mut e = engine(Optimizer::Sgd);
    let full = e.train_step().unwrap();
    // Unbudgeted: eager-evict frees on release (evict_count may be > 0) but
    // nothing is ever recomputed.
    assert_eq!(full.stats.remat_count, 0);

    let rungs = engine(Optimizer::Sgd).headroom_budgets(&[80, 70, 60]).unwrap();
    for budget in rungs {
        let mut tight_engine = engine(Optimizer::Sgd);
        tight_engine.dtr_cfg = dtr_core::Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            ..dtr_core::Config::default()
        };
        let Ok(tight) = tight_engine.train_step() else { continue };
        assert!(tight.stats.evict_count > 0, "no evictions at budget {budget}");
        assert!(tight.stats.peak_memory <= budget);
        return;
    }
    panic!("every rung of the budget ladder OOMed");
}

#[test]
fn engine_runs_deterministically() {
    // Analytic op costs (no wall-clock in decisions) make budgeted runs
    // bit-reproducible: same budget, same heuristic -> same stats and loss.
    let run = |budget: u64| {
        let mut e = engine(Optimizer::Sgd);
        e.dtr_cfg = dtr_core::Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            ..dtr_core::Config::default()
        };
        e.train_step().ok().map(|r| {
            (r.loss, r.stats.clock, r.stats.evict_count, r.stats.remat_count, r.stats.peak_memory)
        })
    };
    let rungs = engine(Optimizer::Sgd).headroom_budgets(&[85, 70]).unwrap();
    for budget in rungs {
        let first = run(budget);
        if first.is_some() {
            assert_eq!(first, run(budget), "identical budgeted runs diverged");
            return;
        }
    }
    panic!("every rung of the budget ladder OOMed");
}

#[test]
fn profile_mode_accounts_eviction_time() {
    let rungs = engine(Optimizer::Sgd).headroom_budgets(&[80, 70, 60]).unwrap();
    for budget in rungs {
        let mut e = engine(Optimizer::Sgd);
        e.dtr_cfg = dtr_core::Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            profile: true,
            ..dtr_core::Config::default()
        };
        let Ok(r) = e.train_step() else { continue };
        assert!(r.stats.eviction_searches > 0);
        assert!(r.stats.eviction_loop_ns > 0, "profiling must record search time");
        assert!(r.stats.cost_compute_ns <= r.stats.eviction_loop_ns);
        // DTR bookkeeping must stay well below operator compute (the Fig. 4
        // low-overhead claim); loose factor to absorb tiny-model noise.
        assert!(
            r.stats.eviction_loop_ns < 10 * r.exec_ns.max(1),
            "eviction loop ({}) dominated compute ({})",
            r.stats.eviction_loop_ns,
            r.exec_ns
        );
        return;
    }
    panic!("every rung of the budget ladder OOMed");
}
