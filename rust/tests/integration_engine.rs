//! Integration: the real three-layer stack. Requires `make artifacts`
//! (tests self-skip when artifacts are absent so `cargo test` works
//! pre-build, but `make test` always builds them first).

use std::path::PathBuf;

use dtr::coordinator::{train, TrainConfig};
use dtr::dtr as dtr_core;
use dtr::dtr::Heuristic;
use dtr::exec::{Engine, Optimizer};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn trainer_end_to_end_under_budget() {
    if !have_artifacts() {
        return;
    }
    let cfg = TrainConfig {
        artifacts_dir: artifacts_dir(),
        steps: 6,
        budget_ratio: Some(0.7),
        heuristic: Heuristic::dtr_eq(),
        optimizer: Optimizer::Sgd,
        log_every: 100,
        curve_out: None,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    assert_eq!(report.losses.len(), 6);
    assert!(report.peak_budgeted <= report.budget, "budget violated");
    assert!(
        report.losses.last().unwrap() < report.losses.first().unwrap(),
        "loss must descend: {:?}",
        report.losses
    );
}

#[test]
fn heuristics_agree_numerically_on_real_training() {
    if !have_artifacts() {
        return;
    }
    // Different eviction heuristics change *what* is rematerialized but can
    // never change the numbers (pure ops, exact replay).
    let run = |h: Heuristic| -> Vec<f32> {
        let mut e = Engine::new(&artifacts_dir(), dtr_core::Config::default(), Optimizer::Sgd).unwrap();
        let peak = e.measure_peak().unwrap();
        e.dtr_cfg = dtr_core::Config { budget: peak * 3 / 4, heuristic: h, ..dtr_core::Config::default() };
        (0..2).map(|_| e.train_step().unwrap().loss).collect()
    };
    let a = run(Heuristic::dtr_eq());
    let b = run(Heuristic::lru());
    assert_eq!(a, b, "heuristic changed numerics");
}

#[test]
fn engine_reports_remats_under_pressure_but_not_at_full_memory() {
    if !have_artifacts() {
        return;
    }
    let mut e = Engine::new(&artifacts_dir(), dtr_core::Config::default(), Optimizer::Sgd).unwrap();
    let full = e.train_step().unwrap();
    assert_eq!(full.stats.remat_count, 0);
    let peak = e.measure_peak().unwrap();
    e.dtr_cfg = dtr_core::Config {
        budget: peak * 7 / 10,
        heuristic: Heuristic::dtr_eq(),
        ..dtr_core::Config::default()
    };
    let tight = e.train_step().unwrap();
    assert!(tight.stats.evict_count > 0);
    assert!(tight.stats.peak_memory <= peak * 7 / 10);
}

#[test]
fn profile_mode_accounts_eviction_time() {
    if !have_artifacts() {
        return;
    }
    let mut e = Engine::new(
        &artifacts_dir(),
        dtr_core::Config { profile: true, ..dtr_core::Config::default() },
        Optimizer::Sgd,
    )
    .unwrap();
    let peak = e.measure_peak().unwrap();
    e.dtr_cfg = dtr_core::Config {
        budget: peak * 7 / 10,
        heuristic: Heuristic::dtr_eq(),
        profile: true,
        ..dtr_core::Config::default()
    };
    let r = e.train_step().unwrap();
    assert!(r.stats.eviction_searches > 0);
    assert!(r.stats.eviction_loop_ns > 0, "profiling must record search time");
    assert!(r.stats.cost_compute_ns <= r.stats.eviction_loop_ns);
    // DTR bookkeeping must be a small fraction of operator time here.
    assert!(
        r.stats.eviction_loop_ns < r.exec_ns,
        "eviction loop ({}) dominated compute ({})",
        r.stats.eviction_loop_ns,
        r.exec_ns
    );
}
