//! Integration: dynamic models trained end-to-end on the real interpreter
//! through the `dtr::api` session surface — the workloads whose computation
//! graphs are data-dependent (per-batch sequence lengths, per-sample tree
//! shapes) and therefore impossible for static checkpointing planners.

use dtr::api::Session;
use dtr::dtr::{Config, Heuristic};
use dtr::exec::dynamic::{headroom_budget, LstmTrainer, TreeLstmTrainer};
use dtr::runtime::{HostTensor, InterpExecutor, RnnConfig};

/// The acceptance test for this API: a TreeLSTM whose tree shapes vary
/// per step, trained under a tight budget on the interpreter, must stay
/// under budget, actually rematerialize, and still learn.
#[test]
fn treelstm_trains_under_tight_budget_with_remat() {
    let rnn = RnnConfig::tiny();
    let (peak, floor) = TreeLstmTrainer::interp(rnn, Config::default())
        .unwrap()
        .measure_envelope(8)
        .unwrap();
    assert!(peak > floor, "no evictable headroom to exercise");

    // Walk the ladder from snug to tight until a rung both completes and
    // rematerializes (looser rungs may never evict; overly tight ones may
    // OOM on the largest tree in the stream).
    for pct in [75u64, 60, 45, 30] {
        let budget = headroom_budget(peak, floor, pct);
        let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let mut t = TreeLstmTrainer::interp(rnn, cfg).unwrap();
        let before = t.probe_loss(99).unwrap();
        let mut remats = 0u64;
        let mut evictions = 0u64;
        let mut completed = true;
        for _ in 0..30 {
            match t.train_step() {
                Ok(r) => {
                    assert!(
                        r.stats.peak_memory <= budget,
                        "budget {budget} violated: peak {}",
                        r.stats.peak_memory
                    );
                    remats += r.stats.remat_count;
                    evictions += r.stats.evict_count;
                }
                Err(_) => {
                    completed = false;
                    break;
                }
            }
        }
        if !completed || remats == 0 {
            continue;
        }
        assert!(evictions > 0, "remats without evictions?");
        let after = t.probe_loss(99).unwrap();
        assert!(
            after < before,
            "loss did not decrease under budget {budget}: {before} -> {after}"
        );
        return;
    }
    panic!("no budget rung both completed and rematerialized");
}

/// The LSTM counterpart: per-batch sequence lengths, tight budget, exact
/// replay — the budgeted loss stream must be bitwise identical to the
/// unbudgeted one.
#[test]
fn lstm_budgeted_stream_bitwise_matches_unbudgeted() {
    let rnn = RnnConfig::tiny();
    let steps = 6;
    let (peak, floor) = LstmTrainer::interp(rnn, Config::default())
        .unwrap()
        .measure_envelope(steps)
        .unwrap();
    let mut reference = LstmTrainer::interp(rnn, Config::default()).unwrap();
    let expect: Vec<f32> = (0..steps).map(|_| reference.train_step().unwrap().loss).collect();

    let mut compared = false;
    for pct in [70u64, 50, 35] {
        let budget = headroom_budget(peak, floor, pct);
        let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let mut t = LstmTrainer::interp(rnn, cfg).unwrap();
        let got: Option<Vec<f32>> = (0..steps).map(|_| t.train_step().ok().map(|r| r.loss)).collect();
        if let Some(got) = got {
            assert_eq!(expect, got, "budgeted LSTM diverged at {pct}%");
            compared = true;
        }
    }
    assert!(compared, "every budget rung OOMed");
}

/// RAII semantics through the public API: clones retain, drops release
/// (eager eviction frees the buffer), and there is no way to leak or
/// double-release.
#[test]
fn session_raii_clone_retains_and_drop_releases() {
    let rnn = RnnConfig::tiny();
    let cfg = Config { budget: u64::MAX, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let s = Session::new(Box::new(InterpExecutor::rnn(rnn).unwrap()), cfg);

    let x = s.constant(HostTensor::zeros(&[rnn.batch, rnn.input]));
    let wc = s.constant(HostTensor::zeros(&[rnn.input, rnn.hidden]));
    let h = s.call("tree_leaf_fwd", &[&x, &wc]).unwrap().remove(0);
    let mem_with_h = s.memory();

    // A clone retains: dropping one handle must NOT free the buffer.
    let h2 = h.clone();
    drop(h);
    assert_eq!(s.memory(), mem_with_h, "drop of a cloned handle freed the storage");
    assert!(s.is_defined(&h2));

    // Dropping the last handle releases; the eager policy evicts.
    drop(h2);
    assert!(s.memory() < mem_with_h, "last drop did not free the storage");
    s.check_invariants().unwrap();
}

/// `get` on an evicted (but still referenced) tensor transparently
/// rematerializes it and returns the recomputed buffer.
#[test]
fn session_get_rematerializes_evicted_tensors() {
    let rnn = RnnConfig::tiny();
    let pinned = (rnn.batch * rnn.input + rnn.input * rnn.hidden) as u64 * 4;
    let out_bytes = (rnn.batch * rnn.hidden) as u64 * 4;
    // Room for the pinned constants plus only 3 of the 8 outputs below.
    let budget = pinned + 3 * out_bytes;
    let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let s = Session::new(Box::new(InterpExecutor::rnn(rnn).unwrap()), cfg);

    let x = s.constant(HostTensor::new(
        vec![rnn.batch, rnn.input],
        (0..rnn.batch * rnn.input).map(|i| (i % 3) as f32 * 0.1).collect(),
    ));
    let wc = s.constant(HostTensor::new(
        vec![rnn.input, rnn.hidden],
        (0..rnn.input * rnn.hidden).map(|i| ((i % 7) as f32 - 3.0) * 0.05).collect(),
    ));
    let outs: Vec<_> =
        (0..8).map(|_| s.call("tree_leaf_fwd", &[&x, &wc]).unwrap().remove(0)).collect();
    assert!(s.stats().evict_count > 0, "budget never forced an eviction");
    let evicted = outs
        .iter()
        .find(|t| !s.is_defined(t))
        .expect("some live handle must be evicted under this budget");

    let v = s.get(evicted).unwrap();
    assert_eq!(v.shape, vec![rnn.batch, rnn.hidden]);
    assert!(s.stats().remat_count > 0, "get did not rematerialize");
    // The recomputed value equals a fresh handle's value (pure replay).
    let fresh = s.call("tree_leaf_fwd", &[&x, &wc]).unwrap().remove(0);
    assert_eq!(v.data, s.get(&fresh).unwrap().data);
    s.check_invariants().unwrap();
}

/// Budgets are honored mid-stream even though each step's working set is
/// unknown until the batch is drawn — the online-planning claim.
#[test]
fn lstm_remats_under_budget_pressure() {
    let rnn = RnnConfig::tiny();
    let (peak, floor) = LstmTrainer::interp(rnn, Config::default())
        .unwrap()
        .measure_envelope(6)
        .unwrap();
    for pct in [60u64, 45, 30] {
        let budget = headroom_budget(peak, floor, pct);
        let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let mut t = LstmTrainer::interp(rnn, cfg).unwrap();
        let mut remats = 0u64;
        let mut completed = true;
        for _ in 0..10 {
            match t.train_step() {
                Ok(r) => {
                    assert!(r.stats.peak_memory <= budget);
                    remats += r.stats.remat_count;
                }
                Err(_) => {
                    completed = false;
                    break;
                }
            }
        }
        if completed && remats > 0 {
            return;
        }
    }
    panic!("no LSTM budget rung rematerialized");
}
