//! Integration: DTR vs the static-checkpointing baselines and the
//! exhaustively optimal scheduler — the Fig. 3 claims plus cross-validation
//! of the DP against the Dijkstra optimum on small instances.

use dtr::baselines::{chen_sqrt, optimal_chain_ops, optimal_cost, SmallDag};
use dtr::dtr::Heuristic;
use dtr::graphs::linear::{run_linear, theorem_budget};

#[test]
fn revolve_dp_matches_dijkstra_on_small_chains() {
    // Both model "reverse a chain with budget b"; the Dijkstra model asks
    // for each prefix target in reverse order. Equivalence on total forward
    // work: validate D(n, c) + n == dijkstra-with-reverse-targets for tiny n.
    for n in [4usize, 6, 8] {
        for b in [3u64, 4, 6] {
            let dp = optimal_chain_ops(n, b);
            // Dijkstra lower bound: computing just the last node (single
            // target) under budget b costs at least n (each node once).
            let dag = SmallDag::chain(n);
            let single = optimal_cost(&dag, b as u32, &[n - 1]).unwrap();
            assert_eq!(single, n as u64, "forward-only must be n");
            if let Some(dp_ops) = dp {
                assert!(dp_ops >= 2 * n as u64, "reverse needs >= 2n");
                // The reverse sweep can't beat touching every node twice.
                assert!(dp_ops <= (n * n) as u64, "DP exploded: {dp_ops}");
            }
        }
    }
}

#[test]
fn dtr_with_estar_matches_optimal_at_generous_budget() {
    let n = 128;
    let b = n as u64 + 3;
    let dtr = run_linear(n, b, Heuristic::EStarCount, false).unwrap().total_ops;
    let opt = optimal_chain_ops(n, b).unwrap();
    assert_eq!(dtr, opt, "no eviction needed: both must be 2n");
    assert_eq!(opt, 2 * n as u64);
}

#[test]
fn dtr_within_small_factor_of_optimal_across_budgets() {
    // The Fig. 3 headline on chains.
    let n = 256;
    for b in [28u64, 36, 48, 96, 160] {
        let opt = optimal_chain_ops(n, b).unwrap() as f64;
        let dtr = run_linear(n, b, Heuristic::EStarCount, false)
            .unwrap_or_else(|e| panic!("dtr OOM at b={b}: {e}"))
            .total_ops as f64;
        assert!(
            dtr <= opt * 1.75 + 16.0,
            "b={b}: dtr {dtr} vs optimal {opt} (ratio {:.2})",
            dtr / opt
        );
    }
}

#[test]
fn chen_never_beats_optimal() {
    let n = 512;
    for b in [50u64, 70, 100, 200, 400] {
        if let Some((chen, _)) = chen_sqrt(n, b) {
            let opt = optimal_chain_ops(n, b).unwrap();
            assert!(opt <= chen, "b={b}: optimal {opt} > chen {chen}");
        }
    }
}

#[test]
fn theorem_budget_feasible_for_all_theorem_heuristics() {
    // At B = 2⌈√N⌉ the h_{e*} run completes with bounded overhead; the
    // richer h_dtr (which includes staleness) must also complete.
    for h in [Heuristic::EStarCount, Heuristic::dtr(), Heuristic::dtr_eq()] {
        let n = 400;
        let r = run_linear(n, theorem_budget(n), h, false)
            .unwrap_or_else(|e| panic!("{}: {e}", h.name()));
        assert!(
            r.total_ops <= 8 * n as u64,
            "{}: {} ops for n={n}",
            h.name(),
            r.total_ops
        );
    }
}

#[test]
fn small_dag_optimal_vs_dtr_on_random_graphs() {
    // DTR is never better than the exhaustive optimum, and stays within a
    // moderate factor on small random DAGs (its greedy gap).
    use dtr::dtr::{Config, NullBackend, OutSpec, Runtime};
    use dtr::util::rng::Rng;

    let mut rng = Rng::new(99);
    for case in 0..20 {
        // Random DAG with 10 nodes, each depending on 1-2 earlier nodes.
        let n = 10;
        let mut deps: Vec<Vec<usize>> = vec![vec![]];
        for i in 1..n {
            let mut d = vec![rng.index(i)];
            if rng.chance(0.4) {
                let extra = rng.index(i);
                if !d.contains(&extra) {
                    d.push(extra);
                }
            }
            deps.push(d);
        }
        let dag = SmallDag { deps: deps.clone(), cost: vec![1; n] };
        let budget = 4u32;
        let targets = vec![n - 1];
        let Some(opt) = optimal_cost(&dag, budget, &targets) else { continue };

        // Drive DTR over the same DAG in creation order.
        let cfg = Config {
            budget: budget as u64,
            heuristic: dtr::dtr::Heuristic::dtr(),
            ..Config::default()
        };
        let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
        let mut ts = Vec::new();
        let mut ok = true;
        for i in 0..n {
            let inputs: Vec<_> = deps[i].iter().map(|&j| ts[j]).collect();
            let r = if inputs.is_empty() {
                // Roots are *evictable* sources in the optimal model; model
                // as unit-cost ops from a shared zero-sized constant.
                let c = if ts.is_empty() {
                    rt.constant(0)
                } else {
                    // reuse first constant
                    rt.graph.storage(dtr::dtr::StorageId(0)).root
                };
                rt.call(&format!("n{i}"), 1, &[c], &[OutSpec::sized(1)])
            } else {
                rt.call(&format!("n{i}"), 1, &inputs, &[OutSpec::sized(1)])
            };
            match r {
                Ok(out) => ts.push(out[0]),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue; // DTR can OOM where reordering would fit: Theorem 3.2
        }
        let dtr_ops = rt.stats.base_compute + rt.stats.remat_compute;
        assert!(
            dtr_ops >= opt,
            "case {case}: DTR {dtr_ops} beat the exhaustive optimum {opt}?!"
        );
        assert!(
            dtr_ops <= opt * 6,
            "case {case}: DTR {dtr_ops} vs optimal {opt} — gap too large"
        );
    }
}
