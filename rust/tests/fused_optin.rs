//! The fused-kernel opt-in (`TrainConfig::fused` → `InterpExecutor::
//! with_fused`) and its compatibility contract:
//!
//! * `fused = false` (the default) is the pre-fusion executor, **bit
//!   exact**: identical losses and identical DTR decision traces, so
//!   existing pinned baselines stay valid.
//! * `fused = true` swaps `block_fwd`/`block_bwd` onto the fused
//!   layernorm / flash-attention kernels. The online softmax reassociates
//!   reductions, so values shift at ~1e-4 — training must still descend,
//!   and budgeted-vs-unbudgeted must stay bitwise *within* the fused
//!   world (rematerialization replays the same fused kernels).

use dtr::dtr::{Config, Heuristic};
use dtr::exec::{Engine, Optimizer};
use dtr::runtime::{InterpExecutor, ModelConfig};

const STEPS: usize = 3;

fn engine(fused: bool) -> Engine {
    let exec = InterpExecutor::new(ModelConfig::tiny()).unwrap().with_fused(fused);
    Engine::new(Box::new(exec), Config::default(), Optimizer::Adam).unwrap()
}

/// First budget rung (from loose to tight) at which a `fused`-flavored
/// engine completes `STEPS` steps with at least one rematerialization,
/// plus the per-step losses and final stats observed there.
fn first_feasible_rung(fused: bool) -> (u64, Vec<f32>, dtr::dtr::Stats) {
    let rungs = engine(fused).headroom_budgets(&[85, 75, 65, 55]).unwrap();
    for budget in rungs {
        let mut e = engine(fused);
        e.dtr_cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let mut losses = Vec::new();
        let mut remats = 0u64;
        let mut failed = false;
        let mut stats = None;
        for _ in 0..STEPS {
            match e.train_step() {
                Ok(r) => {
                    assert!(r.stats.peak_memory <= budget, "budget exceeded");
                    losses.push(r.loss);
                    remats += r.stats.remat_count;
                    stats = Some(r.stats);
                }
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        if !failed && remats > 0 {
            return (budget, losses, stats.unwrap());
        }
    }
    panic!("no budget rung produced a completed, rematerializing fused={fused} run");
}

/// fused=false is the pre-fusion path: same losses bitwise and the same
/// eviction/remat decision trace as a plain `Engine::interp` under the
/// same budget.
#[test]
fn fused_off_is_decision_and_bit_exact() {
    let (budget, off_losses, off_stats) = first_feasible_rung(false);
    let mut plain =
        Engine::interp(ModelConfig::tiny(), Config::default(), Optimizer::Adam).unwrap();
    plain.dtr_cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let mut plain_losses = Vec::new();
    let mut plain_stats = None;
    for _ in 0..STEPS {
        let r = plain.train_step().unwrap();
        plain_losses.push(r.loss);
        plain_stats = Some(r.stats);
    }
    assert_eq!(off_losses, plain_losses, "fused=false changed the numerics");
    assert!(
        plain_stats.unwrap().same_decisions(&off_stats),
        "fused=false changed the decision trace"
    );
}

/// fused=true still learns under a tight budget, and its first-step loss
/// sits within kernel tolerance of the reference (the trajectories then
/// drift as the ~1e-4 attention difference compounds through Adam).
#[test]
fn fused_on_descends_and_stays_within_tolerance() {
    let (_, fused_losses, _) = first_feasible_rung(true);
    assert!(fused_losses.iter().all(|l| l.is_finite()));
    assert!(
        fused_losses[STEPS - 1] < fused_losses[0],
        "fused loss did not descend: {fused_losses:?}"
    );

    let mut reference = engine(false);
    let ref_first = reference.train_step().unwrap().loss;
    let fused_first = fused_losses[0];
    let tol = 1e-2 * ref_first.abs().max(1.0);
    assert!(
        (ref_first - fused_first).abs() <= tol,
        "fused first-step loss {fused_first} vs reference {ref_first}"
    );
}

/// Rematerialization inside the fused world replays the same fused
/// kernels: a budgeted fused run matches the unbudgeted fused run
/// bitwise, step for step.
#[test]
fn budgeted_fused_matches_unbudgeted_fused_bitwise() {
    let (_, budgeted, _) = first_feasible_rung(true);
    let mut free = engine(true);
    let free_losses: Vec<f32> = (0..STEPS).map(|_| free.train_step().unwrap().loss).collect();
    assert_eq!(budgeted, free_losses, "budget changed the fused numerics");
}
