//! Integration: one full transformer training run through the interpreter
//! executor under a tight memory budget — the hermetic end-to-end proof
//! that DTR + the pure-Rust backend compose: rematerialization actually
//! happens, the budget is respected, and training still learns.

use dtr::dtr::{Config, Heuristic};
use dtr::exec::{Engine, Optimizer};
use dtr::runtime::ModelConfig;

fn engine() -> Engine {
    Engine::interp(ModelConfig::tiny(), Config::default(), Optimizer::Adam).unwrap()
}

#[test]
fn tight_budget_training_step_rematerializes_and_learns() {
    // Walk budgets down from loose to tight; take the first rung that both
    // completes and rematerializes (tighter rungs may legitimately OOM —
    // Adam's optimizer state keeps the feasibility floor high).
    let rungs = engine().headroom_budgets(&[85, 75, 65, 55]).unwrap();
    for budget in rungs {
        let mut e = engine();
        e.dtr_cfg = Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            ..Config::default()
        };
        let mut losses = Vec::new();
        let mut remats = 0u64;
        let mut evicts = 0u64;
        let mut oom = false;
        for _ in 0..3 {
            match e.train_step() {
                Ok(r) => {
                    assert!(
                        r.stats.peak_memory <= budget,
                        "peak {} exceeded budget {budget}",
                        r.stats.peak_memory
                    );
                    assert!(r.loss.is_finite(), "non-finite loss at budget {budget}");
                    losses.push(r.loss);
                    remats += r.stats.remat_count;
                    evicts += r.stats.evict_count;
                }
                Err(_) => {
                    oom = true;
                    break;
                }
            }
        }
        if oom || remats == 0 {
            continue;
        }
        // Found a tight-but-feasible rung with real rematerialization.
        assert!(evicts > 0);
        assert_eq!(losses.len(), 3);
        assert!(
            losses[2] < losses[0],
            "loss did not descend under budget: {losses:?}"
        );
        // Rematerialization is exact replay: the budgeted trajectory must
        // match the unbudgeted one bitwise.
        let mut free = engine();
        let free_losses: Vec<f32> = (0..3).map(|_| free.train_step().unwrap().loss).collect();
        assert_eq!(losses, free_losses, "budget changed the numerics");
        return;
    }
    panic!("no budget rung produced a completed, rematerializing run");
}

#[test]
fn unbudgeted_run_never_rematerializes() {
    let mut e = engine();
    for _ in 0..3 {
        let r = e.train_step().unwrap();
        // Eager-evict frees released tensors (evict_count > 0 is normal);
        // nothing may ever need recomputation without a budget.
        assert_eq!(r.stats.remat_count, 0);
        assert!(r.loss.is_finite());
    }
}
