//! Unit tests for the eviction-heuristic family over hand-built pools
//! where the victim is known by construction, plus round-trips of every
//! heuristic name through the CLI flag parser.

use dtr::coordinator::TrainConfig;
use dtr::dtr::evicted::EvictedScratch;
use dtr::dtr::graph::Graph;
use dtr::dtr::heuristics::{score, ScoreCtx};
use dtr::dtr::ids::{StorageId, TensorId};
use dtr::dtr::unionfind::UnionFind;
use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, Runtime};
use dtr::util::cli::Args;
use dtr::util::rng::Rng;

/// Linear chain s0 -> s1 -> ... with given per-node op costs and storage
/// sizes; every storage starts resident with last_access 0.
fn chain(costs: &[u64], sizes: &[u64]) -> (Graph, Vec<StorageId>, UnionFind) {
    assert_eq!(costs.len(), sizes.len());
    let mut g = Graph::new();
    let mut uf = UnionFind::new();
    let mut ss = Vec::new();
    let mut prev: Option<TensorId> = None;
    for i in 0..costs.len() {
        let h = uf.make_set();
        let s = g.new_storage(sizes[i], h);
        let t = if let Some(p) = prev {
            let op = g.new_op(&format!("f{i}"), costs[i], vec![p]);
            let t = g.new_tensor(s, Some(op), false);
            g.ops[op.idx()].outputs.push(t);
            t
        } else {
            g.new_tensor(s, None, false)
        };
        g.storage_mut(s).resident = true;
        ss.push(s);
        prev = Some(t);
    }
    (g, ss, uf)
}

fn score_of(h: Heuristic, g: &Graph, uf: &mut UnionFind, clock: u64, s: StorageId) -> f64 {
    let mut scratch = EvictedScratch::new();
    let mut rng = Rng::new(1);
    let mut acc = 0u64;
    let mut roots = Vec::new();
    let mut ctx = ScoreCtx {
        graph: g,
        uf,
        scratch: &mut scratch,
        clock,
        rng: &mut rng,
        accesses: &mut acc,
        root_buf: &mut roots,
    };
    score(h, s, &mut ctx)
}

/// Argmin of the heuristic over a pool (the victim DTR would select).
fn victim(h: Heuristic, g: &Graph, uf: &mut UnionFind, clock: u64, pool: &[StorageId]) -> StorageId {
    let mut best: Option<(f64, StorageId)> = None;
    for &s in pool {
        let sc = score_of(h, g, uf, clock, s);
        if best.map_or(true, |(b, _)| sc < b) {
            best = Some((sc, s));
        }
    }
    best.unwrap().1
}

#[test]
fn lru_victim_is_stalest() {
    let (mut g, ss, mut uf) = chain(&[0, 1, 1, 1], &[1, 1, 1, 1]);
    g.storage_mut(ss[1]).last_access = 5;
    g.storage_mut(ss[2]).last_access = 1; // stalest
    g.storage_mut(ss[3]).last_access = 9;
    let v = victim(Heuristic::lru(), &g, &mut uf, 10, &ss[1..]);
    assert_eq!(v, ss[2]);
}

#[test]
fn size_victim_is_largest() {
    let (g, ss, mut uf) = chain(&[0, 1, 1, 1], &[1, 10, 40, 20]);
    let v = victim(Heuristic::size(), &g, &mut uf, 10, &ss[1..]);
    assert_eq!(v, ss[2]); // 40 bytes
}

#[test]
fn dtr_victim_accounts_for_evicted_neighborhood() {
    // s2 (cost 50) is evicted. s1 and s3 border it, so their e* includes
    // its cost; s4 is isolated and cheap to replay overall.
    //   h_dtr:   s1 = (2+50+1)/2, s3 = (60+50+1)/2, s4 = (10+1)/2 -> s4
    //   h_local: s1 = (2+1)/2,    s3 = (60+1)/2,    s4 = (10+1)/2 -> s1
    let (mut g, ss, mut uf) = chain(&[0, 2, 50, 60, 10], &[1, 1, 1, 1, 1]);
    g.storage_mut(ss[2]).resident = false;
    let pool = [ss[1], ss[3], ss[4]];
    assert_eq!(victim(Heuristic::dtr(), &g, &mut uf, 1, &pool), ss[4]);
    assert_eq!(victim(Heuristic::dtr_local(), &g, &mut uf, 1, &pool), ss[1]);
}

#[test]
fn dtr_eq_matches_exact_estar_on_single_component() {
    // With the union-find bookkeeping the runtime performs on eviction,
    // the equivalence-class approximation is exact for one evicted node.
    let (mut g, ss, mut uf) = chain(&[0, 2, 50, 60, 10], &[1, 1, 1, 1, 1]);
    g.storage_mut(ss[2]).resident = false;
    let h2 = g.storage(ss[2]).uf;
    uf.add_cost(h2, g.storage(ss[2]).local_cost as f64);
    let pool = [ss[1], ss[3], ss[4]];
    assert_eq!(victim(Heuristic::dtr_eq(), &g, &mut uf, 1, &pool), ss[4]);
    for &s in &pool {
        let exact = score_of(Heuristic::dtr(), &g, &mut uf, 1, s);
        let approx = score_of(Heuristic::dtr_eq(), &g, &mut uf, 1, s);
        assert!((exact - approx).abs() < 1e-9, "{s}: {exact} vs {approx}");
    }
}

#[test]
fn msps_victim_is_cheap_large_with_no_evicted_ancestors() {
    // s1 evicted: s2's rematerialization set includes it; s3 is large,
    // locally cheap, and has resident ancestors.
    //   s2 = (6+6+1)/1 = 13, s3 = (2+0+1)/4 = 0.75 -> s3
    let (mut g, ss, mut uf) = chain(&[0, 6, 6, 2], &[1, 1, 1, 4]);
    g.storage_mut(ss[1]).resident = false;
    let pool = [ss[2], ss[3]];
    assert_eq!(victim(Heuristic::Msps, &g, &mut uf, 1, &pool), ss[3]);
}

// ------------------------------------------------- runtime-driven victims

#[test]
fn runtime_evicts_stalest_under_lru() {
    let cfg = Config { budget: 4, heuristic: Heuristic::lru(), ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let c = rt.constant(1);
    // Three unit outputs of c, touched at clocks 1, 2, 3.
    let a1 = rt.call("f1", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
    let a2 = rt.call("f2", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
    let a3 = rt.call("f3", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
    // Memory is full (1+3); the next output must evict exactly a1.
    let a4 = rt.call("f4", 1, &[c], &[OutSpec::sized(1)]).unwrap()[0];
    assert!(!rt.is_resident(a1), "stalest tensor must be the victim");
    assert!(rt.is_resident(a2) && rt.is_resident(a3) && rt.is_resident(a4));
    rt.check_invariants().unwrap();
}

#[test]
fn runtime_evicts_largest_under_size() {
    let cfg = Config { budget: 10, heuristic: Heuristic::size(), ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
    let c = rt.constant(1);
    let a1 = rt.call("f1", 1, &[c], &[OutSpec::sized(2)]).unwrap()[0];
    let a2 = rt.call("f2", 1, &[c], &[OutSpec::sized(5)]).unwrap()[0];
    // 1+2+5 resident; a 3-byte output must evict the 5-byte storage.
    let a3 = rt.call("f3", 1, &[c], &[OutSpec::sized(3)]).unwrap()[0];
    assert!(!rt.is_resident(a2), "largest tensor must be the victim");
    assert!(rt.is_resident(a1) && rt.is_resident(a3));
    rt.check_invariants().unwrap();
}

// ------------------------------------------------------ CLI name round-trip

#[test]
fn heuristic_names_roundtrip_through_cli_parser() {
    let mut all = Heuristic::fig2_set();
    all.push(Heuristic::EStarCount);
    for h in all {
        let args = Args::parse(vec!["--heuristic".to_string(), h.name()].into_iter());
        let cfg = TrainConfig::load(&args)
            .unwrap_or_else(|e| panic!("flag parser rejected {}: {e:#}", h.name()));
        assert_eq!(cfg.heuristic, h, "{} did not round-trip", h.name());
    }
}

#[test]
fn unknown_heuristic_flag_is_rejected() {
    let args = Args::parse(vec!["--heuristic".to_string(), "h_bogus".to_string()].into_iter());
    assert!(TrainConfig::load(&args).is_err());
}
