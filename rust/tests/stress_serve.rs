//! Multi-tenant serving stress + arbiter-ledger properties.
//!
//! * `tight_budget_mixed_tenants`: several worker threads (static
//!   transformer + dynamic LSTM/TreeLSTM tenants) under one tight global
//!   budget with cross-shard reclaim. Asserts the run terminates (no
//!   deadlock in the arbiter), a live sampler never sees resident bytes
//!   above the budget, every tenant makes progress, and the dynamic
//!   tenants' probe losses descend.
//! * `ledger_equals_shard_accounting_under_random_tapes`: the satellite
//!   property — after every operation of a randomized multi-shard tape,
//!   the arbiter's lease ledger equals each shard's own accounting
//!   (`used == Stats::memory`, `lease == used + headroom`), composed with
//!   each runtime's `check_invariants` (which ties `Stats::memory` to the
//!   graph's resident bytes and the pool-byte counter).
//! * `tenant_churn_refunds_the_ledger_exactly`: tenants joining and
//!   leaving mid-run — teardown refunds the arbiter exactly and joiners
//!   reuse the refunded budget.
//!
//! CI runs this file in release mode as well (debug is too slow to stress
//! thread interleavings hard).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use dtr::api::{Session, Tensor};
use dtr::dtr::{Config, Heuristic, NullBackend};
use dtr::exec::dynamic::{LSTM_SEED, TREE_SEED};
use dtr::serve::{
    fleet_budget, run_tenants, ArbiterPolicy, GlobalIndexKind, ServePool, TenantKind, TenantSpec,
};
use dtr::util::rng::Rng;

#[test]
fn tight_budget_mixed_tenants() {
    const STEPS: usize = 30;
    // Static + dynamic mix; the dynamic tenants use the seeds whose probe
    // descent the dynamic-trainer unit tests already pin.
    let specs = [
        TenantSpec { kind: TenantKind::Transformer, seed: 1 },
        TenantSpec { kind: TenantKind::Lstm, seed: LSTM_SEED },
        TenantSpec { kind: TenantKind::TreeLstm, seed: TREE_SEED },
        TenantSpec { kind: TenantKind::Transformer, seed: 2 },
    ];
    let budget = fleet_budget(&specs, 75).expect("envelope");
    let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, specs.len());

    // Live budget monitor: the *sum of resident bytes across shards* must
    // never exceed the global budget, at any sampled instant.
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let stop = Arc::clone(&stop);
        let arb = Arc::clone(pool.arbiter());
        thread::spawn(move || {
            let mut max_used = 0u64;
            while !stop.load(Ordering::Acquire) {
                max_used = max_used.max(arb.used_bytes());
                thread::sleep(Duration::from_micros(200));
            }
            max_used
        })
    };

    let base = Config { heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let reports = run_tenants(&pool, &specs, &base, STEPS).expect("tenant threads");

    stop.store(true, Ordering::Release);
    let max_used = sampler.join().expect("sampler thread");
    // Pinned-constant overdraft is the one sanctioned way past the budget,
    // and this configuration cannot reach it: the fleet budget covers every
    // tenant's pinned floor (sum of floors < budget), the pinned slow path
    // grants/revokes/reclaims before overdrafting, and its busy-timeout
    // (~4 s of *consecutive* failed try_locks) cannot fire when peers
    // release their runtime locks between every operator.
    assert!(
        max_used <= budget,
        "global budget violated: sampled {max_used} B resident > budget {budget} B"
    );

    let mut evictions = 0u64;
    for r in &reports {
        assert!(
            r.error.is_none(),
            "{} tenant failed under global reclaim: {:?}",
            r.kind,
            r.error
        );
        assert_eq!(r.completed, STEPS, "{} tenant did not finish", r.kind);
        evictions += r.stats.evict_count;
        if let (Some(before), Some(after)) = (r.probe_before, r.probe_after) {
            assert!(
                after < before,
                "{} probe loss did not descend under serving: {before} -> {after}",
                r.kind
            );
        }
    }
    assert!(evictions > 0, "budget never bound: the stress is vacuous");
    // Busy skips are the price of try-lock-only cross-shard probing: a
    // peer mid-operator is skipped, never waited on. The counter must
    // stay *bounded* — at most a handful per slow-path escalation, so a
    // runaway value here means the arbiter is spinning on a locked peer
    // instead of falling back to the remaining candidates.
    let busy: u64 = pool.snapshot().iter().map(|s| s.busy_skips).sum();
    assert!(busy < 100_000, "busy-skip counter ran away under contention: {busy}");
    pool.check_invariants().unwrap();
    assert_eq!(pool.used_bytes(), 0, "tenants tore down but bytes remain leased");
}

/// Drive one random op (call/release/touch) on a shard's tape.
struct ShardTape {
    session: Session<NullBackend>,
    live: Vec<Tensor>,
    rng: Rng,
    step: usize,
}

impl ShardTape {
    fn new(pool: &ServePool, seed: u64, h: Heuristic) -> ShardTape {
        let session = Session::accounting(Config {
            heuristic: h,
            gate: Some(pool.lease()),
            ..Config::default()
        });
        let c = session.constant_sized(8);
        ShardTape { session, live: vec![c], rng: Rng::new(seed), step: 0 }
    }

    fn tick(&mut self) {
        self.step += 1;
        let src = self.rng.index(self.live.len());
        let bytes = 1 + self.rng.below(16);
        let cost = 1 + self.rng.below(4);
        let t = self
            .session
            .call_sized(&format!("s{}", self.step), cost, &[&self.live[src]], &[bytes])
            .expect("tape op within global budget")
            .remove(0);
        self.live.push(t);
        if self.live.len() > 16 {
            let k = 1 + self.rng.index(self.live.len() - 2);
            drop(self.live.remove(k));
        }
        if self.step % 13 == 0 && self.live.len() > 2 {
            let k = 1 + self.rng.index(self.live.len() - 1);
            self.session.touch(&self.live[k]).expect("remat within global budget");
        }
    }
}

#[test]
fn ledger_equals_shard_accounting_under_random_tapes() {
    let h = Heuristic::dtr_eq();
    // Unbudgeted total of three tapes is ~3 * (16 live * <=16 B + 8 pinned);
    // half of that forces steady cross-shard reclaim.
    let pool = ServePool::new(400, ArbiterPolicy::GlobalReclaim, 3);
    let mut shards: Vec<ShardTape> =
        (0..3).map(|i| ShardTape::new(&pool, 0xA11 + i as u64, h)).collect();
    for round in 0..240 {
        for (i, shard) in shards.iter_mut().enumerate() {
            shard.tick();
            // Per-shard runtime accounting (memory == graph resident bytes,
            // pool-byte counter exact)...
            shard.session.check_invariants().unwrap_or_else(|e| {
                panic!("shard {i} invariants broken at round {round}: {e:#}")
            });
        }
        // ...composed with the cross-shard ledger: lease == used + headroom
        // per live shard, leases within the budget, and the arbiter's
        // `used` gauge identical to each runtime's own `Stats::memory`.
        pool.check_invariants()
            .unwrap_or_else(|e| panic!("ledger broken at round {round}: {e:#}"));
        let snap = pool.snapshot();
        let mut total_used = 0u64;
        for (i, shard) in shards.iter().enumerate() {
            assert_eq!(
                snap[i].used,
                shard.session.memory(),
                "shard {i} meter drifted from its runtime at round {round}"
            );
            assert_eq!(
                snap[i].cap,
                pool.total(),
                "global reclaim caps every shard at the whole budget"
            );
            total_used += snap[i].used;
        }
        assert!(
            total_used <= pool.total(),
            "round {round}: resident {total_used} B exceed the budget {} B",
            pool.total()
        );
    }
    let evictions: u64 = shards.iter().map(|s| s.session.stats().evict_count).sum();
    assert!(evictions > 0, "tapes never forced an eviction; property is vacuous");
    drop(shards);
    pool.check_invariants().unwrap();
    assert_eq!(pool.used_bytes(), 0);
}

/// Tenant churn: shards join and leave mid-run. A departing tenant's
/// teardown (sessions + gate dropped) must refund the arbiter *exactly* —
/// the pool's used gauge drops by precisely the departing shard's
/// resident bytes — and later joiners run against the refunded pool with
/// the ledger balanced throughout.
#[test]
fn tenant_churn_refunds_the_ledger_exactly() {
    let h = Heuristic::dtr_eq();
    let pool = ServePool::new(400, ArbiterPolicy::GlobalReclaim, 3);
    let mut shards: Vec<ShardTape> =
        (0..2).map(|i| ShardTape::new(&pool, 0xC33 + i as u64, h)).collect();
    for _ in 0..40 {
        for s in shards.iter_mut() {
            s.tick();
        }
    }
    pool.check_invariants().unwrap();

    // A third tenant joins mid-run and immediately contends for budget.
    shards.push(ShardTape::new(&pool, 0xC41, h));
    for _ in 0..40 {
        for s in shards.iter_mut() {
            s.tick();
        }
    }
    pool.check_invariants().unwrap();

    // The oldest tenant leaves mid-run: exact refund, nothing stranded.
    let departing = shards.remove(0);
    let before = pool.used_bytes();
    let leaving = departing.session.memory();
    assert!(leaving > 0, "departing shard held no bytes; churn is vacuous");
    drop(departing);
    assert_eq!(
        pool.used_bytes(),
        before - leaving,
        "teardown refunded a different amount than the departing shard held"
    );
    pool.check_invariants().unwrap();

    // Survivors plus a fresh joiner reuse the refunded bytes.
    shards.push(ShardTape::new(&pool, 0xC47, h));
    for _ in 0..40 {
        for s in shards.iter_mut() {
            s.tick();
        }
    }
    pool.check_invariants().unwrap();
    let evictions: u64 = shards.iter().map(|s| s.session.stats().evict_count).sum();
    assert!(evictions > 0, "churned pool never bound; stress is vacuous");
    drop(shards);
    pool.check_invariants().unwrap();
    assert_eq!(pool.used_bytes(), 0, "churn left bytes leased after full teardown");
}

/// Shard churn under the *shared* fleet tournament
/// (`GlobalIndexKind::Shared`): joins bind fresh tournament leaves with
/// bumped generations, leaves retire them, and a victim certificate —
/// exercised via `pick_victim`, the same capture the reservation slow
/// path runs — never names a dead shard, even right after a departure
/// whose dirty-queue entries are still draining. Stale entries from dead
/// generations are dropped (visible in `fleet_dead_drops`), and the
/// ledger drains to zero on full teardown.
#[test]
fn shard_churn_under_shared_tournament_never_names_a_dead_shard() {
    let h = Heuristic::dtr_eq();
    let pool = ServePool::new(400, ArbiterPolicy::GlobalReclaim, 3)
        .with_global_index(GlobalIndexKind::Shared);
    assert_eq!(pool.global_index(), GlobalIndexKind::Shared);
    let arb = Arc::clone(pool.arbiter());
    // Track (shard id, tape): registration order assigns ids 0, 1, 2, ...
    let mut shards: Vec<(usize, ShardTape)> =
        (0..3).map(|i| (i, ShardTape::new(&pool, 0xD55 + i as u64, h))).collect();
    let mut next_id = shards.len();
    let mut picks = 0u64;
    for round in 0..8 {
        for _ in 0..30 {
            for (_, s) in shards.iter_mut() {
                s.tick();
            }
        }
        pool.check_invariants()
            .unwrap_or_else(|e| panic!("ledger broken in churn round {round}: {e:#}"));
        let live: Vec<usize> = shards.iter().map(|(id, _)| *id).collect();
        if let Some((victim, score)) = arb.pick_victim(live[round % live.len()]) {
            picks += 1;
            assert!(
                live.contains(&victim),
                "round {round}: certificate names shard {victim}, live set {live:?}"
            );
            assert!(score.is_finite() && score >= 0.0, "round {round}: bad score {score}");
        }
        if round % 2 == 0 {
            // The oldest tenant leaves; its leaf retires and any queued
            // publishes it left behind carry a dead generation.
            let (dead, tape) = shards.remove(0);
            drop(tape);
            let live: Vec<usize> = shards.iter().map(|(id, _)| *id).collect();
            if let Some((victim, _)) = arb.pick_victim(live[0]) {
                picks += 1;
                assert_ne!(victim, dead, "round {round}: certificate names the dead shard");
                assert!(
                    live.contains(&victim),
                    "round {round}: post-leave certificate names shard {victim}, live {live:?}"
                );
            }
            // A fresh tenant joins and binds a new leaf.
            shards.push((next_id, ShardTape::new(&pool, 0xD70 + next_id as u64, h)));
            next_id += 1;
        }
    }
    assert!(picks > 0, "tournament never produced a victim; churn stress is vacuous");
    // Single-threaded driver: no runtime is ever held when the arbiter
    // probes, so the busy counter must be exactly zero here.
    let busy: u64 = pool.snapshot().iter().map(|s| s.busy_skips).sum();
    assert_eq!(busy, 0, "single-threaded churn saw busy skips: {busy}");
    let evictions: u64 = shards.iter().map(|(_, s)| s.session.stats().evict_count).sum();
    assert!(evictions > 0, "churned pool never bound; stress is vacuous");
    drop(shards);
    pool.check_invariants().unwrap();
    assert_eq!(pool.used_bytes(), 0, "shared-tournament churn left bytes leased");
    // The drop counter is monotonic diagnostics, not a guarantee that a
    // dead-generation entry was in flight at drain time — just read it.
    let _ = arb.fleet_dead_drops();
}

/// Static split over an uneven budget: the division remainder is spread
/// across shards, so the per-shard caps always sum to exactly the global
/// budget (no stranded bytes), and no shard's lease ever exceeds its cap.
#[test]
fn static_split_caps_cover_the_whole_budget() {
    let h = Heuristic::dtr_eq();
    // 403 over 3 planned tenants: base share 134, remainder 1.
    let pool = ServePool::new(403, ArbiterPolicy::StaticSplit, 3);
    let mut shards: Vec<ShardTape> =
        (0..3).map(|i| ShardTape::new(&pool, 0xB22 + i as u64, h)).collect();
    for round in 0..120 {
        for shard in shards.iter_mut() {
            shard.tick();
        }
        pool.check_invariants()
            .unwrap_or_else(|e| panic!("ledger broken at round {round}: {e:#}"));
        let snap = pool.snapshot();
        let cap_sum: u64 = snap.iter().filter(|s| s.live).map(|s| s.cap).sum();
        assert_eq!(cap_sum, pool.total(), "round {round}: caps must sum to the budget");
        for s in &snap {
            assert!(
                s.lease <= s.cap,
                "round {round}: shard {} lease {} exceeds its cap {}",
                s.id,
                s.lease,
                s.cap
            );
        }
    }
    drop(shards);
    pool.check_invariants().unwrap();
    assert_eq!(pool.used_bytes(), 0);
}
