//! Kernel-equivalence properties: the blocked/threaded kernels in
//! `runtime/kernels` against the retained scalar oracle
//! (`runtime/kernels/reference.rs`, the interpreter's verbatim pre-PR
//! loop nests).
//!
//! Exactness contract, per op:
//!
//! * **GEMMs (`matmul`/`matmul_at`/`matmul_bt`)** — *bitwise* equal at any
//!   thread count. The unrolled rank-1 row kernel keeps every output
//!   element a single f32 accumulator over `p` ascending from `0.0` (no
//!   k-blocking, no FMA contraction), the transpose variants feed the same
//!   chains, and the row partition assigns whole disjoint output rows to
//!   threads.
//! * **Fused layernorm** — bitwise: the fused one-pass kernel runs the
//!   same mean/var/normalize chains as the composite reference, it merely
//!   skips materializing the intermediates.
//! * **Fused attention** — tolerance-based: flash's online softmax
//!   reassociates the exp-sum and rescales the accumulator by `alpha`
//!   products, so it is a different (equally valid) rounding of the same
//!   value. With `s <= 32` summands in f32 (eps ~ 1.2e-7) and softmax
//!   weights in [0, 1], per-element relative error is bounded well under
//!   1e-5; we assert 1e-4 against `1 + |reference|`.
//! * **Fused layernorm backward** — checked against central finite
//!   differences (the same oracle the interpreter's gradient tests use):
//!   eps 1e-2 keeps the f32 cancellation noise (~|L|·1.2e-7/eps) two
//!   orders below the directional derivatives, tolerance 2%.

use dtr::runtime::kernels::{fused, gemm, reference};
use dtr::runtime::{Executor, HostTensor, InterpExecutor, ModelConfig};
use dtr::util::rng::Rng;

const LN_EPS: f32 = 1e-5;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect()
}

/// Odd shapes (non-multiples of the KU=8 unroll and the 8-lane AVX2 /
/// 4-lane NEON vector widths, unit dims) plus one shape past the
/// parallel-spawn threshold, at several thread counts: all three GEMM
/// variants are bitwise the scalar reference. Under `--features simd`
/// this same test exercises whichever hand-vectorized block the host
/// dispatches (AVX2 on x86-64, NEON on AArch64) — the lanewise
/// mul-then-add chains must round exactly like the scalar loop on both.
#[test]
fn tiled_gemms_bitwise_match_scalar_reference_on_odd_shapes() {
    let mut rng = Rng::new(0xBEEF);
    let shapes = [
        (1, 1, 1),
        (1, 5, 1),
        (3, 7, 5),
        (5, 17, 33),
        (13, 31, 6),
        (8, 64, 192),
        (33, 64, 64), // > PAR_MIN_FLOPS: threads really spawn
    ];
    for &(m, k, n) in &shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let at = randv(&mut rng, k * m);
        let bt = randv(&mut rng, n * k);
        let want = reference::matmul(&a, &b, m, k, n);
        let want_at = reference::matmul_at(&at, &b, k, m, n);
        let want_bt = reference::matmul_bt(&a, &bt, m, k, n);
        for threads in [1, 4] {
            assert_eq!(
                gemm::matmul(&a, &b, m, k, n, threads),
                want,
                "matmul {m}x{k}x{n} t={threads}"
            );
            assert_eq!(
                gemm::matmul_at(&at, &b, k, m, n, threads),
                want_at,
                "matmul_at {m}x{k}x{n} t={threads}"
            );
            assert_eq!(
                gemm::matmul_bt(&a, &bt, m, k, n, threads),
                want_bt,
                "matmul_bt {m}x{k}x{n} t={threads}"
            );
        }
    }
}

/// Pinned: `threads = 1` is the pre-PR scalar path, bit for bit, at the
/// exact GEMM shapes the transformer training step issues at
/// `ModelConfig::small()` (qkv/mlp/loss projections and their backwards).
#[test]
fn threads_one_is_the_pre_pr_scalar_path_at_model_shapes() {
    let mut rng = Rng::new(0xCAFE);
    for &(m, k, n) in &[(256, 64, 192), (256, 128, 64), (256, 64, 256)] {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        assert_eq!(gemm::matmul(&a, &b, m, k, n, 1), reference::matmul(&a, &b, m, k, n));
        let at = randv(&mut rng, k * m);
        assert_eq!(gemm::matmul_at(&at, &b, k, m, n, 1), reference::matmul_at(&at, &b, k, m, n));
        let bt = randv(&mut rng, n * k);
        assert_eq!(gemm::matmul_bt(&a, &bt, m, k, n, 1), reference::matmul_bt(&a, &bt, m, k, n));
    }
}

/// Fused layernorm is the same reduction chains as the composite
/// reference (bitwise), including odd row counts and with threads.
#[test]
fn fused_layernorm_bitwise_matches_composite_reference() {
    let mut rng = Rng::new(0xF00D);
    for &(rows, d) in &[(1, 1), (3, 5), (8, 64), (257, 64)] {
        let x = randv(&mut rng, rows * d);
        let gamma = randv(&mut rng, d);
        let beta = randv(&mut rng, d);
        let want = reference::layernorm(&x, &gamma, &beta, rows, d, LN_EPS);
        for threads in [1, 4] {
            assert_eq!(
                fused::layernorm(&x, &gamma, &beta, rows, d, LN_EPS, threads),
                want,
                "layernorm rows={rows} d={d} t={threads}"
            );
        }
    }
}

/// Fused (online-softmax) attention vs the two-pass materialized
/// reference: 1e-4 relative tolerance (see module docs), batch/seq edge
/// cases included, and threading bitwise-identical to its own t=1 result
/// (slabs are computed independently per head).
#[test]
fn fused_attention_matches_two_pass_reference_within_tolerance() {
    let mut rng = Rng::new(0xA77);
    for &(bh, s, dh) in &[(1, 1, 4), (1, 16, 8), (3, 13, 8), (5, 32, 32)] {
        let q = randv(&mut rng, bh * s * dh);
        let k = randv(&mut rng, bh * s * dh);
        let v = randv(&mut rng, bh * s * dh);
        let want = reference::causal_attention(&q, &k, &v, bh, s, dh);
        let got = fused::causal_attention(&q, &k, &v, bh, s, dh, 1);
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let err = (g - w).abs() / (1.0 + w.abs());
            assert!(
                err <= 1e-4,
                "attention bh={bh} s={s} dh={dh} elem {i}: fused {g} vs ref {w} (rel {err})"
            );
        }
        let threaded = fused::causal_attention(&q, &k, &v, bh, s, dh, 4);
        assert_eq!(threaded, got, "attention threading must be bitwise (bh={bh})");
    }
}

/// Fused layernorm backward against central finite differences of the
/// fused forward, through a random linear probe `L = sum(y * w)`, for
/// each of x, gamma, and beta.
#[test]
fn fused_layernorm_bwd_matches_finite_differences() {
    let (rows, d) = (4, 16);
    let mut rng = Rng::new(0xD1FF);
    let x = randv(&mut rng, rows * d);
    let gamma: Vec<f32> = randv(&mut rng, d).iter().map(|v| v + 1.5).collect();
    let beta = randv(&mut rng, d);
    let w = randv(&mut rng, rows * d); // dL/dy

    let (dx, dgamma, dbeta) = fused::layernorm_bwd(&x, &gamma, &w, rows, d, LN_EPS);

    let loss = |x: &[f32], g: &[f32], b: &[f32]| -> f64 {
        let y = fused::layernorm(x, g, b, rows, d, LN_EPS, 1);
        y.iter().zip(w.iter()).map(|(a, b)| *a as f64 * *b as f64).sum()
    };
    let eps = 1e-2f32;
    let check = |name: &str, analytic: f64, fd: f64| {
        let denom = analytic.abs().max(fd.abs()).max(1e-3);
        assert!(
            (analytic - fd).abs() / denom < 0.02,
            "{name}: analytic {analytic} vs finite-diff {fd}"
        );
    };

    // Directional derivative along a random u, for each argument.
    let ux = randv(&mut rng, rows * d);
    let xp: Vec<f32> = x.iter().zip(&ux).map(|(a, u)| a + eps * u).collect();
    let xm: Vec<f32> = x.iter().zip(&ux).map(|(a, u)| a - eps * u).collect();
    let fd_x = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps as f64);
    let an_x: f64 = dx.iter().zip(&ux).map(|(g, u)| *g as f64 * *u as f64).sum();
    check("dx", an_x, fd_x);

    let ug = randv(&mut rng, d);
    let gp: Vec<f32> = gamma.iter().zip(&ug).map(|(a, u)| a + eps * u).collect();
    let gm: Vec<f32> = gamma.iter().zip(&ug).map(|(a, u)| a - eps * u).collect();
    let fd_g = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps as f64);
    let an_g: f64 = dgamma.iter().zip(&ug).map(|(g, u)| *g as f64 * *u as f64).sum();
    check("dgamma", an_g, fd_g);

    let ub = randv(&mut rng, d);
    let bp: Vec<f32> = beta.iter().zip(&ub).map(|(a, u)| a + eps * u).collect();
    let bm: Vec<f32> = beta.iter().zip(&ub).map(|(a, u)| a - eps * u).collect();
    let fd_b = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps as f64);
    let an_b: f64 = dbeta.iter().zip(&ub).map(|(g, u)| *g as f64 * *u as f64).sum();
    check("dbeta", an_b, fd_b);
}

/// Executor-level: whole interpreter ops (forward + backward transformer
/// block, both fused ops) produce bitwise-identical outputs at threads=1
/// and threads=4, on random inputs drawn from the manifest shapes.
/// `ModelConfig::small()` makes the block GEMMs exceed the parallel-spawn
/// threshold, so threads genuinely run.
#[test]
fn interp_executor_is_bitwise_equal_across_thread_counts() {
    let model = ModelConfig::small();
    let mut one = InterpExecutor::new(model).expect("executor");
    let mut four = InterpExecutor::new(model).expect("executor").with_threads(4);
    let mut rng = Rng::new(0x7EAD);
    for op in ["block_fwd", "block_bwd", "fused_ln_fwd", "fused_attn_fwd"] {
        let sig = one.manifest().op(op).expect("op in manifest").clone();
        let inputs: Vec<HostTensor> = sig
            .inputs
            .iter()
            .map(|t| {
                let n: usize = t.shape.iter().product();
                HostTensor::new(t.shape.clone(), randv(&mut rng, n))
            })
            .collect();
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        let a = one.execute(op, &refs).expect("t=1 execute");
        let b = four.execute(op, &refs).expect("t=4 execute");
        assert_eq!(a, b, "{op}: threads must not change a single bit");
    }
}
