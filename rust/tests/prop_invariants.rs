//! Property tests (DESIGN.md §7): random training-shaped DAGs replayed
//! under random budgets and every heuristic/policy must preserve the DTR
//! invariants — budget safety, lock hygiene, output condition, determinism,
//! and accounting consistency. Uses the in-tree miniprop harness (proptest
//! is not in the offline crate cache).

use std::collections::HashMap;

use dtr::api::{Session, Tensor};
use dtr::dtr::{Config, DeallocPolicy, Heuristic, Stats};
use dtr::exec::dynamic::{headroom_budget, DynStepResult, LstmTrainer, TreeLstmTrainer};
use dtr::exec::{Engine, Optimizer};
use dtr::graphs::models;
use dtr::graphs::tape::{R, Tape};
use dtr::runtime::{InterpExecutor, ModelConfig, NullExecutor, RnnConfig};
use dtr::sim::log::{Instr, Log};
use dtr::sim::replay::{baseline, simulate};
use dtr::util::miniprop::check;
use dtr::util::rng::Rng;

/// Random layered training DAG via the Tape (fan-out, weights, releases).
fn random_model(rng: &mut Rng, size: usize) -> Log {
    let mut t = Tape::new("prop");
    let x = t.data("x", 64 + rng.below(512));
    let mut frontier: Vec<R> = vec![x];
    let mut nodes = 0usize;
    while nodes < size {
        let k = 1 + rng.index(2.min(frontier.len()));
        let mut inputs: Vec<R> = (0..k).map(|_| *rng.choose(&frontier)).collect();
        if rng.chance(0.5) {
            let w = t.weight(&format!("w{nodes}"), 16 + rng.below(128));
            inputs.push(w);
        }
        let out = t.op(
            &format!("op{nodes}"),
            1 + rng.below(50),
            &inputs,
            32 + rng.below(1024),
        );
        frontier.push(out);
        if frontier.len() > 4 {
            frontier.remove(0);
        }
        nodes += 1;
    }
    let last = *frontier.last().unwrap();
    let loss = t.op("loss", 1, &[last], 8);
    t.finish(loss)
}

#[test]
fn prop_budget_safety_and_invariants_all_heuristics() {
    check("budget_safety", 60, 5, 40, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let h = *rng.choose(&Heuristic::fig2_set());
        let ratio = 0.3 + rng.f64() * 0.7;
        let budget = b.budget_at(ratio);
        let out = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
        if let Some(fail) = &out.failed {
            // OOM is legal at low ratios; anything else is a bug.
            if fail.contains("out of memory") {
                return Ok(());
            }
            return Err(format!("{} at ratio {ratio:.2}: {fail}", h.name()));
        }
        if out.stats.peak_memory > budget {
            return Err(format!(
                "{}: peak {} exceeded budget {budget}",
                h.name(),
                out.stats.peak_memory
            ));
        }
        if out.stats.total_compute() < b.total_compute {
            return Err("computed less than the baseline?!".into());
        }
        Ok(())
    });
}

#[test]
fn prop_all_policies_sound() {
    check("policy_soundness", 45, 5, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let policy = *rng.choose(&DeallocPolicy::all());
        let budget = b.budget_at(0.5 + rng.f64() * 0.5);
        let out = simulate(
            &log,
            Config { budget, heuristic: Heuristic::dtr(), policy, ..Config::default() },
        );
        if let Some(fail) = &out.failed {
            if fail.contains("out of memory") {
                return Ok(());
            }
            return Err(format!("{}: {fail}", policy.name()));
        }
        Ok(())
    });
}

#[test]
fn prop_determinism() {
    check("determinism", 25, 5, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let cfg = Config {
            budget: b.budget_at(0.45),
            heuristic: Heuristic::dtr_eq(),
            ..Config::default()
        };
        let x = simulate(&log, cfg.clone());
        let y = simulate(&log, cfg);
        if x.stats.total_compute() != y.stats.total_compute()
            || x.stats.evict_count != y.stats.evict_count
        {
            return Err("two identical runs diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_unbudgeted_equals_baseline_compute() {
    check("unbudgeted_baseline", 30, 5, 40, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let out = simulate(&log, Config::default());
        if !out.ok() {
            return Err(format!("unbudgeted failed: {:?}", out.failed));
        }
        if out.stats.total_compute() != b.total_compute {
            return Err("unbudgeted run recomputed something".into());
        }
        if out.stats.remat_count != 0 {
            return Err("unbudgeted run rematerialized".into());
        }
        Ok(())
    });
}

#[test]
fn prop_jsonl_roundtrip_preserves_simulation() {
    check("jsonl_roundtrip", 25, 5, 25, |rng, size| {
        let log = random_model(rng, size);
        let back = Log::from_jsonl(&log.to_jsonl()).map_err(|e| e.to_string())?;
        let b = baseline(&log);
        let cfg = Config { budget: b.budget_at(0.5), ..Config::default() };
        let x = simulate(&log, cfg.clone());
        let y = simulate(&back, cfg);
        if x.ok() != y.ok() {
            return Err("roundtrip changed feasibility".into());
        }
        if x.ok() && x.stats.total_compute() != y.stats.total_compute() {
            return Err("roundtrip changed compute".into());
        }
        Ok(())
    });
}

/// Backend-equivalence: replaying the same training-step op log through the
/// accounting-only NullExecutor and the real interpreter executor must
/// produce identical DTR `Stats` — eviction/rematerialization decisions
/// depend only on sizes, costs, and the heuristic, never on buffer values
/// or on which backend computes them.
#[test]
fn prop_backend_equivalence_null_vs_interp() {
    let model = ModelConfig {
        vocab: 32,
        d_model: 16,
        n_heads: 2,
        d_ff: 32,
        seq: 8,
        batch: 2,
        n_layers: 2,
    };
    check("backend_equivalence", 10, 1, 100, |rng, _size| {
        let h = *rng.choose(&Heuristic::fig2_set());
        let pct = 55 + rng.below(40); // 55..95% of the non-pinned headroom
        let opt = if rng.chance(0.5) { Optimizer::Adam } else { Optimizer::Sgd };

        let mk = |null: bool| -> Engine {
            let exec: Box<dyn dtr::runtime::Executor> = if null {
                Box::new(NullExecutor::new(model).unwrap())
            } else {
                Box::new(InterpExecutor::new(model).unwrap())
            };
            Engine::new(exec, Config::default(), opt).unwrap()
        };

        let mut interp = mk(false);
        let mut null = mk(true);
        let peak_i = interp.measure_peak().map_err(|e| e.to_string())?;
        let peak_n = null.measure_peak().map_err(|e| e.to_string())?;
        if peak_i != peak_n {
            return Err(format!("unbudgeted peaks differ: interp {peak_i} vs null {peak_n}"));
        }
        let budget = interp.budgets_from_peak(peak_i, &[pct])[0];
        let cfg = Config { budget, heuristic: h, ..Config::default() };
        interp.dtr_cfg = cfg.clone();
        null.dtr_cfg = cfg;

        for step in 0..2 {
            let a = interp.train_step();
            let b = null.train_step();
            match (a, b) {
                // OOM is legal at tight budgets, but both backends must
                // agree on feasibility.
                (Err(_), Err(_)) => return Ok(()),
                (Ok(_), Err(e)) => {
                    return Err(format!("{}: null OOMed but interp ran: {e:#}", h.name()))
                }
                (Err(e), Ok(_)) => {
                    return Err(format!("{}: interp OOMed but null ran: {e:#}", h.name()))
                }
                (Ok(ra), Ok(rb)) => {
                    let key = |s: &dtr::dtr::Stats| {
                        (
                            s.clock,
                            s.base_compute,
                            s.remat_compute,
                            s.remat_count,
                            s.evict_count,
                            s.peak_memory,
                            s.memory,
                        )
                    };
                    if key(&ra.stats) != key(&rb.stats) {
                        return Err(format!(
                            "{} step {step}: stats diverged\n interp: {:?}\n null:   {:?}",
                            h.name(),
                            ra.stats,
                            rb.stats
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Replay an operation log through the public `dtr::api::Session` (RAII
/// handles instead of raw ids: map rebinding drops are the RELEASE events,
/// clones are COPY), mirroring `sim::replay::Replayer` instruction for
/// instruction.
fn replay_log_via_session(log: &Log, cfg: Config) -> Result<Stats, String> {
    let s = Session::accounting(cfg);
    let mut env: HashMap<String, Tensor> = HashMap::new();
    for ins in &log.instrs {
        match ins {
            Instr::Constant { t, size } => {
                let fresh = env.insert(t.clone(), s.constant_sized(*size));
                assert!(fresh.is_none(), "duplicate identifier '{t}'");
            }
            Instr::Call { op, cost, inputs, outputs } => {
                let sizes: Vec<u64> = outputs
                    .iter()
                    .map(|o| {
                        assert!(o.alias_of.is_none(), "alias outputs not exercised here");
                        o.size
                    })
                    .collect();
                let outs = {
                    let ins_t: Vec<&Tensor> = inputs
                        .iter()
                        .map(|n| env.get(n).expect("unbound identifier"))
                        .collect();
                    s.call_sized(op, *cost, &ins_t, &sizes).map_err(|e| e.to_string())?
                };
                for (decl, t) in outputs.iter().zip(outs) {
                    let fresh = env.insert(decl.name.clone(), t);
                    assert!(fresh.is_none(), "duplicate identifier '{}'", decl.name);
                }
            }
            Instr::Copy { dst, src } => {
                let t = env.get(src).expect("unbound copy source").clone();
                env.insert(dst.clone(), t);
            }
            Instr::CopyFrom { dst, src } => {
                // Retain the source first, then rebind (dropping the old
                // dst handle = the release), matching the Replayer's order.
                let t = env.get(src).expect("unbound copy source").clone();
                env.insert(dst.clone(), t);
            }
            Instr::Release { t } => {
                env.remove(t);
            }
            Instr::Mutate { .. } => return Err("mutate not exercised by tape logs".into()),
        }
    }
    s.pin_live().map_err(|e| e.to_string())?;
    s.check_invariants().map_err(|e| e.to_string())?;
    Ok(s.stats())
}

fn stats_key(s: &Stats) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    (
        s.clock,
        s.base_compute,
        s.remat_compute,
        s.remat_count,
        s.evict_count,
        s.banish_count,
        s.metadata_accesses,
        s.memory,
        s.peak_memory,
    )
}

/// Sim-vs-real dynamic equivalence (RAII edition): the same LSTM unrolling
/// driven through the tape generator (`graphs::models::lstm` -> simulator
/// replay, raw ids and explicit RELEASE events) and through the new
/// `Session` (accounting backend, RAII handle drops) must produce
/// *identical* DTR stats — the API veneer adds and loses nothing.
#[test]
fn prop_sim_vs_session_lstm_identical_stats() {
    check("sim_vs_session_lstm", 12, 4, 24, |rng, size| {
        let steps = 4 + size % 12;
        let hidden = 16 + 16 * rng.below(3);
        let batch = 4 + 4 * rng.below(3);
        let log = models::lstm(steps, hidden, batch);
        let b = baseline(&log);
        let ratio = 0.4 + rng.f64() * 0.6;
        let cfg = Config {
            budget: b.budget_at(ratio),
            heuristic: *rng.choose(&Heuristic::fig2_set()),
            ..Config::default()
        };
        let sim = simulate(&log, cfg.clone());
        let ses = replay_log_via_session(&log, cfg);
        match (sim.ok(), ses) {
            (true, Ok(stats)) => {
                if stats_key(&sim.stats) != stats_key(&stats) {
                    return Err(format!(
                        "stats diverged at ratio {ratio:.2}\n sim:     {:?}\n session: {stats:?}",
                        sim.stats
                    ));
                }
                Ok(())
            }
            (false, Err(_)) => Ok(()), // both infeasible: agreement
            (true, Err(e)) => Err(format!("session failed but sim ran: {e}")),
            (false, Ok(_)) => Err("sim failed but session ran".into()),
        }
    });
}

/// Backend-equivalence for the *dynamic* path: the LSTM and TreeLSTM
/// trainers must make identical DTR decisions under the accounting
/// `NullExecutor` and the real interpreter — shapes, costs, and the
/// heuristic drive everything; buffer values drive nothing.
#[test]
fn prop_dynamic_backend_equivalence_null_vs_interp() {
    let rnn = RnnConfig::tiny();
    let (peak, floor) = LstmTrainer::interp(rnn, Config::default())
        .unwrap()
        .measure_envelope(3)
        .unwrap();
    for pct in [100, 70, 55] {
        let cfg = Config {
            budget: headroom_budget(peak, floor, pct),
            heuristic: Heuristic::dtr_eq(),
            ..Config::default()
        };
        let mut interp = LstmTrainer::interp(rnn, cfg.clone()).unwrap();
        let mut null = LstmTrainer::null(rnn, cfg).unwrap();
        for step in 0..3 {
            let (a, b) = (interp.train_step(), null.train_step());
            match (a, b) {
                (Err(_), Err(_)) => break, // agree on infeasibility
                (Ok(ra), Ok(rb)) => {
                    assert_eq!(
                        stats_key(&ra.stats),
                        stats_key(&rb.stats),
                        "lstm {pct}% step {step} diverged"
                    );
                    assert_eq!(ra.units, rb.units, "data streams diverged");
                }
                (a, b) => panic!(
                    "lstm {pct}% step {step}: backends disagree on feasibility: \
                     interp ok={}, null ok={}",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }

    // Tree shapes too: one unbudgeted pass is enough to pin the property.
    let mut ti = TreeLstmTrainer::interp(rnn, Config::default()).unwrap();
    let mut tn = TreeLstmTrainer::null(rnn, Config::default()).unwrap();
    for step in 0..3 {
        let (ra, rb): (DynStepResult, DynStepResult) =
            (ti.train_step().unwrap(), tn.train_step().unwrap());
        assert_eq!(stats_key(&ra.stats), stats_key(&rb.stats), "tree step {step} diverged");
        assert_eq!(ra.units, rb.units, "tree shapes diverged");
    }
}

#[test]
fn prop_lower_budget_never_lowers_compute() {
    check("budget_monotone_compute", 30, 8, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let tight = simulate(
            &log,
            Config { budget: b.budget_at(0.4), heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        let loose = simulate(
            &log,
            Config { budget: b.budget_at(0.9), heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        if !tight.ok() || !loose.ok() {
            return Ok(()); // OOM cases covered elsewhere
        }
        if tight.stats.total_compute() < loose.stats.total_compute() {
            return Err(format!(
                "tighter budget computed less: {} < {}",
                tight.stats.total_compute(),
                loose.stats.total_compute()
            ));
        }
        Ok(())
    });
}
